#!/usr/bin/env python
"""Standalone shard-worker entrypoint.

Serves ARL-Tangram remote plan workers over TCP so an orchestrator on
another machine can point a ``SocketTransport`` fleet at this host::

    python tools/shard_worker.py --host 0.0.0.0 --port 7421

With ``--port 0`` an ephemeral port is bound and announced as a
``PORT <n>`` line on stdout (flushed) — launchers spawning workers as
subprocesses read it from the first line (see
``examples/multi_host_round.py``).

One fresh worker serves each connection; a reconnecting client always
reaches a blank worker, which its reset/full-resend recovery rail
expects — including under worker-owned commit (``plan_commit`` /
``commit_decide`` frames are served too): a blank worker holds no
ownership leases, so the coordinator re-grants fresh epochs and state
rather than trusting a restarted replica.  Thin wrapper over
:func:`repro.core.transport.main`.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.transport import main  # noqa: E402

if __name__ == "__main__":
    raise SystemExit(main())
