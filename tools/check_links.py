"""Markdown link checker for the docs CI job (stdlib only).

Scans the given Markdown files (default: docs/*.md, README.md,
ROADMAP.md, CHANGES.md) for inline links and validates:

* relative file links resolve to an existing file or directory
  (anchors are checked against the target's headings when the target
  is a Markdown file);
* in-page ``#anchor`` links match a heading in the same file.

External links (http/https/mailto) are recorded but NOT fetched — CI
must not flake on the network.  Exit status 1 on any broken link, with
a ``file:line`` report per failure.

Run:  python tools/check_links.py [files...]
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
EXTERNAL = ("http://", "https://", "mailto:")


def slugify(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, spaces to dashes, drop
    punctuation (approximation good enough for our headings)."""
    slug = heading.strip().lower()
    slug = re.sub(r"[`*_~]", "", slug)
    slug = re.sub(r"[^\w\- ]", "", slug)
    return re.sub(r"\s+", "-", slug.strip())


def anchors_of(md: Path) -> set:
    return {slugify(h) for h in HEADING_RE.findall(md.read_text())}


def check_file(md: Path, root: Path) -> list:
    errors = []
    text = md.read_text()
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        line = text[: m.start()].count("\n") + 1
        if target.startswith(EXTERNAL):
            continue
        path_part, _, anchor = target.partition("#")
        if not path_part:  # in-page anchor
            if anchor and slugify(anchor) not in anchors_of(md):
                errors.append((md, line, target, "no such heading"))
            continue
        dest = (md.parent / path_part).resolve()
        if not dest.exists():
            errors.append((md, line, target, "missing file"))
            continue
        if root not in dest.parents and dest != root:
            errors.append((md, line, target, "escapes the repository"))
            continue
        if anchor and dest.suffix == ".md" and slugify(anchor) not in anchors_of(dest):
            errors.append((md, line, target, f"no such heading in {dest.name}"))
    return errors


def main(argv: list) -> int:
    root = Path(__file__).resolve().parent.parent
    if argv:
        files = [Path(a).resolve() for a in argv]
    else:
        files = sorted((root / "docs").glob("*.md"))
        files += [root / n for n in ("README.md", "ROADMAP.md", "CHANGES.md")
                  if (root / n).exists()]
    errors = []
    checked = 0
    for md in files:
        checked += 1
        errors.extend(check_file(md, root))
    for md, line, target, why in errors:
        print(f"{md.relative_to(root)}:{line}: broken link {target!r} ({why})")
    print(f"# link check: {checked} file(s), {len(errors)} broken link(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
