"""Bench regression gate: fresh BENCH_*.json vs the committed baselines.

The bench suites write machine-readable per-scenario results
(``BENCH_scheduler.json``, ``BENCH_remote.json``, ...) whose committed
copies at the repo root are the performance baselines.  CI runs the
suites into a fresh output directory and this tool diffs the two:

* **wall-clock latencies** (``us_per_call``) — a fresh value more than
  ``--threshold`` (default 25%) above baseline fails, *unless* both
  sit under the ``--noise-floor-us`` (tiny timings are all jitter);
* **bytes/round** (parsed from a row's ``derived`` string, the remote
  suite's wire-bill figure) — same threshold, no noise floor (byte
  counts are deterministic: any growth is a protocol change);
* ratio/flag rows (``ns_per_op: null`` — speedups, trace-identity
  bits, fairness shares, chaos counts) are **not** compared here: the
  suites' own ``--check`` gates already enforce their floors, and a
  second, threshold-based gate on a ratio would double-report every
  failure.

Scenarios present on only one side are reported as warnings, never
failures — renames and new rows land through the committed baseline in
the same PR, and a gate that fails on additions would punish coverage.
The exception is the **required-row manifest** (``REQUIRED_ROWS``): the
load-bearing rows of each suite — trace-identity flags, the wire bill,
the commit-phase split — are declared per file, and a run that drops
one of them is a hard failure, not a warning.  A bench refactor that
silently stops emitting the row a gate depends on would otherwise pass
the gate vacuously.

Improvements are never failures (there is no "too fast").

Run:  python tools/bench_compare.py --fresh-dir bench-out [--baseline-dir .]
Exit: 1 on any regression, with a per-scenario report; 0 otherwise.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

#: Fail when fresh > baseline * (1 + threshold).
DEFAULT_THRESHOLD = 0.25

#: Latencies where both sides sit below this are jitter, not signal.
DEFAULT_NOISE_FLOOR_US = 50.0

#: Rows a suite must emit for its gate to mean anything.  A fresh run
#: (or a baseline) missing one of these fails hard — every other
#: missing row stays a warning so new coverage is never punished.
REQUIRED_ROWS: Dict[str, frozenset] = {
    "BENCH_scheduler.json": frozenset({
        "schedule_depth2_queue128",
        "churn_queue128_incremental",
        "shard_churn_queue128_traces_identical",
    }),
    "BENCH_fairness.json": frozenset({
        "fairness_share_maxerr",
        "fairness_interference_speedup",
    }),
    "BENCH_shards.json": frozenset({
        "shard_churn_queue128_shards4",
        "shard_churn_queue128_traces_identical",
    }),
    "BENCH_remote.json": frozenset({
        "remote_churn_queue128_shards4_loopback",
        "remote_churn_queue128_traces_identical",
        "remote_churn_queue128_wire_overhead",
        "remote_churn_queue128_wire_overhead_pipelined",
        # the commit-phase split: worker-owned mode must keep emitting
        # its latency, identity, and critical-path rows
        "remote_churn_queue128_commit_worker",
        "remote_churn_queue128_commit_traces_identical",
        "remote_churn_queue128_commit_serial_wall",
        "remote_churn_queue128_commit_worker_critical",
    }),
    "BENCH_chaos.json": frozenset({
        "chaos_kill_storm_traces_identical",
        "chaos_amnesia_traces_identical",
    }),
    "BENCH_generated.json": frozenset({
        # the differential replay rail and the wave-forming gate result
        # (scenario-smoke runs the suite with --live, so the sim-vs-live
        # structural-equivalence flag is load-bearing too)
        "generated_stream_bitidentical",
        "generated_gate_win_deep",
        "generated_gate_win_mid",
        "generated_gate_separation",
        "generated_live_structural_identical",
        "generated_fleet_us_per_event",
    }),
}


def load_scenarios(path: Path) -> Dict[str, dict]:
    with path.open() as f:
        return json.load(f).get("scenarios", {})


def derived_bytes_per_round(scenario: dict) -> Optional[float]:
    derived = str(scenario.get("derived") or "")
    if "bytes_per_round=" not in derived:
        return None
    try:
        return float(derived.split("bytes_per_round=")[1].split(";")[0])
    except ValueError:
        return None


def compare_file(
    baseline: Dict[str, dict],
    fresh: Dict[str, dict],
    threshold: float,
    noise_floor_us: float,
    required: frozenset = frozenset(),
) -> Tuple[List[str], List[str]]:
    """(regressions, warnings) for one suite's scenario maps."""
    regressions: List[str] = []
    warnings: List[str] = []
    for name in sorted(set(baseline) | set(fresh) | required):
        if name not in fresh:
            if name in required:
                regressions.append(
                    f"required scenario {name!r} missing from fresh run"
                )
            else:
                warnings.append(
                    f"scenario {name!r} missing from fresh run (removed?)"
                )
            continue
        if name not in baseline:
            if name in required:
                regressions.append(
                    f"required scenario {name!r} has no committed baseline"
                )
            else:
                warnings.append(
                    f"scenario {name!r} has no committed baseline (new?)"
                )
            continue
        base, new = baseline[name], fresh[name]

        b_us, n_us = base.get("us_per_call"), new.get("us_per_call")
        if b_us is not None and n_us is not None and b_us > 0:
            if n_us > b_us * (1 + threshold) and not (
                b_us < noise_floor_us and n_us < noise_floor_us
            ):
                regressions.append(
                    f"{name}: {n_us:.1f}us/call vs baseline {b_us:.1f}us/call "
                    f"(+{(n_us / b_us - 1) * 100:.0f}% > {threshold * 100:.0f}%)"
                )

        b_bytes = derived_bytes_per_round(base)
        n_bytes = derived_bytes_per_round(new)
        if b_bytes is not None and n_bytes is not None and b_bytes > 0:
            if n_bytes > b_bytes * (1 + threshold):
                regressions.append(
                    f"{name}: {n_bytes:.0f} bytes/round vs baseline "
                    f"{b_bytes:.0f} (+{(n_bytes / b_bytes - 1) * 100:.0f}% "
                    f"> {threshold * 100:.0f}%)"
                )
    return regressions, warnings


def main(argv: Optional[Iterable[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*",
                    help="BENCH_*.json names to compare (default: every "
                         "BENCH_*.json in the baseline dir)")
    ap.add_argument("--baseline-dir", default=".",
                    help="directory holding the committed baselines "
                         "(default: repo root)")
    ap.add_argument("--fresh-dir", required=True,
                    help="directory the CI run wrote fresh results into")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="relative regression that fails the gate "
                         f"(default {DEFAULT_THRESHOLD:.2f} = 25%%)")
    ap.add_argument("--noise-floor-us", type=float,
                    default=DEFAULT_NOISE_FLOOR_US,
                    help="latency pairs both under this are never failed "
                         f"(default {DEFAULT_NOISE_FLOOR_US:.0f}us)")
    args = ap.parse_args(list(argv) if argv is not None else None)

    baseline_dir = Path(args.baseline_dir)
    fresh_dir = Path(args.fresh_dir)
    names = args.files or sorted(
        p.name for p in baseline_dir.glob("BENCH_*.json")
    )
    if not names:
        print(f"bench-compare: no BENCH_*.json baselines in {baseline_dir}/",
              file=sys.stderr)
        return 1

    all_regressions: List[str] = []
    compared = 0
    for name in names:
        base_path = baseline_dir / name
        fresh_path = fresh_dir / name
        if not base_path.exists():
            print(f"# WARN {name}: no committed baseline — skipped")
            continue
        if not fresh_path.exists():
            print(f"# WARN {name}: fresh run produced no file — skipped")
            continue
        regressions, warnings = compare_file(
            load_scenarios(base_path), load_scenarios(fresh_path),
            args.threshold, args.noise_floor_us,
            required=REQUIRED_ROWS.get(name, frozenset()),
        )
        compared += 1
        for w in warnings:
            print(f"# WARN {name}: {w}")
        if regressions:
            for r in regressions:
                print(f"# FAIL {name}: {r}")
            all_regressions += [f"{name}: {r}" for r in regressions]
        else:
            print(f"# OK   {name}: no regression above "
                  f"{args.threshold * 100:.0f}%")

    if compared == 0:
        print("bench-compare: nothing compared (no overlapping files)",
              file=sys.stderr)
        return 1
    if all_regressions:
        print(f"\nbench-compare: {len(all_regressions)} regression(s)",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
