"""Telemetry-driven rebalance: policy decisions and the DES cadence.

Two layers: :class:`RebalancePolicy.decide` is a pure function of one
tick's signals (unit-testable in isolation — source/sink selection,
hysteresis, move batching), and the orchestrator cadence wires it to
live telemetry on a virtual-time period (integration — measured ACT
improvement on an asymmetric fleet, determinism, clean termination).
"""

import pytest

from repro.core.action import Action, fixed
from repro.core.fairqueue import FairSharePolicy
from repro.core.managers.base import ResourceManager
from repro.core.orchestrator import Orchestrator
from repro.core.rebalance import RebalancePolicy, RebalanceSignals
from repro.core.scenarios import (
    build_managers,
    install_scenario,
    straggler_fleet_spec,
)
from repro.core.simulator import EventLoop
from repro.core.transport import WorkerServer, socket_fleet


# ---------------------------------------------------------------------------
# policy unit tests
# ---------------------------------------------------------------------------


def _signals(depths, backlogs=None, **kw):
    sig = RebalanceSignals(now=kw.pop("now", 10.0))
    sig.depths = dict(depths)
    sig.backlogs = {p: dict(b) for p, b in (backlogs or {}).items()}
    for name in ("backlog_cost", "starvation", "utilization", "plan_cost_s"):
        setattr(sig, name, kw.pop(name, {}))
    assert not kw
    return sig


class TestRebalancePolicy:
    def test_moves_from_deepest_to_shallowest(self):
        sig = _signals(
            {"a": 8, "b": 0, "c": 4},
            backlogs={"a": {"t1": 4, "t2": 4}},
        )
        moves = RebalancePolicy(max_moves=1).decide(sig, ["a", "b", "c"])
        assert moves == [("t1", "a", "b")]

    def test_hysteresis_blocks_small_gaps(self):
        sig = _signals({"a": 3, "b": 1}, backlogs={"a": {"t": 1}})
        assert RebalancePolicy(min_gap=2).decide(sig, ["a", "b"]) == []
        # one deeper and the same shape moves
        sig = _signals({"a": 4, "b": 1}, backlogs={"a": {"t": 1}})
        assert RebalancePolicy(min_gap=2).decide(sig, ["a", "b"]) == [
            ("t", "a", "b")
        ]

    def test_saturated_sink_is_skipped(self):
        sig = _signals(
            {"a": 8, "b": 0, "c": 1},
            backlogs={"a": {"t": 4}},
            utilization={"b": 1.0, "c": 0.5},
        )
        moves = RebalancePolicy(max_moves=1).decide(sig, ["a", "b", "c"])
        assert moves == [("t", "a", "c")]  # b is busier than the ceiling

    def test_all_sinks_saturated_means_no_moves(self):
        sig = _signals(
            {"a": 8, "b": 0},
            backlogs={"a": {"t": 4}},
            utilization={"b": 0.99},
        )
        assert RebalancePolicy().decide(sig, ["a", "b"]) == []

    def test_subqueue_closest_to_half_gap_wins(self):
        """gap=8: a 4-action sub-queue evens the pair exactly; 1 and 7
        are worse; 8 would invert and is refused outright."""
        sig = _signals(
            {"a": 8, "b": 0},
            backlogs={"a": {"small": 1, "mid": 4, "big": 7}},
        )
        moves = RebalancePolicy(max_moves=1).decide(sig, ["a", "b"])
        assert moves == [("mid", "a", "b")]

    def test_move_that_inverts_the_gap_is_refused(self):
        sig = _signals({"a": 4, "b": 0}, backlogs={"a": {"t": 4}})
        assert RebalancePolicy().decide(sig, ["a", "b"]) == []

    def test_starvation_breaks_subqueue_ties(self):
        sig = _signals(
            {"a": 8, "b": 0},
            backlogs={"a": {"t1": 4, "t2": 4}},
            starvation={"a": {"t1": 1.0, "t2": 9.0}},
        )
        moves = RebalancePolicy(max_moves=1).decide(sig, ["a", "b"])
        assert moves == [("t2", "a", "b")]  # most starved moves first

    def test_starvation_breaks_source_ties(self):
        sig = _signals(
            {"a": 6, "b": 6, "c": 0},
            backlogs={"a": {"t": 3}, "b": {"u": 3}},
            starvation={"a": {"t": 2.0}, "b": {"u": 11.0}},
        )
        moves = RebalancePolicy(max_moves=1).decide(sig, ["a", "b", "c"])
        assert moves == [("u", "b", "c")]

    def test_plan_cost_breaks_remaining_ties(self):
        sig = _signals(
            {"a": 6, "b": 6, "c": 0},
            backlogs={"a": {"t": 3}, "b": {"u": 3}},
            plan_cost_s={"a": 0.5, "b": 0.1},
        )
        moves = RebalancePolicy(max_moves=1).decide(sig, ["a", "b", "c"])
        assert moves == [("t", "a", "c")]

    def test_batch_sees_earlier_moves(self):
        """max_moves=2 must not order the same move twice: the second
        decision sees the depths the first will produce."""
        sig = _signals(
            {"a": 12, "b": 0, "c": 0},
            backlogs={"a": {"t1": 4, "t2": 4, "t3": 4}},
        )
        moves = RebalancePolicy(max_moves=2).decide(sig, ["a", "b", "c"])
        assert len(moves) == 2
        assert moves[0][1] == "a" and moves[1][1] == "a"
        assert {m[2] for m in moves} == {"b", "c"}  # spread, not stacked
        assert len({m[0] for m in moves}) == 2  # two different sub-queues

    def test_decide_is_deterministic(self):
        sig = _signals(
            {"a": 9, "b": 2, "c": 4},
            backlogs={"a": {"x": 3, "y": 3, "z": 3}},
            starvation={"a": {"x": 1.0, "y": 1.0, "z": 1.0}},
        )
        p = RebalancePolicy(max_moves=3)
        assert p.decide(sig, ["a", "b", "c"]) == p.decide(sig, ["a", "b", "c"])

    def test_bad_period_rejected(self):
        with pytest.raises(ValueError):
            RebalancePolicy(period_s=0)


# ---------------------------------------------------------------------------
# orchestrator cadence
# ---------------------------------------------------------------------------


POOLS = [f"pool{k}" for k in range(4)]


def _fleet(rebalance, pools=4, cores=2, period_s=1.0):
    loop = EventLoop()
    managers = {p: ResourceManager(p, cores) for p in POOLS[:pools]}
    fs = FairSharePolicy(weights={"a": 2.0, "b": 1.0, "c": 1.0, "d": 1.0})
    orch = Orchestrator(managers, loop=loop, fair_share=fs)
    if rebalance:
        orch.enable_rebalance(POOLS[:pools], period_s=period_s)
    return orch


def _skewed_load(orch, n=48, duration=2.0):
    """Everything lands on pool0 — the asymmetric-fleet worst case."""
    futs = []
    for i in range(n):
        futs.append(orch.submit(Action(
            name=f"w{i}", cost={"pool0": fixed("pool0", 1)},
            base_duration=duration, task_id="abcd"[i % 4],
            trajectory_id=f"t{i}")))
    return futs


def _act(orch):
    recs = orch.telemetry.records
    return sum(r.finish - r.submit for r in recs) / len(recs)


class TestRebalanceCadence:
    def test_asymmetric_fleet_act_improves(self):
        """The acceptance rail: with all load keyed to one pool of a
        4-pool replica fleet, the cadence must spread it and win on ACT
        — by a lot, not at the margin."""
        base = _fleet(rebalance=False)
        _skewed_load(base)
        base.run()
        act_off = _act(base)
        base.close()

        orch = _fleet(rebalance=True)
        futs = _skewed_load(orch)
        orch.run()
        act_on = _act(orch)
        assert all(f.done() for f in futs)
        assert orch.telemetry.rebalance_ticks > 0
        assert orch.telemetry.rebalance_moves > 0
        assert orch.telemetry.migrations == orch.telemetry.rebalance_moves
        for m in orch.managers.values():
            m.check_occupancy()
        orch.close()
        assert act_on < act_off * 0.6  # >40% ACT win on this shape

    def test_migrated_work_really_runs_on_replicas(self):
        orch = _fleet(rebalance=True)
        _skewed_load(orch)
        orch.run()
        pools_used = {next(iter(r.units)) for r in orch.telemetry.records
                      if r.units}
        orch.close()
        assert len(pools_used) > 1  # not everything served by pool0

    def test_cadence_is_deterministic(self):
        def one_run():
            orch = _fleet(rebalance=True)
            _skewed_load(orch)
            orch.run()
            trace = sorted(
                (r.name, r.task_id, r.submit, r.start, r.finish)
                for r in orch.telemetry.records
            )
            stats = (orch.telemetry.rebalance_ticks,
                     orch.telemetry.rebalance_moves)
            orch.close()
            return trace, stats

        assert one_run() == one_run()

    def test_cadence_disarms_on_drain_and_rearms_on_enqueue(self):
        """run() must terminate (no immortal timer), and a second burst
        after the drain gets rebalanced too."""
        orch = _fleet(rebalance=True)
        _skewed_load(orch, n=24)
        orch.run()  # would hang here if the cadence never disarmed
        ticks_first = orch.telemetry.rebalance_ticks
        assert ticks_first > 0
        _skewed_load(orch, n=24)
        orch.run()
        assert orch.telemetry.rebalance_ticks > ticks_first
        assert orch.queue_depth() == 0
        orch.close()

    def test_balanced_load_makes_no_moves(self):
        orch = _fleet(rebalance=True)
        for i in range(24):
            pool = POOLS[i % 4]
            orch.submit(Action(
                name=f"w{i}", cost={pool: fixed(pool, 1)}, base_duration=2.0,
                task_id="abcd"[i % 4], trajectory_id=f"t{i}"))
        orch.run()
        assert orch.telemetry.rebalance_moves == 0
        orch.close()

    def test_unknown_replica_rejected(self):
        orch = _fleet(rebalance=False)
        with pytest.raises(ValueError):
            orch.enable_rebalance(["pool0", "nope"])
        orch.close()

    def test_custom_policy_and_period_override(self):
        orch = _fleet(rebalance=False)
        policy = RebalancePolicy(period_s=9.0, max_moves=1)
        orch.enable_rebalance(["pool0", "pool1"], policy=policy, period_s=0.5)
        assert policy.period_s == 0.5
        _skewed_load(orch, n=16)
        orch.run()
        assert orch.telemetry.rebalance_ticks > 0
        orch.close()

    def test_straggler_worker_flips_rebalance_source(self):
        """Remote-path straggler injection, end to end: the scenario
        fault schedule marks one socket worker a plan-phase straggler,
        the worker's inflated per-partition plan walls feed the
        client's plan-cost EWMA, and the rebalance source pick follows
        the EWMA off the straggled worker's pool.

        The non-vacuity gate is the symmetric flip.  Depth and
        starvation tie across the two loaded pools by construction, and
        the policy's final name tiebreak is *fixed* (``max`` on the
        name picks pool1) — so a first move sourced from **pool0** when
        worker 0 straggles is only reachable through the plan-cost
        signal, and the mirrored run (worker 1 -> pool1) proves the
        pick tracks the fault rather than any constant bias."""
        for straggled in (0, 1):
            moves, costs, served = self._run_straggled_fleet(straggled)
            src_pool = f"pool{straggled}"
            other_pool = f"pool{1 - straggled}"
            assert moves, "rebalance never moved anything"
            task, src, dst = moves[0]
            assert src == src_pool  # load migrates OFF the straggler
            assert dst == "pool2"  # ... onto the idle sink
            assert task.startswith(f"t{src_pool}")
            # the signal that decided it: the straggled worker's pool
            # shows an EWMA dominated by the injected delay, the healthy
            # worker's does not (4ms injected vs ~tens of us measured)
            assert costs[src_pool] > 10 * costs[other_pool]
            assert costs[src_pool] > 0.002
            # and the migration really ran: the sink served real work
            # while the straggled pool served less than the healthy one
            assert served.get("pool2", 0) > 0
            assert served[src_pool] < served[other_pool]

    @staticmethod
    def _run_straggled_fleet(straggler_worker):
        """One scenario-driven run over a two-worker socket fleet; the
        spec's fault schedule decides which endpoint straggles."""

        class _RecordingPolicy(RebalancePolicy):
            def __init__(self):
                super().__init__()
                self.moves = []
                self.first_costs = None

            def decide(self, sig, replicas):
                out = super().decide(sig, replicas)
                if out and self.first_costs is None:
                    self.first_costs = dict(sig.plan_cost_s)
                self.moves.extend(out)
                return out

        spec = straggler_fleet_spec(straggler_worker=straggler_worker)
        (fault,) = spec.stragglers()
        servers = [
            WorkerServer(
                plan_delay_s=fault.plan_delay_s if w == fault.worker else 0.0
            )
            for w in range(2)
        ]
        try:
            loop = EventLoop()
            orch = Orchestrator(
                build_managers(spec, loop), loop=loop, incremental=True,
                shards=2, plan_mode="remote",
                transport=socket_fleet([s.addr for s in servers]),
            )
            policy = _RecordingPolicy()
            orch.enable_rebalance([p.name for p in spec.pools], policy=policy)
            install_scenario(spec, orch)
            orch.run()
            served = {}
            for r in orch.telemetry.records:
                for pool in r.units:
                    served[pool] = served.get(pool, 0) + 1
            assert orch.queue_depth() == 0
            assert orch.telemetry.rebalance_moves == len(policy.moves)
            orch.close()
            return policy.moves, policy.first_costs or {}, served
        finally:
            for s in servers:
                s.close()

    def test_signals_snapshot_live_state(self):
        orch = _fleet(rebalance=True)
        _skewed_load(orch, n=12)
        orch.run(until=0.01)  # let the submit events enqueue
        sig = orch._rebalance_signals()
        assert sig.depths["pool0"] > 0
        assert sig.depths["pool1"] == 0
        assert set(sig.backlogs["pool0"]) <= {"a", "b", "c", "d"}
        assert all(v >= 0 for v in sig.starvation["pool0"].values())
        assert 0.0 <= sig.utilization["pool0"] <= 1.0
        assert sum(sig.backlog_cost["pool0"].values()) > 0
        orch.close()
