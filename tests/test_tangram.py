"""End-to-end Tangram system behaviour + baseline comparisons (DES)."""


import pytest

from repro.core.action import Action, AmdahlElasticity, ResourceRequest, fixed, ranged
from repro.core.baselines import (
    StaticGpuServiceSystem,
    TrajectoryStaticCpuSystem,
    UnmanagedApiSystem,
)
from repro.core.cluster import ApiResourceSpec, CpuNodeSpec, GpuNodeSpec
from repro.core.managers.basic import BasicResourceManager
from repro.core.managers.cpu import CpuManager
from repro.core.managers.gpu import GpuManager, ServiceSpec
from repro.core.simulator import EventLoop
from repro.core.tangram import Tangram


def make_tangram(cores=64, gpu_nodes=1, services=("rm0",)):
    loop = EventLoop()
    managers = {
        "cpu": CpuManager([CpuNodeSpec("n0", cores=cores)]),
        "gpu": GpuManager(
            [GpuNodeSpec(f"g{i}") for i in range(gpu_nodes)],
            [ServiceSpec(s, 40.0) for s in services],
        ),
        "api": BasicResourceManager(
            ApiResourceSpec("api", mode="concurrency", max_concurrency=8), loop.clock
        ),
    }
    return Tangram(managers, loop=loop)


def coding_action(traj, base=5.0, hi=8):
    return Action(
        name="reward:pytest",
        cost={"cpu": ranged("cpu", 1, hi)},
        key_resource="cpu",
        elasticity=AmdahlElasticity(0.08),
        base_duration=base,
        trajectory_id=traj,
    )


class TestTangramE2E:
    def test_all_actions_complete(self):
        tg = make_tangram()
        futs = [tg.submit(coding_action(f"t{i}"), delay=0.1 * i) for i in range(30)]
        tg.run()
        assert all(f.done() for f in futs)
        assert len(tg.telemetry.records) == 30
        assert tg.telemetry.failure_rate() == 0.0

    def test_act_decomposition(self):
        tg = make_tangram()
        tg.submit(coding_action("t0"))
        tg.run()
        r = tg.telemetry.records[0]
        assert r.act == pytest.approx(r.queue_dur + r.exec_dur + r.sys_overhead)
        assert r.exec_dur > 0

    def test_elastic_speedup_under_low_load(self):
        """With a lone action and a big pool, elasticity shortens execution."""
        tg = make_tangram(cores=64)
        tg.submit(coding_action("t0", base=10.0))
        tg.run()
        r = tg.telemetry.records[0]
        assert r.exec_dur < 10.0 / 4  # >=4x speedup from elastic DoP

    def test_resources_fully_released(self):
        tg = make_tangram()
        for i in range(20):
            tg.submit(coding_action(f"t{i}"), delay=0.05 * i)
        tg.run()
        assert tg.managers["cpu"].available == 64
        assert tg.managers["gpu"].available == 8
        for alloc in tg.managers["gpu"].allocators.values():
            alloc.check_invariants()

    def test_gpu_service_multiplexing(self):
        """Two services share one 8-GPU node under EOE."""
        tg = make_tangram(services=("rm0", "rm1"))

        def rm_action(svc, i):
            return Action(
                name=f"rm:{svc}",
                cost={"gpu": ResourceRequest("gpu", (1, 2, 4, 8))},
                key_resource="gpu",
                elasticity=AmdahlElasticity(0.15),
                base_duration=2.0,
                service=svc,
                trajectory_id=f"g{i}",
            )

        for i in range(16):
            tg.submit(rm_action("rm0" if i % 2 else "rm1", i), delay=0.2 * i)
        tg.run()
        assert len(tg.telemetry.records) == 16
        gpu = tg.managers["gpu"]
        assert gpu.stats["hits"] > 0  # EOE cache pays off

    def test_quota_blocked_actions_eventually_run(self):
        tg = make_tangram()
        api = BasicResourceManager(
            ApiResourceSpec("api", mode="quota", quota=2, period_s=10.0),
            tg.loop.clock,
        )
        tg.managers["api"] = api
        for i in range(5):
            a = Action(
                name="api:search",
                cost={"api": fixed("api")},
                base_duration=0.5,
                trajectory_id=f"q{i}",
            )
            tg.submit(a)
        tg.run()
        assert len(tg.telemetry.records) == 5
        # later actions waited for quota refills
        assert max(r.queue_dur for r in tg.telemetry.records) >= 9.0

    def test_trajectory_lifecycle_releases_memory(self):
        tg = make_tangram()
        cpu = tg.managers["cpu"]
        tg.trajectory_start("tX", {})
        tg.submit(coding_action("tX"))
        tg.run()
        assert "tX" in cpu._binding
        tg.trajectory_end("tX")
        assert "tX" not in cpu._binding


class TestVsBaselines:
    def test_tangram_beats_trajectory_baseline_when_bursty(self):
        """Paper Fig. 6/8a: under burst, action-level scheduling wins."""
        n_traj, cores = 64, 32

        def workload(system):
            for i in range(n_traj):
                system.trajectory_start(f"t{i}", {})
                a = coding_action(f"t{i}", base=8.0)
                system.submit(a, delay=0.01 * i)
            system.run()
            return system.telemetry.mean_act()

        tg_loop = EventLoop()
        tg = Tangram({"cpu": CpuManager([CpuNodeSpec("n0", cores=cores)])}, loop=tg_loop)
        act_tangram = workload(tg)

        base = TrajectoryStaticCpuSystem(total_cores=cores)
        act_base = workload(base)
        assert act_tangram < act_base  # Tangram strictly better under burst

    def test_static_gpu_baseline_queues_per_service(self):
        sys_ = StaticGpuServiceSystem({"rm0": 1, "rm1": 1}, tp=4)
        for i in range(8):
            a = Action(
                name="rm:infer",
                cost={"gpu": ResourceRequest("gpu", (1, 2, 4, 8))},
                key_resource="gpu",
                elasticity=AmdahlElasticity(0.15),
                base_duration=2.0,
                service="rm0",  # all hit one service; rm1 idles (over-prov.)
                trajectory_id=f"t{i}",
            )
            sys_.submit(a)
        sys_.run()
        acts = [r.act for r in sys_.telemetry.records]
        assert max(acts) > 4 * min(acts)  # serial queueing behind one replica

    def test_unmanaged_api_fails_under_overload(self):
        sys_ = UnmanagedApiSystem(rate_limit=4, seed=1)
        for i in range(64):
            a = Action(name="api:q", cost={"api": fixed("api")}, base_duration=1.0,
                       trajectory_id=f"t{i}")
            sys_.submit(a)
        sys_.run()
        assert sys_.telemetry.failure_rate() > 0.0  # rate-limit violations
