"""Resource managers (§5): chunk allocator, AOE CPU, EOE GPU, Basic."""


import pytest

from _hypothesis_compat import given, settings, st

from repro.core.action import Action, AmdahlElasticity, fixed, ranged, ResourceRequest
from repro.core.cluster import ApiResourceSpec, CpuNodeSpec, GpuNodeSpec
from repro.core.managers.basic import BasicResourceManager
from repro.core.managers.cpu import CpuManager
from repro.core.managers.gpu import ChunkAllocator, GpuManager, ServiceSpec
from repro.core.simulator import SimClock


# ---------------------------------------------------------------------------
# Chunk allocator (buddy, §5.3 Pool)
# ---------------------------------------------------------------------------


class TestChunkAllocator:
    def test_legal_chunks_only(self):
        a = ChunkAllocator(8)
        got = a.allocate(3, None, 0.0)
        assert got is not None
        start, level, hit = got
        assert level == 2 and start % 4 == 0  # 3 GPUs -> a 4-chunk
        a.check_invariants()

    def test_split_and_merge(self):
        a = ChunkAllocator(8)
        c1 = a.allocate(1, None, 0.0)
        c2 = a.allocate(1, None, 0.0)
        a.check_invariants()
        a.release(c1[0], c1[1], None, 1.0)
        a.release(c2[0], c2[1], None, 1.0)
        # full node reclaimable after merge
        c8 = a.allocate(8, None, 2.0)
        assert c8 is not None and c8[1] == 3
        a.check_invariants()

    def test_cache_hit_preferred(self):
        a = ChunkAllocator(8)
        c = a.allocate(2, ("rm", 2), 0.0)
        a.release(c[0], c[1], ("rm", 2), 1.0)
        c2 = a.allocate(2, ("rm", 2), 2.0)
        assert c2[2] is True  # cache hit
        assert c2[0] == c[0]

    def test_lru_eviction_victim(self):
        a = ChunkAllocator(8)
        chunks = []
        for i in range(4):
            chunks.append(a.allocate(2, (f"s{i}", 2), float(i)))
        for i, c in enumerate(chunks):
            a.release(c[0], c[1], (f"s{i}", 2), 10.0 + i)
        # all four 2-chunks cached; a new service must evict the LRU (s0)
        got = a.allocate(2, ("new", 2), 100.0)
        assert got is not None
        assert got[0] == chunks[0][0]  # s0's chunk was LRU

    def test_exhaustion(self):
        a = ChunkAllocator(8)
        assert a.allocate(8, None, 0.0) is not None
        assert a.allocate(1, None, 0.0) is None


@settings(max_examples=150, deadline=None)
@given(ops=st.lists(st.tuples(st.booleans(), st.sampled_from([1, 2, 3, 4, 5, 8])), max_size=30))
def test_chunk_allocator_invariants_hold(ops):
    """Random alloc/release interleavings never corrupt the buddy state."""
    a = ChunkAllocator(8)
    held = []
    t = 0.0
    for is_alloc, m in ops:
        t += 1.0
        if is_alloc or not held:
            got = a.allocate(m, ("svc", m), t)
            if got is not None:
                held.append(got)
        else:
            start, level, _ = held.pop()
            a.release(start, level, ("svc", 1 << level), t)
        a.check_invariants()
    for start, level, _ in held:
        a.release(start, level, None, t)
    a.check_invariants()
    assert a.free_capacity == 8


# ---------------------------------------------------------------------------
# CPU manager (AOE, §5.2)
# ---------------------------------------------------------------------------


def cpu_action(traj, lo=1, hi=8, mem=4.0):
    return Action(
        name="exec",
        cost={"cpu": ranged("cpu", lo, hi)},
        key_resource="cpu",
        elasticity=AmdahlElasticity(0.1),
        base_duration=5.0,
        trajectory_id=traj,
        metadata={"traj_mem_gb": mem},
    )


class TestCpuManager:
    def test_numa_affinity(self):
        mgr = CpuManager([CpuNodeSpec("n0", cores=16, numa_nodes=2)])
        a = cpu_action("t1", 1, 8)
        alloc = mgr.try_allocate(a, 6)
        assert alloc is not None
        assert len(alloc.detail["numa_domains"]) == 1  # fits one domain

    def test_trajectory_node_binding(self):
        mgr = CpuManager([CpuNodeSpec(f"n{i}", cores=16) for i in range(3)])
        a1, a2 = cpu_action("tA"), cpu_action("tA")
        al1 = mgr.try_allocate(a1, 2)
        al2 = mgr.try_allocate(a2, 2)
        assert al1.node == al2.node  # same trajectory -> same node

    def test_memory_load_balancing(self):
        mgr = CpuManager([CpuNodeSpec("n0", cores=16, memory_gb=100),
                          CpuNodeSpec("n1", cores=16, memory_gb=200)])
        a = cpu_action("tB", mem=50.0)
        alloc = mgr.try_allocate(a, 1)
        assert alloc.node == "n1"  # most free memory wins

    def test_memory_released_at_trajectory_end(self):
        mgr = CpuManager([CpuNodeSpec("n0", cores=16, memory_gb=10)])
        a = cpu_action("tC", mem=8.0)
        alloc = mgr.try_allocate(a, 1)
        assert alloc is not None
        mgr.release(a, alloc)
        # second trajectory cannot fit 8 GB until tC ends
        b = cpu_action("tD", mem=8.0)
        assert mgr.try_allocate(b, 1) is None
        mgr.trajectory_end("tC")
        assert mgr.try_allocate(b, 1) is not None

    def test_exclusive_cores(self):
        mgr = CpuManager([CpuNodeSpec("n0", cores=8, numa_nodes=1)])
        a1, a2 = cpu_action("t1"), cpu_action("t2")
        al1 = mgr.try_allocate(a1, 4)
        al2 = mgr.try_allocate(a2, 4)
        assert set(al1.detail["cores"]).isdisjoint(al2.detail["cores"])
        assert mgr.try_allocate(cpu_action("t3"), 1) is None

    def test_partition_per_node(self):
        mgr = CpuManager([CpuNodeSpec(f"n{i}", cores=8) for i in range(2)])
        acts = [cpu_action(f"t{i}") for i in range(4)]
        parts = mgr.partition(acts)
        assert sum(len(v) for v in parts.values()) == 4
        # every action's trajectory is bound after partitioning
        for a in acts:
            assert mgr.node_of(a.trajectory_id) is not None


# ---------------------------------------------------------------------------
# GPU manager (EOE, §5.3)
# ---------------------------------------------------------------------------


def gpu_action(svc, traj="g0", dops=(1, 2, 4, 8)):
    return Action(
        name=f"rm:{svc}",
        cost={"gpu": ResourceRequest("gpu", tuple(dops))},
        key_resource="gpu",
        elasticity=AmdahlElasticity(0.15),
        base_duration=4.0,
        service=svc,
        trajectory_id=traj,
    )


class TestGpuManager:
    def make(self, nodes=2):
        return GpuManager(
            [GpuNodeSpec(f"g{i}", devices=8, restore_bw_gbps=64.0) for i in range(nodes)],
            [ServiceSpec("rm0", 40.0), ServiceSpec("rm1", 40.0)],
        )

    def test_miss_then_hit(self):
        mgr = self.make()
        a = gpu_action("rm0")
        al = mgr.try_allocate(a, 2)
        assert al is not None and al.detail["hit"] is False
        assert al.overhead > 0.5  # 40 GB / 64 GBps restore
        mgr.release(a, al)
        b = gpu_action("rm0")
        al2 = mgr.try_allocate(b, 2)
        assert al2.detail["hit"] is True
        assert al2.overhead < 0.01

    def test_distinct_dop_is_distinct_service(self):
        mgr = self.make()
        a = gpu_action("rm0")
        al = mgr.try_allocate(a, 2)
        mgr.release(a, al)
        b = gpu_action("rm0")
        al2 = mgr.try_allocate(b, 4)  # different DoP -> miss
        assert al2.detail["hit"] is False

    def test_unknown_service_rejected(self):
        mgr = self.make()
        with pytest.raises(KeyError):
            mgr.try_allocate(gpu_action("never_deployed"), 2)

    def test_feasible_multiset(self):
        mgr = self.make(nodes=1)
        assert mgr.feasible_multiset((0, 0, 0, 1))  # one 8-chunk
        assert mgr.feasible_multiset((0, 0, 2, 0))  # split into two 4s
        assert not mgr.feasible_multiset((1, 0, 0, 1))  # 9 devices > 8

    def test_hit_rate_stats(self):
        mgr = self.make()
        for _ in range(3):
            a = gpu_action("rm0")
            al = mgr.try_allocate(a, 2)
            mgr.release(a, al)
        assert mgr.stats["hits"] == 2 and mgr.stats["misses"] == 1


# ---------------------------------------------------------------------------
# Basic manager (§5.1)
# ---------------------------------------------------------------------------


class TestBasicManager:
    def test_concurrency_mode(self):
        clock = SimClock()
        mgr = BasicResourceManager(
            ApiResourceSpec("api", mode="concurrency", max_concurrency=2), clock
        )
        a1 = Action("q", cost={"api": fixed("api")})
        a2 = Action("q", cost={"api": fixed("api")})
        a3 = Action("q", cost={"api": fixed("api")})
        al1, al2 = mgr.try_allocate(a1, 1), mgr.try_allocate(a2, 1)
        assert al1 and al2
        assert mgr.try_allocate(a3, 1) is None
        mgr.release(a1, al1)
        assert mgr.try_allocate(a3, 1) is not None

    def test_quota_mode_refills(self):
        clock = SimClock()
        mgr = BasicResourceManager(
            ApiResourceSpec("api", mode="quota", quota=2, period_s=60.0), clock
        )
        a = Action("q", cost={"api": fixed("api")})
        assert mgr.try_allocate(a, 1) is not None
        assert mgr.try_allocate(a, 1) is not None
        assert mgr.try_allocate(a, 1) is None  # quota spent
        clock._advance(61.0)
        assert mgr.try_allocate(a, 1) is not None  # refilled
