"""Elastic scheduler (Algorithms 1 & 2): unit + property tests."""



from _hypothesis_compat import given, settings, st

from repro.core.action import (
    Action,
    AmdahlElasticity,
    fixed,
    powers_of_two,
    ranged,
)
from repro.core.managers.base import ResourceManager
from repro.core.scheduler import ElasticScheduler


def scal(name, traj, base=10.0, lo=1, hi=8, serial=0.1):
    return Action(
        name=name,
        cost={"cpu": ranged("cpu", lo, hi)},
        key_resource="cpu",
        elasticity=AmdahlElasticity(serial),
        base_duration=base,
        trajectory_id=traj,
    )


def rigid(name, traj, units=1):
    return Action(name=name, cost={"cpu": fixed("cpu", units)}, trajectory_id=traj)


def mgr(capacity=16):
    return {"cpu": ResourceManager("cpu", capacity)}


class TestCandidateWindow:
    def test_fcfs_prefix(self):
        s = ElasticScheduler()
        waiting = [rigid(f"a{i}", f"t{i}", units=8) for i in range(4)]
        res = s.schedule(waiting, [], mgr(16), 0.0)
        # only the first two fit at min units
        assert len(res.decisions) == 2
        assert [d.action.name for d in res.decisions] == ["a0", "a1"]

    def test_empty_queue(self):
        s = ElasticScheduler()
        assert s.schedule([], [], mgr(), 0.0).decisions == []

    def test_head_blocks_window(self):
        """FCFS: an oversized head blocks later actions (anti-starvation)."""
        s = ElasticScheduler()
        waiting = [rigid("big", "t0", units=32), rigid("small", "t1", units=1)]
        res = s.schedule(waiting, [], mgr(16), 0.0)
        assert res.decisions == []


class TestElasticAllocation:
    def test_lone_scalable_action_gets_more_units(self):
        s = ElasticScheduler()
        res = s.schedule([scal("a", "t0", serial=0.0)], [], mgr(16), 0.0)
        assert len(res.decisions) == 1
        assert res.decisions[0].units["cpu"] == 8  # max feasible

    def test_constraints_never_violated(self):
        s = ElasticScheduler()
        waiting = [scal(f"a{i}", f"t{i}") for i in range(6)]
        res = s.schedule(waiting, [], mgr(16), 0.0)
        total = sum(d.units["cpu"] for d in res.decisions)
        assert total <= 16
        for d in res.decisions:
            assert d.units["cpu"] in d.action.cost["cpu"].units

    def test_greedy_eviction_defers_tail(self):
        """16 cores, 8 perfectly elastic long actions: evicting some tail
        candidates and scaling the head ones up should win."""
        s = ElasticScheduler()
        waiting = [scal(f"a{i}", f"t{i}", base=100.0, serial=0.0) for i in range(8)]
        res = s.schedule(waiting, [], mgr(16), 0.0)
        assert 1 <= len(res.decisions) <= 8
        assert res.evicted == 8 - len(res.decisions)
        # whatever is kept must use the full pool (perfect elasticity)
        assert sum(d.units["cpu"] for d in res.decisions) <= 16

    def test_mixed_scalable_and_rigid(self):
        s = ElasticScheduler()
        waiting = [rigid("r0", "t0"), scal("s0", "t1"), rigid("r1", "t2")]
        res = s.schedule(waiting, [], mgr(16), 0.0)
        names = {d.action.name for d in res.decisions}
        assert {"r0", "r1"} <= names  # rigid actions selected directly

    def test_unknown_duration_not_scaled(self):
        s = ElasticScheduler()
        a = Action(
            name="u",
            cost={"cpu": ranged("cpu", 1, 8)},
            key_resource="cpu",
            elasticity=AmdahlElasticity(0.1),
            base_duration=None,  # unknown -> treated as non-scalable
            trajectory_id="t0",
        )
        res = s.schedule([a], [], mgr(16), 0.0)
        assert res.decisions[0].units["cpu"] == 1


class TestDepthProbes:
    def test_depth_probes_bounded(self):
        s = ElasticScheduler(depth=2)
        probes = s._depth_probes(scal("a", "t"))
        assert len(probes) <= 2

    def test_rigid_probe_single(self):
        s = ElasticScheduler(depth=3)
        assert s._depth_probes(rigid("a", "t")) == [None]


@settings(max_examples=80, deadline=None)
@given(
    n=st.integers(1, 10),
    capacity=st.integers(1, 32),
    data=st.data(),
)
def test_schedule_never_violates_capacity(n, capacity, data):
    s = ElasticScheduler()
    waiting = []
    for i in range(n):
        if data.draw(st.booleans(), label=f"scalable{i}"):
            base = data.draw(st.floats(0.1, 100.0, allow_nan=False), label=f"b{i}")
            hi = data.draw(st.integers(1, 8), label=f"hi{i}")
            waiting.append(scal(f"a{i}", f"t{i}", base=base, hi=hi))
        else:
            units = data.draw(st.integers(1, 4), label=f"u{i}")
            waiting.append(rigid(f"a{i}", f"t{i}", units=units))
    res = s.schedule(waiting, [], mgr(capacity), 0.0)
    assert sum(d.units["cpu"] for d in res.decisions) <= capacity
    # FCFS relative order among decisions of the same kind is preserved
    uids = [d.action.uid for d in res.decisions]
    assert all(d.units["cpu"] in d.action.cost["cpu"].units for d in res.decisions)


@settings(max_examples=40, deadline=None)
@given(n=st.integers(2, 8), data=st.data())
def test_eviction_monotone_objective(n, data):
    """The kept set's approximated objective never exceeds the full set's."""
    s = ElasticScheduler()
    waiting = [
        scal(
            f"a{i}",
            f"t{i}",
            base=data.draw(st.floats(1.0, 50.0, allow_nan=False), label=f"b{i}"),
        )
        for i in range(n)
    ]
    managers = mgr(8)
    full_obj, _ = s._approx_objective(
        waiting, [], "cpu", managers["cpu"], [], 0.0
    )
    res = s.schedule(waiting, [], managers, 0.0)
    assert res.objective <= full_obj + 1e-9


class TestBeyondPaperModes:
    """Opt-in scheduler extensions (EXPERIMENTS.md §Perf, scheduler
    iterations): dp_avg deferred-action pricing, exhaustive eviction
    search, and the DoP floor."""

    def _burst(self, n=24, base=55.0):
        return [
            Action(
                name=f"r{i}",
                cost={"cpu": powers_of_two("cpu", 1, 32)},
                key_resource="cpu",
                elasticity=AmdahlElasticity(0.05),
                base_duration=base,
                trajectory_id=f"t{i}",
            )
            for i in range(n)
        ]

    def test_paper_default_spreads_min_units(self):
        """Paper-faithful Alg. 1/2 on a synchronized burst: min-unit
        pricing of deferred actions means eviction never engages and
        everyone runs thin."""
        s = ElasticScheduler()
        res = s.schedule(self._burst(), [], mgr(48), 0.0)
        assert len(res.decisions) == 24
        assert all(d.units["cpu"] <= 2 for d in res.decisions)

    def test_dp_avg_exhaustive_wave_forms(self):
        """dp_avg pricing + exhaustive prefix scan discovers the
        wave: keep a few candidates at high DoP, defer the rest."""
        s = ElasticScheduler(estimate_units="dp_avg")
        s.eviction_search = "exhaustive"
        res = s.schedule(self._burst(), [], mgr(48), 0.0)
        assert res.evicted > 0
        assert all(d.units["cpu"] >= 4 for d in res.decisions)

    def test_dop_floor_enforced_when_feasible(self):
        s = ElasticScheduler(estimate_units="dp_avg")
        s.eviction_search = "exhaustive"
        s.dop_floor = 4
        res = s.schedule(self._burst(n=8), [], mgr(48), 0.0)
        assert res.decisions
        assert all(d.units["cpu"] >= 4 for d in res.decisions)

    def test_dop_floor_falls_back_when_starved(self):
        """If not even one action can get the floor and no in-flight
        completion guarantees a future round, the scheduler falls back to
        paper behaviour (min units) rather than starving the FCFS head."""
        s = ElasticScheduler(estimate_units="dp_avg")
        s.eviction_search = "exhaustive"
        s.dop_floor = 4
        res = s.schedule(self._burst(n=2), [], mgr(2), 0.0)
        assert len(res.decisions) == 2
        assert all(d.units["cpu"] == 1 for d in res.decisions)

    def test_dop_floor_defers_with_inflight(self):
        """With an in-flight completion due, the floor defers the queue
        instead of grabbing sub-floor scraps."""
        inflight = scal("busy", "tb", base=10.0)
        inflight.finish_time = 5.0
        s = ElasticScheduler(estimate_units="dp_avg")
        s.eviction_search = "exhaustive"
        s.dop_floor = 4
        res = s.schedule(self._burst(n=2), [inflight], mgr(2), 0.0)
        assert res.decisions == []
