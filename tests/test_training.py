"""Training substrate: optimizer, train step convergence, GRPO, checkpoint."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.training import (
    AdamWConfig,
    DataConfig,
    MarkovTextStream,
    group_advantages,
    grpo_loss,
    init_train_state,
    load_checkpoint,
    make_grpo_step,
    make_train_step,
    save_checkpoint,
)
from repro.training.optimizer import lr_schedule


class TestOptimizer:
    def test_lr_schedule_shape(self):
        cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
        lrs = [float(lr_schedule(cfg, jnp.array(s))) for s in [0, 5, 10, 50, 100]]
        assert lrs[0] < lrs[1] < lrs[2]  # warmup rises
        assert lrs[2] == pytest.approx(1e-3, rel=1e-3)  # peak at warmup end
        assert lrs[4] == pytest.approx(1e-4, rel=1e-2)  # min ratio 0.1

    def test_grad_clipping(self):
        from repro.training.optimizer import adamw_update, init_adamw

        cfg = AdamWConfig(grad_clip=1.0, weight_decay=0.0)
        params = {"w": jnp.ones((4,))}
        grads = {"w": jnp.full((4,), 100.0)}
        st = init_adamw(params)
        new_params, st2, m = adamw_update(cfg, params, grads, st)
        assert float(m["grad_norm"]) == pytest.approx(200.0)
        assert bool(jnp.all(jnp.isfinite(new_params["w"])))


class TestTrainStep:
    def test_loss_decreases_on_learnable_stream(self):
        """smollm-family reduced model on the Markov stream: loss must drop
        from ~ln(V) toward the ln(branching) entropy floor."""
        cfg = get_config("smollm-360m").reduced()
        api = build_model(cfg)
        state = init_train_state(api, jax.random.PRNGKey(0))
        opt = AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=60, weight_decay=0.01)
        step = jax.jit(make_train_step(api, opt))
        data = MarkovTextStream(
            DataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_size=16, branching=4)
        )
        losses = []
        for i, batch in zip(range(40), data):
            state, metrics = step(state, {"tokens": jnp.asarray(batch["tokens"][:, :32])})
            losses.append(float(metrics["loss"]))
        assert all(np.isfinite(losses))
        assert losses[-1] < losses[0] * 0.7, f"no learning: {losses[0]} -> {losses[-1]}"

    def test_moe_train_step_updates_router(self):
        cfg = get_config("granite-moe-3b-a800m").reduced()
        api = build_model(cfg)
        state = init_train_state(api, jax.random.PRNGKey(0))
        opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        step = jax.jit(make_train_step(api, opt))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)}
        before = state.params["layers"]["moe"]["router"].copy()
        state, metrics = step(state, batch)
        after = state.params["layers"]["moe"]["router"]
        assert not np.allclose(np.asarray(before), np.asarray(after))
        assert float(metrics["load_balance"]) > 0


class TestGRPO:
    def test_group_advantages_zero_mean(self):
        r = jnp.array([[1.0, 0.0, 0.5, 0.5], [0.0, 0.0, 1.0, 1.0]])
        adv = group_advantages(r)
        np.testing.assert_allclose(np.mean(np.asarray(adv), axis=1), 0.0, atol=1e-6)
        assert float(adv[0, 0]) > 0 > float(adv[0, 1])

    def test_grpo_step_moves_policy_toward_reward(self):
        cfg = get_config("smollm-360m").reduced()
        api = build_model(cfg)
        state = init_train_state(api, jax.random.PRNGKey(0))
        N, S = 8, 12
        key = jax.random.PRNGKey(2)
        tokens = jax.random.randint(key, (N, S), 0, cfg.vocab_size)
        from repro.training.grpo import token_logprobs

        old_logp = token_logprobs(state.params, tokens, api)
        adv = jnp.concatenate([jnp.ones(N // 2), -jnp.ones(N // 2)])
        batch = {
            "tokens": tokens,
            "mask": jnp.ones((N, S - 1)),
            "advantages": adv,
            "old_logp": old_logp,
            "ref_logp": old_logp,
        }
        opt = AdamWConfig(lr=5e-3, warmup_steps=1, total_steps=10, weight_decay=0.0)
        step = jax.jit(make_grpo_step(api, opt))
        state2, metrics = step(state, batch)
        assert bool(jnp.isfinite(metrics["loss"]))
        new_logp = token_logprobs(state2.params, tokens, api)
        pos = float(jnp.mean(new_logp[: N // 2] - old_logp[: N // 2]))
        neg = float(jnp.mean(new_logp[N // 2 :] - old_logp[N // 2 :]))
        assert pos > neg, "positive-advantage sequences should gain probability"

    def test_kl_zero_at_reference(self):
        cfg = get_config("smollm-360m").reduced()
        api = build_model(cfg)
        state = init_train_state(api, jax.random.PRNGKey(0))
        N, S = 2, 8
        tokens = jax.random.randint(jax.random.PRNGKey(3), (N, S), 0, cfg.vocab_size)
        from repro.training.grpo import token_logprobs

        logp = token_logprobs(state.params, tokens, api)
        batch = {
            "tokens": tokens,
            "mask": jnp.ones((N, S - 1)),
            "advantages": jnp.zeros(N),
            "old_logp": logp,
            "ref_logp": logp,
        }
        loss, metrics = grpo_loss(state.params, batch, api)
        assert float(metrics["kl"]) == pytest.approx(0.0, abs=1e-5)
        assert float(loss) == pytest.approx(0.0, abs=1e-5)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        cfg = get_config("smollm-360m").reduced()
        api = build_model(cfg)
        state = init_train_state(api, jax.random.PRNGKey(0))
        path = os.path.join(tmp_path, "ckpt.npz")
        save_checkpoint(path, state.params, step=7)
        restored, step = load_checkpoint(path, state.params)
        assert step == 7
        for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_shape_mismatch_rejected(self, tmp_path):
        path = os.path.join(tmp_path, "c.npz")
        save_checkpoint(path, {"w": jnp.ones((3,))})
        with pytest.raises(ValueError):
            load_checkpoint(path, {"w": jnp.ones((4,))})


class TestDataPipeline:
    def test_deterministic(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, batch_size=4, seed=42)
        a = next(iter(MarkovTextStream(cfg)))
        b = next(iter(MarkovTextStream(cfg)))
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_markov_structure(self):
        cfg = DataConfig(vocab_size=50, seq_len=64, batch_size=8, branching=2)
        stream = MarkovTextStream(cfg)
        batch = next(iter(stream))
        toks = batch["tokens"]
        # every transition must be one of the 2 allowed successors
        for b in range(toks.shape[0]):
            for t in range(toks.shape[1] - 1):
                assert toks[b, t + 1] in stream._succ[toks[b, t]]
