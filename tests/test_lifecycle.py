"""Action lifecycle: deadlines, bounded retry, cancellation, and
failure propagation through managers, futures, and telemetry."""

import math

import pytest

from repro.core.action import Action, ActionState, fixed
from repro.core.cluster import CpuNodeSpec, GpuNodeSpec
from repro.core.managers.base import ResourceManager
from repro.core.managers.cpu import CpuManager
from repro.core.managers.gpu import GpuManager, ServiceSpec
from repro.core.orchestrator import (
    ActionCancelled,
    ActionTimeout,
    Orchestrator,
)
from repro.core.simulator import EventLoop


def make_orch(cores=8):
    loop = EventLoop()
    return Orchestrator({"cpu": CpuManager([CpuNodeSpec("n0", cores=cores)])}, loop=loop)


def act(name="a", traj="t0", dur=1.0, units=1, **kw):
    return Action(
        name=name, cost={"cpu": fixed("cpu", units)}, base_duration=dur,
        trajectory_id=traj, **kw
    )


class TestTimeouts:
    def test_running_timeout_fails_and_releases(self):
        orch = make_orch()
        fut = orch.submit(act(dur=100.0, units=4, timeout_s=2.0))
        orch.run()
        assert fut.done()
        with pytest.raises(ActionTimeout):
            fut.result()
        # resources fully reclaimed via release_on_failure
        assert orch.managers["cpu"].available == 8
        assert orch.in_flight() == 0 and orch.queue_depth() == 0
        assert orch.telemetry.timeouts == 1
        assert orch.telemetry.failure_rate() == 1.0

    def test_queued_timeout_fails_without_start(self):
        orch = make_orch(cores=2)
        blocker = orch.submit(act(name="blocker", dur=50.0, units=2))
        fut = orch.submit(act(name="starved", traj="t1", dur=1.0, units=2,
                              timeout_s=5.0))
        orch.run()
        assert blocker.result() == pytest.approx(50.0)
        with pytest.raises(ActionTimeout):
            fut.result()
        rec = next(r for r in orch.telemetry.records if r.name == "starved")
        assert rec.failed and math.isnan(rec.start)

    def test_timeout_retry_then_success(self):
        """First attempt exceeds the deadline; the retry (faster sample)
        completes — the future resolves normally, telemetry counts one
        retry and one timeout."""
        orch = make_orch()
        durations = iter([100.0, 1.0])

        a = Action(
            name="flaky",
            cost={"cpu": fixed("cpu", 1)},
            duration_sampler=lambda m: next(durations),
            trajectory_id="t0",
            timeout_s=5.0,
            max_retries=2,
        )
        fut = orch.submit(a)
        orch.run()
        assert fut.result() == pytest.approx(1.0)
        assert a.state is ActionState.DONE
        assert a.attempts == 1
        assert orch.telemetry.timeouts == 1
        assert orch.telemetry.retries == 1
        rec = orch.telemetry.records[0]
        assert not rec.failed and rec.retries == 1
        assert rec.act == pytest.approx(5.0 + 1.0 + rec.sys_overhead)

    def test_bounded_retries_then_terminal_timeout(self):
        orch = make_orch()
        a = act(dur=100.0, timeout_s=1.0, max_retries=2)
        fut = orch.submit(a)
        orch.run()
        with pytest.raises(ActionTimeout):
            fut.result()
        assert a.state is ActionState.TIMEOUT
        assert a.attempts == 3  # initial + 2 retries
        assert orch.telemetry.timeouts == 3
        assert orch.telemetry.retries == 2
        rec = orch.telemetry.records[0]
        assert rec.failed and rec.retries == 2
        assert orch.managers["cpu"].available == 8

    def test_retry_requeues_at_fcfs_head(self):
        """After a timeout the retry goes back to the head of its
        partition, ahead of later arrivals."""
        orch = make_orch(cores=2)
        durations = iter([100.0, 1.0])
        flaky = Action(
            name="flaky",
            cost={"cpu": fixed("cpu", 2)},
            duration_sampler=lambda m: next(durations),
            trajectory_id="t0",
            timeout_s=2.0,
            max_retries=1,
        )
        orch.submit(flaky)
        later = orch.submit(act(name="later", traj="t1", dur=1.0, units=2), delay=0.5)
        orch.run()
        recs = {r.name: r for r in orch.telemetry.records}
        assert not recs["flaky"].failed
        # the retry launched before the younger action
        assert recs["flaky"].start < recs["later"].start

    def test_gpu_chunk_released_on_timeout(self):
        loop = EventLoop()
        gpu = GpuManager([GpuNodeSpec("g0")], [ServiceSpec("rm0", 40.0)])
        orch = Orchestrator({"gpu": gpu}, loop=loop)
        a = Action(
            name="rm", cost={"gpu": fixed("gpu", 4)}, base_duration=100.0,
            service="rm0", trajectory_id="t0", timeout_s=2.0,
        )
        fut = orch.submit(a)
        orch.run()
        assert fut.done()
        assert gpu.available == 8
        for alloc in gpu.allocators.values():
            alloc.check_invariants()


class TestCancellation:
    def test_cancel_queued(self):
        orch = make_orch(cores=2)
        orch.submit(act(name="run", dur=5.0, units=2))
        a = act(name="waiting", traj="t1", dur=1.0, units=2)
        fut = orch.submit(a)
        orch.run(until=1.0)
        assert a.state is ActionState.QUEUED
        assert orch.cancel(a)
        orch.run()
        with pytest.raises(ActionCancelled):
            fut.result()
        assert a.state is ActionState.CANCELLED
        assert orch.telemetry.cancellations == 1
        assert len(orch.telemetry.records) == 2  # blocker + cancelled

    def test_cancel_running_releases(self):
        orch = make_orch()
        a = act(dur=50.0, units=4)
        fut = orch.submit(a)
        orch.run(until=1.0)
        assert a.state is ActionState.RUNNING
        assert orch.cancel(a)
        assert orch.managers["cpu"].available == 8
        orch.run()
        with pytest.raises(ActionCancelled):
            fut.result()
        assert orch.in_flight() == 0

    def test_cancel_pending_delayed_submission(self):
        """Cancelling before the delayed submission lands must kill the
        pending enqueue — the action never resurrects, runs, or
        double-records."""
        orch = make_orch()
        a = act(dur=1.0)
        fut = orch.submit(a, delay=5.0)
        orch.run(until=1.0)
        assert orch.cancel(a)
        orch.run()
        with pytest.raises(ActionCancelled):
            fut.result()
        assert a.state is ActionState.CANCELLED
        recs = orch.telemetry.records
        assert len(recs) == 1 and recs[0].failed
        assert orch.queue_depth() == 0 and orch.in_flight() == 0

    def test_cancel_terminal_is_noop(self):
        orch = make_orch()
        a = act(dur=1.0)
        fut = orch.submit(a)
        orch.run()
        assert fut.result() == pytest.approx(1.0)
        assert not orch.cancel(a)
        assert orch.telemetry.cancellations == 0


class TestLifecycleSchedulingInteraction:
    def test_retry_releases_wake_other_partitions(self):
        """A timed-out multi-resource action whose retry re-queues
        blocked must still wake partitions waiting on the resources the
        withdrawn attempt freed (incremental == full)."""
        from repro.core.cluster import ApiResourceSpec
        from repro.core.managers.basic import BasicResourceManager

        def build(incremental):
            loop = EventLoop()
            quota = BasicResourceManager(
                ApiResourceSpec("a", mode="quota", quota=1, period_s=1000.0),
                loop.clock,
            )
            shared = ResourceManager("y", 1)
            orch = Orchestrator(
                {"a": quota, "y": shared}, loop=loop, incremental=incremental
            )
            # A consumes the only quota token AND the only y unit, hangs,
            # times out, and re-queues quota-blocked (token not refunded).
            hog = Action(
                name="hog",
                cost={"a": fixed("a"), "y": fixed("y")},
                key_resource="a",
                base_duration=100.0,
                trajectory_id="t0",
                timeout_s=5.0,
                max_retries=3,
            )
            orch.submit(hog)
            fut = orch.submit(
                Action(name="waiter", cost={"y": fixed("y")}, base_duration=1.0,
                       trajectory_id="t1"),
                delay=1.0,
            )
            orch.run(until=60.0)
            return fut

        for incremental in (True, False):
            fut = build(incremental)
            assert fut.done(), f"waiter starved (incremental={incremental})"

    def test_timeout_unblocks_queued_work(self):
        """A hung head action's timeout must free capacity for the queue
        behind it in the same virtual instant."""
        orch = make_orch(cores=2)
        orch.submit(act(name="hung", dur=1000.0, units=2, timeout_s=3.0))
        fut = orch.submit(act(name="next", traj="t1", dur=1.0, units=2))
        orch.run()
        assert fut.result() == pytest.approx(1.0)
        rec = next(r for r in orch.telemetry.records if r.name == "next")
        assert rec.start == pytest.approx(3.0, abs=0.01)

    def test_failure_rate_feeds_step_stats(self):
        from repro.core.simulator import EventLoop as _Loop
        from repro.rl.rollout import RolloutRunner
        from repro.rl.tasks import ActionTemplate, TrajectorySpec, TurnSpec

        loop = _Loop()
        orch = Orchestrator(
            {"cpu": CpuManager([CpuNodeSpec("n0", cores=4)])}, loop=loop
        )

        def mk(timeout):
            return ActionTemplate(
                build=lambda task_id, traj_id: Action(
                    name="tool", cost={"cpu": fixed("cpu", 1)},
                    base_duration=10.0, trajectory_id=traj_id,
                    timeout_s=timeout,
                )
            )

        trajs = [
            TrajectorySpec(
                task_id="task", traj_id=f"t{i}", arrival_s=0.0,
                turns=[TurnSpec(gen_s=0.0, actions=[mk(1.0 if i == 0 else None)])],
                reward=[],
            )
            for i in range(3)
        ]
        runner = RolloutRunner({"*": orch, "cpu": orch}, loop)
        stats = runner.run_step(trajs)
        assert stats.failure_rate == pytest.approx(1 / 3)
