"""RL layer: workload generators + rollout runner + paper-claim checks."""

import math


from repro.core.cluster import paper_testbed
from repro.rl.driver import run_baseline_step, run_tangram_step
from repro.rl.tasks import (
    make_coding_workload,
    make_deepsearch_workload,
    make_mopd_workload,
    workload_services,
)


class TestWorkloadGenerators:
    def test_deterministic(self):
        a = make_coding_workload(10, seed=3)
        b = make_coding_workload(10, seed=3)
        for x, y in zip(a, b):
            assert x.traj_id == y.traj_id
            assert len(x.turns) == len(y.turns)
            assert x.arrival_s == y.arrival_s

    def test_coding_actions_well_formed(self):
        trajs = make_coding_workload(5)
        for t in trajs:
            assert t.turns, "coding trajectories are multi-turn"
            a = t.reward[0].make(t.task_id, t.traj_id)
            assert a.key_resource == "cpu"
            assert a.scalable
            assert a.cost["cpu"].units == (1, 2, 4, 8, 16, 32)

    def test_mopd_teachers_enumerated(self):
        trajs = make_mopd_workload(20, n_teachers=5, teachers_per_traj=2)
        services = workload_services(trajs)
        assert all(s.startswith("teacher") for s in services)
        assert len(services) <= 5

    def test_deepsearch_uses_basic_resources(self):
        trajs = make_deepsearch_workload(5)
        apis = set()
        for t in trajs:
            for turn in t.turns:
                for tmpl in turn.actions:
                    a = tmpl.make(t.task_id, t.traj_id)
                    apis.update(a.cost)
        assert apis <= {"google_search", "web_fetch", "pdf_parse"}


class TestRolloutRunner:
    def test_all_trajectories_complete(self):
        cluster = paper_testbed(cpu_nodes=2, gpu_nodes=2)
        trajs = make_coding_workload(16)
        stats, tg = run_tangram_step(trajs, cluster)
        assert stats.step_duration > 0
        assert math.isfinite(stats.mean_act)
        assert tg.queue_depth() == 0 and tg.in_flight() == 0
        # every reward ran exactly once
        rewards = [r for r in tg.telemetry.records if r.name.startswith("reward")]
        assert len(rewards) == 16

    def test_stage_durations_tracked(self):
        cluster = paper_testbed(cpu_nodes=2, gpu_nodes=2)
        trajs = make_coding_workload(8)
        stats, _ = run_tangram_step(trajs, cluster)
        assert stats.stage_durations["gen"] > 0
        assert stats.stage_durations["tool"] > 0
        assert stats.stage_durations["reward"] > 0


class TestPaperClaims:
    """Qualitative reproduction gates on small-scale versions of §6.2/6.3."""

    def test_coding_act_improvement(self):
        """Tangram must beat the k8s baseline clearly on bursty coding."""
        cluster = paper_testbed(cpu_nodes=2, cores_per_node=128, gpu_nodes=1)
        trajs = make_coding_workload(128, arrival_spread_s=20)
        tg, _ = run_tangram_step(trajs, cluster)
        bl, _ = run_baseline_step(trajs, cluster)
        assert bl.mean_act / tg.mean_act > 1.5, (
            f"expected >1.5x ACT gain, got {bl.mean_act / tg.mean_act:.2f}"
        )

    def test_coding_step_speedup(self):
        cluster = paper_testbed(cpu_nodes=2, cores_per_node=128, gpu_nodes=1)
        trajs = make_coding_workload(128, arrival_spread_s=20)
        tg, _ = run_tangram_step(trajs, cluster)
        bl, _ = run_baseline_step(trajs, cluster)
        assert bl.step_duration > tg.step_duration

    def test_mopd_multiplexing_beats_static(self):
        cluster = paper_testbed(cpu_nodes=1, gpu_nodes=3)
        trajs = make_mopd_workload(128, n_teachers=6, arrival_spread_s=5)
        tg, _ = run_tangram_step(trajs, cluster)
        st, _ = run_baseline_step(trajs, cluster, gpu_baseline="static")
        assert tg.mean_act < st.mean_act

    def test_resource_saving_at_equal_act(self):
        """§6.3 / Fig. 8b Right: Tangram serves 10 reward services on ~30%
        of the GPUs the static baseline needs, at comparable ACT (paper:
        29% of GPUs, same ACT — i.e. 71.2% savings).

        Regime calibration (see EXPERIMENTS.md): the claim holds where
        teacher popularity is heavily skewed (Fig. 3d: invocations vary by
        orders of magnitude) so the static baseline's hot services saturate
        while its cold services idle, and aggregate demand (~9 GPU-equiv)
        still fits Tangram's pooled 12 GPUs."""
        from repro.core.cluster import ClusterSpec, CpuNodeSpec, GpuNodeSpec

        trajs = make_mopd_workload(
            128, n_teachers=10, arrival_spread_s=240, teacher_skew=3.0
        )
        static, _ = run_baseline_step(
            trajs, paper_testbed(cpu_nodes=1, gpu_nodes=5), gpu_baseline="static"
        )
        small_cluster = ClusterSpec(
            cpu_nodes=(CpuNodeSpec(name="cpu0"),),
            gpu_nodes=(
                GpuNodeSpec(name="gpu0", devices=8),
                GpuNodeSpec(name="gpu1", devices=4),
            ),
        )
        small, _ = run_tangram_step(trajs, small_cluster)
        # 12 GPUs (30% of the static baseline's 40) at <=1.2x its ACT
        assert small.mean_act <= static.mean_act * 1.2

    def test_elastic_beats_fixed_dop(self):
        """Fig. 9: elastic allocation adapts to contention where any fixed
        DoP is wrong at one end of the load range.  Paper: 2.0x vs DoP=4
        at low batch (resources abundant -> scale up) and 3.0x vs DoP=16
        at high batch (congested -> shrink toward min units)."""
        from benchmarks.fig9_elastic import _fix_dop

        # abundant: elastic scales rewards up, fixed4 underuses the pool
        cluster = paper_testbed(cpu_nodes=1, cores_per_node=128, gpu_nodes=1)
        trajs = make_coding_workload(32, arrival_spread_s=10)
        elastic, _ = run_tangram_step(trajs, cluster)
        fixed4, _ = run_tangram_step(_fix_dop(trajs, 4), cluster)
        assert fixed4.mean_act / elastic.mean_act > 1.5, (
            f"abundant: expected >1.5x vs fixed4, got "
            f"{fixed4.mean_act / elastic.mean_act:.2f}"
        )

        # congested: elastic shrinks toward min units, fixed16 thrashes
        cluster = paper_testbed(cpu_nodes=1, cores_per_node=64, gpu_nodes=1)
        trajs = make_coding_workload(192, arrival_spread_s=10)
        elastic, _ = run_tangram_step(trajs, cluster)
        fixed16, _ = run_tangram_step(_fix_dop(trajs, 16), cluster)
        assert fixed16.mean_act / elastic.mean_act > 1.5, (
            f"congested: expected >1.5x vs fixed16, got "
            f"{fixed16.mean_act / elastic.mean_act:.2f}"
        )

    def test_serverless_baseline_worse_than_tangram(self):
        cluster = paper_testbed(cpu_nodes=1, gpu_nodes=2)
        trajs = make_mopd_workload(96, n_teachers=6, arrival_spread_s=5)
        tg, _ = run_tangram_step(trajs, cluster)
        sl, _ = run_baseline_step(trajs, cluster, gpu_baseline="serverless")
        assert tg.mean_act < sl.mean_act
