"""Model zoo correctness: per-arch smoke tests + decode/train consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, get_config
from repro.models import build_model
from repro.models.layers import logits_fn
from repro.models.ssm import ssd_scan_with_state, ssd_decode_step
from repro.models.transformer import embed_tokens, forward

ARCHS = sorted(all_configs())


def make_batch(cfg, B=2, S=64, key=None):
    key = key or jax.random.PRNGKey(7)
    if cfg.family == "audio":
        return {
            "frames": jax.random.normal(key, (B, 32, cfg.d_model)) * 0.02,
            "tokens": jax.random.randint(key, (B, 16), 0, cfg.vocab_size),
        }
    if cfg.family == "vlm":
        return {
            "tokens": jax.random.randint(key, (B, S - cfg.num_patches), 0, cfg.vocab_size),
            "patch_embeds": jax.random.normal(key, (B, cfg.num_patches, cfg.d_model)) * 0.02,
        }
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_reduced_forward_and_shapes(arch):
    """Brief requirement: reduced variant, one forward/train step on CPU,
    output shapes + no NaNs."""
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, metrics = jax.jit(lambda p, b: api.loss_fn(p, b))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    grads = jax.grad(lambda p: api.loss_fn(p, batch)[0])(params)
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    assert bool(jnp.isfinite(gnorm)), f"{arch}: non-finite grads"
    assert float(gnorm) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B = 2
    state = api.init_decode_state(B, 128)
    step = jax.jit(lambda p, s, t: api.decode_step(p, s, t))
    tok = jnp.zeros((B, 1), jnp.int32)
    for _ in range(3):
        logits, state = step(params, tok, None) if False else step(params, state, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite decode logits"
    assert int(state.pos) == 3


@pytest.mark.parametrize("arch", ["smollm-360m", "granite-moe-3b-a800m", "mamba2-130m", "hymba-1.5b"])
def test_decode_matches_teacher_forcing(arch):
    """Greedy decode logits must match the full-sequence forward pass."""
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)

    # full forward logits at every position
    x = embed_tokens(params, tokens, cfg)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    h, _ = forward(params, x, pos, cfg, None)
    full_logits = logits_fn(params, h, cfg)  # [B,S,V]

    # incremental decode feeding the same tokens
    state = api.init_decode_state(B, S)
    step = jax.jit(lambda p, s, t: api.decode_step(p, s, t))
    for t in range(S):
        logits, state = step(params, state, tokens[:, t : t + 1])
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(full_logits[:, t, :], np.float32),
            rtol=2e-2,
            atol=2e-2,
            err_msg=f"{arch}: decode diverges from forward at t={t}",
        )


@pytest.mark.parametrize("arch", ["smollm-360m", "whisper-medium", "hymba-1.5b"])
def test_prefill_then_decode_consistency(arch):
    """prefill(prompt) + decode_step must equal pure decode from scratch."""
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B, S = 2, 8
    batch = make_batch(cfg, B=B, S=S)
    pf_logits, state = jax.jit(lambda p, b: api.prefill(p, b))(params, batch)
    assert bool(jnp.all(jnp.isfinite(pf_logits)))

    # run one more token through decode; caches must be usable
    tok = jnp.argmax(pf_logits, -1)[:, None].astype(jnp.int32)
    logits, state2 = jax.jit(lambda p, s, t: api.decode_step(p, s, t))(params, state, tok)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(state2.pos) == int(state.pos) + 1


def test_prefill_matches_decode_exactly_dense():
    """Strong check on the dense path: prefill caches == incremental caches."""
    cfg = get_config("smollm-360m").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B, S = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(9), (B, S), 0, cfg.vocab_size)

    # incremental to position S-1
    state = api.init_decode_state(B, S)
    for t in range(S):
        inc_logits, state = api.decode_step(params, state, tokens[:, t : t + 1])

    pf_logits, pf_state = api.prefill(params, {"tokens": tokens})
    np.testing.assert_allclose(
        np.asarray(pf_logits, np.float32),
        np.asarray(inc_logits, np.float32),
        rtol=2e-2,
        atol=2e-2,
    )
    np.testing.assert_allclose(
        np.asarray(pf_state.k_cache, np.float32),
        np.asarray(state.k_cache, np.float32),
        rtol=2e-2,
        atol=2e-2,
    )


class TestSSD:
    def test_chunked_matches_naive_recurrence(self):
        """SSD chunked scan == step-by-step recurrence (the oracle)."""
        cfg = get_config("mamba2-130m").reduced()
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        lp = jax.tree.map(lambda a: a[0], params["layers"])  # layer 0 params
        B, S = 2, 64
        x = jax.random.normal(jax.random.PRNGKey(5), (B, S, cfg.d_model)) * 0.5

        y_chunked, final_state = ssd_scan_with_state(lp["ssm"], x, cfg, None)

        # naive: run the O(1) decode recurrence token by token
        state = jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
        ys = []
        for t in range(S):
            y_t, state = ssd_decode_step(lp["ssm"], x[:, t : t + 1], state, cfg)
            ys.append(y_t)
        y_naive = jnp.concatenate(ys, axis=1)

        np.testing.assert_allclose(
            np.asarray(y_chunked, np.float32),
            np.asarray(y_naive, np.float32),
            rtol=1e-3,
            atol=1e-3,
        )
        np.testing.assert_allclose(
            np.asarray(final_state), np.asarray(state), rtol=1e-3, atol=1e-3
        )

    def test_state_decay_bounded(self):
        cfg = get_config("mamba2-130m").reduced()
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        lp = jax.tree.map(lambda a: a[0], params["layers"])
        B = 1
        state = jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
        x = jnp.ones((B, 1, cfg.d_model)) * 0.1
        for _ in range(200):
            _, state = ssd_decode_step(lp["ssm"], x, state, cfg)
        assert bool(jnp.all(jnp.isfinite(state))), "SSD state blew up"


class TestMoE:
    def test_router_probs_normalized_and_capacity_respected(self):
        from repro.models.moe import moe_ffn

        cfg = get_config("granite-moe-3b-a800m").reduced()
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        lp = jax.tree.map(lambda a: a[0], params["layers"])
        B, S = 2, 32
        x = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model)) * 0.5
        y, aux = moe_ffn(lp["moe"], x, cfg)
        assert y.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(y)))
        assert float(aux["load_balance"]) >= 0.99  # >= 1 at perfect balance

    def test_moe_zero_when_router_uniform_and_experts_zero(self):
        from repro.models.moe import moe_ffn

        cfg = get_config("granite-moe-3b-a800m").reduced()
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        lp = jax.tree.map(lambda a: a[0], params["layers"])
        zeroed = jax.tree.map(jnp.zeros_like, lp["moe"])
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model))
        y, _ = moe_ffn(zeroed, x, cfg)
        np.testing.assert_allclose(np.asarray(y), 0.0, atol=1e-6)


class TestSlidingWindow:
    def test_sliding_window_decode_differs_from_full(self):
        cfg = get_config("smollm-360m").reduced()
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        B, S = 1, 96
        tokens = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab_size)
        W = cfg.sliding_window  # 64 in reduced configs

        def run(sw):
            state = api.init_decode_state(B, S)
            for t in range(S):
                logits, state = api.decode_step(
                    params, state, tokens[:, t : t + 1], sliding_window=sw
                )
            return logits

        full = run(0)
        windowed = run(W)
        assert bool(jnp.all(jnp.isfinite(windowed)))
        # past-window tokens are masked out -> different distribution
        assert not np.allclose(np.asarray(full), np.asarray(windowed), atol=1e-4)

    def test_sliding_window_equals_full_within_window(self):
        cfg = get_config("smollm-360m").reduced()
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        B, S, W = 1, 32, 64  # S < W: window never truncates
        tokens = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab_size)

        def run(sw):
            state = api.init_decode_state(B, 128)
            for t in range(S):
                logits, state = api.decode_step(
                    params, state, tokens[:, t : t + 1], sliding_window=sw
                )
            return logits

        np.testing.assert_allclose(
            np.asarray(run(0), np.float32), np.asarray(run(W), np.float32),
            rtol=1e-4, atol=1e-4,
        )
