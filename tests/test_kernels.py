"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

I = dict(interpret=True)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,KV,S,d", [
    (1, 2, 2, 128, 32),
    (2, 4, 2, 128, 64),   # GQA g=2
    (1, 8, 1, 256, 32),   # MQA
    (2, 2, 2, 256, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, H, KV, S, d, dtype):
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (B, H, S, d), dtype)
    k = jax.random.normal(keys[1], (B, KV, S, d), dtype)
    v = jax.random.normal(keys[2], (B, KV, S, d), dtype)
    got = ops.flash_attention_op(q, k, v, block_q=64, block_k=64, **I)
    want = ref.flash_attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_flash_attention_non_causal():
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(keys[0], (1, 2, 128, 32))
    k = jax.random.normal(keys[1], (1, 2, 128, 32))
    v = jax.random.normal(keys[2], (1, 2, 128, 32))
    got = ops.flash_attention_op(q, k, v, causal=False, block_q=64, block_k=64, **I)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("block_q,block_k", [(32, 128), (128, 32), (64, 64)])
def test_flash_attention_block_shape_sweep(block_q, block_k):
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(keys[0], (1, 2, 128, 64))
    k = jax.random.normal(keys[1], (1, 1, 128, 64))
    v = jax.random.normal(keys[2], (1, 1, 128, 64))
    got = ops.flash_attention_op(q, k, v, block_q=block_q, block_k=block_k, **I)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# SSD intra-chunk
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("BNC,H,Q,hd,N", [
    (2, 3, 32, 16, 8),
    (4, 2, 64, 32, 16),
    (1, 1, 128, 64, 32),
])
def test_ssd_intra_chunk_matches_ref(BNC, H, Q, hd, N):
    keys = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(keys[0], (BNC, H, Q, hd)) * 0.5
    b = jax.random.normal(keys[1], (BNC, Q, N)) * 0.5
    c = jax.random.normal(keys[2], (BNC, Q, N)) * 0.5
    # realistic decays: negative, monotonically decreasing cumsums
    cum = -jnp.cumsum(jax.random.uniform(keys[3], (BNC, H, Q)) * 0.1, axis=-1)
    y, st = ops.ssd_intra_chunk_op(x, b, c, cum, **I)
    for i in range(BNC):
        for h in range(H):
            y_ref, st_ref = ref.ssd_chunk_ref(x[i, h], b[i], c[i], cum[i, h])
            np.testing.assert_allclose(
                np.asarray(y[i, h]), np.asarray(y_ref), rtol=1e-4, atol=1e-4
            )
            np.testing.assert_allclose(
                np.asarray(st[i, h]), np.asarray(st_ref), rtol=1e-4, atol=1e-4
            )


def test_ssd_kernel_agrees_with_model_ssd():
    """The kernel's intra-chunk math must match the model's ssd_scan when
    the sequence is a single chunk (no inter-chunk contribution)."""
    from repro.configs import get_config
    from repro.models import build_model
    from repro.models.ssm import _project, ssd_scan_with_state

    cfg = get_config("mamba2-130m").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda a: a[0], params["layers"])["ssm"]
    B, S = 1, cfg.ssm_chunk  # one chunk
    xin = jax.random.normal(jax.random.PRNGKey(5), (B, S, cfg.d_model)) * 0.5
    z, xs, b, c, dt = _project(lp, xin, cfg)
    A = -jnp.exp(lp["a_log"].astype(jnp.float32))
    cum = jnp.cumsum(dt * A, axis=1)  # [B,S,H]
    xdt = (xs * dt[..., None].astype(xs.dtype)).astype(jnp.float32)

    # kernel layout: [BNC=B, H, Q, hd] / [B, Q, N] / [B, H, Q]
    y_k, st_k = ops.ssd_intra_chunk_op(
        jnp.moveaxis(xdt, 2, 1),  # [B,H,S,hd]
        b.astype(jnp.float32),
        c.astype(jnp.float32),
        jnp.moveaxis(cum, 2, 1),  # [B,H,S]
        **I,
    )
    # model: full ssd on the same single chunk
    _, st_model = ssd_scan_with_state(lp, xin, cfg, None)
    np.testing.assert_allclose(
        np.asarray(st_k[:, :, :, :]).transpose(0, 1, 2, 3),
        np.asarray(st_model),
        rtol=5e-3,
        atol=5e-3,
    )


# ---------------------------------------------------------------------------
# MoE grouped matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("E,C,D,F", [
    (4, 128, 128, 128),
    (2, 256, 128, 256),
    (8, 128, 256, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_matmul_matches_ref(E, C, D, F, dtype):
    keys = jax.random.split(jax.random.PRNGKey(4), 2)
    buf = jax.random.normal(keys[0], (E, C, D), dtype)
    w = jax.random.normal(keys[1], (E, D, F), dtype) * 0.1
    got = ops.moe_matmul_op(buf, w, **I)
    want = ref.moe_matmul_ref(buf, w)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_moe_matmul_block_sweep():
    buf = jax.random.normal(jax.random.PRNGKey(6), (2, 256, 256))
    w = jax.random.normal(jax.random.PRNGKey(7), (2, 256, 256)) * 0.1
    want = ref.moe_matmul_ref(buf, w)
    for bc, bd, bf in [(64, 128, 64), (128, 64, 128), (256, 256, 256)]:
        got = ops.moe_matmul_op(buf, w, block_c=bc, block_d=bd, block_f=bf, **I)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("T,D", [(256, 128), (512, 256), (128, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(T, D, dtype):
    x = jax.random.normal(jax.random.PRNGKey(8), (T, D), dtype)
    w = jax.random.normal(jax.random.PRNGKey(9), (D,), dtype)
    got = ops.rmsnorm_op(x, w, block_rows=128, **I)
    want = ref.rmsnorm_ref(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_rmsnorm_matches_model_layer():
    from repro.models.layers import rms_norm

    x = jax.random.normal(jax.random.PRNGKey(10), (64, 4, 128))
    w = jnp.ones((128,))
    got = ops.rmsnorm_op(x, w, block_rows=64, **I)
    want = rms_norm(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
