"""Scenario factory: the differential replay rail and its properties.

Five layers of guarantees:

* **replay** — a spec plus its seed IS the workload: compiling twice
  yields byte-identical event streams, the committed spec files under
  ``benchmarks/scenarios/`` compile to exactly what their builders
  produce, and the seed is load-bearing (reseeding a stochastic spec
  moves the fingerprint);
* **distributions** — the in-house samplers really produce the shapes
  the specs declare (Pareto tail index via a Hill estimator, lognormal
  mean, clamp bounds, the legacy cycle ladders bit-for-bit);
* **codec** — specs round-trip through the wire-style sparse dict
  encoding, unknown fields are ignored (additive schema changes stay
  compatible), and every malformed input dies with a *typed*
  ``ScenarioError``;
* **legacy equivalence** — the three hand-written bench scenarios the
  factory replaced (fleet churn / mixed churn / multi-tenant fairness)
  are PINNED here as frozen copies, and the spec-driven runs must
  reproduce their launch traces bit-identically;
* **sim-vs-live** — the same compiled scenario run under the DES clock
  and under the real-time live harness must produce the same
  structural (per-pool launch order) trace, and the worked examples in
  ``docs/scenarios.md`` must decode, compile, and fingerprint exactly
  as documented.
"""

import dataclasses
import json
import math
import random
import re
from pathlib import Path

import pytest

from repro.core.action import Action, AmdahlElasticity, ResourceRequest, fixed
from repro.core.cluster import ApiResourceSpec, CpuNodeSpec, GpuNodeSpec
from repro.core.fairqueue import FairSharePolicy
from repro.core.managers.base import ResourceManager
from repro.core.managers.basic import BasicResourceManager
from repro.core.managers.cpu import CpuManager
from repro.core.managers.gpu import GpuManager, ServiceSpec
from repro.core.orchestrator import Orchestrator
from repro.core.scenarios import (
    CHURN_APIS,
    FAIRNESS_WEIGHTS,
    SCENARIO_BUILDERS,
    ActionKindSpec,
    ArrivalSpec,
    DurationSpec,
    MixSpec,
    PoolSpec,
    ScenarioError,
    ScenarioSpec,
    StreamSpec,
    build_fair_share,
    build_managers,
    build_policy,
    churn_spec,
    compile_scenario,
    decode_scenario,
    encode_scenario,
    fairness_spec,
    fleet_churn_spec,
    install_scenario,
    live_smoke_spec,
    load_scenario,
    structural_trace,
)
from repro.core.scheduler import ElasticScheduler
from repro.core.simulator import EventLoop

REPO = Path(__file__).resolve().parent.parent
SPEC_DIR = REPO / "benchmarks" / "scenarios"


def _trace(orch):
    return sorted(
        (r.name, r.task_id, r.trajectory_id, round(r.submit, 9),
         round(r.start, 9), round(r.finish, 9),
         tuple(sorted(r.units.items())), r.failed)
        for r in orch.telemetry.records
    )


def _spec_orch(spec, loop=None):
    loop = loop or EventLoop()
    return Orchestrator(
        build_managers(spec, loop), loop=loop, policy=build_policy(spec),
        incremental=True, fair_share=build_fair_share(spec),
    )


def _run_spec(spec, until=None):
    orch = _spec_orch(spec)
    install_scenario(spec, orch)
    orch.run(until=until)
    trace = _trace(orch)
    orch.close()
    return trace


# ---------------------------------------------------------------------------
# the replay rail: seed determinism, spec files, fingerprints
# ---------------------------------------------------------------------------


class TestReplayRail:
    @pytest.mark.parametrize("name", sorted(SCENARIO_BUILDERS))
    def test_stream_bit_identical(self, name):
        """Identical spec + seed => byte-identical compiled streams."""
        build = SCENARIO_BUILDERS[name]
        a, b = compile_scenario(build()), compile_scenario(build())
        assert a.stream_bytes() == b.stream_bytes()
        assert a.fingerprint() == b.fingerprint()

    @pytest.mark.parametrize("name", sorted(SCENARIO_BUILDERS))
    def test_codec_round_trip_preserves_stream(self, name):
        """The replay rail survives the wire boundary: a decoded copy
        compiles to the same bytes as the original."""
        spec = SCENARIO_BUILDERS[name]()
        copied = decode_scenario(encode_scenario(spec))
        assert copied == spec
        assert (compile_scenario(copied).stream_bytes()
                == compile_scenario(spec).stream_bytes())

    def test_committed_spec_files_match_builders(self):
        """benchmarks/scenarios/*.json is exactly the builder registry:
        nothing stale, nothing missing, nothing diverged."""
        assert sorted(p.stem for p in SPEC_DIR.glob("*.json")) == sorted(
            SCENARIO_BUILDERS
        )
        for name, build in SCENARIO_BUILDERS.items():
            assert load_scenario(str(SPEC_DIR / f"{name}.json")) == build(), (
                f"{name}.json diverged from its builder — re-export with "
                f"save_scenario"
            )

    @pytest.mark.parametrize("name", ["heavy_tail", "diurnal"])
    def test_seed_is_load_bearing(self, name):
        spec = SCENARIO_BUILDERS[name]()
        reseeded = dataclasses.replace(spec, seed=spec.seed + 1)
        assert (compile_scenario(spec).fingerprint()
                != compile_scenario(reseeded).fingerprint())

    def test_time_scale_shrinks_times_and_durations(self):
        """The live runner's knob: every arrival instant and duration
        scales, nothing else changes (same templates, same order)."""
        spec = SCENARIO_BUILDERS["heavy_tail"]()
        full = compile_scenario(spec)
        half = compile_scenario(spec, time_scale=0.5)
        assert len(full.events) == len(half.events)
        for a, b in zip(full.events, half.events):
            assert b.t == pytest.approx(a.t * 0.5)
            assert b.template.base_duration == pytest.approx(
                a.template.base_duration * 0.5)
            assert b.template.trajectory_id == a.template.trajectory_id

    def test_horizon_gated_preview_is_bounded(self):
        """A closed-loop stream without a total compiles a bounded
        preview (the driver draws past it on demand)."""
        spec = fairness_spec()
        compiled = compile_scenario(spec, max_actions=40)
        assert len(compiled.events) == 40 * len(spec.streams)
        assert compiled.totals == (None,) * len(spec.streams)


# ---------------------------------------------------------------------------
# distribution sanity: the in-house samplers produce what specs declare
# ---------------------------------------------------------------------------


class TestDistributions:
    def test_pareto_tail_index_recovered(self):
        """Hill estimator over the top decile of 4000 draws must
        recover the declared tail index (alpha=1.6, infinite variance —
        sample moments would never converge, the tail index does)."""
        d = DurationSpec(kind="pareto", base=0.4, alpha=1.6)
        rng = random.Random(1234)
        draws = sorted((d.sample({}, rng) for _ in range(4000)), reverse=True)
        k = 400
        hill = sum(math.log(draws[i] / draws[k]) for i in range(k)) / k
        assert 1.35 < 1.0 / hill < 1.85

    def test_pareto_scale_is_the_minimum(self):
        d = DurationSpec(kind="pareto", base=0.4, alpha=1.6)
        rng = random.Random(7)
        draws = [d.sample({}, rng) for _ in range(1000)]
        assert min(draws) >= 0.4
        assert min(draws) == pytest.approx(0.4, rel=0.01)

    def test_lognormal_mean_within_tolerance(self):
        mu, sigma = -0.5, 0.6
        d = DurationSpec(kind="lognormal", base=mu, sigma=sigma)
        rng = random.Random(99)
        n = 4000
        mean = sum(d.sample({}, rng) for _ in range(n)) / n
        expected = math.exp(mu + sigma * sigma / 2.0)
        assert abs(mean - expected) < 0.08 * expected

    def test_clamps_respected(self):
        d = DurationSpec(kind="lognormal", base=0.0, sigma=2.0,
                         lo=0.5, hi=3.0)
        rng = random.Random(5)
        draws = [d.sample({}, rng) for _ in range(500)]
        assert min(draws) >= 0.5 and max(draws) <= 3.0
        # with sigma=2 both clamps really engage
        assert 0.5 in draws and 3.0 in draws

    def test_cycle_ladder_matches_legacy_formula(self):
        """The churn bench's duration ladder, 5.0 + (i % 7)."""
        d = DurationSpec(kind="cycle", base=5.0, step=1.0, mod=7)
        rng = random.Random(0)
        assert [d.sample({"seq": i}, rng) for i in range(15)] == [
            5.0 + (i % 7) for i in range(15)
        ]

    def test_sampling_never_touches_global_rng(self):
        """Streams draw from their own seeded Random — the global RNG
        state is irrelevant to compilation."""
        spec = SCENARIO_BUILDERS["heavy_tail"]()
        random.seed(1)
        fp1 = compile_scenario(spec).fingerprint()
        random.seed(2)
        random.random()
        fp2 = compile_scenario(spec).fingerprint()
        assert fp1 == fp2


# ---------------------------------------------------------------------------
# codec: sparse round-trip, compatibility, typed rejection
# ---------------------------------------------------------------------------


def _tiny_spec(**over):
    kw = dict(
        name="tiny",
        pools=(PoolSpec("pool0", kind="pool", cores=2),),
        streams=(StreamSpec(
            mix=MixSpec(pattern=(0,), kinds=(ActionKindSpec(
                name="w", units=(1,),
                duration=DurationSpec(kind="fixed", base=1.0)),)),
            pools=("pool0",), traj="t{seq}"),),
        arrival=ArrivalSpec(kind="burst", n=4),
    )
    kw.update(over)
    return ScenarioSpec(**kw)


class TestCodec:
    def test_sparse_encoding_omits_defaults(self):
        body = encode_scenario(_tiny_spec())["spec"]
        assert "seed" not in body  # seed=0 is the default
        assert "faults" not in body
        assert "seed" in encode_scenario(_tiny_spec(seed=11))["spec"]

    def test_unknown_fields_ignored(self):
        """The wire idiom: additive schema changes never break an old
        decoder."""
        spec = _tiny_spec()
        payload = encode_scenario(spec)
        payload["spec"]["future_field"] = {"nested": True}
        payload["spec"]["arrival"]["frobnicate"] = 7
        payload["spec"]["streams"][0]["mix"]["kinds"][0]["extra"] = "x"
        assert decode_scenario(payload) == spec

    def test_error_is_a_value_error_with_code(self):
        err = ScenarioError("bad_thing", "message")
        assert isinstance(err, ValueError)
        assert err.code == "bad_thing"

    @pytest.mark.parametrize("mutate,code", [
        (lambda p: p.update(v=99), "bad_version"),
        (lambda p: p.update(kind="not_a_spec"), "bad_envelope"),
        (lambda p: p.update(spec=[1, 2]), "bad_field"),
        (lambda p: p["spec"].update(arrival={"kind": "nope"}),
         "bad_arrival"),
        (lambda p: p["spec"]["streams"][0]["mix"]["kinds"][0].update(
            duration={"kind": "weibull"}), "bad_duration"),
        (lambda p: p["spec"]["streams"][0]["mix"]["kinds"][0].update(
            units=[]), "bad_kind"),
        (lambda p: p["spec"]["streams"][0]["mix"].update(pattern=[9]),
         "bad_mix"),
        (lambda p: p["spec"]["pools"][0].update(kind="quantum"),
         "bad_pool"),
        (lambda p: p["spec"].update(pools=[]), "bad_spec"),
        (lambda p: p["spec"]["streams"][0].update(pools=["ghost"]),
         "unknown_pool"),
        (lambda p: p["spec"].update(faults=[{"kind": "gremlin"}]),
         "bad_fault"),
    ])
    def test_malformed_payload_rejected_with_typed_error(self, mutate, code):
        payload = encode_scenario(_tiny_spec())
        mutate(payload)
        with pytest.raises(ScenarioError) as ei:
            decode_scenario(payload)
        assert ei.value.code == code

    def test_non_dict_payload_rejected(self):
        with pytest.raises(ScenarioError) as ei:
            decode_scenario("not a dict")
        assert ei.value.code == "bad_envelope"

    def test_closed_loop_needs_deterministic_durations(self):
        """Refill times are decided by the run, so a stochastic
        duration would couple the stream to scheduling order and break
        replay — rejected at spec construction."""
        with pytest.raises(ScenarioError) as ei:
            _tiny_spec(
                streams=(StreamSpec(
                    mix=MixSpec(pattern=(0,), kinds=(ActionKindSpec(
                        name="w", units=(1,),
                        duration=DurationSpec(kind="pareto", base=0.4)),)),
                    pools=("pool0",), traj="t{seq}"),),
                arrival=ArrivalSpec(kind="closed_loop", prime=4, wave=2,
                                    total=8),
            )
        assert ei.value.code == "closed_loop_stochastic"

    def test_unknown_policy_knob_rejected(self):
        spec = _tiny_spec(policy={"not_a_knob": 1})
        with pytest.raises(ScenarioError) as ei:
            build_policy(spec, gated=True)
        assert ei.value.code == "bad_policy"
        # ungated runs never apply (or validate) the spec's knobs
        assert build_policy(spec) is not None


# ---------------------------------------------------------------------------
# legacy equivalence: the pinned pre-factory bench scenarios
# ---------------------------------------------------------------------------
#
# Frozen copies of the generators + harness loops the scenario factory
# replaced (benchmarks/bench_scheduler.py before the refactor).  The
# equivalence gate below is only meaningful against THIS reference —
# never "fix" these to match the factory; a mismatch means the factory
# broke replay of the legacy workloads.

_LEGACY_APIS = ("google_search", "web_fetch", "pdf_parse", "embed",
                "code_exec", "translate")
_LEGACY_WEIGHTS = {"heavy0": 2.0, "heavy1": 2.0, "light0": 1.0,
                   "light1": 1.0}


def _legacy_churn_action(i):
    kind = i % 8
    if kind == 0:
        return Action(
            name="reward", cost={"cpu": ResourceRequest("cpu", (1, 2, 4, 8))},
            key_resource="cpu", elasticity=AmdahlElasticity(0.05),
            base_duration=5.0 + (i % 7), trajectory_id=f"c{i}",
        )
    if kind == 1:
        return Action(
            name="tool", cost={"cpu": fixed("cpu", 1)},
            base_duration=0.5 + 0.1 * (i % 5), trajectory_id=f"c{i}",
        )
    if kind == 2:
        return Action(
            name="rm:score", cost={"gpu": ResourceRequest("gpu", (1, 2, 4))},
            key_resource="gpu", elasticity=AmdahlElasticity(0.15),
            base_duration=1.0 + 0.25 * (i % 4), service="rm0",
            trajectory_id=f"c{i}",
        )
    api = _LEGACY_APIS[i % len(_LEGACY_APIS)]
    return Action(
        name=f"api:{api}", cost={api: fixed(api, 1)},
        base_duration=0.3 + 0.2 * (i % 3), trajectory_id=f"c{i}",
    )


def _legacy_fleet_action(pool, wave, i):
    rt = f"pool{pool}"
    if i % 3 == 2:
        return Action(
            name="tool", cost={rt: fixed(rt, 1)},
            base_duration=0.5 + 0.1 * (wave % 3),
            trajectory_id=f"p{pool}-{wave}-{i}",
        )
    return Action(
        name="reward", cost={rt: ResourceRequest(rt, (1, 2, 4, 8))},
        key_resource=rt, elasticity=AmdahlElasticity(0.05),
        base_duration=4.0 + 0.5 * ((wave + i) % 4),
        trajectory_id=f"p{pool}-{wave}-{i}",
    )


def _legacy_tenant_action(task, i):
    heavy = task.startswith("heavy")
    i += 3 * (task.endswith("1"))
    if heavy and i % 6 == 5:
        return Action(
            name="rm:score", cost={"gpu": ResourceRequest("gpu", (1, 2, 4))},
            key_resource="gpu", elasticity=AmdahlElasticity(0.15),
            base_duration=1.0 + 0.2 * (i % 3), service="rm0", task_id=task,
            trajectory_id=f"{task}-{i}",
        )
    if heavy:
        return Action(
            name="reward", cost={"cpu": ResourceRequest("cpu", (2, 4, 8))},
            key_resource="cpu", elasticity=AmdahlElasticity(0.08),
            base_duration=3.5 + 0.3 * (i % 4), task_id=task,
            trajectory_id=f"{task}-{i}",
        )
    if i % 8 == 7:
        return Action(
            name="rm:probe", cost={"gpu": fixed("gpu", 1)},
            base_duration=0.3, service="rm0", task_id=task,
            trajectory_id=f"{task}-{i}",
        )
    return Action(
        name="tool", cost={"cpu": fixed("cpu", 1)},
        base_duration=0.4 + 0.1 * (i % 3), task_id=task,
        trajectory_id=f"{task}-{i}",
    )


def _legacy_churn_run(queue, events):
    loop = EventLoop()
    managers = {
        "cpu": CpuManager([CpuNodeSpec("n0", cores=32)]),
        "gpu": GpuManager([GpuNodeSpec("g0")], [ServiceSpec("rm0", 40.0)]),
    }
    for api in _LEGACY_APIS:
        managers[api] = BasicResourceManager(
            ApiResourceSpec(api, mode="concurrency", max_concurrency=3),
            loop.clock,
        )
    orch = Orchestrator(managers, loop=loop, policy=ElasticScheduler(),
                        incremental=True)
    counter = [queue]
    done_since_wave = [0]
    wave = max(8, queue // 4)

    def refill(_fut):
        done_since_wave[0] += 1
        if done_since_wave[0] < wave or counter[0] >= queue + events:
            return
        done_since_wave[0] = 0
        for _ in range(wave):
            if counter[0] >= queue + events:
                break
            i = counter[0]
            counter[0] += 1
            orch.submit(_legacy_churn_action(i)).add_done_callback(refill)

    for i in range(queue):
        fut = orch.submit(_legacy_churn_action(i), delay=0.001 * i)
        fut.add_done_callback(refill)
    orch.run()
    trace = _trace(orch)
    orch.close()
    return trace


def _legacy_fleet_run(queue, waves, cores=8, period_s=4.0, pools=8):
    per_pool = max(1, queue // pools)
    loop = EventLoop()
    managers = {
        f"pool{k}": ResourceManager(f"pool{k}", cores) for k in range(pools)
    }
    orch = Orchestrator(managers, loop=loop, policy=ElasticScheduler(),
                        incremental=True)
    wave_no = [0]

    def submit_wave():
        w = wave_no[0]
        wave_no[0] += 1
        for k in range(pools):
            for i in range(per_pool):
                orch.submit(_legacy_fleet_action(k, w, i))
        if w + 1 < waves:
            orch.loop.call_after(period_s, submit_wave)

    submit_wave()
    orch.run()
    trace = _trace(orch)
    orch.close()
    return trace


def _legacy_fairness_run(fair, horizon, tasks=None):
    tasks = list(tasks or _LEGACY_WEIGHTS)
    loop = EventLoop()
    managers = {
        "cpu": CpuManager([CpuNodeSpec("n0", cores=16)]),
        "gpu": GpuManager([GpuNodeSpec("g0")], [ServiceSpec("rm0", 40.0)]),
    }
    fs = FairSharePolicy(weights=dict(_LEGACY_WEIGHTS)) if fair else None
    orch = Orchestrator(managers, loop=loop, policy=ElasticScheduler(),
                        fair_share=fs)
    wave = 6
    counters = {t: 0 for t in tasks}
    pending_wave = {t: 0 for t in tasks}

    def submit(task, burst):
        for _ in range(burst):
            i = counters[task]
            counters[task] += 1
            fut = orch.submit(_legacy_tenant_action(task, i))
            fut.add_done_callback(lambda _f, t=task: refill(t))

    def refill(task):
        if orch.now >= horizon:
            return
        pending_wave[task] += 1
        if pending_wave[task] >= wave:
            pending_wave[task] = 0
            submit(task, wave)

    for k, t in enumerate(tasks):
        orch.loop.call_after(0.001 * k, lambda t=t: submit(t, 2 * wave))
    orch.run(until=horizon * 2)
    trace = _trace(orch)
    orch.close()
    return trace


class TestLegacyEquivalence:
    def test_pinned_constants_still_current(self):
        """The factory's exported constants must equal the frozen
        legacy values (the benches now import them from scenarios)."""
        assert CHURN_APIS == _LEGACY_APIS
        assert FAIRNESS_WEIGHTS == _LEGACY_WEIGHTS

    def test_churn_spec_reproduces_legacy_trace(self):
        """Mixed agentic churn: closed-loop primes + wave refills over
        cpu/gpu/6-api managers, bit-identical launch trace."""
        spec = churn_spec(queue=32, events=64)
        assert _run_spec(spec) == _legacy_churn_run(queue=32, events=64)

    def test_fleet_churn_spec_reproduces_legacy_trace(self):
        """Synchronized fleet waves over 8 replica pools."""
        spec = fleet_churn_spec(queue=32, waves=4)
        assert _run_spec(spec) == _legacy_fleet_run(queue=32, waves=4)

    def test_fairness_spec_reproduces_legacy_trace(self):
        """Multi-tenant WFQ churn: staggered closed-loop streams,
        horizon-gated refills, weighted fair share enabled."""
        horizon = 30.0
        spec = fairness_spec(horizon_s=horizon)
        fs = build_fair_share(spec)
        assert fs is not None and fs.weight_of("heavy0") == 2.0
        assert (_run_spec(spec, until=horizon * 2)
                == _legacy_fairness_run(fair=True, horizon=horizon))

    def test_fleet_managers_match_legacy_shape(self):
        spec = fleet_churn_spec(queue=32, waves=4)
        managers = build_managers(spec, EventLoop())
        assert sorted(managers) == [f"pool{k}" for k in range(8)]
        assert all(isinstance(m, ResourceManager) for m in managers.values())


# ---------------------------------------------------------------------------
# sim vs live: the structural-equivalence rail (no jax needed here —
# the sleep payload exercises the identical control plane)
# ---------------------------------------------------------------------------


class TestSimVsLive:
    def test_live_run_reproduces_sim_structural_trace(self):
        from repro.core.live import run_live_scenario

        spec = live_smoke_spec()
        compiled = compile_scenario(spec, time_scale=0.1)

        orch = _spec_orch(spec)
        install_scenario(compiled, orch)
        orch.run()
        sim_tr = structural_trace(orch.telemetry.records)
        n_sim = len(orch.telemetry.records)
        orch.close()

        live = run_live_scenario(compiled, use_kernels=False,
                                 wall_limit_s=60.0)
        live_tr = structural_trace(live.telemetry.records)
        assert len(live.telemetry.records) == n_sim
        assert live_tr == sim_tr
        # live timing is real: every completion took measurable wall
        assert all(r.finish > r.start for r in live.telemetry.records)


# ---------------------------------------------------------------------------
# the documented worked examples must decode against the REAL codec
# ---------------------------------------------------------------------------

DOC = REPO / "docs" / "scenarios.md"

#: What docs/scenarios.md promises for its worked examples.
DOC_EXPECTED = {
    "diurnal": (64, "29d36e846b8ec910eaa6328b7310df16b9fc159f"),
    "heavy-tail": (112, "ed97a916ac7687aa1aef9be047516c324d46e653"),
}


def _doc_examples():
    """``<!-- scenario-example: <name> -->`` fenced JSON blocks."""
    out = {}
    for m in re.finditer(
        r"<!--\s*scenario-example:\s*(?P<name>[\w-]+)\s*-->\s*"
        r"```json\n(?P<body>.*?)```",
        DOC.read_text(),
        re.DOTALL,
    ):
        out[m.group("name")] = json.loads(m.group("body"))
    return out


class TestDocumentedExamples:
    def test_doc_exists_and_has_examples(self):
        assert set(DOC_EXPECTED) <= set(_doc_examples())

    @pytest.mark.parametrize("name", sorted(DOC_EXPECTED))
    def test_documented_example_compiles_as_documented(self, name):
        """Decode -> compile -> the exact event count and fingerprint
        the doc prose pins (and the prose really pins them)."""
        payload = _doc_examples()[name]
        spec = decode_scenario(payload)
        compiled = compile_scenario(spec)
        n_events, fingerprint = DOC_EXPECTED[name]
        assert len(compiled.events) == n_events
        assert compiled.fingerprint() == fingerprint
        text = DOC.read_text()
        assert fingerprint in text and str(n_events) in text
        # re-encoding reproduces the documented payload field-for-field
        assert encode_scenario(spec) == payload
