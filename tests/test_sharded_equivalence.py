"""Multi-device equivalence tests for the sharded substrate paths.

The main suite runs on 1 CPU device (the dry-run owns the 512-device
flag), so these tests spawn a subprocess with 8 host devices and assert
the shard_map MoE dispatch and the padded-head attention match their
unsharded oracles bit-for-bit (fwd) and numerically (grads).
"""

import subprocess
import sys
import textwrap

import pytest

_PROLOGUE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.sharding.rules import make_rules
"""


def _run(body: str) -> None:
    proc = subprocess.run(
        [sys.executable, "-c", _PROLOGUE + textwrap.dedent(body)],
        capture_output=True,
        text=True,
        cwd=".",
        timeout=240,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"


@pytest.mark.parametrize("mesh_shape", [(2, 4), (4, 2)])
def test_moe_sharded_matches_global(mesh_shape):
    _run(f"""
    from repro.models import moe
    cfg = dataclasses.replace(
        get_config("granite-moe-3b-a800m"), num_layers=2, d_model=128,
        expert_d_ff=64, num_experts=10, experts_per_token=4,
        capacity_factor=4.0)
    mesh = jax.make_mesh({mesh_shape}, ("data", "model"))
    rules = make_rules(mesh)
    B, S, D = 8, 16, 128
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D)) * 0.1
    params = {{k: jax.random.normal(jax.random.PRNGKey(i), s) * 0.05
              for i, (k, s) in enumerate({{
                  "router": (D, 10), "w_gate": (10, D, 64),
                  "w_up": (10, D, 64), "w_down": (10, 64, D)}}.items())}}
    with mesh:
        y_ref, aux_ref = jax.jit(
            lambda p, x: moe._moe_ffn_global(p, x, cfg, None))(params, x)
        y_sh, aux_sh = jax.jit(
            lambda p, x: moe._moe_ffn_sharded(p, x, cfg, rules))(params, x)
        g = jax.jit(jax.grad(lambda p, x: jnp.sum(
            moe._moe_ffn_sharded(p, x, cfg, rules)[0] ** 2)))(params, x)
        g_ref = jax.jit(jax.grad(lambda p, x: jnp.sum(
            moe._moe_ffn_global(p, x, cfg, None)[0] ** 2)))(params, x)
    assert np.allclose(y_ref, y_sh, atol=1e-5), "forward mismatch"
    for k in aux_ref:
        assert np.allclose(aux_ref[k], aux_sh[k], atol=1e-5), k
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g_ref)):
        assert np.allclose(a, b, atol=2e-4), "grad mismatch"
    print("ok")
    """)


def test_padded_head_attention_matches_unsharded():
    _run("""
    from repro.models.layers import multihead_attention, _pad_plan
    # pad plans for the real indivisible archs on a 16-way axis
    assert _pad_plan(8, 3, 16) == (8, 4)    # granite 24H -> 32
    assert _pad_plan(5, 3, 16) == (8, 4)    # smollm 15H -> 32
    assert _pad_plan(2, 7, 16) == (2, 8)    # internvl2 14H -> 16
    cfg = dataclasses.replace(
        get_config("llama3.2-1b"), num_layers=2, d_model=96,
        num_heads=6, num_kv_heads=2, head_dim=16)
    mesh = jax.make_mesh((2, 4), ("data", "model"))  # 6 % 4 != 0 -> pad
    rules = make_rules(mesh)
    B, S, D, h, kv, hd = 4, 16, 96, 6, 2, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D)) * 0.2
    params = {
        "wq": jax.random.normal(jax.random.PRNGKey(1), (D, h * hd)) * 0.1,
        "wk": jax.random.normal(jax.random.PRNGKey(2), (D, kv * hd)) * 0.1,
        "wv": jax.random.normal(jax.random.PRNGKey(3), (D, kv * hd)) * 0.1,
        "wo": jax.random.normal(jax.random.PRNGKey(4), (h * hd, D)) * 0.1,
    }
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    with mesh:
        y_ref = jax.jit(
            lambda p, x: multihead_attention(p, x, pos, cfg))(params, x)
        y_sh = jax.jit(
            lambda p, x: multihead_attention(p, x, pos, cfg, rules=rules))(params, x)
        g = jax.jit(jax.grad(lambda p, x: jnp.sum(
            multihead_attention(p, x, pos, cfg, rules=rules) ** 2)))(params, x)
    assert np.allclose(y_ref, y_sh, atol=1e-4), "forward mismatch"
    for k, v in g.items():
        assert v.shape == params[k].shape, (k, v.shape)
        assert np.isfinite(np.asarray(v)).all()
    print("ok")
    """)


def test_flat_cache_decode_matches_5d_math():
    """Decode with the flat [B,S,kv*hd] cache reproduces prefill logits."""
    _run("""
    from repro.models.model import build_model
    cfg = get_config("glm4-9b").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B, S = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, cfg.vocab_size)
    state = api.init_decode_state(B, S)
    assert state.k_cache.ndim == 4  # flat layout
    for t in range(S):
        logits, state = api.decode_step(params, state, tokens[:, t:t+1])
    pf_logits, pf_state = api.prefill(params, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(pf_logits, np.float32),
                               np.asarray(logits, np.float32),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(pf_state.k_cache, np.float32),
                               np.asarray(state.k_cache, np.float32),
                               rtol=2e-2, atol=2e-2)
    print("ok")
    """)
