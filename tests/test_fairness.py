"""Multi-tenant fair-share layer: WFQ partition queues, single-task
bit-equivalence with the FCFS path, weighted shares under saturation,
the preempt-scalable shrink, quota caps, per-task telemetry, and the
SimClock relative-epsilon regression."""

import math
import random
import statistics

import pytest

from repro.core.action import Action, AmdahlElasticity, ResourceRequest, fixed, ranged
from repro.core.cluster import ApiResourceSpec, CpuNodeSpec, GpuNodeSpec
from repro.core.fairqueue import FairSharePolicy, PartitionQueue, default_cost
from repro.core.managers.base import ResourceManager
from repro.core.managers.basic import BasicResourceManager
from repro.core.managers.cpu import CpuManager
from repro.core.managers.gpu import GpuManager, ServiceSpec
from repro.core.orchestrator import Orchestrator
from repro.core.scheduler import ElasticScheduler
from repro.core.simulator import EventLoop, SimClock


def _action(task, name="a", units=(1,), dur=1.0, elastic=False, **kw):
    return Action(
        name=name,
        cost={"cpu": ResourceRequest("cpu", tuple(units))},
        key_resource="cpu" if elastic else None,
        elasticity=AmdahlElasticity(0.05) if elastic else None,
        base_duration=dur,
        task_id=task,
        trajectory_id=kw.pop("trajectory_id", f"{task}-t"),
        **kw,
    )


def _trace(orch):
    return sorted(
        (r.name, r.task_id, r.trajectory_id, round(r.submit, 9), round(r.start, 9),
         round(r.finish, 9), tuple(sorted(r.units.items())), r.failed)
        for r in orch.telemetry.records
    )


# ---------------------------------------------------------------------------
# PartitionQueue unit behaviour
# ---------------------------------------------------------------------------


class TestPartitionQueue:
    def test_single_task_is_fcfs(self):
        q = PartitionQueue(fair=True, cost_of=lambda a: 1.0)
        acts = [_action("t0", name=f"a{i}") for i in range(10)]
        for a in acts:
            q.push(a)
        assert [a.name for a in q.ordered()] == [f"a{i}" for i in range(10)]

    def test_at_head_requeue_resumes_front(self):
        for fair in (False, True):
            q = PartitionQueue(fair=fair, cost_of=lambda a: 1.0)
            acts = [_action("t0", name=f"a{i}") for i in range(4)]
            for a in acts:
                q.push(a)
            q.remove(acts[2].uid)
            q.push(acts[2], at_head=True)
            assert [a.name for a in q.ordered()] == ["a2", "a0", "a1", "a3"]

    def test_fcfs_mode_ignores_tasks(self):
        q = PartitionQueue(fair=False)
        names = []
        for i, task in enumerate(["b", "a", "b", "c", "a"]):
            a = _action(task, name=f"x{i}")
            names.append(a.name)
            q.push(a)
        assert [a.name for a in q.ordered()] == names

    def test_weighted_interleave(self):
        """Service order tracks weights: w(A)=2, w(B)=1 with equal costs
        drains ~2 A per B."""
        w = {"A": 2.0, "B": 1.0}
        q = PartitionQueue(
            fair=True, weight_of=lambda a: w[a.task_id], cost_of=lambda a: 1.0
        )
        for i in range(12):
            q.push(_action("A", name=f"A{i}"))
        for i in range(6):
            q.push(_action("B", name=f"B{i}"))
        order = [a.task_id for a in q.ordered()]
        # in any prefix of 3k, A holds ~2/3 of the slots (±1 boundary)
        for k in (3, 6, 9, 12):
            a_count = order[:k].count("A")
            assert abs(a_count - 2 * k / 3) <= 1.0, (k, order)

    def test_served_removal_advances_vtime(self):
        q = PartitionQueue(fair=True, cost_of=lambda a: 1.0)
        a0, a1 = _action("t0"), _action("t0")
        q.push(a0)
        q.push(a1)
        assert q.vtime == 0.0
        q.remove(a1.uid, served=True)
        assert q.vtime == pytest.approx(1.0)  # a1's start tag

    def test_tombstone_compaction(self):
        q = PartitionQueue(fair=True, cost_of=lambda a: 1.0)
        acts = [_action("t0", name=f"a{i}") for i in range(64)]
        for a in acts:
            q.push(a)
        for a in acts[:48]:
            q.remove(a.uid)
        assert q.compactions >= 1
        assert [a.name for a in q.ordered()] == [f"a{i}" for i in range(48, 64)]
        assert len(q) == 16

    def test_default_cost_prices_elastic_min_allocation(self):
        rigid = _action("t", units=(2,), dur=3.0)
        assert default_cost(rigid, "cpu") == pytest.approx(6.0)
        elastic = _action("t", units=(2, 4), dur=3.0, elastic=True)
        # 2 units x dur at DoP 2 (sped up), NOT 2 x base
        expect = 2 * elastic.get_dur(2)
        assert default_cost(elastic, "cpu") == pytest.approx(expect)
        assert default_cost(_action("t"), None) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# WFQ virtual-clock monotonicity across drain/refill (busy-period rule)
# ---------------------------------------------------------------------------


class TestDrainRefill:
    def _drain_all_served(self, q):
        while True:
            head = q.head()
            if head is None:
                return
            q.remove(head.uid, served=True)

    def test_busy_period_end_settles_clock_at_max_finish(self):
        """When the last sub-queue empties, V jumps to the largest finish
        tag charged (monotone) and the finish chains reset."""
        w = {"A": 1.0, "B": 1.0}
        q = PartitionQueue(fair=True, weight_of=lambda a: w[a.task_id],
                           cost_of=lambda a: 10.0)
        for i in range(3):
            q.push(_action("A", name=f"A{i}"))
        self._drain_all_served(q)
        # A was charged F=30; serving only advanced V to A2's START (20)
        # — the busy-period rule must settle the remaining debt
        assert q.vtime == pytest.approx(30.0)
        assert q._task_finish == {}

    def test_refill_after_drain_starts_level(self):
        """Post-drain arrivals start level: a task that burst heavily in
        the PREVIOUS busy period is not still paying its old finish
        chain, and a fresh task cannot back-date to the stale V and
        starve the returning one."""
        w = {"A": 1.0, "B": 1.0}
        q = PartitionQueue(fair=True, weight_of=lambda a: w[a.task_id],
                           cost_of=lambda a: 10.0)
        for i in range(3):
            q.push(_action("A", name=f"A{i}"))
        self._drain_all_served(q)
        v_settled = q.vtime
        a_return = _action("A", name="A-return")
        b_fresh = _action("B", name="B-fresh")
        q.push(a_return)
        q.push(b_fresh)
        sa, sb = q.tag_of(a_return.uid)[0], q.tag_of(b_fresh.uid)[0]
        assert sa == pytest.approx(v_settled)  # debt forgiven at idle
        assert sb == pytest.approx(v_settled)  # no stale back-dated tag
        # FCFS tie-break: the earlier arrival drains first
        assert [a.name for a in q.ordered()] == ["A-return", "B-fresh"]

    def test_vtime_never_leaps_backward_randomized(self):
        """Property test: under random pushes / serves / unserved drops /
        full drains across tasks, the virtual clock is monotone and every
        post-drain arrival's start tag is >= the settled clock."""
        rng = random.Random(42)
        weights = {"a": 2.0, "b": 1.0, "c": 0.5}
        q = PartitionQueue(
            fair=True,
            weight_of=lambda x: weights[x.task_id],
            cost_of=lambda x: x.base_duration,
        )
        live = []
        last_v = 0.0
        for step in range(600):
            op = rng.random()
            if op < 0.5 or not live:
                a = _action(rng.choice(list(weights)), name=f"s{step}",
                            dur=rng.uniform(0.1, 5.0))
                was_empty = len(q) == 0
                q.push(a)
                live.append(a)
                if was_empty:
                    # resume rule: nobody may start before the settled clock
                    assert q.tag_of(a.uid)[0] >= last_v - 1e-12
            elif op < 0.85:
                a = live.pop(rng.randrange(len(live)))
                q.remove(a.uid, served=True)
            else:
                a = live.pop(rng.randrange(len(live)))
                q.remove(a.uid, served=False)  # cancel/withdraw path
            assert q.vtime >= last_v - 1e-12, "virtual clock leapt backward"
            last_v = q.vtime
        assert len(q) == len(live)

    def test_single_task_fcfs_survives_drain_cycles(self):
        """The busy-period rule must not disturb single-tenant FCFS
        order (the bit-equivalence rail)."""
        q = PartitionQueue(fair=True, cost_of=lambda a: 1.0)
        order = []
        for cycle in range(3):
            acts = [_action("t", name=f"c{cycle}-{i}") for i in range(4)]
            for a in acts:
                q.push(a)
            while q.head() is not None:
                order.append(q.head().name)
                q.remove(q.head().uid, served=True)
        assert order == [f"c{c}-{i}" for c in range(3) for i in range(4)]


# ---------------------------------------------------------------------------
# sub-queue detach / merge + virtual-clock sync (the distribution seam)
# ---------------------------------------------------------------------------


class TestDetachMerge:
    def _mk(self):
        w = {"A": 2.0, "B": 1.0}
        return PartitionQueue(fair=True, weight_of=lambda a: w[a.task_id],
                              cost_of=lambda a: 1.0)

    def test_detach_merge_round_trip_preserves_order(self):
        q = self._mk()
        acts = []
        for i in range(6):
            a = _action("A" if i % 2 == 0 else "B", name=f"x{i}")
            acts.append(a)
            q.push(a)
        before = [a.name for a in q.ordered()]
        shard = q.detach_task("A")
        assert shard is not None and len(shard.entries) == 3
        assert all(a.name not in ("x0", "x2", "x4")
                   for a in q.ordered())
        q.merge_shard(shard)
        assert [a.name for a in q.ordered()] == before
        # the finish chain survived: a new A arrival continues it
        a_new = _action("A", name="x-new")
        q.push(a_new)
        assert q.tag_of(a_new.uid)[0] >= shard.finish_tag - 1e-12

    def test_merge_into_fresh_replica_syncs_clock(self):
        src = self._mk()
        for i in range(4):
            src.push(_action("A", name=f"a{i}"))
        src.remove(src.head().uid, served=True)
        src.remove(src.head().uid, served=True)
        shard = src.detach_task("A")
        dst = self._mk()
        dst.merge_shard(shard)
        # clock synced monotonically; tags carried verbatim
        assert dst.vtime >= shard.vtime - 1e-12
        assert [a.name for a in dst.ordered()] == ["a2", "a3"]
        # a local arrival on the replica cannot back-date behind the
        # merged sub-queue's virtual position
        b = _action("B", name="b0")
        dst.push(b)
        assert dst.tag_of(b.uid)[0] >= dst.vtime - 1e-12

    def test_detach_missing_or_empty_task(self):
        q = self._mk()
        assert q.detach_task("nope") is None
        a = _action("A")
        q.push(a)
        q.remove(a.uid, served=True)
        assert q.detach_task("A") is None

    def test_merge_never_double_admits(self):
        q = self._mk()
        a = _action("A", name="dup")
        q.push(a)
        shard = q.detach_task("A")
        q.push(a)  # re-queued locally while the shard was in transit
        q.merge_shard(shard)
        assert len(q) == 1
        assert [x.name for x in q.ordered()] == ["dup"]

    def test_sync_vtime_is_monotone(self):
        q = self._mk()
        q.sync_vtime(5.0)
        assert q.vtime == 5.0
        q.sync_vtime(2.0)  # never backward
        assert q.vtime == 5.0


# ---------------------------------------------------------------------------
# orchestrator equivalence: fairness must be a no-op for one tenant, and
# incremental rounds must stay equivalent to full rescheduling under WFQ
# ---------------------------------------------------------------------------


def _make_system(fair, incremental=True, cores=32, tasks=("task0",)):
    loop = EventLoop()
    managers = {
        "cpu": CpuManager([CpuNodeSpec("n0", cores=cores)]),
        "gpu": GpuManager([GpuNodeSpec("g0")], [ServiceSpec("rm0", 40.0)]),
        "api": BasicResourceManager(
            ApiResourceSpec("api", mode="quota", quota=4, period_s=5.0), loop.clock
        ),
    }
    fs = FairSharePolicy(weights={t: 1.0 + i for i, t in enumerate(tasks)}) if fair else None
    return Orchestrator(managers, loop=loop, incremental=incremental, fair_share=fs)


def _submit_mixed(orch, seed, tasks=("task0",), n=60):
    rng = random.Random(seed)
    for i in range(n):
        task = tasks[i % len(tasks)]
        kind = rng.random()
        delay = rng.uniform(0.0, 5.0)
        if kind < 0.4:
            a = Action(
                name="reward", cost={"cpu": ranged("cpu", 1, 8)}, key_resource="cpu",
                elasticity=AmdahlElasticity(0.08), base_duration=rng.uniform(1, 8),
                task_id=task, trajectory_id=f"{task}-{i}",
            )
        elif kind < 0.6:
            a = Action(
                name="tool", cost={"cpu": fixed("cpu", rng.choice((1, 2)))},
                base_duration=rng.uniform(0.2, 2.0), task_id=task,
                trajectory_id=f"{task}-{i}",
            )
        elif kind < 0.8:
            a = Action(
                name="rm:score", cost={"gpu": ResourceRequest("gpu", (1, 2, 4, 8))},
                key_resource="gpu", elasticity=AmdahlElasticity(0.15),
                base_duration=rng.uniform(0.5, 3.0), service="rm0", task_id=task,
                trajectory_id=f"{task}-{i}",
            )
        else:
            a = Action(
                name="api:q", cost={"api": fixed("api")},
                base_duration=rng.uniform(0.1, 1.0), task_id=task,
                trajectory_id=f"{task}-{i}",
            )
        orch.submit(a, delay=delay)


class TestEquivalence:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_single_task_bit_identical_to_fcfs_path(self, seed):
        """With one tenant, WFQ order == FCFS order, so enabling the
        fairness layer must not change a single launch."""
        fair = _make_system(fair=True)
        fcfs = _make_system(fair=False)
        _submit_mixed(fair, seed)
        _submit_mixed(fcfs, seed)
        fair.run()
        fcfs.run()
        assert len(fair.telemetry.records) == 60
        assert _trace(fair) == _trace(fcfs)

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_incremental_equals_full_under_fair_share(self, seed):
        """Dirty-tracked incremental rounds must launch exactly what full
        rescheduling would, with multi-tenant WFQ queues active."""
        tasks = ("heavy", "light")
        inc = _make_system(fair=True, incremental=True, tasks=tasks)
        full = _make_system(fair=True, incremental=False, tasks=tasks)
        _submit_mixed(inc, seed, tasks=tasks)
        _submit_mixed(full, seed, tasks=tasks)
        inc.run()
        full.run()
        assert _trace(inc) == _trace(full)
        assert inc.queue_depth() == 0 and inc.in_flight() == 0


# ---------------------------------------------------------------------------
# weighted shares + the WFQ no-starvation invariant
# ---------------------------------------------------------------------------


def _saturated_run(fair, weights, horizon=120.0, cores=8):
    loop = EventLoop()
    orch = Orchestrator(
        {"cpu": CpuManager([CpuNodeSpec("n0", cores=cores)])},
        loop=loop,
        fair_share=FairSharePolicy(weights=dict(weights)) if fair else None,
    )
    counters = {t: 0 for t in weights}

    def tenant_action(task, i):
        heavy = task.startswith("heavy")
        return Action(
            name=task[0],
            cost={"cpu": fixed("cpu", 2 if heavy else 1)},
            base_duration=2.0 if heavy else 0.5,
            task_id=task,
            trajectory_id=f"{task}-{i}",
        )

    def submit(task):
        i = counters[task]
        counters[task] += 1
        fut = orch.submit(tenant_action(task, i))

        def refill(_f):
            if orch.now < horizon:
                submit(task)

        fut.add_done_callback(refill)

    for t in weights:
        for _ in range(6):
            submit(t)
    orch.run(until=horizon * 1.5)
    return orch


WEIGHTS = {"heavy0": 2.0, "heavy1": 2.0, "light0": 1.0, "light1": 1.0}


class TestWeightedShares:
    def test_shares_track_weights_within_10pct(self):
        orch = _saturated_run(True, WEIGHTS)
        share = orch.telemetry.task_share("cpu", until=120.0)
        wsum = sum(WEIGHTS.values())
        for task, w in WEIGHTS.items():
            target = w / wsum
            assert abs(share.get(task, 0.0) - target) / target <= 0.10, (task, share)

    def test_fcfs_ablation_does_not_track_weights(self):
        orch = _saturated_run(False, WEIGHTS)
        share = orch.telemetry.task_share("cpu", until=120.0)
        wsum = sum(WEIGHTS.values())
        err = max(
            abs(share.get(t, 0.0) - w / wsum) / (w / wsum) for t, w in WEIGHTS.items()
        )
        assert err > 0.10  # the pathology the fairness layer removes

    def test_light_tenant_interference_drops_2x(self):
        fair = _saturated_run(True, WEIGHTS)
        fcfs = _saturated_run(False, WEIGHTS)
        light_fair = statistics.fmean(
            fair.telemetry.mean_act(t) for t in ("light0", "light1")
        )
        light_fcfs = statistics.fmean(
            fcfs.telemetry.mean_act(t) for t in ("light0", "light1")
        )
        assert light_fcfs / light_fair >= 2.0

    def test_no_unbounded_backlog_aging(self):
        """WFQ invariant: while a heavy tenant floods, a backlogged light
        tenant's worst queueing age stays bounded near its service period
        — it does not grow with the heavy backlog as under FCFS."""
        fair = _saturated_run(True, WEIGHTS)
        fcfs = _saturated_run(False, WEIGHTS)
        for t in ("light0", "light1"):
            assert fair.telemetry.max_queue_dur(t) < fcfs.telemetry.max_queue_dur(t) / 2
        # and no task ever launched more than weight-share while another
        # backlogged task starved: worst light age is a few service quanta
        assert max(fair.telemetry.max_queue_dur(t) for t in ("light0", "light1")) < 10.0


# ---------------------------------------------------------------------------
# preempt_scalable: shrink the rich before deferring the poor
# ---------------------------------------------------------------------------


def _elastic(task, units, dur):
    return Action(
        name=f"{task}-a", cost={"cpu": ResourceRequest("cpu", tuple(units))},
        key_resource="cpu", elasticity=AmdahlElasticity(0.05), base_duration=dur,
        task_id=task, trajectory_id=task,
    )


class TestPreemptScalable:
    def _arrange(self, preempt):
        mgr = ResourceManager("cpu", 8)
        mgr.note_allocated("rich", 6)  # rich already holds most of the pool
        sched = ElasticScheduler(fair_share=FairSharePolicy(preempt_scalable=preempt))
        running = _elastic("rich", (2,), 5.0)
        running.start_time, running.finish_time = 0.0, 4.0
        rich = _elastic("rich", (2, 4, 8), 100.0)
        poor = _elastic("poor", (2, 4), 3.0)
        return sched.arrange([rich, poor], [], [running], {"cpu": mgr}, 0.0)

    def test_without_preempt_poor_is_deferred(self):
        res = self._arrange(preempt=False)
        assert res.evicted == 1
        assert [(d.action.task_id, d.units["cpu"]) for d in res.decisions] == [
            ("rich", 8)
        ]

    def test_preempt_shrinks_rich_and_keeps_poor(self):
        res = self._arrange(preempt=True)
        assert res.evicted == 0
        got = {d.action.task_id: d.units["cpu"] for d in res.decisions}
        assert got["rich"] == 2  # clamped to min units
        assert got["poor"] == 4  # under-share work launches instead

    def test_share_bands(self):
        mgr = ResourceManager("cpu", 8)
        mgr.note_allocated("rich", 6)
        mgr.note_allocated("poor", 1)
        sched = ElasticScheduler(fair_share=FairSharePolicy())
        group = [_elastic("rich", (2, 4), 5.0), _elastic("poor", (2, 4), 5.0)]
        over, under = sched._share_bands(group, [], mgr)
        assert over == {"rich"} and under == {"poor"}
        # uniform usage -> nobody over-share
        mgr2 = ResourceManager("cpu", 8)
        mgr2.note_allocated("a", 2)
        mgr2.note_allocated("b", 2)
        over2, under2 = sched._share_bands(
            [_elastic("a", (2,), 1.0), _elastic("b", (2,), 1.0)], [], mgr2
        )
        assert over2 == set()

    def test_usage_accounting_roundtrip(self):
        mgr = ResourceManager("cpu", 8)
        mgr.note_allocated("a", 3)
        mgr.note_allocated("a", 2)
        assert mgr.task_usage() == {"a": 5}
        mgr.note_released("a", 3)
        assert mgr.task_usage() == {"a": 2}
        mgr.note_released("a", 2)
        assert mgr.task_usage() == {}


# ---------------------------------------------------------------------------
# weighted DPArrange: dense and reference stay bit-identical
# ---------------------------------------------------------------------------


class TestWeightedDP:
    def test_dense_matches_ref_with_weights(self):
        from repro.core.dparrange import (
            BasicDPOperator,
            DPTask,
            dp_arrange_prefixes_dense,
            dp_arrange_prefixes_ref,
        )

        rng = random.Random(11)
        for _ in range(20):
            m = rng.randint(1, 5)
            tasks = []
            for i in range(m):
                units = tuple(sorted(rng.sample(range(1, 9), rng.randint(1, 3))))
                tasks.append(
                    DPTask(
                        name=str(i),
                        units=units,
                        durations=tuple(rng.uniform(0.5, 20.0) for _ in units),
                    )
                )
            weights = tuple(rng.choice((0.5, 1.0, 2.0, 3.0)) for _ in range(m))
            op = BasicDPOperator(rng.randint(4, 24))
            dense = dp_arrange_prefixes_dense(tasks, op, weights=weights)
            ref = dp_arrange_prefixes_ref(tasks, op, weights=weights)
            assert dense is not None
            for d, r in zip(dense, ref):
                assert (d is None) == (r is None)
                if d is not None:
                    assert d.total_duration == r.total_duration  # bit-identical
                    # reported durations are TRUE durations, not weighted
                    for name, k in d.allocation.items():
                        t = tasks[int(name)]
                        assert d.durations[name] == t.durations[t.units.index(k)]

    def test_uniform_weights_equal_unweighted(self):
        from repro.core.dparrange import BasicDPOperator, DPTask, dp_arrange

        tasks = [
            DPTask(name="0", units=(1, 2, 4), durations=(8.0, 4.4, 2.6)),
            DPTask(name="1", units=(1, 2), durations=(3.0, 1.7)),
        ]
        op = BasicDPOperator(6)
        plain = dp_arrange(tasks, op)
        uniform = dp_arrange(tasks, op, weights=(1.0, 1.0))
        assert plain.total_duration == uniform.total_duration
        assert plain.allocation == uniform.allocation


# ---------------------------------------------------------------------------
# quota caps
# ---------------------------------------------------------------------------


class TestQuota:
    def test_quota_caps_concurrent_share(self):
        """quota=0.5 on an 8-core pool: the capped tenant never holds
        more than 4 cores even with the pool otherwise idle."""
        loop = EventLoop()
        orch = Orchestrator(
            {"cpu": CpuManager([CpuNodeSpec("n0", cores=8)])},
            loop=loop,
            fair_share=FairSharePolicy(quota={"greedy": 0.5}),
        )
        peak = [0]
        for i in range(6):
            fut = orch.submit(
                Action(name="g", cost={"cpu": fixed("cpu", 2)}, base_duration=1.0,
                       task_id="greedy", trajectory_id=f"g{i}")
            )
            fut.add_done_callback(
                lambda _f: peak.__setitem__(
                    0, max(peak[0], orch.managers["cpu"].task_usage().get("greedy", 0))
                )
            )
        orch.run()
        assert orch.queue_depth() == 0  # everything eventually runs
        assert peak[0] <= 4
        assert orch.stats["quota_deferrals"] > 0

    def test_sub_min_quota_degrades_to_serial_not_deadlock(self):
        """A quota smaller than an action's min requirement must run the
        actions one at a time, not strand them forever (review fix)."""
        loop = EventLoop()
        orch = Orchestrator(
            {"cpu": CpuManager([CpuNodeSpec("n0", cores=16)])},
            loop=loop,
            fair_share=FairSharePolicy(quota={"t": 0.1}),  # cap 1.6 < min 2
        )
        futs = [
            orch.submit(
                Action(name="a", cost={"cpu": fixed("cpu", 2)}, base_duration=1.0,
                       task_id="t", trajectory_id=f"t{i}")
            )
            for i in range(3)
        ]
        end = orch.run()
        assert all(f.done() for f in futs)
        assert orch.queue_depth() == 0
        # serialized: ~one at a time, so makespan spans >= 3 durations
        assert end >= 3.0

    def test_quota_clamps_elastic_scale_up(self):
        """The quota cap binds scalable grants too: a lone elastic action
        cannot scale past its task's budget (review fix)."""
        loop = EventLoop()
        orch = Orchestrator(
            {"cpu": CpuManager([CpuNodeSpec("n0", cores=16)])},
            loop=loop,
            fair_share=FairSharePolicy(quota={"t": 0.25}),  # cap = 4 units
        )
        orch.submit(
            Action(name="r", cost={"cpu": ResourceRequest("cpu", (1, 2, 4, 8, 16))},
                   key_resource="cpu", elasticity=AmdahlElasticity(0.05),
                   base_duration=4.0, task_id="t", trajectory_id="t0")
        )
        orch.run()
        (rec,) = orch.telemetry.records
        assert rec.units["cpu"] <= 4

    @pytest.mark.parametrize("shards", [None, 2])
    def test_quota_exact_under_concurrent_scale_up(self, shards):
        """Exact quota for scalable scale-up (ROADMAP item): several
        co-scheduled DoP-8-scalable actions of one quota'd tenant must
        never jointly exceed the cap mid-flight.  Before the fix, the
        first launch ate the whole budget and its siblings' min-unit
        progress rail pushed the task past the cap."""
        loop = EventLoop()
        mgr = CpuManager([CpuNodeSpec("n0", cores=16)])
        orch = Orchestrator(
            {"cpu": mgr}, loop=loop,
            fair_share=FairSharePolicy(quota={"t": 0.5}),  # cap = 8 units
            shards=shards,
        )
        peak = [0]
        orig = mgr.note_allocated

        def spy(task_id, units):
            orig(task_id, units)
            peak[0] = max(peak[0], mgr.task_usage().get("t", 0))

        mgr.note_allocated = spy
        futs = [
            orch.submit(
                Action(name=f"r{i}",
                       cost={"cpu": ResourceRequest("cpu", (1, 2, 4, 8))},
                       key_resource="cpu", elasticity=AmdahlElasticity(0.05),
                       base_duration=6.0, task_id="t", trajectory_id=f"t{i}")
            )
            for i in range(3)
        ]
        orch.run()
        assert all(f.done() for f in futs)
        assert peak[0] <= 8, f"quota cap exceeded mid-flight: {peak[0]} > 8"
        assert peak[0] >= 4  # the budget is still being used, not starved
        mgr.check_occupancy()


# ---------------------------------------------------------------------------
# per-task telemetry + live starvation ages
# ---------------------------------------------------------------------------


class TestPerTaskTelemetry:
    def test_per_task_breakdown(self):
        orch = _saturated_run(True, WEIGHTS, horizon=30.0)
        per = orch.telemetry.per_task("cpu")
        assert set(per) == set(WEIGHTS)
        for task, row in per.items():
            assert row["completed"] > 0
            assert not math.isnan(row["mean_act"])
            assert 0.0 < row["share"] < 1.0
            assert row["max_queue_dur"] >= 0.0
        # per-task mean ACT composes back to the global one
        acts = [orch.telemetry.mean_act(t) for t in WEIGHTS]
        assert min(acts) <= orch.telemetry.mean_act() <= max(acts)

    def test_live_starvation_ages(self):
        loop = EventLoop()
        orch = Orchestrator(
            {"cpu": CpuManager([CpuNodeSpec("n0", cores=2)])}, loop=loop,
            fair_share=FairSharePolicy(),
        )
        orch.submit(_action("busy", units=(2,), dur=50.0))
        orch.submit(_action("starved", units=(2,), dur=1.0))
        orch.run(until=10.0)
        ages = orch.starvation_ages()
        assert ages.get("starved", 0.0) == pytest.approx(10.0)
        assert "busy" not in ages  # running, not queued

    def test_task_share_until_window(self):
        orch = _saturated_run(True, WEIGHTS, horizon=30.0)
        inside = orch.telemetry.task_share("cpu", until=30.0)
        assert inside and abs(sum(inside.values()) - 1.0) < 1e-9


# ---------------------------------------------------------------------------
# SimClock relative-epsilon regression (satellite fix)
# ---------------------------------------------------------------------------


class TestSimClockEpsilon:
    def test_ulp_jitter_at_large_time_does_not_raise(self):
        """Coalesced same-timestamp events can disagree by a few ulps at
        large virtual times; the old absolute 1e-12 guard raised 'time
        went backwards' on them."""
        clock = SimClock()
        t = 1.0e6
        clock._advance(t)
        jitter = t - 5 * math.ulp(t)  # well beyond 1e-12, within rel eps
        clock._advance(jitter)  # must not raise
        assert clock.now() == t

    def test_true_backwards_still_raises(self):
        clock = SimClock()
        clock._advance(1.0e6)
        with pytest.raises(RuntimeError):
            clock._advance(1.0e6 - 1.0)

    def test_call_at_tolerates_ulp_past(self):
        loop = EventLoop()
        loop.clock._advance(1.0e6)
        fired = []
        loop.call_at(1.0e6 - 5 * math.ulp(1.0e6), lambda: fired.append(1))
        loop.run()
        assert fired == [1]
        with pytest.raises(ValueError):
            loop.call_at(1.0e6 - 1.0, lambda: None)

    def test_float_accumulation_round_trip(self):
        """Timestamps reached via different float-sum paths coalesce into
        one round instead of crashing the loop."""
        loop = EventLoop()
        base = 1.0e6  # long-run virtual time, ulp(base) >> 1e-12
        # two logically simultaneous timestamps whose float-sum paths
        # disagree by a few ulps (far more than the old 1e-12 guard)
        t2 = base + 0.3
        t1 = t2 - 3 * math.ulp(t2)
        assert t1 != t2 and abs(t1 - t2) > 1e-12
        order = []
        loop.call_at(t2, lambda: order.append("late"))
        loop.call_at(t1, lambda: order.append("early"))
        end = loop.run()
        assert order == ["early", "late"]
        assert end == pytest.approx(base + 0.3)
