"""Wire-protocol codecs: round-trip identity, schema enforcement, and
the docs/wire-protocol.md worked example validated against the real
codecs (so the documentation cannot rot silently)."""

import json
import math
import re
from pathlib import Path

import pytest

from repro.core import wire
from repro.core.action import (
    Action,
    ActionState,
    AmdahlElasticity,
    Elasticity,
    LinearElasticity,
    ResourceRequest,
    TableElasticity,
    fixed,
)
from repro.core.baselines import FcfsPolicy, StaticDopPolicy
from repro.core.cluster import ApiResourceSpec, CpuNodeSpec, GpuNodeSpec
from repro.core.fairqueue import FairSharePolicy, PartitionQueue
from repro.core.managers.base import ResourceManager
from repro.core.managers.basic import BasicResourceManager
from repro.core.managers.cpu import CpuManager
from repro.core.managers.gpu import GpuManager, ServiceSpec
from repro.core.scheduler import Decision, ElasticScheduler, ScheduleResult
from repro.core.shards import PartitionPlan
from repro.core.simulator import EventLoop


def roundtrip(payload):
    """Through the actual byte boundary, not just dict->dict."""
    return wire.loads(wire.dumps(payload))


def wire_equal(a, b):
    """Payload equality modulo NaN (NaN != NaN under ==)."""
    return wire.dumps(a) == wire.dumps(b)


# ---------------------------------------------------------------------------
# actions
# ---------------------------------------------------------------------------


class TestActionCodec:
    def _rich_action(self):
        return Action(
            name="reward",
            cost={
                "cpu": ResourceRequest("cpu", (2, 4, 8)),
                "api": fixed("api", 1),
            },
            key_resource="cpu",
            elasticity=AmdahlElasticity(0.07),
            base_duration=3.25,
            task_id="tenant-a",
            trajectory_id="traj-9",
            weight=2.5,
            service="rm0",
            timeout_s=12.0,
            max_retries=2,
            metadata={"traj_mem_gb": 6.0, "stage": "rollout"},
        )

    def test_round_trip_identity(self):
        a = self._rich_action()
        a.state = ActionState.QUEUED
        a.submit_time = 41.5
        a.attempts = 1
        b = wire.decode_action(roundtrip(wire.encode_action(a)))
        assert wire_equal(wire.encode_action(b), wire.encode_action(a))
        assert b.uid == a.uid
        assert b.cost["cpu"].units == (2, 4, 8)
        assert b.elasticity.serial == pytest.approx(0.07)
        assert b.state is ActionState.QUEUED
        assert b.submit_time == 41.5
        assert b.weight == 2.5
        # schedulable surface identical where it matters for the DP
        assert b.get_dur(4) == a.get_dur(4)
        assert b.scalable and b.key_units() == a.key_units()

    def test_nan_timestamps_survive(self):
        a = Action(name="t", cost={"r": fixed("r")}, trajectory_id="x")
        b = wire.decode_action(roundtrip(wire.encode_action(a)))
        assert math.isnan(b.submit_time) and math.isnan(b.finish_time)

    def test_callables_do_not_cross(self):
        a = Action(
            name="t", cost={"r": fixed("r")}, trajectory_id="x",
            fn=lambda: None, duration_sampler=lambda m: 1.0,
        )
        b = wire.decode_action(wire.encode_action(a))
        assert b.fn is None and b.duration_sampler is None

    def test_metadata_filtered_to_scalars(self):
        a = Action(
            name="t", cost={"r": fixed("r")}, trajectory_id="x",
            metadata={"traj_mem_gb": 2.0, "_dp_durs": ((1,), (1.0,)),
                      "blob": object(), "tag": "ok"},
        )
        b = wire.decode_action(wire.encode_action(a))
        assert b.metadata == {"traj_mem_gb": 2.0, "tag": "ok"}

    @pytest.mark.parametrize(
        "el",
        [
            AmdahlElasticity(0.12),
            TableElasticity(((1, 1.0), (4, 0.8), (8, 0.6))),
            LinearElasticity(),
        ],
    )
    def test_elasticity_models(self, el):
        back = wire.decode_elasticity(roundtrip(wire.encode_elasticity(el)))
        for m in (1, 2, 4, 8):
            assert back.ratio(m) == pytest.approx(el.ratio(m))

    def test_custom_elasticity_rejected(self):
        class Weird(Elasticity):
            def ratio(self, m):
                return 1.0

        with pytest.raises(wire.WireError, match="not wire-serializable"):
            wire.encode_elasticity(Weird())


# ---------------------------------------------------------------------------
# envelopes / schema enforcement
# ---------------------------------------------------------------------------


class TestSchema:
    def test_version_mismatch_rejected(self):
        p = wire.encode_action(Action(name="t", cost={}, trajectory_id="x"))
        p["v"] = wire.WIRE_VERSION + 1
        with pytest.raises(wire.WireError, match="wire version"):
            wire.decode_action(p)

    def test_kind_mismatch_rejected(self):
        p = wire.encode_action(Action(name="t", cost={}, trajectory_id="x"))
        with pytest.raises(wire.WireError, match="expected kind"):
            wire.decode_task_shard(p)

    def test_missing_field_is_wire_error(self):
        p = wire.encode_action(Action(name="t", cost={}, trajectory_id="x"))
        del p["cost"]
        with pytest.raises(wire.WireError, match="missing required field"):
            wire.decode_action(p)

    def test_unknown_fields_ignored(self):
        """Additive evolution: decoders skip fields they don't know."""
        p = wire.encode_action(Action(name="t", cost={}, trajectory_id="x"))
        p["future_field"] = {"anything": 1}
        wire.decode_action(p)  # must not raise

    def test_malformed_blob_is_wire_error(self):
        with pytest.raises(wire.WireError, match="malformed"):
            wire.loads("{not json")

    def test_non_dict_payload_rejected(self):
        with pytest.raises(wire.WireError, match="must be a dict"):
            wire.expect([1, 2], "action")

    def test_unknown_action_state_rejected(self):
        p = wire.encode_action(Action(name="t", cost={}, trajectory_id="x"))
        p["state"] = "levitating"
        with pytest.raises(wire.WireError, match="unknown state"):
            wire.decode_action(p)


# ---------------------------------------------------------------------------
# plans / decisions (uid re-binding)
# ---------------------------------------------------------------------------


class TestPlanCodec:
    def test_plan_round_trip_rebinds_live_actions(self):
        a = Action(name="a", cost={"r": fixed("r", 2)}, trajectory_id="t0")
        b = Action(name="b", cost={"r": ResourceRequest("r", (1, 4))},
                   trajectory_id="t1")
        plan = PartitionPlan(
            "r",
            result=ScheduleResult(
                decisions=[Decision(a, {"r": 2}), Decision(b, {"r": 4})],
                objective=7.5,
                evicted=1,
            ),
            held=2,
            wall_s=0.003,
            shard=1,
        )
        back = wire.decode_plan(
            roundtrip(wire.encode_plan(plan)), wire.uid_index([a, b])
        )
        # decisions are re-bound to the SAME live objects, not copies
        assert back.result.decisions[0].action is a
        assert back.result.decisions[1].action is b
        assert back.result.decisions[1].units == {"r": 4}
        assert back.result.objective == 7.5 and back.result.evicted == 1
        assert (back.part, back.held, back.shard, back.planned) == ("r", 2, 1, True)

    def test_unknown_uid_rejected(self):
        a = Action(name="a", cost={"r": fixed("r")}, trajectory_id="t0")
        plan = PartitionPlan("r", result=ScheduleResult([Decision(a, {"r": 1})]))
        payload = wire.encode_plan(plan)
        with pytest.raises(wire.WireError, match="unknown action uid"):
            wire.decode_plan(payload, {})

    def test_quota_hold_plan(self):
        plan = PartitionPlan("r", result=None, held=3)
        back = wire.decode_plan(roundtrip(wire.encode_plan(plan)), {})
        assert back.result is None and back.held == 3 and back.planned


# ---------------------------------------------------------------------------
# TaskShard (sub-queue migration payload)
# ---------------------------------------------------------------------------


class TestTaskShardCodec:
    def test_round_trip_preserves_tags_and_order(self):
        q = PartitionQueue(fair=True, cost_of=lambda a: 2.0)
        actions = [
            Action(name=f"x{i}", cost={"r": fixed("r")}, task_id="mover",
                   trajectory_id=f"t{i}")
            for i in range(4)
        ]
        for a in actions:
            q.push(a)
        shard = q.detach_task("mover")
        back = wire.decode_task_shard(roundtrip(wire.encode_task_shard(shard)))
        assert back.task_id == "mover"
        assert back.vtime == shard.vtime
        assert back.finish_tag == shard.finish_tag
        assert [k for k, _ in back.entries] == [k for k, _ in shard.entries]
        assert [a.uid for _, a in back.entries] == [a.uid for a in actions]
        # and it merges into a replica queue like the original would
        replica = PartitionQueue(fair=True)
        replica.merge_shard(back)
        assert [a.uid for a in replica.ordered()] == [a.uid for a in actions]
        assert replica.vtime >= shard.vtime


# ---------------------------------------------------------------------------
# manager snapshots
# ---------------------------------------------------------------------------


def _loaded_managers():
    loop = EventLoop()
    ms = {
        "pool": ResourceManager("pool", 16),
        "cpu": CpuManager(
            [CpuNodeSpec("n0", cores=16, memory_gb=64.0),
             CpuNodeSpec("n1", cores=8, numa_nodes=1, memory_gb=32.0)]
        ),
        "gpu": GpuManager([GpuNodeSpec("g0")], [ServiceSpec("rm0", 40.0)]),
        "api": BasicResourceManager(
            ApiResourceSpec("api", mode="quota", quota=6, period_s=9.0), loop.clock
        ),
    }
    # dirty every manager so the snapshots carry non-trivial state
    ms["pool"].note_allocated("t", 3)
    ms["pool"]._in_use = 3
    ms["cpu"].try_allocate(
        Action(name="c", cost={"cpu": fixed("cpu", 3)}, trajectory_id="tr0",
               metadata={"traj_mem_gb": 8.0}),
        3,
    )
    ms["gpu"].allocators["g0"].allocate(2, ("rm0", 2), 1.5)
    ms["api"].try_allocate(
        Action(name="q", cost={"api": fixed("api")}, trajectory_id="tr1"), 2
    )
    return ms


class TestSnapshotCodec:
    @pytest.mark.parametrize("rtype", ["pool", "cpu", "gpu", "api"])
    def test_round_trip_identity(self, rtype):
        m = _loaded_managers()[rtype]
        enc = wire.encode_snapshot(m)
        back = wire.decode_snapshot(roundtrip(enc))
        # encode(restore(encode(m))) == encode(m): the codec is lossless
        assert wire_equal(wire.encode_snapshot(back), enc)

    @pytest.mark.parametrize("rtype", ["pool", "cpu", "gpu", "api"])
    def test_plan_surface_matches_in_process_snapshot(self, rtype):
        m = _loaded_managers()[rtype]
        snap = m.snapshot()
        back = wire.decode_snapshot(wire.encode_snapshot(m))
        assert back.available == snap.available
        assert back.capacity == snap.capacity
        assert back.task_usage() == snap.task_usage()
        assert back.dp_cache_key([]) == snap.dp_cache_key([])
        probe = Action(
            name="p", cost={rtype: fixed(rtype, 1)}, trajectory_id="fresh",
        )
        cur_a, cur_b = snap.begin_admission(), back.begin_admission()
        assert snap.admit_one(cur_a, probe) == back.admit_one(cur_b, probe)

    def test_cpu_snapshot_binding_stays_remote(self):
        """partition() on a decoded snapshot binds trajectories on the
        decoded copy only — the live manager never hears about it."""
        ms = _loaded_managers()
        back = wire.decode_snapshot(wire.encode_snapshot(ms["cpu"]))
        a = Action(name="x", cost={"cpu": fixed("cpu", 2)}, trajectory_id="tX")
        back.partition([a])
        assert back.node_of("tX") is not None
        assert ms["cpu"].node_of("tX") is None

    def test_quota_snapshot_pins_clock(self):
        """A decoded quota snapshot reads the tokens of the instant it
        was taken — its frozen clock cannot drift mid-plan."""
        ms = _loaded_managers()
        back = wire.decode_snapshot(wire.encode_snapshot(ms["api"]))
        assert back.available == ms["api"].available == 4
        assert back.time_to_next_refill() == pytest.approx(
            ms["api"].time_to_next_refill()
        )

    def test_custom_subclass_uses_family_codec(self):
        class Custom(ResourceManager):
            pass

        m = Custom("x", 4)
        m.note_allocated("t", 1)
        m._in_use = 1
        back = wire.decode_snapshot(wire.encode_snapshot(m))
        assert back.available == 3 and back.task_usage() == {"t": 1}

    def test_unknown_impl_rejected(self):
        p = wire.encode_snapshot(ResourceManager("x", 4))
        p["impl"] = "quantum"
        with pytest.raises(wire.WireError, match="unknown snapshot impl"):
            wire.decode_snapshot(p)

    def test_manager_without_codec_rejected(self):
        class NoWire(ResourceManager):
            wire_impl = None

        with pytest.raises(wire.WireError, match="no wire snapshot impl"):
            wire.encode_snapshot(NoWire("x", 4))


# ---------------------------------------------------------------------------
# policy / fairness config
# ---------------------------------------------------------------------------


class TestPolicyCodec:
    def test_elastic_round_trip(self):
        p = ElasticScheduler(depth=3, candidate_limit=64,
                             estimate_units="dp_avg", cache_dp=True)
        p.eviction_search = "exhaustive"
        p.use_dense = False
        p.dop_floor = 2
        p.fair_share = FairSharePolicy(weights={"a": 2.0}, quota={"a": 0.5})
        back = wire.decode_policy(roundtrip(wire.encode_policy(p)))
        assert isinstance(back, ElasticScheduler)
        for attr in ("depth", "candidate_limit", "estimate_units",
                     "eviction_search", "cache_dp", "use_dense",
                     "dense_backend", "dop_floor", "floor_pressure"):
            assert getattr(back, attr) == getattr(p, attr), attr
        assert back.fair_share.weights == {"a": 2.0}
        assert back.fair_share.quota == {"a": 0.5}

    def test_baseline_policies_round_trip(self):
        back = wire.decode_policy(roundtrip(wire.encode_policy(
            FcfsPolicy(candidate_limit=7))))
        assert isinstance(back, FcfsPolicy) and back.candidate_limit == 7
        back = wire.decode_policy(roundtrip(wire.encode_policy(
            StaticDopPolicy(dop=8, candidate_limit=9))))
        assert isinstance(back, StaticDopPolicy)
        assert back.dop == 8 and back.candidate_limit == 9

    def test_custom_policy_rejected(self):
        class MyPolicy:
            candidate_limit = 4

        with pytest.raises(wire.WireError, match="not wire-serializable"):
            wire.encode_policy(MyPolicy())

    def test_fair_share_none_round_trips(self):
        assert wire.encode_fair_share(None) is None
        assert wire.decode_fair_share(None) is None


# ---------------------------------------------------------------------------
# fingerprints (snapshot-delta suppression)
# ---------------------------------------------------------------------------


class TestFingerprint:
    def test_stable_across_key_order(self):
        assert wire.fingerprint({"a": 1, "b": 2}) == wire.fingerprint(
            {"b": 2, "a": 1}
        )

    def test_state_change_rotates(self):
        m = ResourceManager("r", 8)
        fp0 = wire.fingerprint(wire.encode_snapshot(m))
        m.try_allocate(Action(name="x", cost={"r": fixed("r")},
                              trajectory_id="t"), 2)
        assert wire.fingerprint(wire.encode_snapshot(m)) != fp0


# ---------------------------------------------------------------------------
# the documented worked example must decode against the REAL codecs
# ---------------------------------------------------------------------------

DOC = Path(__file__).resolve().parent.parent / "docs" / "wire-protocol.md"


def _doc_examples():
    """``<!-- wire-example: <name> -->`` fenced JSON blocks from the
    wire-protocol doc, as (name, parsed payload) pairs."""
    text = DOC.read_text()
    out = {}
    for m in re.finditer(
        r"<!--\s*wire-example:\s*(?P<name>[\w-]+)\s*-->\s*```json\n(?P<body>.*?)```",
        text,
        re.DOTALL,
    ):
        out[m.group("name")] = json.loads(m.group("body"))
    return out


class TestDocumentedExample:
    def test_doc_exists_and_has_examples(self):
        examples = _doc_examples()
        assert {"action", "snapshot", "plan-request", "plan-response"} <= set(
            examples
        ), f"wire-protocol.md examples incomplete: {sorted(examples)}"

    def test_documented_action_decodes(self):
        a = wire.decode_action(_doc_examples()["action"])
        assert a.scalable and a.key_resource == "cpu"
        # and re-encoding reproduces the documented payload field-for-field
        assert wire.encode_action(a) == _doc_examples()["action"]

    def test_documented_snapshot_decodes(self):
        m = wire.decode_snapshot(_doc_examples()["snapshot"])
        assert m.available >= 0
        assert wire.encode_snapshot(m) == _doc_examples()["snapshot"]

    def test_documented_round_replays_through_a_real_worker(self):
        """The doc's plan-request example, fed to a real RemoteShardWorker,
        must produce exactly the documented plan-response (modulo the
        measured timing fields)."""
        from repro.core.remote import RemoteShardWorker

        examples = _doc_examples()
        worker = RemoteShardWorker()
        resp = wire.loads(worker.handle(wire.dumps(examples["plan-request"])))
        assert resp["kind"] == "plan_response", resp
        documented = examples["plan-response"]
        for got, want in zip(resp["plans"], documented["plans"], strict=True):
            got = dict(got)
            want = dict(want)
            got.pop("wall_s"), want.pop("wall_s")  # measured, not schema
            assert got == want

    def test_documented_delta_and_ref_rounds_replay(self):
        """The doc's incremental sequence — full request, then snapshot-delta +
        intern-define request, then the all-refs steady-state request — must
        replay through one worker, each round yielding a plan_response, with
        the ref round planning identically to the delta round off pure cached
        state."""
        from repro.core.remote import RemoteShardWorker

        examples = _doc_examples()
        assert {"plan-request-delta", "plan-request-ref"} <= set(examples)
        worker = RemoteShardWorker()
        blobs = []
        resps = []
        for name in ("plan-request", "plan-request-delta", "plan-request-ref"):
            blob = wire.encode_frame(examples[name], codec="json")
            blobs.append(blob)
            resp = wire.decode_frame(worker.handle_bytes(blob))
            assert resp["kind"] == "plan_response", (name, resp)
            resps.append(resp)
        delta_plan, ref_plan = resps[1]["plans"], resps[2]["plans"]
        assert [p["result"]["decisions"] for p in ref_plan] == [
            p["result"]["decisions"] for p in delta_plan
        ]
        # the steady-state request is a fraction of the priming requests
        assert len(blobs[2]) < len(blobs[0]) / 2
        assert len(blobs[2]) < len(blobs[1]) / 2


class TestDocumentedPatchDefine:
    def test_documented_patch_define_resolves(self):
        """The doc's patch-define node names the real fingerprints: its
        ``base`` is the documented action's fingerprint, and resolving
        the patch through a real worker yields an action whose
        re-encoded fingerprint is exactly the node's ``idef``."""
        from repro.core.remote import RemoteShardWorker

        examples = _doc_examples()
        assert "patch-define" in examples
        node = examples["patch-define"]
        act = examples["action"]
        assert node["base"] == wire.fingerprint(act)

        worker = RemoteShardWorker()
        missing = []
        base = worker._resolve_action(wire.intern_def(node["base"], act), missing)
        patched = worker._resolve_action(node, missing)
        assert missing == []
        assert patched is not base
        assert wire.fingerprint(wire.encode_action(patched)) == node["idef"]
        assert patched.state.value == "running" and patched.attempts == 1


class TestDocumentedCommitExample:
    """The two-phase commit section's worked payloads must replay
    through a real worker: the documented commit block fused onto the
    documented plan-request yields exactly the documented
    plan_commit_response (modulo measured timings), and the documented
    commit_decide abort restores the stash and revokes the lease."""

    REQUIRED = {
        "commit-block",
        "plan-commit-response",
        "commit-decide",
        "commit-decide-response",
    }

    def test_doc_has_commit_examples(self):
        examples = _doc_examples()
        assert self.REQUIRED <= set(examples), sorted(examples)

    @staticmethod
    def _fused_prepare(examples):
        return {
            **examples["plan-request"],
            "kind": "plan_commit",
            "commit": examples["commit-block"],
        }

    def test_documented_lease_round_trips(self):
        node = _doc_examples()["commit-block"]["leases"][0]
        assert wire.decode_lease(node) == ("pool0", 0, True, None)
        assert wire.encode_lease("pool0", 0, fresh=True) == node

    def test_documented_outcome_round_trips(self):
        node = _doc_examples()["plan-commit-response"]["passes"][0]["outcomes"][0]
        part, launched, failed, held = wire.decode_commit_outcome(node)
        assert (part, failed, held) == ("pool0", 0, 0)
        assert wire.encode_commit_outcome(part, launched, failed, held) == node

    def test_documented_prepare_replays_through_a_real_worker(self):
        from repro.core.remote import RemoteShardWorker

        examples = _doc_examples()
        worker = RemoteShardWorker()
        resp = wire.loads(worker.handle(wire.dumps(self._fused_prepare(examples))))
        assert resp["kind"] == "plan_commit_response", resp
        documented = examples["plan-commit-response"]
        # measured timings (and the cache stats block) are not schema
        for d in (resp, documented):
            for key in ("plan_s", "commit_s", "codec_s", "cache"):
                d.pop(key, None)
        for got, want in zip(
            resp["passes"], documented["passes"], strict=True
        ):
            for gp, wp in zip(got["plans"], want["plans"], strict=True):
                gp, wp = dict(gp), dict(wp)
                gp.pop("wall_s"), wp.pop("wall_s")
                assert gp == wp
            assert got["outcomes"] == want["outcomes"]
        # everything else — shard, more, and the post-commit replica
        # fingerprints — must match the doc byte for byte
        resp.pop("passes"), documented.pop("passes")
        assert resp == documented

    def test_documented_decide_aborts_and_revokes(self):
        from repro.core.remote import RemoteShardWorker

        examples = _doc_examples()
        worker = RemoteShardWorker()
        wire.loads(worker.handle(wire.dumps(self._fused_prepare(examples))))
        resp = wire.loads(worker.handle(wire.dumps(examples["commit-decide"])))
        assert resp == examples["commit-decide-response"]
        # the revoked lease is gone: re-asserting epoch 0 (no fresh
        # grant this time) is the documented stale_epoch refusal
        stale = dict(self._fused_prepare(examples))
        stale["commit"] = {
            **examples["commit-block"],
            "leases": [wire.encode_lease("pool0", 0)],
        }
        refusal = wire.loads(worker.handle(wire.dumps(stale)))
        assert refusal["kind"] == "error"
        assert refusal["code"] == "stale_epoch"
        assert refusal["rtypes"] == ["pool0"]
