"""Dense vectorized DPArrange vs the dict-based reference (PR 2).

Three layers of evidence that the fast path is safe to trust:

* seeded-random sweeps (always run, no dev deps) asserting the dense
  prefix DP is objective-identical to :func:`dp_arrange_prefixes_ref`
  over both operators, including fragmented GPU free-chunk
  configurations and infeasible prefixes;
* hypothesis property tests (skip without the dev dependency) over the
  same contract;
* regressions: the transition-table cache must invalidate when the GPU
  manager's free chunks change, the sorted-merge ESTIMATE replay must
  equal the heap simulation it replaced, and the incremental candidate
  window must equal the per-prefix rescan it replaced.
"""

import heapq
import random

import pytest

from _hypothesis_compat import given, settings, st

from repro.core.action import Action, AmdahlElasticity, ResourceRequest, fixed
from repro.core.cluster import CpuNodeSpec, GpuNodeSpec
from repro.core.dparrange import (
    BasicDPOperator,
    DPTask,
    GpuChunkDPOperator,
    dp_arrange,
    dp_arrange_prefixes,
    dp_arrange_prefixes_dense,
    dp_arrange_prefixes_ref,
    dp_arrange_ref,
)
from repro.core.managers.cpu import CpuManager
from repro.core.managers.gpu import GpuManager, ServiceSpec
from repro.core.scheduler import ElasticScheduler

np = pytest.importorskip("numpy")


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _random_tasks(rng, n_tasks, unit_pool, max_units=4):
    tasks = []
    for i in range(n_tasks):
        units = tuple(
            sorted(rng.sample(unit_pool, rng.randint(1, min(max_units, len(unit_pool)))))
        )
        durs = tuple(round(rng.uniform(0.1, 60.0), 4) for _ in units)
        tasks.append(DPTask(f"t{i}", units, durs))
    return tasks


def _assert_prefixes_equivalent(tasks, ref, dense, capacity=None, feasible=None):
    assert dense is not None
    assert len(ref) == len(dense) == len(tasks) + 1
    for i, (r, d) in enumerate(zip(ref, dense)):
        assert (r is None) == (d is None), f"prefix {i}: feasibility mismatch"
        if r is None:
            continue
        # objectives are bit-identical (same float64 sums, same minima)
        assert d.total_duration == r.total_duration, f"prefix {i}"
        # the dense allocation must itself be valid and consistent
        total = 0
        recomputed = 0.0
        for t in range(i):
            k = d.allocation[tasks[t].name]
            assert k in tasks[t].units
            total += k
            recomputed += tasks[t].durations[tasks[t].units.index(k)]
        assert recomputed == pytest.approx(d.total_duration)
        if capacity is not None:
            assert total <= capacity
        if feasible is not None:
            counts = [0, 0, 0, 0]
            for t in range(i):
                dec = GpuChunkDPOperator.greedy_decompose(d.allocation[tasks[t].name])
                assert dec is not None
                counts = [x + y for x, y in zip(counts, dec)]
            assert feasible(tuple(counts))


# ---------------------------------------------------------------------------
# seeded-random equivalence sweeps (always run)
# ---------------------------------------------------------------------------


def test_dense_matches_ref_basic_operator_random():
    rng = random.Random(1)
    for _ in range(200):
        capacity = rng.randint(0, 24)
        tasks = _random_tasks(rng, rng.randint(1, 5), list(range(1, 9)))
        ref = dp_arrange_prefixes_ref(tasks, BasicDPOperator(capacity))
        dense = dp_arrange_prefixes_dense(tasks, BasicDPOperator(capacity))
        _assert_prefixes_equivalent(tasks, ref, dense, capacity=capacity)


def test_dense_matches_ref_gpu_operator_random_free_chunks():
    """Random fragmentation: allocate random chunks out of 1-2 GPU nodes,
    then the DP over the resulting free-chunk configuration must match
    the reference exactly (objective AND multiset feasibility)."""
    rng = random.Random(2)
    for _ in range(150):
        nodes = [GpuNodeSpec(f"g{i}") for i in range(rng.randint(1, 2))]
        mgr = GpuManager(nodes, [ServiceSpec("rm0", 10.0)])
        for _ in range(rng.randint(0, 5)):
            m = rng.choice([1, 2, 4, 8])
            a = Action(
                name="hold",
                cost={"gpu": ResourceRequest("gpu", (m,))},
                trajectory_id="t",
            )
            mgr.try_allocate(a, m)
        tasks = _random_tasks(rng, rng.randint(1, 4), [1, 2, 3, 4, 5, 6, 7, 8])
        ref = dp_arrange_prefixes_ref(tasks, mgr.dp_operator([]))
        dense = dp_arrange_prefixes_dense(tasks, mgr.dp_operator([]))
        _assert_prefixes_equivalent(
            tasks, ref, dense, feasible=mgr.feasible_multiset
        )


def test_infeasible_prefixes_match():
    """Once demand exceeds capacity, both paths report the same prefixes
    as infeasible (None) and keep the feasible ones identical."""
    tasks = [DPTask(f"t{i}", (2, 4), (6.0, 3.0)) for i in range(5)]
    op_ref = BasicDPOperator(5)
    op_dense = BasicDPOperator(5)
    ref = dp_arrange_prefixes_ref(tasks, op_ref)
    dense = dp_arrange_prefixes_dense(tasks, op_dense)
    _assert_prefixes_equivalent(tasks, ref, dense, capacity=5)
    assert ref[3] is None and dense[3] is None  # 3 tasks need >= 6 > 5
    assert ref[2] is not None and dense[2] is not None
    assert dp_arrange(tasks, BasicDPOperator(5)) is None
    assert dp_arrange_ref(tasks, BasicDPOperator(5)) is None


def test_dispatcher_uses_dense_and_falls_back():
    tasks = [DPTask("a", (1, 2), (2.0, 1.0))]

    class OpaqueOperator(BasicDPOperator):
        def transition_table(self, ks, limit=None):
            return None  # force the sparse reference

    got = dp_arrange_prefixes(tasks, OpaqueOperator(4))
    want = dp_arrange_prefixes_ref(tasks, BasicDPOperator(4))
    assert got[1].total_duration == want[1].total_duration
    # explicit table=None also forces the reference path
    got2 = dp_arrange_prefixes(tasks, BasicDPOperator(4), table=None)
    assert got2[1].total_duration == want[1].total_duration


def test_state_limit_falls_back_to_ref(monkeypatch):
    import repro.core.dparrange as dpmod

    op = BasicDPOperator(10)
    assert op.transition_table((1, 2), limit=5) is None
    # with the module limit tightened below the state space, the dense
    # path reports "unsupported" and the dispatcher uses the reference
    monkeypatch.setattr(dpmod, "DENSE_STATE_LIMIT", 5)
    tasks = [DPTask("a", (1, 2), (2.0, 1.0))]
    assert dp_arrange_prefixes_dense(tasks, BasicDPOperator(10)) is None
    got = dp_arrange_prefixes(tasks, BasicDPOperator(10))
    want = dp_arrange_prefixes_ref(tasks, BasicDPOperator(10))
    assert got[1].total_duration == want[1].total_duration


def test_jax_backend_matches_ref():
    jax = pytest.importorskip("jax")  # noqa: F841
    rng = random.Random(3)
    for _ in range(10):
        capacity = rng.randint(1, 16)
        tasks = _random_tasks(rng, rng.randint(1, 4), list(range(1, 9)))
        ref = dp_arrange_prefixes_ref(tasks, BasicDPOperator(capacity))
        dense = dp_arrange_prefixes_dense(
            tasks, BasicDPOperator(capacity), backend="jax"
        )
        _assert_prefixes_equivalent(tasks, ref, dense, capacity=capacity)
    op = GpuChunkDPOperator((8, 4, 2, 1), total_devices=8)
    tasks = _random_tasks(rng, 3, [1, 2, 4, 8])
    ref = dp_arrange_prefixes_ref(tasks, op)
    dense = dp_arrange_prefixes_dense(
        tasks, GpuChunkDPOperator((8, 4, 2, 1), total_devices=8), backend="jax"
    )
    _assert_prefixes_equivalent(tasks, ref, dense)


# ---------------------------------------------------------------------------
# hypothesis property tests (skip cleanly without the dev dependency)
# ---------------------------------------------------------------------------


@settings(max_examples=150, deadline=None)
@given(n_tasks=st.integers(1, 5), total=st.integers(0, 16), data=st.data())
def test_property_dense_matches_ref_basic(n_tasks, total, data):
    tasks = []
    for i in range(n_tasks):
        units = tuple(
            sorted(
                data.draw(st.sets(st.integers(1, 8), min_size=1, max_size=4), label=f"u{i}")
            )
        )
        durs = tuple(
            data.draw(st.floats(0.1, 100.0, allow_nan=False), label=f"d{i}{k}")
            for k in units
        )
        tasks.append(DPTask(f"t{i}", units, durs))
    ref = dp_arrange_prefixes_ref(tasks, BasicDPOperator(total))
    dense = dp_arrange_prefixes_dense(tasks, BasicDPOperator(total))
    _assert_prefixes_equivalent(tasks, ref, dense, capacity=total)


@settings(max_examples=80, deadline=None)
@given(n_tasks=st.integers(1, 3), n_held=st.integers(0, 4), data=st.data())
def test_property_dense_matches_ref_gpu(n_tasks, n_held, data):
    mgr = GpuManager([GpuNodeSpec("g0")], [ServiceSpec("rm0", 10.0)])
    for h in range(n_held):
        m = data.draw(st.sampled_from([1, 2, 4, 8]), label=f"h{h}")
        a = Action(
            name="hold", cost={"gpu": ResourceRequest("gpu", (m,))}, trajectory_id="t"
        )
        mgr.try_allocate(a, m)
    tasks = []
    for i in range(n_tasks):
        units = tuple(
            sorted(
                data.draw(st.sets(st.integers(1, 8), min_size=1, max_size=3), label=f"u{i}")
            )
        )
        durs = tuple(
            data.draw(st.floats(0.1, 50.0, allow_nan=False), label=f"d{i}{k}")
            for k in units
        )
        tasks.append(DPTask(f"t{i}", units, durs))
    ref = dp_arrange_prefixes_ref(tasks, mgr.dp_operator([]))
    dense = dp_arrange_prefixes_dense(tasks, mgr.dp_operator([]))
    _assert_prefixes_equivalent(tasks, ref, dense, feasible=mgr.feasible_multiset)


# ---------------------------------------------------------------------------
# transition-table cache regressions
# ---------------------------------------------------------------------------


class TestTableCache:
    def _tasks(self):
        return [DPTask("0", (1, 2, 4, 8), (8.0, 4.2, 2.3, 1.4))]

    def test_hit_on_unchanged_gpu_state(self):
        mgr = GpuManager([GpuNodeSpec("g0")], [ServiceSpec("rm0", 10.0)])
        s = ElasticScheduler()
        tasks = self._tasks()
        t1 = s._table_for(mgr.dp_operator([]), tasks, mgr.dp_cache_key([]))
        t2 = s._table_for(mgr.dp_operator([]), tasks, mgr.dp_cache_key([]))
        assert t1 is t2
        assert s.table_cache_hits == 1 and s.table_cache_misses == 1

    def test_invalidates_when_free_chunks_change(self):
        """REGRESSION: allocating (and releasing) GPU chunks must rotate
        dp_cache_key so a stale transition table is never reused."""
        mgr = GpuManager([GpuNodeSpec("g0")], [ServiceSpec("rm0", 10.0)])
        s = ElasticScheduler()
        tasks = self._tasks()
        key0 = mgr.dp_cache_key([])
        t1 = s._table_for(mgr.dp_operator([]), tasks, key0)
        # an 8-chunk consumption is feasible on the pristine node
        assert dp_arrange_prefixes_dense(
            [DPTask("0", (8,), (1.0,))], mgr.dp_operator([]), table=t1
        )[1] is not None

        a = Action(
            name="hold", cost={"gpu": ResourceRequest("gpu", (4,))}, trajectory_id="t"
        )
        alloc = mgr.try_allocate(a, 4)
        assert alloc is not None
        key1 = mgr.dp_cache_key([])
        assert key1 != key0
        t2 = s._table_for(mgr.dp_operator([]), tasks, key1)
        assert t2 is not t1
        assert s.table_cache_misses == 2
        # with 4 of 8 devices held, an 8-unit task is now infeasible
        assert dp_arrange_prefixes(
            [DPTask("0", (8,), (1.0,))], mgr.dp_operator([]), table=t2
        )[1] is None

        # releasing restores the original key -> the first table hits again
        mgr.release(a, alloc)
        assert mgr.dp_cache_key([]) == key0
        t3 = s._table_for(mgr.dp_operator([]), tasks, mgr.dp_cache_key([]))
        assert t3 is t1

    def test_unsupported_operator_verdict_cached(self):
        class NoTableOp(BasicDPOperator):
            def transition_table(self, ks, limit=None):
                return None

        s = ElasticScheduler()
        tasks = self._tasks()
        assert s._table_for(NoTableOp(8), tasks, ("x", 8)) is None
        assert s._table_for(NoTableOp(8), tasks, ("x", 8)) is None
        assert s.table_cache_hits == 1  # the None verdict itself is cached


# ---------------------------------------------------------------------------
# ESTIMATE sorted-merge replay == the heap simulation it replaced
# ---------------------------------------------------------------------------


def _heap_replay_reference(base, durs):
    heap = list(base)
    heapq.heapify(heap)
    obj = 0.0
    for t in durs:
        ts = heapq.heappop(heap) if heap else 0.0
        obj += ts + t
        heapq.heappush(heap, ts + t)
    return obj


def test_sorted_merge_replay_matches_heap_replay():
    rng = random.Random(4)
    for _ in range(300):
        base = sorted(round(rng.uniform(0.0, 20.0), 3) for _ in range(rng.randint(0, 12)))
        durs = [round(rng.uniform(0.01, 10.0), 3) for _ in range(rng.randint(1, 15))]
        want = _heap_replay_reference(base, durs)
        got = ElasticScheduler._replay(base, durs[0], durs[1:])
        assert got == pytest.approx(want, abs=1e-12)


def test_estimate_empty_rest_is_zero():
    s = ElasticScheduler()
    assert s._estimate([1.0, 2.0], []) == 0.0


# ---------------------------------------------------------------------------
# incremental candidate window == the per-prefix rescan it replaced
# ---------------------------------------------------------------------------


def test_candidate_window_incremental_matches_rescan():
    rng = random.Random(5)
    for _ in range(50):
        managers = {
            "cpu": CpuManager(
                [
                    CpuNodeSpec("n0", cores=rng.randint(2, 8), memory_gb=24.0),
                    CpuNodeSpec("n1", cores=rng.randint(2, 8), memory_gb=16.0),
                ]
            )
        }
        waiting = []
        for i in range(rng.randint(1, 14)):
            a = Action(
                name=f"a{i}",
                cost={"cpu": fixed("cpu", rng.randint(1, 4))},
                trajectory_id=f"t{i}",
                metadata={"traj_mem_gb": rng.choice([2.0, 4.0, 8.0])},
            )
            waiting.append(a)
        s = ElasticScheduler()
        fast = s._candidate_window(waiting, managers)
        # reference: the seed's per-prefix full rescan
        best = 0
        for i in range(1, len(waiting) + 1):
            prefix = waiting[:i]
            touched = {r for a in prefix for r in a.cost}
            ok = all(
                managers[r].can_accommodate([a for a in prefix if r in a.cost])
                for r in touched
                if r in managers
            )
            if ok:
                best = i
            else:
                break
        assert [a.uid for a in fast] == [a.uid for a in waiting[:best]]


# ---------------------------------------------------------------------------
# end-to-end: dense scheduling decisions == reference scheduling decisions
# ---------------------------------------------------------------------------


def test_schedule_decisions_identical_dense_vs_ref():
    rng = random.Random(6)
    for _ in range(25):
        n = rng.randint(1, 24)
        waiting = []
        for i in range(n):
            if rng.random() < 0.4:
                waiting.append(
                    Action(
                        name=f"s{i}",
                        cost={"cpu": ResourceRequest("cpu", (1, 2, 4, 8))},
                        key_resource="cpu",
                        elasticity=AmdahlElasticity(0.05),
                        base_duration=rng.uniform(1.0, 30.0),
                        trajectory_id=f"t{i}",
                    )
                )
            else:
                waiting.append(
                    Action(
                        name=f"r{i}",
                        cost={"cpu": fixed("cpu", rng.randint(1, 2))},
                        base_duration=1.0,
                        trajectory_id=f"t{i}",
                    )
                )
        cores = rng.choice([8, 16, 32])
        m_dense = {"cpu": CpuManager([CpuNodeSpec("n0", cores=cores)])}
        m_ref = {"cpu": CpuManager([CpuNodeSpec("n0", cores=cores)])}
        s_dense = ElasticScheduler(depth=2)
        s_ref = ElasticScheduler(depth=2)
        s_ref.use_dense = False
        r_dense = s_dense.schedule(waiting, [], m_dense, 0.0)
        r_ref = s_ref.schedule(waiting, [], m_ref, 0.0)
        assert r_dense.objective == r_ref.objective
        assert r_dense.evicted == r_ref.evicted
        assert [(d.action.uid, d.units) for d in r_dense.decisions] == [
            (d.action.uid, d.units) for d in r_ref.decisions
        ]
