"""Ownership handoff under the two-phase worker-owned commit.

The rails under test:

* **fence during an open prepare window** — a ``migrate_task`` /
  ``fence`` that fires while a ``plan_commit`` frame is in flight must
  deterministically abort the fenced intents (the ack is never adopted,
  the worker restores its pre-round replicas) and the round must stay
  trace-identical to the serial loop when the fence itself moves no
  state;
* **amnesia** — a silently restarted worker holds no leases; its next
  epoch assertion must fail with a *typed* ``stale_epoch`` BEFORE any
  replica mutation (never a double launch), and the coordinator's
  re-grant + full re-send must recover the round;
* **loss mid-prepare** — a connection that dies between prepare and
  ack rides the adoption rail: the orphaned leases are revoked by
  epoch bump and the partitions commit inline from fallback plans —
  zero lost launches, and the zombie's late ack can never land.
"""

import random

import pytest

from repro.core import wire
from repro.core.action import Action, AmdahlElasticity, ResourceRequest, fixed
from repro.core.fairqueue import FairSharePolicy
from repro.core.managers.base import ResourceManager
from repro.core.orchestrator import Orchestrator
from repro.core.remote import (
    RECOVERABLE_CODES,
    LoopbackTransport,
    RemoteShardWorker,
)
from repro.core.scheduler import ElasticScheduler
from repro.core.simulator import EventLoop
from repro.core.wire import TransportError

from test_remote import _make_system, _submit_workload, _trace


# ---------------------------------------------------------------------------
# a loopback transport with frame-kind hooks (the interleaving probe)
# ---------------------------------------------------------------------------


class HookTransport:
    """Loopback transport that exposes the prepare window: hooks fire
    keyed on the decoded frame kind, between submit and recv — exactly
    where a concurrent handoff or a worker death lands."""

    def __init__(self, shard, hooks):
        self.shard = shard
        self.hooks = hooks
        self._inner = LoopbackTransport()
        self._last_kind = None

    def _kind(self, request):
        blob = request if isinstance(request, bytes) else request.encode("utf-8")
        try:
            payload = wire.decode_frame(blob)
        except wire.WireError:
            return None
        return payload.get("kind") if isinstance(payload, dict) else None

    def amnesia(self):
        """Silently replace the worker (fresh process, no leases)."""
        self._inner = LoopbackTransport()

    def submit(self, request):
        self._last_kind = self._kind(request)
        on_submit = self.hooks.get("on_submit")
        if on_submit is not None:
            on_submit(self, self._last_kind)
        self._inner.submit(request)

    def recv(self):
        on_recv = self.hooks.get("on_recv")
        if on_recv is not None:
            on_recv(self, self._last_kind)
        return self._inner.recv()

    def close(self):
        self._inner.close()


def _hook_factory(hooks):
    return lambda shard: HookTransport(shard, hooks)


def _assert_clean(orch, trace):
    assert orch.queue_depth() == 0 and orch.in_flight() == 0
    for m in orch.managers.values():
        m.check_occupancy()
    uids = [(r[0], r[1], r[2]) for r in trace]
    assert len(uids) == len(set(uids)), "double launch"


def _run_hooked(seed, hooks, **kw):
    orch = _make_system(
        shards=4, plan_mode="remote", commit_mode="worker",
        transport=_hook_factory(hooks), **kw,
    )
    hooks["orch"] = orch
    _submit_workload(orch, seed)
    orch.run()
    trace = _trace(orch)
    _assert_clean(orch, trace)
    summary = orch.telemetry.wire_summary()
    orch.close()
    return trace, summary


# ---------------------------------------------------------------------------
# fence during the open prepare window
# ---------------------------------------------------------------------------


class TestFenceMidPrepare:
    def test_fence_aborts_open_intents_and_trace_holds(self):
        """A full fence fired between prepare and ack: the in-flight
        intents are fenced (never adopted, worker stash restored), the
        parts re-dirty and replan at the same virtual instant — so a
        fence that moves no state is trace-neutral."""
        _, serial = (None, None)
        orch0 = _make_system(shards=None)
        _submit_workload(orch0, 5)
        orch0.run()
        serial = _trace(orch0)
        orch0.close()

        fired = [0]

        def on_recv(t, kind):
            if kind == "plan_commit" and not fired[0]:
                fired[0] = 1
                hooks["orch"]._commit_engine.fence()

        hooks = {"on_recv": on_recv}
        trace, summary = _run_hooked(5, hooks)
        assert fired[0] == 1
        assert trace == serial
        assert summary.get("fenced_intents", 0) >= 1
        assert summary.get("commit_aborts", 0) >= 1

    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5])
    def test_randomized_fence_interleavings_stay_serial(self, seed):
        """Property over random interleavings: fences of random scope at
        random points inside the prepare window never bend the trace,
        lose a launch, or double-launch — the fenced rounds abort
        deterministically and replan."""
        orch0 = _make_system(shards=None)
        _submit_workload(orch0, seed)
        orch0.run()
        serial = _trace(orch0)
        orch0.close()

        rng = random.Random(7000 + seed)
        targets = sorted(rng.sample(range(1, 12), k=3))
        scopes = [
            rng.choice([None, ["cpu"], ["gpu"], ["api"], ["cpu", "api"]])
            for _ in targets
        ]
        seen = [0]

        def on_recv(t, kind):
            if kind != "plan_commit":
                return
            seen[0] += 1
            if seen[0] in targets:
                scope = scopes[targets.index(seen[0])]
                hooks["orch"]._commit_engine.fence(scope)

        hooks = {"on_recv": on_recv}
        trace, _summary = _run_hooked(seed, hooks)
        assert trace == serial

    def test_fence_between_rounds_settles_pending_confirms(self):
        """A fence with no round open finalizes verified-but-unconfirmed
        stashes with an explicit commit (the coordinator already applied
        them) and revokes by epoch bump — the next round re-grants."""
        orch = _make_system(shards=4, plan_mode="remote", commit_mode="worker")
        _submit_workload(orch, 3)
        orch.run(until=6.0)
        engine = orch._commit_engine
        fenced = engine.fence()  # nothing in flight -> 0 fenced intents
        assert fenced == 0
        assert not engine._pending_confirm
        assert all(not g for g in engine._granted.values())
        orch.run()
        trace = _trace(orch)
        _assert_clean(orch, trace)
        orch.close()

        orch0 = _make_system(shards=None)
        _submit_workload(orch0, 3)
        orch0.run()
        assert trace == _trace(orch0)
        orch0.close()


# ---------------------------------------------------------------------------
# migrate_task against an open prepare window
# ---------------------------------------------------------------------------


def _pool_fleet(transport=None, **kw):
    loop = EventLoop()
    managers = {f"pool{k}": ResourceManager(f"pool{k}", 2) for k in range(2)}
    fs = FairSharePolicy(weights={"a": 2.0, "b": 1.0})
    extra = {}
    if transport is not None:
        extra = dict(plan_mode="remote", commit_mode="worker",
                     transport=transport)
    return Orchestrator(
        managers, loop=loop, fair_share=fs, shards=2, **extra, **kw
    )


def _pool_load(orch, n=12):
    futs = []
    for i in range(n):
        part = "pool0" if i % 3 else "pool1"
        task = "a" if i % 2 == 0 else "b"
        if i % 4 == 0:
            cost = {part: ResourceRequest(part, (1, 2))}
            kws = dict(key_resource=part, elasticity=AmdahlElasticity(0.1))
        else:
            cost, kws = {part: fixed(part, 1)}, {}
        futs.append(orch.submit(Action(
            name=f"w{i}", cost=cost, base_duration=2.0, task_id=task,
            trajectory_id=f"t{i}", **kws)))
    return futs


class TestMigrateMidPrepare:
    def _run_migrating(self, migrate_at):
        """One worker-commit run where migrate_task fires from INSIDE
        the prepare window of the ``migrate_at``-th plan_commit ack."""
        seen = [0]
        done = [0]

        def on_recv(t, kind):
            if kind != "plan_commit" or done[0]:
                return
            seen[0] += 1
            if seen[0] == migrate_at:
                done[0] = 1
                hooks["orch"].migrate_task("a", "pool0", "pool1")

        hooks = {"on_recv": on_recv}
        orch = _pool_fleet(transport=_hook_factory(hooks))
        hooks["orch"] = orch
        futs = _pool_load(orch)
        orch.run()
        assert done[0] == 1, "migration never interleaved with a prepare"
        assert all(f.done() for f in futs)
        trace = _trace(orch)
        _assert_clean(orch, trace)
        summary = orch.telemetry.wire_summary()
        orch.close()
        return trace, summary

    @pytest.mark.parametrize("migrate_at", [1, 2, 3])
    def test_migration_fences_and_is_deterministic(self, migrate_at):
        """The handoff fences the open intents (they abort, never adopt)
        and the interleaving is deterministic: the same virtual-time
        migration produces the same launch trace every run."""
        t1, s1 = self._run_migrating(migrate_at)
        t2, s2 = self._run_migrating(migrate_at)
        assert t1 == t2
        assert s1.get("fenced_intents", 0) >= 1
        assert s1.get("fenced_intents") == s2.get("fenced_intents")
        # the migrated tenant really ran on the destination replica
        pools = {u[0] for r in t1 for u in r[6]}
        assert "pool1" in pools


class TestRetargetEncodeMemo:
    """Regression: ``migrate_task`` retargets action cost vectors IN
    PLACE, so the client encode memo must re-key on the cost targeting
    (rtype set + key_resource) and ship a full re-define — a stale
    reference would make workers plan the migrated backlog against the
    pre-handoff pool (KeyError on the replica set, or silent
    divergence)."""

    def _run(self, plan_mode=None, commit_mode=None):
        kw = {}
        if plan_mode is not None:
            kw["plan_mode"] = plan_mode
        if commit_mode is not None:
            kw["commit_mode"] = commit_mode
        loop = EventLoop()
        managers = {f"pool{k}": ResourceManager(f"pool{k}", 2) for k in range(2)}
        fs = FairSharePolicy(weights={"a": 2.0, "b": 1.0})
        orch = Orchestrator(managers, loop=loop, fair_share=fs, shards=2, **kw)
        _pool_load(orch)
        orch.loop.call_after(0.5, lambda: orch.migrate_task("a", "pool0", "pool1"))
        orch.run()
        trace = _trace(orch)
        _assert_clean(orch, trace)
        orch.close()
        return trace

    def test_migration_over_the_wire_matches_inline(self):
        inline = self._run()
        remote = self._run(plan_mode="remote")
        worker = self._run(plan_mode="remote", commit_mode="worker")
        assert remote == inline
        assert worker == inline


# ---------------------------------------------------------------------------
# amnesia: restarted worker, stale epoch
# ---------------------------------------------------------------------------


class TestAmnesia:
    def test_restarted_worker_regrants_never_double_launches(self):
        """Swap a worker for a blank one right before its SECOND fused
        frame (its leases are epoch asserts by then): the blank worker
        must refuse typed — stale_epoch, before any replica mutation —
        and the re-grant + full re-send recovers the very same round."""
        orch0 = _make_system(shards=None)
        _submit_workload(orch0, 2)
        orch0.run()
        serial = _trace(orch0)
        orch0.close()

        counts = {}

        def on_submit(t, kind):
            if kind != "plan_commit":
                return
            counts[t.shard] = counts.get(t.shard, 0) + 1
            if counts[t.shard] == 2:
                t.amnesia()

        hooks = {"on_submit": on_submit}
        trace, summary = _run_hooked(2, hooks)
        assert trace == serial
        assert summary.get("lease_regrants", 0) >= 1
        assert summary.get("commit_diverged", 0) == 0
        assert summary.get("worker_losses", 0) == 0

    def test_stale_epoch_is_typed_and_recoverable(self):
        """Protocol-level: an epoch assertion a worker does not hold is
        refused with a typed, recoverable ``stale_epoch`` naming the
        stale rtypes — raised BEFORE the decode preamble, so no replica
        state can have been touched."""
        assert "stale_epoch" in RECOVERABLE_CODES
        worker = RemoteShardWorker()
        req = wire.envelope("plan_commit", {
            "shard": 0,
            "now": 0.0,
            "incremental": True,
            "policy": wire.encode_policy(ElasticScheduler()),
            "fair_share": None,
            "history": {"avg": {}},
            "snapshots": {},
            "executing": [],
            "partitions": [],
            "commit": {
                "leases": [wire.encode_lease("cpu", 3)],
                "max_passes": 2,
                "tick": 0.0005,
            },
        })
        resp = wire.loads(worker.handle(wire.dumps(req)))
        assert resp["kind"] == "error"
        assert resp["code"] == "stale_epoch"
        assert resp["rtypes"] == ["cpu"]
        # nothing was planned, stashed, or committed
        assert worker._stash is None
        assert worker._resident == {}

    def test_fresh_grant_then_revoke_then_assert_is_stale(self):
        """The fence's revocation really invalidates the lease: grant
        fresh, revoke via commit_decide, then the same epoch assert is
        stale — a fenced worker can never ack an old round again."""
        m = ResourceManager("r", 8)
        worker = RemoteShardWorker()
        base = {
            "shard": 0,
            "now": 0.0,
            "incremental": True,
            "policy": wire.encode_policy(ElasticScheduler()),
            "fair_share": None,
            "history": {"avg": {}},
            "snapshots": {"r": wire.encode_snapshot(m)},
            "executing": [],
            "partitions": [{"part": "r", "waiting": []}],
        }
        grant = dict(base)
        grant["commit"] = {
            "leases": [wire.encode_lease("r", 0, fresh=True)],
            "max_passes": 1, "tick": 0.0005,
        }
        resp = wire.loads(worker.handle(wire.dumps(
            wire.envelope("plan_commit", grant))))
        assert resp["kind"] == "plan_commit_response"
        # revoke (fence): commit the stash, withdraw the lease
        resp = wire.loads(worker.handle(wire.dumps(wire.envelope(
            "commit_decide", {"commit": True, "revoke": ["r"]}))))
        assert resp["kind"] == "commit_decide_response"
        assert resp["leases"] == 0
        stale = dict(base)
        stale["policy"] = None
        stale["snapshots"] = {}
        stale["partitions"] = []
        stale["commit"] = {
            "leases": [wire.encode_lease("r", 0)],
            "max_passes": 1, "tick": 0.0005,
        }
        resp = wire.loads(worker.handle(wire.dumps(
            wire.envelope("plan_commit", stale))))
        assert resp["kind"] == "error" and resp["code"] == "stale_epoch"


# ---------------------------------------------------------------------------
# worker loss mid-prepare: the adoption rail
# ---------------------------------------------------------------------------


class TestLossMidPrepare:
    def test_connection_death_between_prepare_and_ack_adopts(self):
        """The ack never arrives: the coordinator bumps the orphaned
        epochs (late acks can never land), plans the partitions inline,
        and commits them itself — same plan core, zero lost launches,
        trace identical to serial."""
        orch0 = _make_system(shards=None)
        _submit_workload(orch0, 4)
        orch0.run()
        serial = _trace(orch0)
        orch0.close()

        dropped = [0]

        def on_recv(t, kind):
            if kind == "plan_commit" and not dropped[0]:
                dropped[0] = 1
                raise TransportError("reset", "connection died mid-prepare")

        hooks = {"on_recv": on_recv}
        trace, summary = _run_hooked(4, hooks)
        assert dropped[0] == 1
        assert trace == serial
        assert summary.get("lease_adoptions", 0) >= 1
        assert summary.get("worker_losses", 0) >= 1
        assert summary.get("inline_parts", 0) >= 1

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_randomized_loss_storms_stay_serial(self, seed):
        """Property: random subsets of plan_commit exchanges dying at
        random points (submit or recv) never lose or double a launch
        and never bend the trace."""
        orch0 = _make_system(shards=None)
        _submit_workload(orch0, seed)
        orch0.run()
        serial = _trace(orch0)
        orch0.close()

        rng = random.Random(9000 + seed)
        kill_recv = set(rng.sample(range(1, 16), k=3))
        kill_submit = set(rng.sample(range(1, 16), k=2))
        n_recv = [0]
        n_submit = [0]

        def on_recv(t, kind):
            if kind != "plan_commit":
                return
            n_recv[0] += 1
            if n_recv[0] in kill_recv:
                raise TransportError("reset", "storm: ack dropped")

        def on_submit(t, kind):
            if kind != "plan_commit":
                return
            n_submit[0] += 1
            if n_submit[0] in kill_submit:
                raise TransportError("reset", "storm: prepare dropped")

        hooks = {"on_recv": on_recv, "on_submit": on_submit}
        trace, _summary = _run_hooked(seed, hooks)
        assert trace == serial
