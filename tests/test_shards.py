"""Sharded plan/commit scheduling rounds: launch-trace equivalence with
the serial round loop, commit-phase conflict convergence, manager
snapshot isolation, and the occupancy invariant under cancel/timeout
storms."""

import math
import random

import pytest

from repro.core.action import Action, AmdahlElasticity, ResourceRequest, fixed, ranged
from repro.core.cluster import ApiResourceSpec, CpuNodeSpec, GpuNodeSpec
from repro.core.fairqueue import FairSharePolicy
from repro.core.managers.base import ResourceManager
from repro.core.managers.basic import BasicResourceManager
from repro.core.managers.cpu import CpuManager
from repro.core.managers.gpu import GpuManager, ServiceSpec
from repro.core.orchestrator import Orchestrator
from repro.core.shards import RoundExecutor, SnapshotMap
from repro.core.simulator import EventLoop


# ---------------------------------------------------------------------------
# workload / system factories (fresh managers + actions per run so every
# mode replays an identical event trace)
# ---------------------------------------------------------------------------


def _make_system(shards, incremental=True, fair=False, cores=32, **kw):
    loop = EventLoop()
    managers = {
        "cpu": CpuManager([CpuNodeSpec("n0", cores=cores)]),
        "gpu": GpuManager([GpuNodeSpec("g0")], [ServiceSpec("rm0", 40.0)]),
        "api": BasicResourceManager(
            ApiResourceSpec("api", mode="quota", quota=4, period_s=5.0), loop.clock
        ),
    }
    fs = FairSharePolicy(weights={"heavy": 2.0, "light": 1.0}) if fair else None
    return Orchestrator(
        managers, loop=loop, incremental=incremental, fair_share=fs,
        shards=shards, **kw,
    )


def _submit_workload(orch, seed, tasks=("task0",), n=60):
    rng = random.Random(seed)
    for i in range(n):
        task = tasks[i % len(tasks)]
        kind = rng.random()
        delay = rng.uniform(0.0, 5.0)
        if kind < 0.4:
            a = Action(
                name="reward", cost={"cpu": ranged("cpu", 1, 8)}, key_resource="cpu",
                elasticity=AmdahlElasticity(0.08), base_duration=rng.uniform(1, 8),
                task_id=task, trajectory_id=f"{task}-{i}",
            )
        elif kind < 0.6:
            a = Action(
                name="tool", cost={"cpu": fixed("cpu", rng.choice((1, 2)))},
                base_duration=rng.uniform(0.2, 2.0), task_id=task,
                trajectory_id=f"{task}-{i}",
            )
        elif kind < 0.8:
            a = Action(
                name="rm:score", cost={"gpu": ResourceRequest("gpu", (1, 2, 4, 8))},
                key_resource="gpu", elasticity=AmdahlElasticity(0.15),
                base_duration=rng.uniform(0.5, 3.0), service="rm0", task_id=task,
                trajectory_id=f"{task}-{i}",
            )
        else:
            a = Action(
                name="api:q", cost={"api": fixed("api")},
                base_duration=rng.uniform(0.1, 1.0), task_id=task,
                trajectory_id=f"{task}-{i}",
            )
        orch.submit(a, delay=delay)


def _trace(orch):
    return sorted(
        (r.name, r.task_id, r.trajectory_id, round(r.submit, 9), round(r.start, 9),
         round(r.finish, 9), tuple(sorted(r.units.items())), r.failed)
        for r in orch.telemetry.records
    )


def _check_all_occupancy(orch):
    for m in orch.managers.values():
        m.check_occupancy()


# ---------------------------------------------------------------------------
# launch-trace equivalence: serial == shards=1 == shards=4 on the
# conflict-free workloads (every action touches one resource type)
# ---------------------------------------------------------------------------


class TestShardEquivalence:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5, 6, 7])
    def test_serial_vs_sharded_trace_identity(self, seed):
        """shards=1 and shards=4 must launch exactly what the serial
        round loop launches — plan-over-snapshot + serialized commit is
        a pure refactor on conflict-free workloads."""
        traces = {}
        for shards in (None, 1, 4):
            orch = _make_system(shards)
            _submit_workload(orch, seed)
            orch.run()
            traces[shards] = _trace(orch)
            assert orch.queue_depth() == 0 and orch.in_flight() == 0
            _check_all_occupancy(orch)
        assert traces[None] == traces[1], f"seed {seed}: shards=1 diverged"
        assert traces[None] == traces[4], f"seed {seed}: shards=4 diverged"

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_sharded_full_reschedule_equivalence(self, seed):
        """The plan/commit engine composes with incremental=False (every
        partition dirty, the policy's own window scan)."""
        serial = _make_system(None, incremental=False)
        sharded = _make_system(4, incremental=False)
        _submit_workload(serial, seed)
        _submit_workload(sharded, seed)
        serial.run()
        sharded.run()
        assert _trace(serial) == _trace(sharded)

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_sharded_fairness_equivalence(self, seed):
        """Multi-tenant WFQ queues drain identically under the sharded
        engine (sub-queues never straddle shards)."""
        tasks = ("heavy", "light")
        serial = _make_system(None, fair=True)
        sharded = _make_system(4, fair=True)
        _submit_workload(serial, seed, tasks=tasks)
        _submit_workload(sharded, seed, tasks=tasks)
        serial.run()
        sharded.run()
        assert _trace(serial) == _trace(sharded)
        assert sharded.queue_depth() == 0 and sharded.in_flight() == 0

    def test_thread_pool_plans_match_inline(self):
        """plan_mode='threads' dispatches shards to a real pool; plans
        are deterministic, so the trace matches the inline mode."""
        inline = _make_system(4, plan_mode="inline")
        threaded = _make_system(4, plan_mode="threads")
        _submit_workload(inline, seed=11)
        _submit_workload(threaded, seed=11)
        inline.run()
        threaded.run()
        assert _trace(inline) == _trace(threaded)

    def test_sharded_rounds_actually_engage(self):
        """A coalesced multi-partition round must go through the plan
        pool, not the serial fallback."""
        orch = _make_system(4)
        _submit_workload(orch, seed=3)
        orch.run()
        assert orch.stats["sharded_rounds"] > 0
        summary = orch.telemetry.shard_summary()
        assert summary["shards"] >= 2
        assert summary["plan_total_s"] > 0.0

    def test_shard_assignment_is_deterministic_striping(self):
        orch = _make_system(4)
        ex = orch._executor
        assert isinstance(ex, RoundExecutor)
        keys = ["e", "a", "c", "b", "d"]
        groups = ex.assign(keys)
        assert groups == [["a", "e"], ["b"], ["c"], ["d"]]
        # whole partitions only, every key exactly once
        flat = sorted(k for g in groups for k in g)
        assert flat == sorted(keys)
        assert ex.assign(keys) == groups  # stable

    def test_invalid_shard_config_rejected(self):
        with pytest.raises(ValueError):
            _make_system(0)
        with pytest.raises(ValueError):
            _make_system(2, plan_mode="quantum")


# ---------------------------------------------------------------------------
# forced commit-phase conflicts: two partitions' plans claim the same
# shared resource off the same snapshot; the commit must re-dirty the
# loser and converge with no lost or double-launched action
# ---------------------------------------------------------------------------


class TestCommitConflicts:
    def _conflict_system(self, shards):
        loop = EventLoop()
        managers = {
            "a": ResourceManager("a", 4),
            "b": ResourceManager("b", 4),
            "shared": ResourceManager("shared", 2),
        }
        return Orchestrator(managers, loop=loop, shards=shards)

    def _submit_contenders(self, orch, n=6):
        futs = []
        for i in range(n):
            part = "a" if i % 2 == 0 else "b"
            futs.append(
                orch.submit(
                    Action(
                        name=f"{part}{i}",
                        cost={part: fixed(part, 1), "shared": fixed("shared", 2)},
                        key_resource=part,
                        base_duration=1.0,
                        trajectory_id=f"t{i}",
                    )
                )
            )
        return futs

    def test_conflicts_converge_without_loss_or_double_launch(self):
        orch = self._conflict_system(shards=2)
        futs = self._submit_contenders(orch)
        orch.run()
        # both partitions planned 'shared' off the same snapshot: only
        # one commit fits, the other must have been refused and retried
        assert orch.telemetry.commit_conflicts > 0
        assert all(f.done() for f in futs)  # no lost actions
        records = [r for r in orch.telemetry.records if not r.failed]
        assert len(records) == 6
        # no double launch: every trajectory completes exactly once
        assert len({r.trajectory_id for r in records}) == 6
        assert orch.queue_depth() == 0 and orch.in_flight() == 0
        _check_all_occupancy(orch)

    def test_serial_never_conflicts_on_same_workload(self):
        """The serial loop plans against live state, so the same
        workload produces zero commit conflicts — the conflicts above
        are purely a property of snapshot planning."""
        orch = self._conflict_system(shards=None)
        futs = self._submit_contenders(orch)
        orch.run()
        assert orch.telemetry.commit_conflicts == 0
        assert all(f.done() for f in futs)


# ---------------------------------------------------------------------------
# manager snapshots: plans must not touch live state
# ---------------------------------------------------------------------------


class TestSnapshots:
    def test_base_snapshot_isolates_usage_and_admission(self):
        m = ResourceManager("r", 8)
        m.note_allocated("t", 3)
        snap = m.snapshot()
        snap.note_allocated("t", 2)  # a plan-side what-if
        assert m.task_usage() == {"t": 3}
        cur = snap.begin_admission()
        assert snap.admit_one(cur, Action(name="a", cost={"r": fixed("r", 8)},
                                          trajectory_id="t0"))
        assert m.available == 8

    def test_cpu_snapshot_binding_does_not_leak(self):
        m = CpuManager([CpuNodeSpec("n0", cores=8, memory_gb=16.0)])
        snap = m.snapshot()
        a = Action(name="a", cost={"cpu": fixed("cpu", 2)}, trajectory_id="tX")
        snap.partition([a])  # binds tX on the SNAPSHOT only
        assert snap.node_of("tX") == "n0"
        assert m.node_of("tX") is None
        assert m.nodes["n0"].free_mem_gb == pytest.approx(16.0)

    def test_gpu_snapshot_allocator_isolated(self):
        m = GpuManager([GpuNodeSpec("g0")], [ServiceSpec("rm0", 40.0)])
        snap = m.snapshot()
        got = snap.allocators["g0"].allocate(4, None, 0.0)
        assert got is not None
        assert snap.available == m.available - 4
        assert m.available == 8
        m.check_occupancy()

    def test_quota_snapshot_tokens_isolated(self):
        loop = EventLoop()
        m = BasicResourceManager(
            ApiResourceSpec("api", mode="quota", quota=4, period_s=5.0), loop.clock
        )
        snap = m.snapshot()
        a = Action(name="a", cost={"api": fixed("api")}, trajectory_id="t0")
        assert snap.try_allocate(a, 2) is not None  # plan-side what-if only
        assert m.available == 4

    def test_snapshot_map_is_lazy(self):
        taken = []

        class Spy(ResourceManager):
            def snapshot(self):
                taken.append(self.rtype)
                return super().snapshot()

        managers = {"a": Spy("a", 4), "b": Spy("b", 4)}
        view = SnapshotMap(managers)
        assert "a" in view and "missing" not in view
        assert taken == []
        _ = view["a"]
        _ = view.get("a")  # cached — no second snapshot
        assert taken == ["a"]
        assert view.get("missing", None) is None
        assert taken == ["a"]


# ---------------------------------------------------------------------------
# occupancy invariant under randomized cancel/timeout storms (the
# note_released audit), plus the unlaunched-rollback token refund
# ---------------------------------------------------------------------------


class _FlakyManager(ResourceManager):
    """Admits but refuses the first ``fail_n`` placements — forces the
    partial-acquisition rollback path."""

    def __init__(self, rtype, capacity, fail_n):
        super().__init__(rtype, capacity)
        self.fail_n = fail_n

    def try_allocate(self, action, units):
        if self.fail_n > 0:
            self.fail_n -= 1
            return None
        return super().try_allocate(action, units)


class TestOccupancyInvariant:
    @pytest.mark.parametrize("shards", [None, 4])
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_cancel_timeout_storm_leaks_nothing(self, shards, seed):
        """Randomized cancels + tight timeouts with retries: after the
        storm drains, every manager's task_usage ledger must sum exactly
        to its held units (zero, here) — the invariant that catches any
        release path skipping note_released."""
        orch = _make_system(shards, cores=8)
        rng = random.Random(seed)
        actions, futs = [], []
        for i in range(40):
            kind = rng.random()
            if kind < 0.5:
                a = Action(
                    name="reward", cost={"cpu": ranged("cpu", 1, 4)},
                    key_resource="cpu", elasticity=AmdahlElasticity(0.1),
                    base_duration=rng.uniform(0.5, 4.0),
                    timeout_s=rng.choice([0.4, 1.5, None]),
                    max_retries=rng.choice([0, 1, 2]),
                    task_id=f"t{i % 3}", trajectory_id=f"t{i}",
                )
            elif kind < 0.8:
                a = Action(
                    name="rm:score", cost={"gpu": ResourceRequest("gpu", (1, 2))},
                    key_resource="gpu", elasticity=AmdahlElasticity(0.15),
                    base_duration=rng.uniform(0.5, 2.0), service="rm0",
                    timeout_s=rng.choice([0.5, None]), max_retries=1,
                    task_id=f"t{i % 3}", trajectory_id=f"t{i}",
                )
            else:
                a = Action(
                    name="api:q", cost={"api": fixed("api")},
                    base_duration=rng.uniform(0.1, 1.0),
                    task_id=f"t{i % 3}", trajectory_id=f"t{i}",
                )
            actions.append(a)
            futs.append(orch.submit(a, delay=rng.uniform(0.0, 3.0)))
        # storm of cancellations at random mid-run instants
        for a in rng.sample(actions, 12):
            orch.loop.call_after(rng.uniform(0.2, 4.0), lambda a=a: orch.cancel(a))
        # and invariant probes WHILE the storm is in flight
        for t in (1.0, 2.5, 4.0):
            orch.loop.call_after(t, lambda: _check_all_occupancy(orch))
        orch.run()
        assert all(f.done() for f in futs)
        assert orch.in_flight() == 0
        _check_all_occupancy(orch)
        for rtype in ("cpu", "gpu", "api"):
            assert orch.managers[rtype].task_usage() == {}

    def test_unlaunched_rollback_refunds_quota_tokens(self):
        """A partial acquisition that rolls back must REFUND quota
        tokens (the call never happened); the old release path silently
        burned them — the occupancy/quota leak this PR's audit fixes."""
        loop = EventLoop()
        managers = {
            "api": BasicResourceManager(
                ApiResourceSpec("api", mode="quota", quota=4, period_s=100.0),
                loop.clock,
            ),
            "flaky": _FlakyManager("flaky", 8, fail_n=2),
        }
        orch = Orchestrator(managers, loop=loop)
        fut = orch.submit(
            Action(
                name="a",
                cost={"api": fixed("api", 3), "flaky": fixed("flaky", 2)},
                key_resource="flaky",
                base_duration=1.0,
                trajectory_id="t0",
            )
        )
        orch.run()
        assert fut.done()
        # exactly ONE successful attempt consumed tokens; both rolled-
        # back attempts refunded theirs
        assert managers["api"].available == 1
        _check_all_occupancy(orch)

    def test_quota_occupancy_tracks_in_flight(self):
        """Quota-mode managers now track occupancy separately from
        tokens: mid-flight the ledger matches held units, and release
        clears occupancy without returning tokens."""
        loop = EventLoop()
        m = BasicResourceManager(
            ApiResourceSpec("api", mode="quota", quota=4, period_s=100.0), loop.clock
        )
        orch = Orchestrator({"api": m}, loop=loop)
        orch.submit(
            Action(name="a", cost={"api": fixed("api", 2)}, base_duration=1.0,
                   trajectory_id="t0", task_id="t")
        )
        orch.run(until=0.5)
        assert m.held_units() == 2
        assert m.task_usage() == {"t": 2}
        m.check_occupancy()
        orch.run()
        assert m.held_units() == 0
        assert m.task_usage() == {}
        assert m.available == 2  # tokens stay consumed until the refill
        m.check_occupancy()


# ---------------------------------------------------------------------------
# telemetry surface
# ---------------------------------------------------------------------------


class TestShardTelemetry:
    def test_per_shard_round_stats(self):
        orch = _make_system(2)
        _submit_workload(orch, seed=5)
        orch.run()
        assert orch.telemetry.shards  # populated by the plan phase
        total_rounds = sum(s.rounds for s in orch.telemetry.shards.values())
        assert total_rounds >= orch.stats["sharded_rounds"]
        summary = orch.telemetry.shard_summary()
        assert summary["imbalance"] >= 1.0
        assert summary["plan_critical_s"] <= summary["plan_total_s"] + 1e-12
        assert not math.isnan(summary["plan_wall_s"])

    def test_serial_mode_has_no_shard_stats(self):
        orch = _make_system(None)
        _submit_workload(orch, seed=5, n=20)
        orch.run()
        assert orch.telemetry.shard_summary() == {}
        assert orch.stats["sharded_rounds"] == 0
