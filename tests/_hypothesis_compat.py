"""Shared hypothesis import guard: property tests skip cleanly on a
checkout without the dev-only dependency (requirements-dev.txt), while
the plain unit tests in the same modules keep running."""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover - property tests skip cleanly

    def given(*a, **k):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()
