"""Socket shard transport, fault tolerance, and the chaos storm rails.

Three layers of guarantees:

* **framing** — length-prefixed frames fail with *typed* errors on
  every malformed input (mid-frame disconnect, oversized length,
  refused connect, read timeout) so the round client can route every
  failure through one recovery rail;
* **lifecycle** — transports are safe to close twice, safe to close
  concurrently with a blocked read, and process-backed workers never
  outlive an abandoned orchestrator;
* **equivalence under fire** — an 8-seed kill/restart/reconnect storm
  over real TCP sockets, plus packet-level chaos schedules (drops,
  truncation, silent worker amnesia), must produce launch traces
  bit-identical to the serial round loop with zero lost or doubled
  launches.
"""

import gc
import multiprocessing
import socket
import threading
import time

import pytest

from repro.core import wire
from repro.core.remote import ProcessTransport, _sweep_process_transports
from repro.core.transport import (
    ChaosPlan,
    ChaosTransport,
    SocketTransport,
    WorkerServer,
    chaos_fleet,
    read_frame,
    socket_fleet,
    write_frame,
)
from repro.core.wire import TransportError

from test_remote import _make_system, _submit_workload, _trace


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _drain_frame() -> bytes:
    """The smallest real round-trip: a drain envelope the worker
    answers with ``drain_response``."""
    return wire.encode_frame(wire.envelope("drain", {}), "json")


def _free_port() -> int:
    """A port that was just free — nothing listens on it afterwards."""
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _pipe_pair():
    """A connected TCP socket pair on loopback (real sockets, so
    shutdown semantics match production, unlike socketpair on some
    platforms)."""
    with socket.socket() as srv:
        srv.bind(("127.0.0.1", 0))
        srv.listen(1)
        client = socket.create_connection(srv.getsockname(), timeout=5)
        peer, _ = srv.accept()
    return client, peer


def _run_serial(seed, tasks=("heavy", "light"), n=60):
    orch = _make_system(shards=4, plan_mode="inline")
    _submit_workload(orch, seed=seed, tasks=list(tasks), n=n)
    orch.run()
    trace = _trace(orch)
    orch.close()
    return trace


def _run_socket(seed, transport, tasks=("heavy", "light"), n=60, kills=(), **kw):
    orch = _make_system(shards=4, plan_mode="remote", transport=transport, **kw)
    _submit_workload(orch, seed=seed, tasks=list(tasks), n=n)
    for t, fn in kills:
        orch.loop.call_after(t, fn)
    orch.run()
    trace = _trace(orch)
    summary = orch.telemetry.wire_summary()
    orch.close()
    return trace, summary


# ---------------------------------------------------------------------------
# framing edge cases
# ---------------------------------------------------------------------------


class TestFraming:
    def test_round_trip(self):
        client, peer = _pipe_pair()
        try:
            write_frame(client, b"\xb1hello")
            assert read_frame(peer) == b"\xb1hello"
        finally:
            client.close()
            peer.close()

    def test_mid_frame_disconnect_is_truncated(self):
        """Peer dies after the header + part of the payload: the reader
        gets ``truncated_frame``, not a hang or a short read."""
        client, peer = _pipe_pair()
        try:
            import struct

            peer.sendall(struct.pack(">I", 100) + b"only-part")
            peer.close()
            with pytest.raises(TransportError) as ei:
                read_frame(client)
            assert ei.value.code == "truncated_frame"
        finally:
            client.close()

    def test_header_only_disconnect_is_truncated(self):
        client, peer = _pipe_pair()
        try:
            peer.sendall(b"\x00\x00")  # half a length prefix
            peer.close()
            with pytest.raises(TransportError) as ei:
                read_frame(client)
            assert ei.value.code == "truncated_frame"
        finally:
            client.close()

    def test_oversized_length_rejected_before_allocation(self):
        """A hostile/corrupt length prefix larger than MAX_FRAME_BYTES
        is refused from the 4 header bytes alone."""
        client, peer = _pipe_pair()
        try:
            import struct

            peer.sendall(struct.pack(">I", wire.MAX_FRAME_BYTES + 1))
            with pytest.raises(TransportError) as ei:
                read_frame(client)
            assert ei.value.code == "frame_too_large"
        finally:
            client.close()
            peer.close()

    def test_oversized_write_rejected_locally(self):
        client, peer = _pipe_pair()
        try:
            blob = memoryview(bytearray(8))  # stand-in; size check first

            class Huge(bytes):
                def __len__(self):
                    return wire.MAX_FRAME_BYTES + 1

            with pytest.raises(TransportError) as ei:
                write_frame(client, Huge(blob))
            assert ei.value.code == "frame_too_large"
        finally:
            client.close()
            peer.close()

    def test_zero_length_frame_is_truncated(self):
        client, peer = _pipe_pair()
        try:
            import struct

            peer.sendall(struct.pack(">I", 0))
            with pytest.raises(TransportError) as ei:
                read_frame(client)
            assert ei.value.code == "truncated_frame"
        finally:
            client.close()
            peer.close()


# ---------------------------------------------------------------------------
# SocketTransport lifecycle
# ---------------------------------------------------------------------------


class TestSocketTransport:
    def test_connect_refused_is_typed(self):
        t = SocketTransport(("127.0.0.1", _free_port()), connect_timeout=2)
        with pytest.raises(TransportError) as ei:
            t.submit(b"x")
        assert ei.value.code == "connect"
        t.close()

    def test_read_timeout_is_typed_and_resets(self):
        """A worker that never answers trips ``read_timeout`` and drops
        the connection so the next submit reconnects."""
        with socket.socket() as srv:
            srv.bind(("127.0.0.1", 0))
            srv.listen(1)
            t = SocketTransport(srv.getsockname(), read_timeout=0.2)
            t.submit(b"ping")
            with pytest.raises(TransportError) as ei:
                t.recv()
            assert ei.value.code == "read_timeout"
            assert t._sock is None  # connection dropped → reconnect next
            t.close()

    def test_double_close_is_idempotent(self):
        with WorkerServer() as srv:
            t = SocketTransport(srv.addr)
            t.submit(_drain_frame())
            t.recv()
            t.close()
            t.close()  # second close is a no-op, not an error
            with pytest.raises(TransportError) as ei:
                t.submit(b"x")
            assert ei.value.code == "closed"

    def test_recv_without_submit_is_closed(self):
        t = SocketTransport(("127.0.0.1", 1))
        with pytest.raises(TransportError) as ei:
            t.recv()
        assert ei.value.code == "closed"

    def test_concurrent_close_wakes_blocked_reader(self):
        """close() from another thread while recv() is blocked must wake
        the reader with a typed error (teardown during an in-flight
        pipelined round)."""
        with socket.socket() as srv:
            srv.bind(("127.0.0.1", 0))
            srv.listen(1)
            t = SocketTransport(srv.getsockname(), read_timeout=30)
            t.submit(b"ping")  # server never answers
            errors = []

            def reader():
                try:
                    t.recv()
                    errors.append(None)
                except TransportError as e:
                    errors.append(e.code)

            th = threading.Thread(target=reader)
            th.start()
            time.sleep(0.05)  # let the reader block in recv
            t.close()
            th.join(timeout=5)
            assert not th.is_alive()
            assert errors and errors[0] in ("reset", "truncated_frame", "closed")

    def test_context_manager_closes(self):
        with WorkerServer() as srv:
            with SocketTransport(srv.addr) as t:
                t.submit(_drain_frame())
                t.recv()
            with pytest.raises(TransportError):
                t.submit(b"x")

    def test_socket_fleet_maps_shards_to_addrs(self):
        fac = socket_fleet([("a", 1), ("b", 2)])
        assert fac(0).addr == ("a", 1)
        assert fac(1).addr == ("b", 2)
        assert fac(2).addr == ("a", 1)  # wraps
        with pytest.raises(ValueError):
            socket_fleet([])

    def test_zero_arg_transport_factories_still_work(self):
        """Pre-fleet callers pass a transport class/zero-arg factory
        (``transport=LoopbackTransport``); the client must keep
        accepting those beside ``shard_idx -> transport`` fleets."""
        from repro.core.remote import LoopbackTransport, _per_shard

        wrapped = _per_shard(LoopbackTransport)
        a, b = wrapped(0), wrapped(1)
        assert isinstance(a, LoopbackTransport) and a is not b

        def fleet(shard_idx):
            return ("fleet", shard_idx)

        assert _per_shard(fleet)(3) == ("fleet", 3)


class TestWorkerServer:
    def test_kill_connections_counts_and_endpoint_survives(self):
        with WorkerServer() as srv:
            t = SocketTransport(srv.addr)
            t.submit(_drain_frame())
            t.recv()
            deadline = time.monotonic() + 5
            killed = 0
            while killed == 0 and time.monotonic() < deadline:
                killed = srv.kill_connections()
                time.sleep(0.01)
            assert killed == 1
            # the dropped connection surfaces as a typed error ...
            with pytest.raises(TransportError):
                t.submit(_drain_frame())
                t.recv()
            # ... and the endpoint is still up: reconnect just works
            t.submit(_drain_frame())
            assert t.recv()
            t.close()

    def test_close_is_idempotent(self):
        srv = WorkerServer()
        srv.close()
        srv.close()


# ---------------------------------------------------------------------------
# ProcessTransport leak regression
# ---------------------------------------------------------------------------


class TestProcessTransportLeak:
    def test_abandoned_transport_reaps_child(self):
        """Dropping the last reference without close() must not leak the
        daemonic worker process (``__del__`` closes it)."""
        t = ProcessTransport()
        t.submit(_drain_frame())
        t.recv()
        proc = t._proc
        assert proc.is_alive()
        del t
        gc.collect()
        proc.join(timeout=10)
        assert not proc.is_alive()

    def test_abandoned_orchestrator_leaves_no_children(self):
        """End to end: run a remote round over process workers, abandon
        the orchestrator without close(), and verify no child process
        survives collection."""
        before = {p.pid for p in multiprocessing.active_children()}
        orch = _make_system(shards=2, plan_mode="remote", transport="process")
        _submit_workload(orch, seed=3, tasks=["heavy"], n=12)
        orch.run()
        del orch
        gc.collect()
        deadline = time.monotonic() + 10
        while time.monotonic() < deadline:
            leaked = {
                p.pid for p in multiprocessing.active_children()
            } - before
            if not leaked:
                break
            time.sleep(0.05)
        assert not leaked

    def test_atexit_sweep_closes_stragglers(self):
        t = ProcessTransport()
        t.submit(_drain_frame())
        t.recv()
        proc = t._proc
        _sweep_process_transports()
        proc.join(timeout=10)
        assert not proc.is_alive()
        t.close()  # still idempotent after the sweep


# ---------------------------------------------------------------------------
# chaos plans
# ---------------------------------------------------------------------------


class _CountingInner:
    """Minimal in-memory transport standing in for a worker."""

    def __init__(self, log):
        self.log = log
        log.append("new")

    def submit(self, request):
        self.log.append("submit")
        self._last = request

    def recv(self):
        self.log.append("recv")
        return b"ok"

    def close(self):
        self.log.append("close")


class TestChaosTransport:
    def test_drop_submit_raises_and_rebuilds(self):
        log = []
        t = ChaosTransport(lambda: _CountingInner(log), schedule={0: "drop_submit"})
        with pytest.raises(TransportError) as ei:
            t.submit(b"a")
        assert ei.value.code == "reset"
        t.submit(b"b")  # index 1: clean
        assert t.recv() == b"ok"
        assert t.plan.faults_fired == 1
        assert log.count("new") == 2  # rebuilt after the fault

    def test_drop_recv_and_truncate_fire_at_recv(self):
        log = []
        t = ChaosTransport(
            lambda: _CountingInner(log), schedule={0: "drop_recv", 1: "truncate"}
        )
        t.submit(b"a")
        with pytest.raises(TransportError) as ei:
            t.recv()
        assert ei.value.code == "reset"
        t.submit(b"b")
        with pytest.raises(TransportError) as ei:
            t.recv()
        assert ei.value.code == "truncated_frame"
        assert t.plan.faults_fired == 2

    def test_amnesia_is_silent(self):
        log = []
        t = ChaosTransport(lambda: _CountingInner(log), schedule={1: "amnesia"})
        t.submit(b"a")
        assert t.recv() == b"ok"
        t.submit(b"b")  # amnesia: no error, but a fresh inner
        assert t.recv() == b"ok"
        assert log.count("new") == 2
        assert t.plan.faults_fired == 1

    def test_plan_survives_transport_rebuild(self):
        """The whole point of ChaosPlan: a client that recreates the
        transport must not restart the request counter or re-arm
        already-fired faults."""
        log = []
        plan = ChaosPlan({0: "drop_submit", 2: "drop_recv"})
        t1 = ChaosTransport(lambda: _CountingInner(log), plan=plan)
        with pytest.raises(TransportError):
            t1.submit(b"a")  # index 0 fires
        t1.close()
        t2 = ChaosTransport(lambda: _CountingInner(log), plan=plan)
        t2.submit(b"b")  # index 1: clean — NOT a replay of index 0
        assert t2.recv() == b"ok"
        t2.submit(b"c")  # index 2 fires at recv
        with pytest.raises(TransportError):
            t2.recv()
        assert plan.requests == 3
        assert plan.faults_fired == 2

    def test_chaos_fleet_shares_plans(self):
        fac = chaos_fleet(lambda i: _CountingInner([]), {0: {0: "drop_submit"}})
        t = fac(0)
        with pytest.raises(TransportError):
            t.submit(b"a")
        t2 = fac(0)  # rebuild: same plan object
        assert t2.plan is t.plan
        assert fac.plans[0].faults_fired == 1


# ---------------------------------------------------------------------------
# equivalence under fire: the storm rails
# ---------------------------------------------------------------------------

STORM_SEEDS = list(range(8))
KILL_TIMES = (0.5, 1.5, 2.5, 4.0, 6.0, 8.0)

# per-seed wire summaries, filled by the parametrized storm test and
# audited in aggregate afterwards (whether a given seed's rounds
# interleave with the virtual-time kills depends on its workload shape,
# so the losses/reconnects floor is a storm-wide property)
_storm_summaries = {}


class TestKillRestartStorm:
    """The acceptance rail: 8 seeds of kill/restart/reconnect storms
    over real TCP sockets, every launch trace bit-identical to serial,
    zero lost or doubled launches."""

    @pytest.mark.parametrize("seed", STORM_SEEDS)
    def test_kill_storm_trace_identical_to_serial(self, seed):
        serial = _run_serial(seed)
        with WorkerServer() as srv:
            kills = [(t, srv.kill_connections) for t in KILL_TIMES]
            trace, summary = _run_socket(
                seed, socket_fleet([srv.addr]), kills=kills
            )
        assert trace == serial
        # zero lost / doubled launches
        uids = [(r[0], r[1], r[2]) for r in trace]
        assert len(uids) == len(set(uids)) == len(serial)
        _storm_summaries[seed] = summary

    def test_storm_actually_stormed(self):
        """Across the 8 seeds the kills really interleaved with rounds:
        workers were lost, clients reconnected, partitions fell back
        inline — the identical traces above were earned, not vacuous."""
        assert len(_storm_summaries) == len(STORM_SEEDS)
        losses = sum(s["worker_losses"] for s in _storm_summaries.values())
        reconnects = sum(s["reconnects"] for s in _storm_summaries.values())
        inline = sum(s["inline_parts"] for s in _storm_summaries.values())
        assert losses >= 8
        assert reconnects >= 4
        assert inline >= losses  # every loss fell back inline

    def test_clean_socket_round_matches_serial(self):
        serial = _run_serial(99)
        with WorkerServer() as srv:
            trace, summary = _run_socket(99, socket_fleet([srv.addr]))
        assert trace == serial
        assert summary["worker_losses"] == 0
        assert summary["rounds"] > 0

    def test_two_server_fleet_matches_serial(self):
        serial = _run_serial(41)
        with WorkerServer() as a, WorkerServer() as b:
            trace, summary = _run_socket(41, socket_fleet([a.addr, b.addr]))
        assert trace == serial

    def test_dead_fleet_runs_entirely_inline(self):
        """Every worker unreachable: all partitions fall back to inline
        planning, the run still completes, trace still identical."""
        serial = _run_serial(17)
        fac = socket_fleet([("127.0.0.1", _free_port())], connect_timeout=0.5)
        trace, summary = _run_socket(17, fac)
        assert trace == serial
        assert summary["worker_losses"] >= 1
        assert summary["inline_parts"] >= 1
        assert summary["reconnects"] == 0  # it never came back


class TestChaosStorm:
    """Packet-level fault schedules over real sockets."""

    def test_amnesia_storm_drives_full_resend_rail(self):
        """Silent worker replacement must surface as typed stale-state
        errors absorbed by the full-resend rail — NOT worker losses."""
        serial = _run_serial(11, n=80)
        with WorkerServer() as srv:
            fac = chaos_fleet(
                lambda i: SocketTransport(srv.addr),
                {0: {2: "amnesia", 5: "amnesia"}, 1: {3: "amnesia"}, 2: {1: "amnesia"}},
            )
            trace, summary = _run_socket(11, fac, n=80)
        assert trace == serial
        assert summary["fallbacks"] >= 1  # stale-ref storm absorbed
        assert summary["worker_losses"] == 0

    def test_mixed_storm_trace_identical(self):
        serial = _run_serial(23, n=80)
        with WorkerServer() as srv:
            fac = chaos_fleet(
                lambda i: SocketTransport(srv.addr),
                {
                    0: {2: "drop_recv", 6: "amnesia"},
                    1: {1: "drop_submit", 5: "truncate"},
                    2: {4: "amnesia", 7: "drop_recv"},
                },
            )
            trace, summary = _run_socket(23, fac, n=80)
        assert trace == serial
        assert summary["worker_losses"] >= 1
        assert summary["reconnects"] >= 1
        uids = [(r[0], r[1], r[2]) for r in trace]
        assert len(uids) == len(set(uids))

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_seeded_chaos_storms(self, seed):
        import random

        rng = random.Random(1000 + seed)
        faults = ["drop_submit", "drop_recv", "truncate", "amnesia"]
        schedules = {
            i: {rng.randrange(1, 10): rng.choice(faults) for _ in range(2)}
            for i in range(4)
        }
        serial = _run_serial(seed, n=80)
        with WorkerServer() as srv:
            fac = chaos_fleet(lambda i: SocketTransport(srv.addr), schedules)
            trace, _summary = _run_socket(seed, fac, n=80)
        assert trace == serial


# ---------------------------------------------------------------------------
# worker-owned commit under fire: leases + two-phase over real sockets
# ---------------------------------------------------------------------------

_wc_storm_summaries = {}


class TestWorkerCommitStorm:
    """The two-phase worker-owned commit under the same kill/restart
    storms: authoritative replicas die mid-protocol, orphaned leases
    are adopted, fresh workers refuse stale epochs — and every launch
    trace stays bit-identical to serial."""

    @pytest.mark.parametrize("seed", STORM_SEEDS)
    def test_kill_storm_worker_commit_trace_identical(self, seed):
        serial = _run_serial(seed)
        with WorkerServer() as srv:
            kills = [(t, srv.kill_connections) for t in KILL_TIMES]
            trace, summary = _run_socket(
                seed, socket_fleet([srv.addr]), kills=kills,
                commit_mode="worker",
            )
        assert trace == serial
        uids = [(r[0], r[1], r[2]) for r in trace]
        assert len(uids) == len(set(uids)) == len(serial)
        _wc_storm_summaries[seed] = summary

    def test_storm_exercised_the_ownership_rails(self):
        """Across the seeds the storm really hit the two-phase rails:
        prepares happened, workers died holding leases (adoptions or
        regrants recovered them), and no round ever diverged."""
        assert len(_wc_storm_summaries) == len(STORM_SEEDS)
        agg = {}
        for s in _wc_storm_summaries.values():
            for k, v in s.items():
                agg[k] = agg.get(k, 0.0) + v
        assert agg.get("prepares", 0) > 0
        assert agg.get("worker_losses", 0) >= 1
        # every storm recovery rode a typed rail: adoption (loss) or
        # regrant (restarted worker refused a stale epoch)
        assert agg.get("lease_adoptions", 0) + agg.get("lease_regrants", 0) >= 1
        assert agg.get("commit_diverged", 0) == 0

    def test_clean_worker_commit_round_matches_serial(self):
        """Steady state over a real socket: fused rounds carry the
        commits, zero fallbacks, zero aborts, zero losses."""
        serial = _run_serial(99)
        with WorkerServer() as srv:
            trace, summary = _run_socket(
                99, socket_fleet([srv.addr]), commit_mode="worker"
            )
        assert trace == serial
        assert summary["worker_losses"] == 0
        assert summary.get("fallbacks", 0) == 0
        assert summary.get("prepares", 0) > 0
        assert summary.get("commit_aborts", 0) == 0

    def test_amnesia_storm_rides_the_stale_epoch_rail(self):
        """Silent worker replacement while leases are held: the blank
        worker must refuse the epoch assertion typed (stale_epoch ->
        regrant + full re-send), never launch doubled state."""
        serial = _run_serial(11, n=80)
        with WorkerServer() as srv:
            fac = chaos_fleet(
                lambda i: SocketTransport(srv.addr),
                {0: {2: "amnesia", 5: "amnesia"}, 1: {3: "amnesia"},
                 2: {1: "amnesia"}},
            )
            trace, summary = _run_socket(11, fac, n=80, commit_mode="worker")
        assert trace == serial
        assert summary["worker_losses"] == 0
        assert (
            summary.get("lease_regrants", 0) + summary.get("fallbacks", 0) >= 1
        )

    def test_dead_fleet_declines_to_inline(self):
        """Every worker unreachable: no worker can hold authoritative
        state, every round falls back, the run still completes with the
        serial trace."""
        serial = _run_serial(17)
        fac = socket_fleet([("127.0.0.1", _free_port())], connect_timeout=0.5)
        trace, summary = _run_socket(17, fac, commit_mode="worker")
        assert trace == serial
        assert summary["worker_losses"] >= 1
