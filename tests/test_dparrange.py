"""DPArrange (Algorithms 3 & 4): unit + property tests vs brute force."""


import pytest

from _hypothesis_compat import given, settings, st

from repro.core.dparrange import (
    BasicDPOperator,
    DPTask,
    GpuChunkDPOperator,
    brute_force_arrange,
    dp_arrange,
)


def make_task(name, units, t_ori, serial=0.1):
    durs = tuple(t_ori / (m / (1 + serial * (m - 1))) for m in units)
    return DPTask(name, tuple(units), durs)


class TestBasicOperator:
    def test_single_task_takes_best_units(self):
        t = make_task("a", (1, 2, 4, 8), 8.0, serial=0.0)  # perfect scaling
        res = dp_arrange([t], BasicDPOperator(8))
        assert res is not None
        assert res.allocation["a"] == 8
        assert res.total_duration == pytest.approx(1.0)

    def test_respects_capacity(self):
        tasks = [make_task(f"t{i}", (1, 2, 4), 4.0) for i in range(3)]
        res = dp_arrange(tasks, BasicDPOperator(4))
        assert res is not None
        assert sum(res.allocation.values()) <= 4
        assert all(res.allocation[t.name] >= 1 for t in tasks)

    def test_infeasible_returns_none(self):
        tasks = [make_task(f"t{i}", (2, 4), 1.0) for i in range(3)]
        assert dp_arrange(tasks, BasicDPOperator(5)) is None

    def test_inexact_total_is_handled(self):
        # sets {1,4} x2 with 7 units: exact-7 impossible, best feasible is 5
        tasks = [make_task("a", (1, 4), 8.0, 0.0), make_task("b", (1, 4), 8.0, 0.0)]
        res = dp_arrange(tasks, BasicDPOperator(7))
        assert res is not None
        assert sorted(res.allocation.values()) == [1, 4]

    def test_prefers_scaling_long_task(self):
        long = make_task("long", (1, 2, 4), 100.0, serial=0.0)
        short = make_task("short", (1, 2, 4), 1.0, serial=0.0)
        res = dp_arrange([long, short], BasicDPOperator(5))
        assert res.allocation["long"] == 4
        assert res.allocation["short"] == 1


@settings(max_examples=200, deadline=None)
@given(
    n_tasks=st.integers(1, 4),
    total=st.integers(1, 10),
    data=st.data(),
)
def test_basic_dp_matches_brute_force(n_tasks, total, data):
    tasks = []
    for i in range(n_tasks):
        units = tuple(
            sorted(
                data.draw(
                    st.sets(st.integers(1, 6), min_size=1, max_size=4),
                    label=f"units{i}",
                )
            )
        )
        durs = tuple(
            data.draw(
                st.floats(0.1, 100.0, allow_nan=False, allow_infinity=False),
                label=f"dur{i}_{k}",
            )
            for k in units
        )
        tasks.append(DPTask(f"t{i}", units, durs))
    got = dp_arrange(tasks, BasicDPOperator(total))
    want = brute_force_arrange(tasks, total)
    if want is None:
        assert got is None
    else:
        assert got is not None
        assert got.total_duration == pytest.approx(want.total_duration)
        # allocation must itself be feasible and consistent
        assert sum(got.allocation.values()) <= total
        recomputed = sum(
            t.durations[t.units.index(got.allocation[t.name])] for t in tasks
        )
        assert recomputed == pytest.approx(got.total_duration)


class TestGpuChunkOperator:
    def test_encode_decode_roundtrip(self):
        op = GpuChunkDPOperator((8, 4, 2, 1))
        for a in range(9):
            for b in range(5):
                for c in range(3):
                    for d in range(2):
                        assert op.decode(op.encode((a, b, c, d))) == (a, b, c, d)

    def test_greedy_decompose(self):
        gd = GpuChunkDPOperator.greedy_decompose
        assert gd(8) == (0, 0, 0, 1)
        assert gd(7) == (1, 1, 1, 0)
        assert gd(1) == (1, 0, 0, 0)
        assert gd(0) is None

    def test_prev_consumes_from_state(self):
        op = GpuChunkDPOperator((8, 4, 2, 1))
        j = op.encode((2, 1, 0, 0))  # consumed: two 1-chunks + one 2-chunk
        # allocating 2 more GPUs from predecessor: prev must remove a 2-chunk
        p = op.prev(j, 2)
        assert p is not None
        assert op.decode(p) == (2, 0, 0, 0)

    def test_prev_insufficient(self):
        op = GpuChunkDPOperator((8, 4, 2, 1))
        j = op.encode((1, 0, 0, 0))
        assert op.prev(j, 4) is None

    def test_dp_with_chunk_topology(self):
        # one 8-GPU node, two tasks wanting {1,2,4,8}: best is 4+4
        op = GpuChunkDPOperator((8, 4, 2, 1), total_devices=8)
        tasks = [make_task(f"t{i}", (1, 2, 4, 8), 8.0, serial=0.0) for i in range(2)]
        res = dp_arrange(tasks, op)
        assert res is not None
        assert sorted(res.allocation.values()) == [4, 4]
        assert res.total_duration == pytest.approx(4.0)

    def test_feasibility_callback_restricts(self):
        # feasible() rejects any use of 4-chunks -> forces 2+2
        def feas(counts):
            return counts[2] == 0 and counts[3] == 0

        op = GpuChunkDPOperator((8, 4, 2, 1), feasible=feas)
        tasks = [make_task(f"t{i}", (1, 2, 4), 8.0, serial=0.0) for i in range(2)]
        res = dp_arrange(tasks, op)
        assert res is not None
        assert max(res.allocation.values()) <= 2


@settings(max_examples=100, deadline=None)
@given(n_tasks=st.integers(1, 3), data=st.data())
def test_gpu_dp_matches_brute_force_on_pow2(n_tasks, data):
    """With power-of-two unit sets and a single node the chunk DP must
    equal the unconstrained brute force (an 8-device buddy pool can
    realize any power-of-two multiset that fits)."""
    tasks = []
    for i in range(n_tasks):
        units = tuple(
            sorted(
                data.draw(
                    st.sets(st.sampled_from([1, 2, 4, 8]), min_size=1, max_size=4),
                    label=f"units{i}",
                )
            )
        )
        durs = tuple(
            data.draw(st.floats(0.1, 50.0, allow_nan=False), label=f"d{i}{k}")
            for k in units
        )
        tasks.append(DPTask(f"t{i}", units, durs))

    def pool_feasible(counts):
        total = sum(c * s for c, s in zip(counts, (1, 2, 4, 8)))
        return total <= 8

    op = GpuChunkDPOperator((8, 4, 2, 1), feasible=pool_feasible)
    got = dp_arrange(tasks, op)
    want = brute_force_arrange(tasks, 8)
    if want is None:
        assert got is None
    else:
        assert got is not None
        assert got.total_duration == pytest.approx(want.total_duration)
