"""Property tests for the MoE dispatch/combine invariants (1-device)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.models import moe
from repro.models.layers import _pad_plan


def _cfg(E, K, cf):
    return dataclasses.replace(
        get_config("granite-moe-3b-a800m").reduced(),
        num_experts=E, experts_per_token=K, capacity_factor=cf,
    )


@settings(max_examples=25, deadline=None)
@given(
    E=st.integers(2, 12),
    K=st.integers(1, 4),
    T=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_combine_is_convex_combination(E, K, T, seed):
    """With ample capacity, each token's output is a prob-weighted sum of
    expert outputs — identity experts must return the input scaled by 1
    (probs renormalize to sum 1)."""
    K = min(K, E)
    cfg = _cfg(E, K, cf=float(E))  # capacity >= T: no drops
    D = 16
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (1, T, D), jnp.float32)
    params = {
        "router": jax.random.normal(jax.random.fold_in(key, 1), (D, E)) * 0.3,
        # identity experts: silu(x@I)*x@I ... not identity; instead use
        # w_gate scaled so h = silu(g)*u with w_up carrying identity and
        # w_down identity is nonlinear — so test linearity differently:
        # zero experts -> zero output.
        "w_gate": jnp.zeros((E, D, D)),
        "w_up": jnp.zeros((E, D, D)),
        "w_down": jnp.zeros((E, D, D)),
    }
    y, aux = moe._moe_ffn_global(params, x, cfg, None)
    assert np.allclose(np.asarray(y), 0.0), "zero experts must yield zero"
    assert np.isfinite(float(aux["load_balance"]))
    assert np.isfinite(float(aux["router_z"]))


@settings(max_examples=25, deadline=None)
@given(
    E=st.integers(2, 10),
    K=st.integers(1, 3),
    seed=st.integers(0, 2**16),
)
def test_capacity_drop_only_reduces_norm(E, K, seed):
    """Shrinking capacity only ever drops contributions (never invents
    new ones): per-token output of low-cf run equals the high-cf run
    wherever no assignment of that token was dropped."""
    K = min(K, E)
    T, D = 32, 16
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (1, T, D), jnp.float32) * 0.5
    params = {
        "router": jax.random.normal(jax.random.fold_in(key, 1), (D, E)) * 0.5,
        "w_gate": jax.random.normal(jax.random.fold_in(key, 2), (E, D, D)) * 0.2,
        "w_up": jax.random.normal(jax.random.fold_in(key, 3), (E, D, D)) * 0.2,
        "w_down": jax.random.normal(jax.random.fold_in(key, 4), (E, D, D)) * 0.2,
    }
    y_full, _ = moe._moe_ffn_global(params, x, _cfg(E, K, cf=float(E)), None)
    y_low, _ = moe._moe_ffn_global(params, x, _cfg(E, K, cf=0.5), None)
    # low-capacity output is a partial sum of the full one: for every
    # token it equals the full output minus some subset of expert
    # contributions — so where they differ the low norm cannot exceed
    # full norm by more than numerical noise in the OPPOSITE direction
    # is not guaranteed; instead check the universally true invariant:
    assert np.isfinite(np.asarray(y_low)).all()
    # tokens whose outputs match are a superset of tokens with no drops;
    # at cf=E nothing can drop, so y_full is the reference everywhere
    same = np.isclose(np.asarray(y_low), np.asarray(y_full), atol=1e-5).all(axis=-1)
    # at least the earliest-sorted tokens keep their slots under FCFS rank
    assert same.any(), "capacity 0.5 dropped literally every token"


@settings(max_examples=60, deadline=None)
@given(kv=st.integers(1, 16), g=st.integers(1, 16),
       ext=st.sampled_from([2, 4, 8, 16]))
def test_pad_plan_properties(kv, g, ext):
    kv_p, g_p = _pad_plan(kv, g, ext)
    assert kv_p >= kv and g_p >= g
    assert (kv_p * g_p) % ext == 0
    # minimality: no strictly smaller feasible product
    best = min(
        kp * gp
        for kp in range(kv, kv + ext)
        for gp in range(g, g + ext)
        if (kp * gp) % ext == 0
    )
    assert kv_p * g_p == best


@settings(max_examples=20, deadline=None)
@given(tokens=st.integers(1, 100_000))
def test_expert_capacity_alignment(tokens):
    cfg = _cfg(8, 2, cf=1.25)
    c = moe.expert_capacity(tokens, cfg)
    need = -(-tokens * 2 // 8)  # ceil(T*K/E) before cf
    assert c >= min(need, c)  # sanity
    if c >= 128:
        assert c % 128 == 0
    else:
        assert c % 8 == 0 and c >= 8
    # capacity covers the cf-scaled expected load
    import math
    assert c >= math.ceil(tokens * 2 / 8 * 1.25) or c % 128 == 0
