"""Event-driven orchestrator: incremental-vs-full decision equivalence,
partitioned queues, dirty-tracking skips, and the stalled-launch guard."""

import random

import pytest

from repro.core.action import Action, AmdahlElasticity, ResourceRequest, fixed, ranged
from repro.core.baselines import FcfsPolicy, StaticDopPolicy
from repro.core.cluster import ApiResourceSpec, CpuNodeSpec, GpuNodeSpec
from repro.core.managers.base import ResourceManager
from repro.core.managers.basic import BasicResourceManager
from repro.core.managers.cpu import CpuManager
from repro.core.managers.gpu import GpuManager, ServiceSpec
from repro.core.orchestrator import Orchestrator, candidate_window
from repro.core.scheduler import ElasticScheduler
from repro.core.simulator import EventLoop


# ---------------------------------------------------------------------------
# workload / system factories (fresh managers + actions per run, so two
# orchestrator modes replay identical event traces)
# ---------------------------------------------------------------------------


def _make_system(incremental: bool, cores: int = 32, gpus: int = 1):
    loop = EventLoop()
    managers = {
        "cpu": CpuManager([CpuNodeSpec("n0", cores=cores)]),
        "gpu": GpuManager(
            [GpuNodeSpec(f"g{i}") for i in range(gpus)], [ServiceSpec("rm0", 40.0)]
        ),
        "api": BasicResourceManager(
            ApiResourceSpec("api", mode="quota", quota=4, period_s=5.0), loop.clock
        ),
    }
    return Orchestrator(managers, loop=loop, incremental=incremental)


def _submit_workload(orch: Orchestrator, seed: int, n: int = 60) -> None:
    rng = random.Random(seed)
    for i in range(n):
        kind = rng.random()
        delay = rng.uniform(0.0, 5.0)
        if kind < 0.4:
            a = Action(
                name="reward:pytest",
                cost={"cpu": ranged("cpu", 1, 8)},
                key_resource="cpu",
                elasticity=AmdahlElasticity(0.08),
                base_duration=rng.uniform(1.0, 8.0),
                trajectory_id=f"t{i}",
            )
        elif kind < 0.6:
            a = Action(
                name="tool:exec",
                cost={"cpu": fixed("cpu", rng.choice((1, 2)))},
                base_duration=rng.uniform(0.2, 2.0),
                trajectory_id=f"t{i}",
            )
        elif kind < 0.8:
            a = Action(
                name="rm:score",
                cost={"gpu": ResourceRequest("gpu", (1, 2, 4, 8))},
                key_resource="gpu",
                elasticity=AmdahlElasticity(0.15),
                base_duration=rng.uniform(0.5, 3.0),
                service="rm0",
                trajectory_id=f"t{i}",
            )
        else:
            a = Action(
                name="api:search",
                cost={"api": fixed("api")},
                base_duration=rng.uniform(0.1, 1.0),
                trajectory_id=f"t{i}",
            )
        orch.submit(a, delay=delay)


def _trace(orch: Orchestrator):
    """Observable launch/completion trace, insensitive to uid numbering."""
    return sorted(
        (r.name, r.trajectory_id, round(r.submit, 9), round(r.start, 9),
         round(r.finish, 9), tuple(sorted(r.units.items())), r.failed)
        for r in orch.telemetry.records
    )


# ---------------------------------------------------------------------------
# incremental == full rescheduling
# ---------------------------------------------------------------------------


class TestIncrementalEquivalence:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5, 6, 7])
    def test_same_decisions_as_full_reschedule(self, seed):
        """Dirty-tracked incremental rounds (partition skips + admission
        cursor + DP memo) must launch exactly what rescheduling every
        partition from scratch with the seed O(n^2) window would."""
        inc = _make_system(incremental=True)
        full = _make_system(incremental=False)
        _submit_workload(inc, seed)
        _submit_workload(full, seed)
        inc.run()
        full.run()
        assert len(inc.telemetry.records) == 60
        assert _trace(inc) == _trace(full)
        assert inc.queue_depth() == 0 and inc.in_flight() == 0

    def test_identical_queue_state_single_round(self):
        """Same queue, same managers: one coalesced round produces the
        same decisions in both modes (unit-level equivalence)."""
        for seed in range(4):
            inc = _make_system(incremental=True)
            full = _make_system(incremental=False)
            rng_actions = lambda: None  # noqa: E731 - readability only
            for orch in (inc, full):
                rng = random.Random(seed + 100)
                for i in range(24):
                    orch.submit(
                        Action(
                            name=f"a{i}",
                            cost={"cpu": ranged("cpu", 1, 8)},
                            key_resource="cpu",
                            elasticity=AmdahlElasticity(0.1),
                            base_duration=rng.uniform(1.0, 20.0),
                            trajectory_id=f"t{i}",
                        )
                    )
                orch.run(until=0.0)  # exactly the coalesced first round
            started_inc = sorted(
                (a.name, a.state.value) for a in inc._executing.values()
            )
            started_full = sorted(
                (a.name, a.state.value) for a in full._executing.values()
            )
            assert started_inc == started_full

    def test_dp_cache_reuses_arrangements(self):
        orch = _make_system(incremental=True)
        _submit_workload(orch, seed=9, n=80)
        orch.run()
        sched = orch.policy
        assert sched.dp_cache_hits > 0  # steady churn re-sees group states

    def test_incremental_skips_partitions(self):
        """A cpu-only event stream must not re-run the api/gpu partitions."""
        inc = _make_system(incremental=True)
        full = _make_system(incremental=False)
        for orch in (inc, full):
            for i in range(40):
                orch.submit(
                    Action(
                        name="tool",
                        cost={"cpu": fixed("cpu", 8)},
                        base_duration=1.0,
                        trajectory_id=f"t{i}",
                    ),
                    delay=0.01 * i,
                )
            # one queued api action that never becomes admissible mid-churn
            orch.submit(
                Action(name="api:q", cost={"api": fixed("api", 4)},
                       base_duration=0.1, trajectory_id="api0"),
                delay=0.0,
            )
            orch.run()
        assert _trace(inc) == _trace(full)
        assert inc.stats["partition_runs"] < full.stats["partition_runs"]


# ---------------------------------------------------------------------------
# queues, window, policies
# ---------------------------------------------------------------------------


class TestPartitionedQueues:
    def test_partitions_do_not_block_each_other(self):
        """An inadmissible cpu head must not starve gpu/api work (the seed
        global FCFS window would)."""
        orch = _make_system(incremental=True, cores=4)
        blocked = Action(
            name="big", cost={"cpu": fixed("cpu", 64)}, base_duration=1.0,
            trajectory_id="tb",
        )
        orch.submit(blocked)
        done = orch.submit(
            Action(name="api:q", cost={"api": fixed("api")}, base_duration=0.5,
                   trajectory_id="ta"),
        )
        orch.run(until=10.0)
        assert done.done()  # api partition progressed independently

    def test_candidate_window_matches_full_rescan(self):
        """Incremental admission cursor == per-prefix can_accommodate."""
        rng = random.Random(4)
        managers = {"cpu": ResourceManager("cpu", 13)}
        waiting = [
            Action(name=f"a{i}", cost={"cpu": fixed("cpu", rng.randint(1, 5))},
                   trajectory_id=f"t{i}")
            for i in range(12)
        ]
        fast = candidate_window(waiting, managers, limit=128)
        # reference: the seed scan
        best = 0
        for i in range(1, len(waiting) + 1):
            if managers["cpu"].can_accommodate(waiting[:i]):
                best = i
            else:
                break
        assert [a.uid for a in fast] == [a.uid for a in waiting[:best]]

    def test_fcfs_policy_runs_min_units(self):
        loop = EventLoop()
        orch = Orchestrator(
            {"cpu": CpuManager([CpuNodeSpec("n0", cores=16)])},
            loop=loop,
            policy=FcfsPolicy(),
        )
        futs = [
            orch.submit(
                Action(
                    name=f"a{i}",
                    cost={"cpu": ranged("cpu", 1, 8)},
                    key_resource="cpu",
                    elasticity=AmdahlElasticity(0.05),
                    base_duration=4.0,
                    trajectory_id=f"t{i}",
                )
            )
            for i in range(4)
        ]
        orch.run()
        assert all(f.done() for f in futs)
        assert all(r.units["cpu"] == 1 for r in orch.telemetry.records)

    def test_static_dop_policy_pins_units(self):
        loop = EventLoop()
        orch = Orchestrator(
            {"cpu": CpuManager([CpuNodeSpec("n0", cores=16)])},
            loop=loop,
            policy=StaticDopPolicy(dop=4),
        )
        for i in range(3):
            orch.submit(
                Action(
                    name=f"a{i}",
                    cost={"cpu": ranged("cpu", 1, 8)},
                    key_resource="cpu",
                    elasticity=AmdahlElasticity(0.05),
                    base_duration=4.0,
                    trajectory_id=f"t{i}",
                )
            )
        orch.run()
        assert all(r.units["cpu"] == 4 for r in orch.telemetry.records)

    def test_elastic_policy_beats_fcfs_on_mean_act(self):
        """The pluggable-policy seam: same orchestrator, same workload,
        elastic allocation must not lose to rigid FCFS."""

        def run(policy):
            loop = EventLoop()
            orch = Orchestrator(
                {"cpu": CpuManager([CpuNodeSpec("n0", cores=32)])},
                loop=loop, policy=policy,
            )
            rng = random.Random(7)
            for i in range(24):
                orch.submit(
                    Action(
                        name="r",
                        cost={"cpu": ranged("cpu", 1, 8)},
                        key_resource="cpu",
                        elasticity=AmdahlElasticity(0.05),
                        base_duration=rng.uniform(2.0, 10.0),
                        trajectory_id=f"t{i}",
                    ),
                    delay=rng.uniform(0, 3.0),
                )
            orch.run()
            return orch.telemetry.mean_act()

        assert run(ElasticScheduler()) <= run(FcfsPolicy()) + 1e-9


# ---------------------------------------------------------------------------
# stalled-launch guard (the seed bug: a failed try_allocate left the
# action QUEUED with no guaranteed re-tick unless a refill manager existed)
# ---------------------------------------------------------------------------


class _FlakyManager(ResourceManager):
    """Refuses the first ``fail_n`` allocations despite having capacity —
    models placement-level failures the admission test cannot see."""

    def __init__(self, rtype, capacity, fail_n):
        super().__init__(rtype, capacity)
        self.fail_n = fail_n

    def try_allocate(self, action, units):
        if self.fail_n > 0:
            self.fail_n -= 1
            return None
        return super().try_allocate(action, units)


class TestStalledLaunchGuard:
    def test_failed_launch_retries_without_refill_or_inflight(self):
        loop = EventLoop()
        orch = Orchestrator({"cpu": _FlakyManager("cpu", 8, fail_n=2)}, loop=loop)
        fut = orch.submit(
            Action(name="a", cost={"cpu": fixed("cpu", 2)}, base_duration=1.0,
                   trajectory_id="t0")
        )
        orch.run()
        assert fut.done()
        assert fut.result() == pytest.approx(1.0)
        assert orch.stats["launch_failures"] >= 1

    def test_unschedulable_queue_quiesces(self):
        """An action that can never fit must not spin the event loop."""
        loop = EventLoop()
        orch = Orchestrator({"cpu": ResourceManager("cpu", 4)}, loop=loop)
        orch.submit(
            Action(name="too-big", cost={"cpu": fixed("cpu", 64)},
                   base_duration=1.0, trajectory_id="t0")
        )
        end = orch.run()  # must terminate
        assert orch.queue_depth() == 1
        assert end < 1.0
