"""The wire bill: canonical fingerprints, binary framing, byte-budget
LRU caches, cross-round list deltas, and typed-error recovery.

These are the rails for the delta/interning protocol: equal payloads
must always collide (fingerprints are delta suppression), both codecs
must decode to identical payloads (json is the property-test
reference), caches must stay bounded, and every stale-state path must
end in a full re-send — never a silently wrong plan."""

import math
import random

import pytest

from repro.core import wire
from repro.core.action import (
    Action,
    AmdahlElasticity,
    ResourceRequest,
    fixed,
    ranged,
)
from repro.core.cluster import GpuNodeSpec
from repro.core.fairqueue import PartitionQueue
from repro.core.managers.base import ResourceManager
from repro.core.managers.gpu import GpuManager, ServiceSpec
from repro.core.orchestrator import Orchestrator
from repro.core.remote import (
    LoopbackTransport,
    RemoteShardWorker,
)
from repro.core.simulator import EventLoop


# ---------------------------------------------------------------------------
# canonical fingerprints (satellite b: equal payloads always collide)
# ---------------------------------------------------------------------------


class TestCanonicalFingerprint:
    def test_key_order_invariant(self):
        a = {"x": 1, "y": [1, 2, {"p": 3.5, "q": None}]}
        b = {"y": [1, 2, {"q": None, "p": 3.5}], "x": 1}
        assert wire.fingerprint(a) == wire.fingerprint(b)

    def test_negative_zero_collides_with_zero(self):
        """A JSON round trip may turn -0.0 into 0 — the two sides must
        still agree the payload is unchanged (regression: ref misses on
        every idle round when a manager clock serializes as -0.0)."""
        assert wire.fingerprint({"t": -0.0}) == wire.fingerprint({"t": 0})

    def test_integral_float_collides_with_int(self):
        """json.loads(dumps(2.0)) == 2.0 but a recompute may produce the
        int 2; both canonical forms must hash identically."""
        assert wire.fingerprint([2.0, 10.0]) == wire.fingerprint([2, 10])
        # ...but only within the exact-integer range
        assert wire.fingerprint(2.5) != wire.fingerprint(2)

    def test_nan_and_infinities(self):
        assert wire.fingerprint(float("nan")) == wire.fingerprint(float("nan"))
        assert wire.fingerprint(float("inf")) != wire.fingerprint(float("-inf"))
        assert wire.fingerprint(float("inf")) != wire.fingerprint(float("nan"))

    def test_bool_is_not_int(self):
        assert wire.fingerprint(True) != wire.fingerprint(1)
        assert wire.fingerprint(False) != wire.fingerprint(0)

    def test_string_length_prefix_prevents_aliasing(self):
        """Strings are length-prefixed in the canonical form, so a
        string containing canonical-form syntax cannot alias a
        structure (regression for the json.dumps-free fast path)."""
        assert wire.fingerprint(["ab"]) != wire.fingerprint(["a", "b"])
        assert wire.fingerprint({"a:b": 1}) != wire.fingerprint({"a": "b1"})
        assert wire.fingerprint('{"k":1}') != wire.fingerprint({"k": 1})

    def test_non_jsonable_rejected(self):
        with pytest.raises(wire.WireError, match="non-JSON-able"):
            wire.fingerprint({"f": object()})

    def test_list_fingerprint_is_order_sensitive(self):
        assert wire.list_fingerprint(["a", "b"]) != wire.list_fingerprint(["b", "a"])
        assert wire.list_fingerprint(["a", "b"]) == wire.list_fingerprint(["a", "b"])
        assert wire.list_fingerprint([]) != wire.list_fingerprint(["a"])


# ---------------------------------------------------------------------------
# byte-budget LRU (satellite a: worker caches cannot grow unbounded)
# ---------------------------------------------------------------------------


class TestLruBytes:
    def test_evicts_least_recently_touched_under_byte_budget(self):
        lru = wire.LruBytes(100)
        lru.put("a", 1, 40)
        lru.put("b", 2, 40)
        assert lru.get("a") == 1  # refresh a: b is now the oldest
        lru.put("c", 3, 40)  # 120 > 100: evict b, not a
        assert "b" not in lru and lru.get("b") is None
        assert lru.get("a") == 1 and lru.get("c") == 3
        assert lru.evictions == 1
        assert lru.nbytes == 80

    def test_replacement_adjusts_byte_total(self):
        lru = wire.LruBytes(100)
        lru.put("a", 1, 60)
        lru.put("a", 2, 10)
        assert lru.nbytes == 10 and lru.get("a") == 2 and len(lru) == 1

    def test_single_over_budget_entry_is_kept(self):
        """The table must stay usable even when one payload exceeds the
        whole budget — evicting it would livelock define/ref."""
        lru = wire.LruBytes(50)
        lru.put("big", "x", 500)
        assert lru.get("big") == "x" and lru.nbytes == 500
        lru.put("big2", "y", 600)  # now the older one can go
        assert "big" not in lru and lru.get("big2") == "y"

    def test_pop_and_clear(self):
        lru = wire.LruBytes(100)
        lru.put("a", 1, 30)
        lru.pop("a")
        assert lru.nbytes == 0 and "a" not in lru
        lru.pop("a")  # absent: no-op
        lru.put("b", 2, 30)
        lru.clear()
        assert lru.nbytes == 0 and len(lru) == 0

    def test_zero_budget_rejected(self):
        with pytest.raises(ValueError):
            wire.LruBytes(0)


# ---------------------------------------------------------------------------
# binary framing (tentpole layer 2: json is the decode-equivalence
# reference)
# ---------------------------------------------------------------------------


def _random_payload(rng, depth=0):
    """Random JSON-able payload (NaN excluded — equality-compared;
    NaN framing is asserted separately)."""
    kinds = "int float str bool none"
    if depth < 3:
        kinds += " list dict ints floats"
    kind = rng.choice(kinds.split())
    if kind == "int":
        return rng.randint(-(2**40), 2**40)
    if kind == "float":
        return rng.uniform(-1e6, 1e6)
    if kind == "str":
        return "".join(rng.choice("abcé☃:{}[]\"") for _ in range(rng.randint(0, 12)))
    if kind == "bool":
        return rng.random() < 0.5
    if kind == "none":
        return None
    if kind == "ints":  # packed-column shape: homogeneous int list
        return [rng.randint(0, 1000) for _ in range(rng.randint(1, 8))]
    if kind == "floats":
        return [rng.uniform(0, 1) for _ in range(rng.randint(1, 8))]
    if kind == "list":
        return [_random_payload(rng, depth + 1) for _ in range(rng.randint(0, 5))]
    return {
        f"k{i}": _random_payload(rng, depth + 1) for i in range(rng.randint(0, 5))
    }


class TestBinaryFrame:
    @pytest.mark.parametrize("seed", range(8))
    def test_binary_decodes_equal_to_json(self, seed):
        """8 seeds: the two codecs must decode to identical payloads —
        the JSON text path is the v1 reference the binary codec is held
        to."""
        rng = random.Random(seed)
        for _ in range(25):
            p = _random_payload(rng)
            via_json = wire.decode_frame(wire.encode_frame(p, "json"))
            via_bin = wire.decode_frame(wire.encode_frame(p, "binary"))
            assert via_bin == via_json == p

    def test_nan_and_infinities_survive_binary(self):
        blob = wire.encode_frame([float("nan"), float("inf"), float("-inf")], "binary")
        nan, pos, neg = wire.decode_frame(blob)
        assert math.isnan(nan) and pos == math.inf and neg == -math.inf

    def test_magic_byte_discriminates(self):
        p = {"v": 1, "kind": "x"}
        bj = wire.encode_frame(p, "json")
        bb = wire.encode_frame(p, "binary")
        assert wire.frame_codec(bj) == "json"
        assert wire.frame_codec(bb) == "binary"
        assert bb[0] == wire.WIRE_MAGIC and bj[0] != wire.WIRE_MAGIC

    def test_repeated_strings_intern_within_frame(self):
        """Frame-level string interning: a payload repeating one long
        key must cost far less than the JSON text repeating it."""
        key = "a-rather-long-repeated-field-name"
        p = [{key: i} for i in range(50)]
        bb = wire.encode_frame(p, "binary")
        bj = wire.encode_frame(p, "json")
        assert len(bb) < len(bj) / 2

    def test_malformed_binary_frames_rejected(self):
        with pytest.raises(wire.WireError, match="empty"):
            wire.decode_frame(b"")
        good = wire.encode_frame([1, 2], "binary")
        with pytest.raises(wire.WireError, match="trailing"):
            wire.decode_frame(good + b"\x00")
        with pytest.raises(wire.WireError, match="unknown value tag|truncated"):
            wire.decode_frame(bytes([wire.WIRE_MAGIC, 0xEE]))
        with pytest.raises(wire.WireError, match="unknown wire codec"):
            wire.encode_frame({}, "msgpack")

    def test_worker_answers_in_the_request_codec(self):
        """A binary request gets a binary response (and errors stay in
        kind too) — the client never has to guess."""
        worker = RemoteShardWorker()
        bad = wire.envelope("plan_request", {"snapshots": {}, "partitions": []})
        for codec in wire.WIRE_CODECS:
            resp = worker.handle_bytes(wire.encode_frame(bad, codec))
            assert wire.frame_codec(resp) == codec
            assert wire.decode_frame(resp)["kind"] == "error"


# ---------------------------------------------------------------------------
# structural snapshot deltas: edge cases (satellite d)
# ---------------------------------------------------------------------------


def _gpu_manager():
    return GpuManager([GpuNodeSpec("g0")], [ServiceSpec("rm0", 40.0)])


def _gpu_action(i, units=(1, 2)):
    return Action(
        name=f"rm:score{i}",
        cost={"gpu": ResourceRequest("gpu", units)},
        key_resource="gpu",
        base_duration=1.0,
        service="rm0",
        trajectory_id=f"g{i}",
    )


class TestSnapshotDeltaEdges:
    def test_empty_delta_is_a_noop(self):
        m = _gpu_manager()
        snap = wire.encode_snapshot(m)
        fp = wire.fingerprint(snap)
        delta = wire.encode_snapshot_delta(m, snap["state"], snap["state"], fp, fp)
        rebuilt = wire.apply_snapshot_delta(delta, snap)
        assert rebuilt == snap
        assert wire.fingerprint(rebuilt) == fp

    def test_chunk_churn_diffs_stay_small(self):
        """A round that (de)allocates a few chunks must travel as a
        delta much smaller than the full snapshot — the whole point of
        structural diffs on the bytes-dominant GPU free map."""
        m = GpuManager(
            [GpuNodeSpec(f"g{i}") for i in range(16)], [ServiceSpec("rm0", 40.0)]
        )
        snap1 = wire.encode_snapshot(m)
        fp1 = wire.fingerprint(snap1)
        a0, a1 = _gpu_action(0), _gpu_action(1)
        alloc0 = m.try_allocate(a0, 2)
        alloc1 = m.try_allocate(a1, 1)
        assert alloc0 is not None and alloc1 is not None
        m.release(a1, alloc1)
        snap2 = wire.encode_snapshot(m)
        fp2 = wire.fingerprint(snap2)
        assert fp2 != fp1
        delta = wire.encode_snapshot_delta(m, snap1["state"], snap2["state"], fp1, fp2)
        assert wire.apply_snapshot_delta(delta, snap1) == snap2
        delta_bytes = wire.payload_nbytes(delta)
        full_bytes = wire.payload_nbytes(snap2)
        assert delta_bytes < full_bytes / 3, (delta_bytes, full_bytes)

    def test_mismatched_base_raises_wire_error(self):
        """Applying a delta to the wrong base must fail the fingerprint
        verification loudly — apply never returns a state the sender
        did not hash."""
        m = _gpu_manager()
        snap1 = wire.encode_snapshot(m)
        fp1 = wire.fingerprint(snap1)
        a0 = _gpu_action(0)
        alloc = m.try_allocate(a0, 2)
        assert alloc is not None
        snap2 = wire.encode_snapshot(m)
        delta = wire.encode_snapshot_delta(
            m, snap1["state"], snap2["state"], fp1, wire.fingerprint(snap2)
        )
        m2 = _gpu_manager()
        assert m2.try_allocate(_gpu_action(9), 4) is not None
        other = wire.encode_snapshot(m2)
        with pytest.raises(wire.WireError):
            wire.apply_snapshot_delta(delta, other)

    def test_worker_recovers_from_bad_base_via_full_snapshot(self):
        """End to end through a worker: a delta naming a base the worker
        does not hold is a typed ``stale_base``; the follow-up full
        snapshot plans normally (the recovery round the client drives)."""
        from repro.core.scheduler import ElasticScheduler

        m = ResourceManager("r", 8)
        snap = wire.encode_snapshot(m)

        def req(snapshots, policy):
            return wire.envelope(
                "plan_request",
                {
                    "shard": 0,
                    "now": 0.0,
                    "incremental": True,
                    "policy": wire.encode_policy(ElasticScheduler()) if policy else None,
                    "fair_share": None,
                    "history": None,
                    "snapshots": snapshots,
                    "executing": [],
                    "partitions": [{"part": "r", "waiting": []}],
                },
            )

        worker = RemoteShardWorker()
        bad_delta = wire.envelope(
            "snapshot_delta",
            {"rtype": "r", "impl": snap["impl"], "base": "no-such-base",
             "fp": "whatever", "delta": {}},
        )
        resp = wire.decode_frame(worker.handle_bytes(wire.encode_frame(
            req({"r": bad_delta}, policy=True), "json")))
        assert resp["kind"] == "error" and resp["code"] == "stale_base"
        resp = wire.decode_frame(worker.handle_bytes(wire.encode_frame(
            req({"r": snap}, policy=True), "json")))
        assert resp["kind"] == "plan_response"


# ---------------------------------------------------------------------------
# cross-round list deltas + interning at the worker protocol level
# ---------------------------------------------------------------------------


def _exec_action(i):
    return Action(
        name=f"run{i}",
        cost={"r": fixed("r", 1)},
        base_duration=1.0,
        trajectory_id=f"e{i}",
    )


class TestWorkerListProtocol:
    def _worker_and_req(self):
        from repro.core.scheduler import ElasticScheduler

        m = ResourceManager("r", 8)
        snap = wire.encode_snapshot(m)
        fp = wire.fingerprint(snap)
        worker = RemoteShardWorker()

        def req(executing, first=False):
            return wire.envelope(
                "plan_request",
                {
                    "shard": 0,
                    "now": 0.0,
                    "incremental": True,
                    "policy": (
                        wire.encode_policy(ElasticScheduler()) if first else None
                    ),
                    "fair_share": None,
                    "history": None,
                    "snapshots": {"r": snap if first else {"ref": fp}},
                    "executing": executing,
                    "partitions": [{"part": "r", "waiting": []}],
                },
            )

        def ask(executing, first=False):
            return wire.decode_frame(
                worker.handle_bytes(wire.encode_frame(req(executing, first), "json"))
            )

        return worker, ask

    def _nodes(self, actions):
        enc = [wire.encode_action(a) for a in actions]
        fps = [wire.fingerprint(n) for n in enc]
        return enc, fps, wire.list_fingerprint(fps)

    def test_full_then_ref_then_delta(self):
        worker, ask = self._worker_and_req()
        a, b, c = (_exec_action(i) for i in range(3))
        enc, fps, lfp = self._nodes([a, b])
        assert ask({"k": "full", "fp": lfp, "items": enc}, first=True)[
            "kind"] == "plan_response"
        assert ask({"k": "ref", "fp": lfp})["kind"] == "plan_response"
        # delta: drop a, append c after the kept b
        enc_c = wire.encode_action(c)
        fp_c = wire.fingerprint(enc_c)
        new_lfp = wire.list_fingerprint([fps[1], fp_c])
        resp = ask({"k": "delta", "base": lfp, "fp": new_lfp,
                    "rm": [fps[0]], "ins": [[1, enc_c]]})
        assert resp["kind"] == "plan_response"
        # the delta committed: the new list is now ref-able
        assert ask({"k": "ref", "fp": new_lfp})["kind"] == "plan_response"

    def test_stale_ref_and_stale_base_are_typed(self):
        worker, ask = self._worker_and_req()
        enc, fps, lfp = self._nodes([_exec_action(0)])
        assert ask({"k": "full", "fp": lfp, "items": enc}, first=True)[
            "kind"] == "plan_response"
        resp = ask({"k": "ref", "fp": "not-the-list"})
        assert resp["kind"] == "error" and resp["code"] == "stale_ref"
        resp = ask({"k": "delta", "base": "not-the-list", "fp": lfp,
                    "rm": [], "ins": []})
        assert resp["kind"] == "error" and resp["code"] == "stale_base"

    def test_delta_mismatch_does_not_poison_the_cache(self):
        """A delta whose reconstruction misses the sender's fingerprint
        is a typed error, and the worker's cached base survives — the
        next valid ref still hits."""
        worker, ask = self._worker_and_req()
        enc, fps, lfp = self._nodes([_exec_action(0), _exec_action(1)])
        assert ask({"k": "full", "fp": lfp, "items": enc}, first=True)[
            "kind"] == "plan_response"
        resp = ask({"k": "delta", "base": lfp, "fp": "wrong-target",
                    "rm": [fps[0]], "ins": []})
        assert resp["kind"] == "error" and resp["code"] == "delta_mismatch"
        assert ask({"k": "ref", "fp": lfp})["kind"] == "plan_response"

    def test_missing_intern_fails_atomically_with_names(self):
        """An intern miss must fail the whole request BEFORE any list
        commit, naming every missing fingerprint — the client re-sends
        full content once, and the worker never plans a partial queue."""
        worker, ask = self._worker_and_req()
        a = _exec_action(0)
        enc_a = wire.encode_action(a)
        fp_a = wire.fingerprint(enc_a)
        lfp = wire.list_fingerprint([fp_a])
        resp = ask({"k": "full", "fp": lfp, "items": [{"iref": fp_a}]}, first=True)
        assert resp["kind"] == "error" and resp["code"] == "stale_intern"
        assert resp["missing"] == [fp_a]
        # the failed full did NOT commit the list cache
        resp = ask({"k": "ref", "fp": lfp})
        assert resp["kind"] == "error" and resp["code"] == "stale_ref"
        # define + use in one round works and commits
        resp = ask({"k": "full", "fp": lfp,
                    "items": [{"idef": fp_a, "val": enc_a, "n": 300}]})
        assert resp["kind"] == "plan_response"
        assert ask({"k": "full", "fp": lfp, "items": [{"iref": fp_a}]})[
            "kind"] == "plan_response"
        assert ask({"k": "ref", "fp": lfp})["kind"] == "plan_response"


# ---------------------------------------------------------------------------
# end-to-end recovery: restarted / evicting workers mid-run
# ---------------------------------------------------------------------------


def _make_system(shards, pools=3, cores=4, **kw):
    loop = EventLoop()
    managers = {
        f"pool{k}": ResourceManager(f"pool{k}", cores) for k in range(pools)
    }
    return Orchestrator(managers, loop=loop, shards=shards, **kw)


def _submit_workload(orch, seed, pools=3, waves=8, per_pool=6, period=2.0):
    """Wave-style churn: every wave submits to all pools at one
    timestamp, so rounds are genuinely multi-partition (= sharded, =
    over the wire) and the queues stay deep enough for cross-round
    refs/deltas to matter."""
    rng = random.Random(seed)
    wave_no = [0]

    def wave():
        w = wave_no[0]
        wave_no[0] += 1
        for k in range(pools):
            for i in range(per_pool):
                orch.submit(
                    Action(
                        name=f"a{w}-{i}",
                        cost={f"pool{k}": ranged(f"pool{k}", 1, 3)},
                        key_resource=f"pool{k}",
                        elasticity=AmdahlElasticity(0.1),
                        base_duration=rng.uniform(0.5, 3.0),
                        task_id="t",
                        trajectory_id=f"p{k}-w{w}-{i}",
                    )
                )
        if w + 1 < waves:
            orch.loop.call_after(period, wave)

    wave()


def _trace(orch):
    return sorted(
        (r.name, r.trajectory_id, round(r.submit, 9), round(r.start, 9),
         round(r.finish, 9), tuple(sorted(r.units.items())), r.failed)
        for r in orch.telemetry.records
    )


class _RestartingLoopback(LoopbackTransport):
    """Loopback whose worker silently restarts after N requests — the
    client's sent-state (snapshot fps, list bases, intern mirror) now
    describes a worker that remembers nothing."""

    restart_after = 10
    _count = 0

    def submit(self, request):
        cls = _RestartingLoopback
        cls._count += 1
        if cls._count == cls.restart_after:
            self._worker = RemoteShardWorker()
        super().submit(request)


class _EvictingLoopback(LoopbackTransport):
    """Loopback whose worker runs a far smaller intern budget than the
    client mirrors — worker-side evictions the mirror cannot predict."""

    def __init__(self):
        super().__init__()
        self._worker._interns = wire.LruBytes(2048)


class TestRecovery:
    def _run(self, shards, transport=None, seed=7, **kw):
        if transport is not None:
            kw["transport"] = transport
        orch = _make_system(shards, **kw)
        _submit_workload(orch, seed)
        orch.run()
        trace = _trace(orch)
        assert orch.queue_depth() == 0 and orch.in_flight() == 0
        orch.close()
        return orch, trace

    def test_worker_restart_recovers_bit_identically(self):
        _, serial = self._run(None)
        _RestartingLoopback._count = 0
        orch, trace = self._run(
            2, transport=_RestartingLoopback, plan_mode="remote"
        )
        assert trace == serial
        assert orch.telemetry.wire_fallbacks >= 1

    def test_intern_budget_divergence_recovers_bit_identically(self):
        """The worker evicts payloads the client's (bigger) mirror still
        holds; every miss is a typed stale_intern + one full re-send —
        counted, and never a wrong plan."""
        _, serial = self._run(None)
        orch, trace = self._run(
            2, transport=_EvictingLoopback, plan_mode="remote"
        )
        assert trace == serial
        assert orch.telemetry.wire_fallbacks >= 1

    def test_normal_run_has_no_fallbacks(self):
        """With same-budget mirrors and healthy workers the delta
        protocol must never need a recovery round — fallbacks are a
        telemetry signal, not a steady-state subsidy."""
        _, serial = self._run(None)
        orch, trace = self._run(2, plan_mode="remote")
        assert trace == serial
        if orch.telemetry.wire_rounds:
            assert orch.telemetry.wire_fallbacks == 0


# ---------------------------------------------------------------------------
# the wire bill shrinks across rounds (deltas + interning, observable)
# ---------------------------------------------------------------------------


class _RecordingLoopback(LoopbackTransport):
    frames = []

    def submit(self, request):
        _RecordingLoopback.frames.append(bytes(request))
        super().submit(request)


class TestCrossRoundShrink:
    def test_steady_state_requests_are_references(self):
        """After the first sharded round, repeated content travels as
        refs/deltas/irefs: later requests must be materially smaller
        than the priming ones, and must actually contain reference
        forms (not re-sent payloads)."""
        _RecordingLoopback.frames = []
        orch = _make_system(2, plan_mode="remote", transport=_RecordingLoopback)
        _submit_workload(orch, seed=3)
        orch.run()
        orch.close()
        frames = _RecordingLoopback.frames
        _RecordingLoopback.frames = []
        if len(frames) < 6:
            pytest.skip("workload produced too few sharded rounds")
        sizes = [len(f) for f in frames]
        first = max(sizes[:2])
        # completion-triggered rounds between waves change almost
        # nothing: they must travel as refs/deltas, a fraction of the
        # priming frame (wave rounds legitimately define new actions)
        assert min(sizes[2:]) < first / 3, (first, sorted(sizes[2:])[:3])
        tail_text = b"".join(frames[2:])
        assert b'"k":"ref"' in tail_text
        assert b'"k":"delta"' in tail_text
        # each action's payload travels when it changes, not once per
        # round it sits in a queue: total defines stay proportional to
        # the action count (arrival + a few mutations each), never to
        # queue-depth x rounds as full re-sends would be
        total_actions = 3 * 8 * 6  # pools x waves x per_pool
        defines = tail_text.count(b'"idef"')
        assert defines < 4 * total_actions, defines


# ---------------------------------------------------------------------------
# fairqueue version counter (drives the client's per-partition cache)
# ---------------------------------------------------------------------------


class TestQueueVersion:
    def _queue(self):
        return PartitionQueue("cpu")

    def _act(self, i, task="t"):
        return Action(
            name=f"q{i}", cost={"cpu": fixed("cpu", 1)}, base_duration=1.0,
            task_id=task, trajectory_id=f"{task}-{i}",
        )

    def test_membership_mutations_bump_version(self):
        q = self._queue()
        v0 = q.version
        a = self._act(0)
        q.push(a)
        assert q.version > v0
        v1 = q.version
        q.remove(a.uid)
        assert q.version > v1

    def test_ordered_is_stable_between_versions(self):
        q = self._queue()
        acts = [self._act(i) for i in range(5)]
        for a in acts:
            q.push(a)
        v = q.version
        first = [a.uid for a in q.ordered()]
        assert [a.uid for a in q.ordered()] == first
        assert q.version == v  # reads never bump
        q.remove(acts[2].uid)
        assert q.version > v
        assert [a.uid for a in q.ordered()] == [
            u for u in first if u != acts[2].uid
        ]


# ---------------------------------------------------------------------------
# encode memoization primitives: spliced segments, patch-defines,
# batched frames and the drain flush
# ---------------------------------------------------------------------------


class TestEncodedSegments:
    PAYLOAD = {
        "kind": "x", "vals": [1, 2.5, "s", None, {"k": [3, 4]}], "t": True,
    }

    def test_json_splice_is_byte_identical(self):
        """A frame assembled from cached json segments must be byte-for-
        byte the frame a plain dumps would have produced — splicing is
        an encode shortcut, never a wire dialect."""
        seg = wire.encode_segment(self.PAYLOAD, "json")
        framed = {"v": 1, "body": seg, "tail": [seg, 7]}
        plain = {"v": 1, "body": self.PAYLOAD, "tail": [self.PAYLOAD, 7]}
        assert wire.encode_frame(framed, "json") == wire.encode_frame(
            plain, "json"
        )

    def test_binary_blob_round_trips(self):
        """A binary segment is a standalone sub-frame with its own
        string table; strings repeated inside and outside the segment
        must not confuse either table."""
        seg = wire.encode_segment(self.PAYLOAD, "binary")
        framed = {"v": 1, "kind": "outer", "body": seg, "again": "kind"}
        blob = wire.encode_frame(framed, "binary")
        assert wire.decode_frame(blob) == {
            "v": 1, "kind": "outer", "body": self.PAYLOAD, "again": "kind",
        }

    def test_codec_mismatch_is_typed(self):
        jseg = wire.encode_segment(self.PAYLOAD, "json")
        bseg = wire.encode_segment(self.PAYLOAD, "binary")
        with pytest.raises(wire.WireError):
            wire.encode_frame({"x": bseg}, "json")
        with pytest.raises(wire.WireError):
            wire.encode_frame({"x": jseg}, "binary")

    def test_truncated_segment_is_typed(self):
        blob = wire.encode_frame(
            {"x": wire.encode_segment(self.PAYLOAD, "binary")}, "binary"
        )
        with pytest.raises(wire.WireError):
            wire.decode_frame(blob[:-1])


class TestPatchDefineResolution:
    def _act(self):
        return Action(
            name="r", cost={"cpu": ranged("cpu", 1, 4)}, key_resource="cpu",
            base_duration=2.0, task_id="t", trajectory_id="t-0",
        )

    def test_patch_define_through_a_real_worker(self):
        """Lifecycle transition as a patch-define: the worker clones its
        interned base, applies the diff, and the result is field-for-
        field the action a full re-send would have defined."""
        w = RemoteShardWorker()
        a = self._act()
        enc = wire.encode_action(a)
        fp0 = wire.fingerprint(enc)
        missing = []
        r0 = w._resolve_action(wire.intern_def(fp0, enc), missing)
        assert missing == [] and r0.uid == a.uid

        a.state = type(a.state)("running")
        a.start_time = 1.5
        a.attempts = 1
        d = {"state": a.state.value, "start_time": 1.5, "attempts": 1}
        fp1 = wire.fingerprint(wire.encode_action(a))
        r1 = w._resolve_action(wire.intern_patch(fp1, fp0, d), missing)
        assert missing == []
        assert wire.fingerprint(wire.encode_action(r1)) == fp1
        assert r1 is not r0  # the interned base was cloned, not mutated
        assert r0.state.value == "pending" and math.isnan(r0.start_time)
        assert w._stats["intern_patches"] == 1
        # the patched action is interned under the NEW fingerprint
        assert w._resolve_action({"iref": fp1}, missing) is r1

    def test_missing_base_reports_the_new_fingerprint(self):
        """A patch against an evicted base is exactly a missed ref — and
        what the worker asks to be re-sent is the NEW fingerprint (what
        the recovery full-send will define), not the base it lacks."""
        w = RemoteShardWorker()
        missing = []
        out = w._resolve_action(
            wire.intern_patch("fp-new", "fp-gone", {"start_time": 1.0}),
            missing,
        )
        assert out is None and missing == ["fp-new"]


class _StreamRecorder(LoopbackTransport):
    streams = []

    def __init__(self):
        super().__init__()
        self._frames = []
        _StreamRecorder.streams.append(self._frames)

    def submit(self, request):
        self._frames.append(bytes(request))
        super().submit(request)


class TestPlanBatchAndDrain:
    def _real_requests(self, seed=5):
        """Record one worker's full request stream from a healthy run —
        batching semantics are only meaningful against real frames whose
        refs/deltas/interns assume in-order application."""
        _StreamRecorder.streams = []
        orch = _make_system(2, plan_mode="remote", transport=_StreamRecorder)
        _submit_workload(orch, seed=seed)
        orch.run()
        orch.close()
        streams = _StreamRecorder.streams
        _StreamRecorder.streams = []
        frames = max(streams, key=len)
        reqs = [wire.decode_frame(f) for f in frames]
        return [r for r in reqs if r.get("kind") == "plan_request"]

    def test_plan_batch_equals_sequential_frames(self):
        """One plan_batch frame must produce exactly the plans the same
        requests produce as individual frames: each batched request is
        applied against the cache state its predecessors left behind."""
        reqs = self._real_requests()
        if len(reqs) < 4:
            pytest.skip("workload produced too few sharded rounds")

        def strip(plans):  # wall_s is a measured duration, not a plan
            return [
                {k: v for k, v in p.items() if k != "wall_s"} for p in plans
            ]

        w_seq = RemoteShardWorker()
        seq_plans = [strip(w_seq._handle(r)["plans"]) for r in reqs]

        w_bat = RemoteShardWorker()
        blob = wire.encode_frame(
            wire.envelope("plan_batch", {"reqs": reqs}), "json"
        )
        resp = wire.expect(
            wire.decode_frame(w_bat.handle_bytes(blob)), "plan_batch_response"
        )
        assert [strip(r["plans"]) for r in resp["resps"]] == seq_plans

    def test_drain_flushes_carried_dump_cost(self):
        """The run's LAST response-encode cost is carried, not dropped:
        a drain message flushes it into an accounted reply, and the
        carry starts over from just the drain's own (tiny) dump."""
        w = RemoteShardWorker()
        w._carry_dump_s = 0.125
        out = w.handle_bytes(
            wire.encode_frame(wire.envelope("drain", {}), "json")
        )
        resp = wire.expect(wire.decode_frame(out), "drain_response")
        assert resp["codec_s"] >= 0.125
        assert w._carry_dump_s < 0.125
