"""Out-of-process shard workers and sub-queue migration.

Equivalence rails: plan-over-wire (loopback AND real worker processes)
must launch exactly what the serial round loop launches on conflict-free
workloads; forced commit conflicts must converge over the wire; and
`TaskShard` migrate-then-merge must preserve WFQ order and virtual-clock
monotonicity."""

import math
import random

import pytest

from repro.core.action import Action, AmdahlElasticity, ResourceRequest, fixed, ranged
from repro.core.cluster import ApiResourceSpec, CpuNodeSpec, GpuNodeSpec
from repro.core.fairqueue import FairSharePolicy, PartitionQueue
from repro.core.managers.base import ResourceManager
from repro.core.managers.basic import BasicResourceManager
from repro.core.managers.cpu import CpuManager
from repro.core.managers.gpu import GpuManager, ServiceSpec
from repro.core.orchestrator import Orchestrator
from repro.core.remote import (
    LoopbackTransport,
    ProcessTransport,
    RemoteShardWorker,
)
from repro.core import wire
from repro.core.simulator import EventLoop


# ---------------------------------------------------------------------------
# workload factories (fresh managers + actions per run so every mode
# replays an identical event trace — mirrors tests/test_shards.py)
# ---------------------------------------------------------------------------


def _make_system(shards, incremental=True, fair=False, cores=32, **kw):
    loop = EventLoop()
    managers = {
        "cpu": CpuManager([CpuNodeSpec("n0", cores=cores)]),
        "gpu": GpuManager([GpuNodeSpec("g0")], [ServiceSpec("rm0", 40.0)]),
        "api": BasicResourceManager(
            ApiResourceSpec("api", mode="quota", quota=4, period_s=5.0), loop.clock
        ),
    }
    fs = FairSharePolicy(weights={"heavy": 2.0, "light": 1.0}) if fair else None
    return Orchestrator(
        managers, loop=loop, incremental=incremental, fair_share=fs,
        shards=shards, **kw,
    )


def _submit_workload(orch, seed, tasks=("task0",), n=60):
    rng = random.Random(seed)
    for i in range(n):
        task = tasks[i % len(tasks)]
        kind = rng.random()
        delay = rng.uniform(0.0, 5.0)
        if kind < 0.4:
            a = Action(
                name="reward", cost={"cpu": ranged("cpu", 1, 8)}, key_resource="cpu",
                elasticity=AmdahlElasticity(0.08), base_duration=rng.uniform(1, 8),
                task_id=task, trajectory_id=f"{task}-{i}",
            )
        elif kind < 0.6:
            a = Action(
                name="tool", cost={"cpu": fixed("cpu", rng.choice((1, 2)))},
                base_duration=rng.uniform(0.2, 2.0), task_id=task,
                trajectory_id=f"{task}-{i}",
            )
        elif kind < 0.8:
            a = Action(
                name="rm:score", cost={"gpu": ResourceRequest("gpu", (1, 2, 4, 8))},
                key_resource="gpu", elasticity=AmdahlElasticity(0.15),
                base_duration=rng.uniform(0.5, 3.0), service="rm0", task_id=task,
                trajectory_id=f"{task}-{i}",
            )
        else:
            a = Action(
                name="api:q", cost={"api": fixed("api")},
                base_duration=rng.uniform(0.1, 1.0), task_id=task,
                trajectory_id=f"{task}-{i}",
            )
        orch.submit(a, delay=delay)


def _trace(orch):
    return sorted(
        (r.name, r.task_id, r.trajectory_id, round(r.submit, 9), round(r.start, 9),
         round(r.finish, 9), tuple(sorted(r.units.items())), r.failed)
        for r in orch.telemetry.records
    )


def _run_mode(seed, tasks=("task0",), **kw):
    orch = _make_system(**kw)
    _submit_workload(orch, seed, tasks=tasks)
    orch.run()
    trace = _trace(orch)
    assert orch.queue_depth() == 0 and orch.in_flight() == 0
    for m in orch.managers.values():
        m.check_occupancy()
    orch.close()
    return orch, trace


# ---------------------------------------------------------------------------
# remote-plan trace identity (the acceptance rail)
# ---------------------------------------------------------------------------


class TestRemoteEquivalence:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5, 6, 7])
    def test_remote_loopback_bit_identical_to_serial(self, seed):
        """8 seeds: plans computed through the full wire codec path must
        launch exactly what the serial loop (and in-process sharding)
        launches on conflict-free workloads."""
        _, serial = _run_mode(seed, shards=None)
        _, remote1 = _run_mode(seed, shards=1, plan_mode="remote")
        orch4, remote4 = _run_mode(seed, shards=4, plan_mode="remote")
        assert remote1 == serial, f"seed {seed}: remote shards=1 diverged"
        assert remote4 == serial, f"seed {seed}: remote shards=4 diverged"
        # the wire was actually exercised (multi-partition rounds exist)
        if orch4.stats["sharded_rounds"]:
            assert orch4.telemetry.wire_rounds > 0
            assert orch4.telemetry.wire_bytes > 0

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_remote_fairness_equivalence(self, seed):
        """Multi-tenant WFQ queues drain identically when plans cross
        the wire (weights, quota budgeting, history all serialize)."""
        tasks = ("heavy", "light")
        _, serial = _run_mode(seed, tasks=tasks, shards=None, fair=True)
        _, remote = _run_mode(seed, tasks=tasks, shards=4, plan_mode="remote",
                              fair=True)
        assert remote == serial

    @pytest.mark.parametrize("seed", [0, 1])
    def test_remote_full_reschedule_equivalence(self, seed):
        _, serial = _run_mode(seed, shards=None, incremental=False)
        _, remote = _run_mode(seed, shards=4, plan_mode="remote",
                              incremental=False)
        assert remote == serial

    def test_remote_serialization_accounted_separately(self):
        """Wire overhead lands in Telemetry.wire_*, never in the modeled
        critical-path plan cost (which is worker-measured arrange time)."""
        orch, _ = _run_mode(3, shards=4, plan_mode="remote")
        t = orch.telemetry
        if not t.wire_rounds:
            pytest.skip("workload produced no multi-partition rounds")
        summary = t.wire_summary()
        assert summary["bytes"] > 0
        assert summary["encode_s"] > 0 and summary["decode_s"] > 0
        # the critical path is plan compute only; wire cost is additive
        # and visible on its own
        assert t.plan_critical_s <= t.plan_wall_s + 1e-9
        assert t.wire_encode_s + t.wire_decode_s <= t.plan_wall_s + 1e-9


class TestProcessTransport:
    def test_real_worker_processes_bit_identical(self):
        """The plan phase in actual OS processes: same trace, clean
        shutdown."""
        _, serial = _run_mode(2, shards=None)
        orch = _make_system(2, plan_mode="remote", transport="process")
        _submit_workload(orch, 2)
        orch.run()
        assert _trace(orch) == serial
        orch.close()
        orch.close()  # idempotent

    def test_unknown_transport_rejected(self):
        with pytest.raises(ValueError, match="unknown transport"):
            _make_system(2, plan_mode="remote", transport="carrier-pigeon")


# ---------------------------------------------------------------------------
# forced commit conflicts over the wire
# ---------------------------------------------------------------------------


class TestRemoteConflicts:
    def _conflict_system(self, shards, **kw):
        loop = EventLoop()
        managers = {
            "a": ResourceManager("a", 4),
            "b": ResourceManager("b", 4),
            "shared": ResourceManager("shared", 2),
        }
        return Orchestrator(managers, loop=loop, shards=shards, **kw)

    def _submit_contenders(self, orch, n=6):
        futs = []
        for i in range(n):
            part = "a" if i % 2 == 0 else "b"
            futs.append(
                orch.submit(
                    Action(
                        name=f"{part}{i}",
                        cost={part: fixed(part, 1), "shared": fixed("shared", 2)},
                        key_resource=part,
                        base_duration=1.0,
                        trajectory_id=f"t{i}",
                    )
                )
            )
        return futs

    def test_conflicts_converge_over_the_wire(self):
        """Two shards' remote plans claim the same shared pool off the
        same snapshot; the live commit refuses one, rolls it back, and
        the retry rail converges — no lost or double-launched action."""
        orch = self._conflict_system(shards=2, plan_mode="remote")
        futs = self._submit_contenders(orch)
        orch.run()
        assert orch.telemetry.commit_conflicts > 0
        assert all(f.done() for f in futs)
        records = [r for r in orch.telemetry.records if not r.failed]
        assert len(records) == 6
        assert len({r.trajectory_id for r in records}) == 6
        assert orch.queue_depth() == 0 and orch.in_flight() == 0
        for m in orch.managers.values():
            m.check_occupancy()
        orch.close()

    def test_conflict_trace_matches_in_process_sharding(self):
        """Remote and in-process sharding resolve the SAME conflicts the
        same way (the commit order is the global sorted partition walk
        either way)."""
        a = self._conflict_system(shards=2)
        b = self._conflict_system(shards=2, plan_mode="remote")
        self._submit_contenders(a)
        self._submit_contenders(b)
        a.run()
        b.run()
        assert _trace(a) == _trace(b)
        b.close()


# ---------------------------------------------------------------------------
# the worker protocol itself (deltas, errors)
# ---------------------------------------------------------------------------


class TestWorkerProtocol:
    def _request(self, policy=True, snapshots=None, waiting=(), now=0.0):
        from repro.core.scheduler import ElasticScheduler

        return wire.envelope(
            "plan_request",
            {
                "shard": 0,
                "now": now,
                "incremental": True,
                "policy": wire.encode_policy(ElasticScheduler()) if policy else None,
                "fair_share": None,
                "history": {"avg": {}},
                "snapshots": snapshots or {},
                "executing": [],
                "partitions": [
                    {"part": "r", "waiting": [wire.encode_action(a) for a in waiting]}
                ],
            },
        )

    def test_snapshot_delta_refs_replan_identically(self):
        m = ResourceManager("r", 8)
        snap = wire.encode_snapshot(m)
        fp = wire.fingerprint(snap)
        a = Action(name="w", cost={"r": fixed("r", 2)}, trajectory_id="t0",
                   base_duration=1.0)
        worker = RemoteShardWorker()
        full = wire.loads(worker.handle(wire.dumps(
            self._request(snapshots={"r": snap}, waiting=[a])
        )))
        ref = wire.loads(worker.handle(wire.dumps(
            self._request(policy=False, snapshots={"r": {"ref": fp}}, waiting=[a])
        )))
        assert full["kind"] == ref["kind"] == "plan_response"
        strip = lambda p: [
            {k: v for k, v in d.items() if k != "wall_s"} for d in p["plans"]
        ]
        assert strip(full) == strip(ref)

    def test_stale_snapshot_ref_is_protocol_error(self):
        worker = RemoteShardWorker()
        resp = wire.loads(worker.handle(wire.dumps(
            self._request(snapshots={"r": {"ref": "deadbeef"}})
        )))
        assert resp["kind"] == "error"
        assert "does not match cached state" in resp["error"]

    def test_plan_before_policy_is_protocol_error(self):
        worker = RemoteShardWorker()
        resp = wire.loads(worker.handle(wire.dumps(self._request(policy=False))))
        assert resp["kind"] == "error"
        assert "before any policy" in resp["error"]

    def test_malformed_request_returns_error_payload(self):
        """The worker must survive garbage — the transport stays up and
        the client sees a typed error, not a dead pipe."""
        worker = RemoteShardWorker()
        resp = wire.loads(worker.handle("{not json"))
        assert resp["kind"] == "error"
        resp = wire.loads(worker.handle(wire.dumps({"v": 99, "kind": "plan_request"})))
        assert resp["kind"] == "error" and "wire version" in resp["error"]

    def test_history_survives_policy_refresh(self):
        """A re-sent policy config rebuilds a fresh policy on the
        worker; an unchanged history arriving as a ref must still
        repopulate it — otherwise unprofiled actions price at the
        default and remote plans silently diverge (regression)."""
        from repro.core.scheduler import ElasticScheduler

        worker = RemoteShardWorker()
        hist_payload = {"avg": {"tool:slow": 7.5}}
        hist_fp = wire.fingerprint(hist_payload)
        req = self._request(snapshots={"r": wire.encode_snapshot(
            ResourceManager("r", 8))})
        req["history"] = hist_payload
        assert wire.loads(worker.handle(wire.dumps(req)))["kind"] == "plan_response"
        assert worker._policy.history._avg == {"tool:slow": 7.5}
        # now refresh the policy (knob change) with history as a ref
        policy = ElasticScheduler(depth=3)
        req2 = self._request(snapshots={"r": {"ref": wire.fingerprint(
            wire.encode_snapshot(ResourceManager("r", 8)))}})
        req2["policy"] = wire.encode_policy(policy)
        req2["history"] = {"ref": hist_fp}
        assert wire.loads(worker.handle(wire.dumps(req2)))["kind"] == "plan_response"
        assert worker._policy.depth == 3  # fresh policy adopted...
        assert worker._policy.history._avg == {"tool:slow": 7.5}  # ...with history

    def test_codec_bill_includes_request_parse(self):
        """codec_s must cover the wire.loads of the request (the
        dominant worker-side codec cost on big payloads), not just the
        object decoding."""
        m = ResourceManager("r", 8)
        waiting = [Action(name=f"w{i}", cost={"r": fixed("r")}, task_id="t",
                          trajectory_id=f"t{i}", base_duration=1.0)
                   for i in range(50)]
        worker = RemoteShardWorker()
        resp = wire.loads(worker.handle(wire.dumps(self._request(
            snapshots={"r": wire.encode_snapshot(m)}, waiting=waiting))))
        assert resp["codec_s"] > 0

    def test_loopback_recv_without_submit_raises(self):
        with pytest.raises(RuntimeError, match="without a submitted request"):
            LoopbackTransport().recv()

    def test_process_transport_survives_error_payloads(self):
        t = ProcessTransport()
        try:
            t.submit("{not json")
            resp = wire.loads(t.recv())
            assert resp["kind"] == "error"
        finally:
            t.close()


# ---------------------------------------------------------------------------
# sub-queue migration: WFQ order + clock monotonicity, orchestration
# ---------------------------------------------------------------------------


def _tagged_queue(tasks=("mover", "stay"), per_task=3):
    q = PartitionQueue(
        fair=True,
        weight_of=lambda a: 2.0 if a.task_id == "mover" else 1.0,
        cost_of=lambda a: 1.0,
    )
    actions = []
    for i in range(per_task):
        for t in tasks:
            a = Action(name=f"{t}{i}", cost={"r": fixed("r")}, task_id=t,
                       trajectory_id=f"{t}-{i}")
            q.push(a)
            actions.append(a)
    return q, actions


class TestMigrateThenMerge:
    def test_wfq_order_preserved_across_replicas(self):
        """Detached entries keep their tags, so after merging into a
        replica that has its own backlog the GLOBAL drain order is the
        WFQ order the tags encode — migration must not reset or re-tag."""
        src, _ = _tagged_queue()
        dst = PartitionQueue(fair=True, weight_of=lambda a: 1.0,
                             cost_of=lambda a: 1.0)
        # the replica has its own tenant already queued
        local = [
            Action(name=f"local{i}", cost={"r": fixed("r")}, task_id="local",
                   trajectory_id=f"l{i}")
            for i in range(2)
        ]
        for a in local:
            dst.push(a)
        mover_order = [a.uid for a in src.ordered() if a.task_id == "mover"]
        shard = src.detach_task("mover")
        dst.merge_shard(shard)
        merged = [a.uid for a in dst.ordered() if a.task_id == "mover"]
        assert merged == mover_order  # FCFS within the task survives
        # WFQ across tasks: mover's finish chain resumed, so its future
        # arrivals are charged from the carried tag, not from zero
        a_new = Action(name="late", cost={"r": fixed("r")}, task_id="mover",
                       trajectory_id="late")
        dst.push(a_new)
        assert dst.tag_of(a_new.uid)[0] >= shard.finish_tag - 1e-12

    def test_vclock_monotone_through_detach_merge(self):
        src, actions = _tagged_queue()
        # serve a few so the source clock advances
        for a in list(src.ordered())[:3]:
            src.remove(a.uid, served=True)
        v_src = src.vtime
        shard = src.detach_task("mover")
        assert shard is not None and shard.vtime == v_src
        dst = PartitionQueue(fair=True)
        v_dst_before = dst.vtime
        dst.merge_shard(shard)
        assert dst.vtime >= max(v_dst_before, v_src)  # never backward
        # and merging BACK into the source is also monotone + lossless
        back = dst.detach_task("mover")
        src.merge_shard(back)
        assert src.vtime >= v_src
        assert {a.uid for a in src.ordered() if a.task_id == "mover"} == {
            e[1].uid for e in shard.entries
        }

    def test_detach_is_not_a_busy_period_end(self):
        """Detaching the last sub-queue empties the partition but the
        work still exists elsewhere — the clock must NOT settle (that is
        the drain rule, reserved for served work)."""
        q = PartitionQueue(fair=True, cost_of=lambda a: 5.0)
        a = Action(name="x", cost={"r": fixed("r")}, task_id="t",
                   trajectory_id="t0")
        q.push(a)
        v = q.vtime
        shard = q.detach_task("t")
        assert len(q) == 0
        assert q.vtime == v  # unchanged, no settle
        assert shard.finish_tag > 0  # the debt travels with the shard


class TestOrchestratedMigration:
    def _fleet(self, pools=2, cores=2, fair=True):
        loop = EventLoop()
        managers = {
            f"pool{k}": ResourceManager(f"pool{k}", cores) for k in range(pools)
        }
        fs = FairSharePolicy(weights={"a": 2.0, "b": 1.0}) if fair else None
        return Orchestrator(managers, loop=loop, fair_share=fs)

    def _load(self, orch, part="pool0", n=12, scalable=False):
        futs = []
        for i in range(n):
            task = "a" if i % 2 == 0 else "b"
            if scalable and i % 3 == 0:
                cost = {part: ResourceRequest(part, (1, 2))}
                kw = dict(key_resource=part, elasticity=AmdahlElasticity(0.1))
            else:
                cost, kw = {part: fixed(part, 1)}, {}
            futs.append(orch.submit(Action(
                name=f"w{i}", cost=cost, base_duration=2.0, task_id=task,
                trajectory_id=f"t{i}", **kw)))
        return futs

    def test_migrated_backlog_runs_on_the_replica(self):
        orch = self._fleet()
        futs = self._load(orch, scalable=True)
        orch.run(until=0.01)
        assert orch.in_flight() > 0
        moved = orch.migrate_task("a", "pool0", "pool1")
        assert moved > 0
        assert orch.telemetry.migrations == 1
        assert orch.telemetry.migrated_actions == moved
        assert orch.telemetry.migration_wall_s > 0
        orch.run()
        assert all(f.done() for f in futs)
        assert orch.queue_depth() == 0 and orch.in_flight() == 0
        for m in orch.managers.values():
            m.check_occupancy()
        # the moved tenant really executed on the replica pool
        pools_used = {r.units and next(iter(r.units)) for r in
                      orch.telemetry.records if r.task_id == "a"}
        assert "pool1" in pools_used

    def test_migration_waits_for_running_actions(self):
        """In-flight actions keep their src allocations; only the queued
        sub-queue moves."""
        orch = self._fleet()
        self._load(orch)
        orch.run(until=0.01)
        running_before = orch.in_flight()
        orch.migrate_task("a", "pool0", "pool1")
        assert orch.in_flight() == running_before
        orch.run()
        orch.managers["pool0"].check_occupancy()
        orch.managers["pool1"].check_occupancy()

    def test_replica_contract_enforced(self):
        """A migration that cannot land its actions in dst's partition
        is refused before any mutation."""
        loop = EventLoop()
        managers = {
            "pool0": ResourceManager("pool0", 2),
            "pool1": ResourceManager("pool1", 2),
            "aaa": ResourceManager("aaa", 2),
        }
        orch = Orchestrator(managers, loop=loop)
        with pytest.raises(ValueError, match="unknown partition"):
            orch.migrate_task("mv", "pool0", "nope")
        # key_resource=None + multi-resource cost partitions by
        # min(cost): this action lives on "aaa".  Retargeting aaa->pool1
        # would leave min(cost) = "pool0" != "pool1" — not a replica
        # move for this cost vector, so it must refuse untouched.
        for i in range(2):  # saturate "aaa" so d stays queued
            orch.submit(Action(name=f"blk{i}", cost={"aaa": fixed("aaa")},
                               base_duration=50.0, trajectory_id=f"blk{i}",
                               task_id="blocker"))
        d = Action(name="q", cost={"pool0": fixed("pool0"), "aaa": fixed("aaa")},
                   base_duration=1.0, trajectory_id="t3", task_id="mv3")
        assert d.key_resource is None
        orch.submit(d)
        orch.run(until=0.01)
        assert d.uid in orch._queues["aaa"]
        with pytest.raises(ValueError, match="not replicas"):
            orch.migrate_task("mv3", "aaa", "pool1")
        # nothing was mutated by the refusal
        assert d.cost.keys() == {"pool0", "aaa"}
        assert d.uid in orch._queues["aaa"]

    def test_rebalance_is_deterministic_and_telemetered(self):
        def build():
            orch = self._fleet(pools=2)
            self._load(orch, n=18)
            orch.run(until=0.01)
            return orch

        orch = build()
        before = {p: len(orch._queues.get(p) or ()) for p in ("pool0", "pool1")}
        gap_before = before["pool0"] - before["pool1"]
        moved = orch.rebalance(["pool0", "pool1"])
        assert moved > 0
        depths = {p: len(orch._queues.get(p) or ()) for p in ("pool0", "pool1")}
        # a whole task sub-queue moved to the idle replica and the gap
        # strictly improved (whole-sub-queue granularity bounds how even
        # it can get)
        assert abs(depths["pool0"] - depths["pool1"]) < gap_before
        assert depths["pool1"] > 0
        assert orch.telemetry.migrated_actions == moved
        # deterministic: the same state rebalances the same way
        orch2 = build()
        assert orch2.rebalance(["pool0", "pool1"]) == moved
        orch.run()
        assert orch.queue_depth() == 0

    def test_rebalance_never_inverts_the_imbalance(self):
        """The best single move is the sub-queue sized closest to half
        the gap: with backlogs {A:9, B:5} vs an idle replica it must
        move B (one migration, gap 14 -> 4), never A (which would
        invert to 5/9 and trigger churn) — regression."""
        loop = EventLoop()
        managers = {  # zero capacity: everything stays queued
            "pool0": ResourceManager("pool0", 0),
            "pool1": ResourceManager("pool1", 0),
        }
        orch = Orchestrator(managers, loop=loop)
        for i in range(9):
            orch.submit(Action(name=f"a{i}", cost={"pool0": fixed("pool0")},
                               task_id="A", trajectory_id=f"a{i}",
                               base_duration=1.0))
        for i in range(5):
            orch.submit(Action(name=f"b{i}", cost={"pool0": fixed("pool0")},
                               task_id="B", trajectory_id=f"b{i}",
                               base_duration=1.0))
        orch.run(until=0.5)
        assert len(orch._queues["pool0"]) == 14
        moved = orch.rebalance(["pool0", "pool1"])
        assert moved == 5  # B moved, A stayed
        assert orch.telemetry.migrations == 1
        assert len(orch._queues["pool0"]) == 9
        assert len(orch._queues["pool1"]) == 5

    def test_migrate_noop_cases(self):
        orch = self._fleet()
        assert orch.migrate_task("a", "pool0", "pool0") == 0
        assert orch.migrate_task("a", "pool0", "pool1") == 0  # nothing queued
        assert orch.telemetry.migrations == 0

    def test_wire_round_trip_of_live_shard(self):
        """A detached sub-queue survives the wire and merges into a
        DIFFERENT orchestrator's replica queue (the cross-process
        migration story, minus the process)."""
        orch = self._fleet()
        self._load(orch)
        orch.run(until=0.01)
        src_q = orch._queues["pool0"]
        shard = src_q.detach_task("a")
        blob = wire.dumps(wire.encode_task_shard(shard))
        other = self._fleet()
        back = wire.decode_task_shard(wire.loads(blob))
        q = other._queues.setdefault("pool0", other._make_queue("pool0"))
        q.merge_shard(back)
        assert [a.uid for a in q.ordered()] == [e[1].uid for e in shard.entries]
        assert q.vtime >= shard.vtime


# ---------------------------------------------------------------------------
# auto plan mode (measured plan-cost EWMA -> inline vs threads)
# ---------------------------------------------------------------------------


class TestAutoPlanMode:
    def test_auto_trace_identical_and_logged(self):
        serial = _make_system(None)
        _submit_workload(serial, 7)
        serial.run()
        auto = _make_system(4, plan_mode="auto")
        _submit_workload(auto, 7)
        auto.run()
        assert _trace(auto) == _trace(serial)
        if auto.stats["sharded_rounds"]:
            # every sharded round logged its decision + the driving EWMA
            assert sum(auto.telemetry.plan_mode_rounds.values()) == (
                auto.stats["sharded_rounds"]
            )
            assert auto.telemetry.plan_cost_ewma_s > 0

    def test_cheap_plans_stay_inline(self):
        auto = _make_system(4, plan_mode="auto")
        _submit_workload(auto, 7)
        auto.run()
        # DES plan costs are far under the cutover: no pool dispatch
        assert auto.telemetry.plan_mode_rounds.get("threads", 0) == 0

    def test_expensive_ewma_dispatches_to_pool(self):
        auto = _make_system(4, plan_mode="auto")
        _submit_workload(auto, 7)
        # pretend history says partitions are expensive to plan
        auto._executor.plan_cost_ewma = 1.0
        auto.run(until=6.0)
        if auto.stats["sharded_rounds"]:
            assert auto.telemetry.plan_mode_rounds.get("threads", 0) > 0
        serial = _make_system(None)
        _submit_workload(serial, 7)
        serial.run(until=6.0)
        assert _trace(auto) == _trace(serial)

    def test_ewma_tracks_measured_cost(self):
        auto = _make_system(2, plan_mode="auto")
        _submit_workload(auto, 4)
        auto.run()
        ex = auto._executor
        assert ex.plan_cost_ewma is not None and ex.plan_cost_ewma > 0
        assert math.isfinite(ex.plan_cost_ewma)


# ---------------------------------------------------------------------------
# worker-owned two-phase commit (the fused plan_commit rail)
# ---------------------------------------------------------------------------


class TestWorkerCommit:
    """The worker-owned commit engine's acceptance rails: launch traces
    bit-identical to serial and to client-serial remote across seeds,
    dependent-pass batching equivalent to sequential passes, conflicts
    resolved worker-side on the authoritative replicas, cross-owner
    footprints declined to the client-serial walk — and the commit
    phase really off the wire (zero steady-state fallbacks)."""

    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5, 6, 7])
    def test_worker_commit_bit_identical_to_serial(self, seed):
        _, serial = _run_mode(seed, shards=None)
        orch, worker = _run_mode(
            seed, shards=4, plan_mode="remote", commit_mode="worker"
        )
        assert worker == serial, f"seed {seed}: worker-owned commit diverged"
        s = orch.telemetry.wire_summary()
        if orch.stats["sharded_rounds"]:
            # the fused rail really carried the rounds, and steady state
            # needed no recovery: no fallbacks, no declines, no aborts
            assert s.get("prepares", 0) > 0
            assert s.get("fallbacks", 0) == 0
            assert s.get("commit_inline_rounds", 0) == 0
            assert s.get("commit_diverged", 0) == 0
            assert s.get("commit_aborts", 0) == 0
            # every managed rtype was granted exactly once
            assert s.get("lease_grants", 0) == len(orch.managers)

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_batched_passes_equal_sequential(self, seed):
        """A fused round carrying up to 8 dependent fixpoint passes
        must launch exactly what one-pass-per-wire-round launches (and
        serial): the pass boundary is an optimization, never semantics."""
        _, serial = _run_mode(seed, shards=None)
        _, batched = _run_mode(seed, shards=4, plan_mode="remote",
                               commit_mode="worker", commit_max_passes=8)
        _, sequential = _run_mode(seed, shards=4, plan_mode="remote",
                                  commit_mode="worker", commit_max_passes=1)
        assert batched == serial
        assert sequential == serial

    @pytest.mark.parametrize("seed", [0, 1])
    def test_worker_commit_fairness_equivalence(self, seed):
        tasks = ("heavy", "light")
        _, serial = _run_mode(seed, tasks=tasks, shards=None, fair=True)
        _, worker = _run_mode(seed, tasks=tasks, shards=4, plan_mode="remote",
                              commit_mode="worker", fair=True)
        assert worker == serial

    def test_commit_phase_accounting(self):
        """Fused rounds charge the modeled fleet critical path (max
        worker plan + max worker commit) to sched_wall_s; the client's
        replay is mirror maintenance recorded separately in
        commit_apply_s; commit_wall_s (the client-serial commit wall)
        stays untouched — the wire left the commit path."""
        orch, _ = _run_mode(3, shards=4, plan_mode="remote",
                            commit_mode="worker")
        t = orch.telemetry
        if not t.wire_prepares:
            pytest.skip("workload produced no fused rounds")
        assert t.commit_critical_s >= 0.0
        assert t.commit_apply_s > 0.0
        assert t.wire_commit_acks == t.wire_prepares
        assert t.commit_wall_s == 0.0

    def test_worker_mode_requires_remote_plan(self):
        with pytest.raises(ValueError, match="commit_mode"):
            _make_system(4, commit_mode="worker")  # plan_mode defaults inline
        with pytest.raises(ValueError, match="commit_mode"):
            _make_system(None, plan_mode="remote", commit_mode="worker")

    def test_real_worker_processes_bit_identical(self):
        _, serial = _run_mode(2, shards=None)
        orch = _make_system(2, plan_mode="remote", transport="process",
                            commit_mode="worker")
        _submit_workload(orch, 2)
        orch.run()
        assert _trace(orch) == serial
        orch.close()


class TestWorkerCommitConflicts(TestRemoteConflicts):
    def test_conflict_resolved_on_authoritative_replicas(self):
        """Both contending partitions live in ONE owner's domain
        (shards=1): the worker's local passes hit the shared-pool
        conflict, roll the loser back through release_unlaunched on its
        own replicas, and converge — the client replay sees the same
        held/retry rail, so the trace matches client-serial remote."""
        a = self._conflict_system(shards=1, plan_mode="remote")
        b = self._conflict_system(shards=1, plan_mode="remote",
                                  commit_mode="worker")
        self._submit_contenders(a)
        self._submit_contenders(b)
        a.run()
        b.run()
        assert _trace(a) == _trace(b)
        assert b.telemetry.commit_conflicts > 0
        assert b.telemetry.wire_prepares > 0
        records = [r for r in b.telemetry.records if not r.failed]
        assert len({r.trajectory_id for r in records}) == 6
        assert b.queue_depth() == 0 and b.in_flight() == 0
        for m in b.managers.values():
            m.check_occupancy()
        a.close()
        b.close()

    def test_cross_owner_footprint_declines_to_client_serial(self):
        """With shards=2 the contenders' commit footprints span owners
        (each part touches its own pool AND the shared pool): the
        engine must decline those rounds to the client-serial walk —
        counted, and trace-identical to client-serial remote."""
        a = self._conflict_system(shards=2, plan_mode="remote")
        b = self._conflict_system(shards=2, plan_mode="remote",
                                  commit_mode="worker")
        self._submit_contenders(a)
        self._submit_contenders(b)
        a.run()
        b.run()
        assert _trace(a) == _trace(b)
        assert b.telemetry.commit_inline_rounds > 0
        assert b.telemetry.wire_prepares == 0
        b.close()
        a.close()


class TestPlanBatchCarriesCommit:
    def test_plan_batch_mixes_plan_and_plan_commit(self):
        """A plan_batch frame may carry plan_commit requests next to
        plain plan requests — each processed in arrival order, each
        answered by its own response kind inside plan_batch_response."""
        from repro.core.action import ActionState
        from repro.core.scheduler import ElasticScheduler

        m = ResourceManager("r", 8)
        act = Action(name="w", cost={"r": fixed("r", 2)}, trajectory_id="t0",
                     base_duration=1.0)
        act.state = ActionState.QUEUED  # as a submitted action arrives

        def body(commit):
            b = {
                "shard": 0,
                "now": 0.0,
                "incremental": True,
                "policy": wire.encode_policy(ElasticScheduler()),
                "fair_share": None,
                "history": {"avg": {}},
                "snapshots": {"r": wire.encode_snapshot(m)},
                "executing": [],
                "partitions": [
                    {"part": "r", "waiting": [wire.encode_action(act)]}
                ],
            }
            if commit:
                b["commit"] = {
                    "leases": [wire.encode_lease("r", 0, fresh=True)],
                    "max_passes": 4,
                    "tick": 0.0005,
                }
            return b

        worker = RemoteShardWorker()
        batch = wire.envelope("plan_batch", {"reqs": [
            wire.envelope("plan_commit", body(commit=True)),
            wire.envelope("plan_request", body(commit=False)),
        ]})
        resp = wire.loads(worker.handle(wire.dumps(batch)))
        assert resp["kind"] == "plan_batch_response"
        kinds = [r["kind"] for r in resp["resps"]]
        assert kinds == ["plan_commit_response", "plan_response"]
        fused = resp["resps"][0]
        # the fused round really committed: one pass, one launch outcome
        assert fused["passes"], "no committed passes in the ack"
        part, rows, failed, held = wire.decode_commit_outcome(
            fused["passes"][0]["outcomes"][0]
        )
        assert part == "r" and len(rows) == 1 and not failed and not held
        assert fused["fps"]["r"] != wire.fingerprint(wire.encode_snapshot(m))
