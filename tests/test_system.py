"""End-to-end behaviour tests: the full submit -> formulate -> schedule ->
execute -> observe cycle (paper §3) across heterogeneous resources, with
the mixed "MOPD+Search" scenario from §6.2 and Table-1-style invariants.
"""

import math

import pytest

from repro.core.cluster import paper_testbed
from repro.core.managers.gpu import GpuManager
from repro.rl.driver import build_tangram, run_baseline_step, run_tangram_step
from repro.rl.rollout import RolloutRunner
from repro.rl.tasks import (
    make_coding_workload,
    make_deepsearch_workload,
    make_mopd_workload,
    workload_services,
)


def _mixed_workload(seed=0):
    """MOPD + DeepSearch sharing one resource pool (paper 'MOPD+Search')."""
    mopd = make_mopd_workload(48, seed=seed, n_teachers=6, arrival_spread_s=30)
    search = make_deepsearch_workload(32, seed=seed + 1)
    return mopd + search


class TestMixedWorkloadE2E:
    def test_all_actions_complete_exactly_once(self):
        cluster = paper_testbed(cpu_nodes=2, gpu_nodes=2)
        trajs = _mixed_workload()
        stats, tg = run_tangram_step(trajs, cluster)
        assert tg.queue_depth() == 0 and tg.in_flight() == 0
        # one telemetry record per submitted action
        expected = sum(
            len(turn.actions) for t in trajs for turn in t.turns
        ) + sum(len(t.reward) for t in trajs)
        assert len(tg.telemetry.records) == expected
        assert math.isfinite(stats.mean_act) and stats.mean_act > 0

    def test_breakdown_structure_matches_table1(self):
        """ACT decomposes into exec + queue + sys overhead (Table 1)."""
        cluster = paper_testbed(cpu_nodes=2, gpu_nodes=2)
        stats, tg = run_tangram_step(_mixed_workload(), cluster)
        br = stats.breakdown
        assert set(br) >= {"exec", "queue", "overhead"}
        assert all(v >= 0 for v in br.values())
        assert br["exec"] > 0
        # mean ACT equals the breakdown sum (it is a decomposition)
        assert stats.mean_act == pytest.approx(
            br["exec"] + br["queue"] + br["overhead"], rel=1e-6
        )

    def test_cpu_overhead_under_3_percent(self):
        """Table 1: CPU-workload system overhead is <3% of exec time."""
        cluster = paper_testbed(cpu_nodes=2, cores_per_node=128, gpu_nodes=1)
        stats, _ = run_tangram_step(make_coding_workload(64), cluster)
        assert stats.breakdown["overhead"] < 0.03 * stats.breakdown["exec"]

    def test_mixed_beats_static_baseline(self):
        """§6.2 'MOPD+Search': pooling across tasks beats per-task statics.

        The static baseline deploys every service on dedicated TP-4 GPUs
        regardless of cluster size (that IS the over-provisioning), so the
        equal-resources comparison needs a cluster that can actually hold
        all 7 services x 4 GPUs: gpu_nodes=4 -> 32 devices."""
        cluster = paper_testbed(cpu_nodes=2, gpu_nodes=4)
        trajs = _mixed_workload()
        tg_stats, _ = run_tangram_step(trajs, cluster)
        bl_stats, _ = run_baseline_step(trajs, cluster, gpu_baseline="static")
        assert tg_stats.mean_act < bl_stats.mean_act


class TestResourceInvariants:
    def test_gpu_chunks_never_oversubscribed(self):
        """EOE + chunk allocator: at completion all chunks are free and
        hits+misses account for every service-backed execution."""
        cluster = paper_testbed(cpu_nodes=1, gpu_nodes=2)
        trajs = make_mopd_workload(48, n_teachers=6, arrival_spread_s=10)
        _, tg = run_tangram_step(trajs, cluster)
        gm = tg.managers["gpu"]
        assert isinstance(gm, GpuManager)
        assert gm.available == gm.capacity  # everything released
        served = gm.stats["hits"] + gm.stats["misses"]
        gpu_actions = [
            r for r in tg.telemetry.records if r.name.startswith("reward")
        ]
        assert served == len(gpu_actions)
        assert gm.stats["restore_s"] >= 0

    def test_api_quota_respected(self):
        """Basic manager: per-window quota consumption never exceeds the
        configured quota (DeepSearch google_search is quota-mode)."""
        cluster = paper_testbed(cpu_nodes=1, gpu_nodes=1)
        trajs = make_deepsearch_workload(64, seed=3)
        _, tg = run_tangram_step(trajs, cluster)
        mgr = tg.managers["google_search"]
        spec = next(a for a in cluster.apis if a.name == "google_search")
        for used in getattr(mgr, "window_usage", lambda: [])():
            assert used <= spec.quota

    def test_fcfs_no_starvation(self):
        """Every submitted action eventually runs; queue drains to zero
        even under heavy contention (starvation kills trajectories)."""
        cluster = paper_testbed(cpu_nodes=1, cores_per_node=64, gpu_nodes=1)
        trajs = make_coding_workload(128, arrival_spread_s=5)
        _, tg = run_tangram_step(trajs, cluster)
        assert tg.queue_depth() == 0 and tg.in_flight() == 0
        for rec in tg.telemetry.records:
            assert rec.start >= rec.submit
            assert rec.finish > rec.start

    def test_vectorized_constraints_all_resources(self):
        """An action's allocation never exceeds any cost dimension's
        feasible set (the scheduler's vectorized constraint, §4.1)."""
        cluster = paper_testbed(cpu_nodes=1, gpu_nodes=1)
        trajs = _mixed_workload(seed=5)
        _, tg = run_tangram_step(trajs, cluster)
        by_name = {}
        for t in trajs:
            for turn in t.turns:
                for tmpl in turn.actions:
                    a = tmpl.make(t.task_id, t.traj_id)
                    by_name.setdefault(a.name, a)
            for tmpl in t.reward:
                a = tmpl.make(t.task_id, t.traj_id)
                by_name.setdefault(a.name, a)
        for rec in tg.telemetry.records:
            proto = by_name.get(rec.name)
            if proto is None:
                continue
            for rtype, units in rec.units.items():
                assert units in proto.cost[rtype].units


class TestSchedulerModesE2E:
    def test_beyond_paper_mode_runs_clean(self):
        """The opt-in scheduler extensions complete the same workload with
        identical action accounting (no lost/duplicated actions)."""
        from repro.core.scheduler import ElasticScheduler

        cluster = paper_testbed(cpu_nodes=1, cores_per_node=64, gpu_nodes=1)
        trajs = make_coding_workload(64, arrival_spread_s=10)
        services = workload_services(trajs)

        tg = build_tangram(cluster, services)
        tg.scheduler = ElasticScheduler(
            depth=2, history=tg.history, estimate_units="dp_avg"
        )
        tg.scheduler.eviction_search = "exhaustive"
        tg.scheduler.dop_floor = 4
        runner = RolloutRunner(
            {"*": tg, "cpu": tg, "gpu": tg, **{a.name: tg for a in cluster.apis}},
            tg.loop,
        )
        stats = runner.run_step(trajs)
        assert tg.queue_depth() == 0 and tg.in_flight() == 0
        rewards = [r for r in tg.telemetry.records if r.name.startswith("reward")]
        assert len(rewards) == 64
        assert math.isfinite(stats.mean_act)
