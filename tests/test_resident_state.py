"""Resident worker plan state: in-place delta application plus the
copy-on-plan reset must be indistinguishable from rebuilding a fresh
manager from every snapshot.

Two rails, checked at two levels:

* **property** — inside a real worker, after every resident-state
  resolution (fingerprint hit, in-place ``apply_state`` patch, or full
  rebuild) the replica's ``snapshot_state()`` must byte-equal both a
  from-scratch ``restore_snapshot`` of the same payload and the payload
  itself; and planning (which clones ``plan_mutates`` families) must
  leave the resident replicas byte-untouched.
* **end to end** — 8 seeds of the mixed four-family workload, traces
  bit-identical to serial, under healthy workers AND under eviction,
  mid-run worker restart, and resident-amnesia fault injection."""

import random

import pytest

from repro.core import wire
from repro.core.action import (
    Action,
    AmdahlElasticity,
    ResourceRequest,
    fixed,
    ranged,
)
from repro.core.cluster import ApiResourceSpec, CpuNodeSpec, GpuNodeSpec
from repro.core.fairqueue import FairSharePolicy
from repro.core.managers.base import ResourceManager
from repro.core.managers.basic import BasicResourceManager
from repro.core.managers.cpu import CpuManager
from repro.core.managers.gpu import GpuManager, ServiceSpec
from repro.core.orchestrator import Orchestrator
from repro.core.remote import LoopbackTransport, RemoteShardWorker
from repro.core.simulator import EventLoop, FrozenClock


# ---------------------------------------------------------------------------
# workload: all four manager families in one system (mirrors
# tests/test_remote.py so every family's resident replica is exercised)
# ---------------------------------------------------------------------------


def _make_system(shards, cores=32, fair=False, **kw):
    loop = EventLoop()
    managers = {
        "cpu": CpuManager([CpuNodeSpec("n0", cores=cores)]),
        "gpu": GpuManager([GpuNodeSpec("g0")], [ServiceSpec("rm0", 40.0)]),
        "api": BasicResourceManager(
            ApiResourceSpec("api", mode="quota", quota=4, period_s=5.0),
            loop.clock,
        ),
        "pool": ResourceManager("pool", 6),
    }
    fs = FairSharePolicy(weights={"heavy": 2.0, "light": 1.0}) if fair else None
    return Orchestrator(
        managers, loop=loop, shards=shards, fair_share=fs, **kw
    )


def _submit_workload(orch, seed, tasks=("task0",), waves=8, per_wave=8,
                     period=2.0):
    """Wave-style churn (mirrors tests/test_wire_bill.py): every wave
    submits a mix across all four families at ONE timestamp, so rounds
    are genuinely multi-partition (= sharded, = over the wire) and every
    worker sees a steady stream of plan requests."""
    rng = random.Random(seed)
    wave_no = [0]

    def wave():
        w = wave_no[0]
        wave_no[0] += 1
        for i in range(per_wave):
            task = tasks[(w * per_wave + i) % len(tasks)]
            kind = rng.random()
            tid = f"{task}-w{w}-{i}"
            if kind < 0.3:
                a = Action(
                    name="reward", cost={"cpu": ranged("cpu", 1, 8)},
                    key_resource="cpu", elasticity=AmdahlElasticity(0.08),
                    base_duration=rng.uniform(1, 8), task_id=task,
                    trajectory_id=tid,
                )
            elif kind < 0.5:
                a = Action(
                    name="tool",
                    cost={"pool": fixed("pool", rng.choice((1, 2)))},
                    base_duration=rng.uniform(0.2, 2.0), task_id=task,
                    trajectory_id=tid,
                )
            elif kind < 0.75:
                a = Action(
                    name="rm:score",
                    cost={"gpu": ResourceRequest("gpu", (1, 2, 4, 8))},
                    key_resource="gpu", elasticity=AmdahlElasticity(0.15),
                    base_duration=rng.uniform(0.5, 3.0), service="rm0",
                    task_id=task, trajectory_id=tid,
                )
            else:
                a = Action(
                    name="api:q", cost={"api": fixed("api")},
                    base_duration=rng.uniform(0.1, 1.0), task_id=task,
                    trajectory_id=tid,
                )
            orch.submit(a)
        if w + 1 < waves:
            orch.loop.call_after(period, wave)

    wave()


def _trace(orch):
    return sorted(
        (r.name, r.task_id, r.trajectory_id, round(r.submit, 9),
         round(r.start, 9), round(r.finish, 9),
         tuple(sorted(r.units.items())), r.failed)
        for r in orch.telemetry.records
    )


def _run(seed, shards, transport=None, tasks=("task0",), **kw):
    if transport is not None:
        kw["transport"] = transport
    orch = _make_system(shards, **kw)
    _submit_workload(orch, seed, tasks=tasks)
    orch.run()
    trace = _trace(orch)
    assert orch.queue_depth() == 0 and orch.in_flight() == 0
    for m in orch.managers.values():
        m.check_occupancy()
    orch.close()
    return orch, trace


# ---------------------------------------------------------------------------
# the property worker: byte-compares every resident resolution against
# a from-scratch rebuild, and pins resident state across planning
# ---------------------------------------------------------------------------


class _PropertyWorker(RemoteShardWorker):
    """Worker that asserts the resident-state equivalence property on
    every request it serves, whatever path resolution took."""

    checks = [0]

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self._expect = {}

    def _manager(self, rtype, fp, full):
        mgr = super()._manager(rtype, fp, full)
        rebuilt = type(mgr).restore_snapshot(full["state"])
        # resident replica (hit / patched / rebuilt) == fresh rebuild,
        # byte for byte on the canonical wire encoding...
        assert wire.dumps(mgr.snapshot_state()) == wire.dumps(
            rebuilt.snapshot_state()
        ), f"{rtype}: resident replica diverged from rebuild"
        # ...and both round-trip the payload itself (canonical-form
        # compare: the payload crossed a codec, so ints/floats and
        # list/tuple spellings may differ while the value may not)
        assert wire.fingerprint(mgr.snapshot_state()) == wire.fingerprint(
            full["state"]
        ), f"{rtype}: snapshot_state does not round-trip the payload"
        # pin the post-resolution state: planning must not move it
        self._expect[rtype] = wire.dumps(mgr.snapshot_state())
        _PropertyWorker.checks[0] += 1
        return mgr

    def _plan(self, payload, parse_s=0.0):
        self._expect = {}
        resp = super()._plan(payload, parse_s)
        # planning clones plan_mutates families (copy-on-plan); the
        # resident replicas themselves must come out byte-untouched
        for rt, expected in self._expect.items():
            res = self._resident.get(rt)
            assert res is not None and wire.dumps(
                res[1].snapshot_state()
            ) == expected, f"{rt}: planning mutated the resident replica"
        return resp


class _PropertyLoopback(LoopbackTransport):
    def __init__(self):
        super().__init__()
        self._worker = _PropertyWorker()


class TestResidentProperty:
    @pytest.mark.parametrize("seed", [3, 11])
    def test_resident_equals_rebuild_every_request(self, seed):
        _, serial = _run(seed, None)
        _PropertyWorker.checks[0] = 0
        orch, trace = _run(
            seed, 2, transport=_PropertyLoopback, plan_mode="remote"
        )
        assert trace == serial
        assert _PropertyWorker.checks[0] > 0
        # steady state is in-place patches (this workload moves every
        # touched manager's clock each round); rebuilds happen only on
        # first sight of a family at a worker, never again
        cache = orch.telemetry.wire_worker_cache
        assert cache.get("resident_patches", 0) > 0
        assert cache.get("resident_rebuilds", 0) <= 2 * len(orch.managers)
        assert orch.telemetry.wire_fallbacks == 0

    def test_resident_resolution_paths_direct(self):
        """One worker, one family, all three paths in order: first sight
        rebuilds, an identical fingerprint reuses the same object, a
        changed state patches that same object in place."""
        w = RemoteShardWorker()
        m = ResourceManager("pool", 6)
        full = wire.encode_snapshot(m)
        fp = wire.fingerprint(full)
        m1 = w._manager("pool", fp, full)
        assert w._stats["resident_rebuilds"] == 1
        m2 = w._manager("pool", fp, full)
        assert m2 is m1 and w._stats["resident_hits"] == 1
        m._in_use, m._task_use = 2, {"t": 2}
        full2 = wire.encode_snapshot(m)
        m3 = w._manager("pool", wire.fingerprint(full2), full2)
        assert m3 is m1 and w._stats["resident_patches"] == 1
        assert wire.dumps(m3.snapshot_state()) == wire.dumps(
            m.snapshot_state()
        )
        # a different-topology payload rebuilds (apply_state refused)
        big = wire.encode_snapshot(ResourceManager("pool", 12))
        m4 = w._manager("pool", wire.fingerprint(big), big)
        assert m4 is not m1 and w._stats["resident_rebuilds"] == 2


# ---------------------------------------------------------------------------
# apply_state unit rails: topology changes refuse, state changes land
# byte-identically (all four families)
# ---------------------------------------------------------------------------


class TestApplyStateRails:
    def _roundtrip(self, mgr, mutate):
        state0 = mgr.snapshot_state()
        replica = type(mgr).restore_snapshot(state0)
        mutate(mgr)
        state1 = mgr.snapshot_state()
        assert replica.apply_state(state1) is True
        assert wire.dumps(replica.snapshot_state()) == wire.dumps(state1)
        return replica

    def test_pool_roundtrips_and_refuses_topology(self):
        m = ResourceManager("pool", 6)

        def mutate(m):
            m._in_use = 3
            m._task_use = {"t": 3}

        replica = self._roundtrip(m, mutate)
        assert replica.apply_state({"rtype": "other", "capacity": 6}) is False
        assert replica.apply_state({"rtype": "pool", "capacity": 9}) is False

    def test_cpu_roundtrips_and_refuses_topology(self):
        m = CpuManager([CpuNodeSpec("n0", cores=8)])

        def mutate(m):
            a = Action(
                name="r", cost={"cpu": ranged("cpu", 1, 4)},
                key_resource="cpu", base_duration=1.0, task_id="t",
                trajectory_id="t-0",
            )
            assert m.try_allocate(a, 2) is not None

        replica = self._roundtrip(m, mutate)
        other = CpuManager([CpuNodeSpec("n1", cores=8)]).snapshot_state()
        assert replica.apply_state(other) is False

    def test_gpu_roundtrips_and_refuses_topology(self):
        m = GpuManager([GpuNodeSpec("g0")], [ServiceSpec("rm0", 40.0)])

        def mutate(m):
            a = Action(
                name="rm:score",
                cost={"gpu": ResourceRequest("gpu", (1, 2, 4))},
                key_resource="gpu", base_duration=1.0, service="rm0",
                task_id="t", trajectory_id="t-0",
            )
            assert m.try_allocate(a, 2) is not None

        replica = self._roundtrip(m, mutate)
        other = GpuManager(
            [GpuNodeSpec("g1")], [ServiceSpec("rm0", 40.0)]
        ).snapshot_state()
        assert replica.apply_state(other) is False
        osvc = GpuManager(
            [GpuNodeSpec("g0")], [ServiceSpec("rm1", 40.0)]
        ).snapshot_state()
        assert replica.apply_state(osvc) is False

    def test_api_quota_roundtrips_and_refuses_spec_change(self):
        spec = ApiResourceSpec("api", mode="quota", quota=4, period_s=5.0)
        m = BasicResourceManager(spec, FrozenClock(0.0))

        def mutate(m):
            a = Action(
                name="api:q", cost={"api": fixed("api")},
                base_duration=1.0, task_id="t", trajectory_id="t-0",
            )
            assert m.try_allocate(a, 1) is not None
            m._clock = FrozenClock(2.5)

        replica = self._roundtrip(m, mutate)
        wider = BasicResourceManager(
            ApiResourceSpec("api", mode="quota", quota=9, period_s=5.0),
            FrozenClock(0.0),
        ).snapshot_state()
        assert replica.apply_state(wider) is False
        # the patched replica's clock is re-pinned at the new instant:
        # available must read the settled tokens without a refill jump
        assert replica._clock.now() == 2.5
        assert replica.available == 3


# ---------------------------------------------------------------------------
# 8-seed e2e trace identity under fault injection (eviction, restart,
# resident amnesia) — every divergence path must end in a recovery
# round, never a different trace
# ---------------------------------------------------------------------------


class _RestartingLoopback(LoopbackTransport):
    """Worker silently restarts mid-run: resident replicas, intern
    table, and snapshot caches all vanish while the client's sent-state
    still describes the old worker."""

    restart_after = 6
    restarted_warm = False

    def __init__(self):
        super().__init__()
        self._n = 0

    def submit(self, request):
        self._n += 1
        if self._n == self.restart_after:
            # per-instance count: by its own 6th request this worker is
            # warm (policy, interns, residents), so the swap strands
            # client refs for certain — a cold swap would be a no-op
            if self._worker._policy is not None:
                _RestartingLoopback.restarted_warm = True
            self._worker = RemoteShardWorker()
        super().submit(request)


class _EvictingLoopback(LoopbackTransport):
    """Worker intern budget far below the client mirror's — worker-side
    evictions the mirror cannot predict force stale_intern recoveries."""

    def __init__(self):
        super().__init__()
        self._worker._interns = wire.LruBytes(2048)


class _AmnesiacLoopback(LoopbackTransport):
    """Worker whose resident replicas are dropped before every request:
    each round takes the full decode/rebuild path.  Traces must not
    move — resident state is a cache, not an input."""

    def submit(self, request):
        self._worker._resident.clear()
        super().submit(request)


class TestResidentTraceIdentity:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5, 6, 7])
    def test_faulted_workers_stay_bit_identical(self, seed):
        _, serial = _run(seed, None)

        # healthy resident workers: identical, zero fallbacks
        orch, trace = _run(seed, 2, plan_mode="remote")
        assert trace == serial, f"seed {seed}: resident run diverged"
        if orch.telemetry.wire_rounds:
            assert orch.telemetry.wire_fallbacks == 0

        # rotate one fault per seed so all three appear across the set
        fault = (_RestartingLoopback, _EvictingLoopback, _AmnesiacLoopback)[
            seed % 3
        ]
        _RestartingLoopback.restarted_warm = False
        orch_f, trace_f = _run(seed, 2, transport=fault, plan_mode="remote")
        assert trace_f == serial, (
            f"seed {seed}: {fault.__name__} diverged from serial"
        )
        if fault is _RestartingLoopback and _RestartingLoopback.restarted_warm:
            # a warmed worker died mid-run: the stranded refs must
            # surface as a counted recovery round
            assert orch_f.telemetry.wire_fallbacks >= 1

    def test_restart_rebuilds_resident_state(self):
        """After the mid-run restart the new worker rebuilds its
        resident replicas from the recovery full-send and keeps going —
        rebuilds are visible in the cache telemetry."""
        _, serial = _run(5, None)
        _RestartingLoopback.restarted_warm = False
        orch, trace = _run(
            5, 2, transport=_RestartingLoopback, plan_mode="remote"
        )
        assert trace == serial
        cache = orch.telemetry.wire_worker_cache
        assert cache.get("resident_rebuilds", 0) >= 1
        assert orch.telemetry.wire_fallbacks >= 1
