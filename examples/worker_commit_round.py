"""Worker-owned two-phase commit over TCP, with a forced conflict and
a mid-run ownership fence (abort + retry).

Launches one standalone shard-worker process (``tools/shard_worker.py``)
and runs the contender workload with ``commit_mode="worker"``: the
worker holds the *authoritative* manager replicas under epoch-stamped
ownership leases, plans AND commits each round on its own state, and the
client confirms or aborts the intent on the next frame (two-phase
prepare -> intent/ack -> commit|abort, fused into ``plan_commit``
frames).

Two things are forced to go wrong, on purpose:

* **conflict** — every contender claims 2 units of the 2-unit
  ``shared`` pool, so each round's plans over-claim it; the worker
  resolves the loser on its authoritative replicas (rolls the launch
  back via ``release_unlaunched``) and the next pass retries — the
  client never arbitrates;
* **abort/retry** — a lease fence mid-run (what ``migrate_task`` or a
  rebalance issues before moving ownership) aborts the open commit
  intent with an explicit ``commit_decide`` frame and revokes the
  leases; the next round re-grants fresh epochs and retries.

Both runs must end with a launch trace bit-identical to the serial
round loop — conflicts and fences cost wire frames, never correctness.

Referenced from docs/architecture.md and docs/wire-protocol.md.

Run:  PYTHONPATH=src python examples/worker_commit_round.py
"""

import subprocess
import sys
from pathlib import Path

from repro.core import wire
from repro.core.action import Action, fixed
from repro.core.managers.base import ResourceManager
from repro.core.orchestrator import Orchestrator
from repro.core.simulator import EventLoop
from repro.core.transport import SocketTransport, socket_fleet

WORKER = Path(__file__).resolve().parents[1] / "tools" / "shard_worker.py"


class FenceMidPrepare:
    """Shard-transport wrapper that fences ownership while the prepare
    window is OPEN: once armed, the next in-flight ``plan_commit``
    frame triggers a full lease fence before its ack is read — the
    worst-case handoff timing (``migrate_task``/``rebalance`` racing a
    live two-phase round).  The fenced intent must be aborted, never
    adopted."""

    def __init__(self, inner, state):
        self._inner = inner
        self._state = state  # {"orch": Orchestrator|None, "armed": bool}
        self._last_kind = None

    def submit(self, request):
        try:
            payload = wire.decode_frame(request)
            self._last_kind = (
                payload.get("kind") if isinstance(payload, dict) else None
            )
        except wire.WireError:
            self._last_kind = None
        self._inner.submit(request)

    def recv(self):
        st = self._state
        if (st.get("armed") and self._last_kind == "plan_commit"
                and st.get("orch") is not None):
            st["armed"] = False
            st["orch"]._commit_engine.fence()
        return self._inner.recv()

    def close(self):
        self._inner.close()


def spawn_worker() -> subprocess.Popen:
    return subprocess.Popen(
        [sys.executable, str(WORKER), "--port", "0"],
        stdout=subprocess.PIPE, text=True,
    )


def worker_port(proc: subprocess.Popen) -> int:
    line = proc.stdout.readline().strip()
    assert line.startswith("PORT "), f"unexpected worker banner: {line!r}"
    return int(line.split()[1])


def build(**kw) -> Orchestrator:
    managers = {
        "a": ResourceManager("a", 4),
        "b": ResourceManager("b", 4),
        "shared": ResourceManager("shared", 2),
    }
    return Orchestrator(managers, loop=EventLoop(), **kw)


def submit_contenders(orch: Orchestrator, n: int = 18) -> None:
    """Waves of contenders: every action needs its home pool plus BOTH
    units of the 2-unit shared pool, so concurrent per-partition plans
    over-claim ``shared`` every round and commit must arbitrate."""
    for i in range(n):
        part = "a" if i % 2 == 0 else "b"
        orch.submit(
            Action(
                name=f"{part}{i}",
                cost={part: fixed(part, 1), "shared": fixed("shared", 2)},
                key_resource=part,
                base_duration=1.0 + 0.25 * (i % 3),
                trajectory_id=f"t{i}",
            ),
            delay=0.5 * (i // 6),
        )


def trace(orch: Orchestrator):
    return sorted(
        (r.name, r.trajectory_id, round(r.submit, 9), round(r.start, 9),
         round(r.finish, 9), tuple(sorted(r.units.items())))
        for r in orch.telemetry.records if not r.failed
    )


def main() -> None:
    print("== serial baseline (client-side managers, serial commit)")
    serial = build()
    submit_contenders(serial)
    serial.run()
    serial_trace = trace(serial)
    print(f"   completed={len(serial_trace)}  "
          f"mean ACT={serial.telemetry.mean_act():.3f}s")
    serial.close()

    proc = spawn_worker()
    try:
        addr = ("127.0.0.1", worker_port(proc))
        print(f"\n== worker-owned commit (authoritative replicas on :{addr[1]})")
        orch = build(shards=1, plan_mode="remote",
                     transport=socket_fleet([addr]), commit_mode="worker")
        submit_contenders(orch)
        orch.run()
        w = orch.telemetry.wire_summary()
        conflict_trace = trace(orch)
        print(f"   completed={len(conflict_trace)}  "
              f"prepares={w['prepares']:.0f}  acks={w['commit_acks']:.0f}  "
              f"lease grants={w['lease_grants']:.0f}")
        print(f"   conflicts resolved worker-side="
              f"{orch.telemetry.commit_conflicts}  "
              f"(client-serial commit walk never ran: "
              f"{orch.telemetry.commit_wall_s * 1e3:.2f}ms)")
        assert orch.telemetry.commit_conflicts > 0, "no conflict was forced?"
        orch.close()

        print("\n== same run + a lease fence mid-prepare (abort, then retry)")
        state = {"orch": None, "armed": False}
        orch = build(
            shards=1, plan_mode="remote", commit_mode="worker",
            transport=lambda i: FenceMidPrepare(SocketTransport(addr), state),
        )
        state["orch"] = orch
        submit_contenders(orch)
        # virtual time 1.25: arm the fence.  The next round's plan_commit
        # frame is answered with its intent already fenced — exactly what
        # an ownership handoff (migrate_task / rebalance) issues before
        # moving state.  The ack is discarded, the worker rolls back to
        # its pre-round replicas on an explicit commit_decide abort, the
        # leases are revoked, and the next round re-grants fresh epochs
        # and retries — the abort/retry rail.
        orch.loop.call_after(1.25, lambda: state.update(armed=True))
        orch.run()
        w = orch.telemetry.wire_summary()
        fenced_trace = trace(orch)
        print(f"   completed={len(fenced_trace)}  "
              f"fenced intents={w['fenced_intents']:.0f}  "
              f"aborts={w['commit_aborts']:.0f}  "
              f"lease grants={w['lease_grants']:.0f} "
              f"(re-granted after the fence)")
        assert w["fenced_intents"] >= 1, "the fence caught no open intent?"
        assert w["commit_aborts"] >= 1, "the fenced intent was not aborted?"
        orch.close()
    finally:
        proc.kill()
        proc.wait(timeout=10)

    assert conflict_trace == serial_trace, "conflict run diverged from serial!"
    assert fenced_trace == serial_trace, "fenced run diverged from serial!"
    print("\n== launch traces bit-identical to serial — conflict, fence, and all")


if __name__ == "__main__":
    main()
