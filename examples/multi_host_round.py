"""Multi-host plan rounds over TCP, with a worker killed mid-run.

Launches two standalone shard-worker processes (``tools/shard_worker.py``
— in production these run on other machines), plans the fleet-churn
workload over a :func:`repro.core.transport.socket_fleet` spanning both,
and **kills one worker halfway through**.  The orchestrator notes the
loss, plans that worker's partitions inline for the round, and keeps
retrying the endpoint with bounded backoff; the run completes with a
launch trace bit-identical to the serial round loop — fault tolerance
costs wire time, never correctness.

Referenced from docs/architecture.md and docs/wire-protocol.md.

Run:  PYTHONPATH=src python examples/multi_host_round.py
"""

import subprocess
import sys
from pathlib import Path

from repro.core.action import Action, AmdahlElasticity, ResourceRequest, fixed
from repro.core.managers.base import ResourceManager
from repro.core.orchestrator import Orchestrator
from repro.core.simulator import EventLoop
from repro.core.transport import socket_fleet

POOLS = 4
WORKER = Path(__file__).resolve().parents[1] / "tools" / "shard_worker.py"


def spawn_worker() -> subprocess.Popen:
    """One standalone worker endpoint; reads its ephemeral port from the
    ``PORT <n>`` line the entrypoint prints once listening."""
    return subprocess.Popen(
        [sys.executable, str(WORKER), "--port", "0"],
        stdout=subprocess.PIPE, text=True,
    )


def worker_port(proc: subprocess.Popen) -> int:
    line = proc.stdout.readline().strip()
    assert line.startswith("PORT "), f"unexpected worker banner: {line!r}"
    return int(line.split()[1])


def build(shards=None, **kw):
    loop = EventLoop()
    managers = {f"pool{k}": ResourceManager(f"pool{k}", 4) for k in range(POOLS)}
    return Orchestrator(managers, loop=loop, shards=shards, **kw)


def submit_workload(orch):
    for i in range(64):
        pool = f"pool{i % POOLS}"
        if i % 2:
            a = Action(
                name="reward", cost={pool: ResourceRequest(pool, (1, 2, 4))},
                key_resource=pool, elasticity=AmdahlElasticity(0.08),
                base_duration=2.0 + 0.25 * (i % 5), trajectory_id=f"t{i}",
            )
        else:
            a = Action(
                name="tool", cost={pool: fixed(pool, 1)},
                base_duration=0.5 + 0.1 * (i % 3), trajectory_id=f"t{i}",
            )
        orch.submit(a, delay=0.75 * (i // 8))


def trace(orch):
    return sorted(
        (r.name, r.trajectory_id, round(r.submit, 9), round(r.start, 9),
         round(r.finish, 9), tuple(sorted(r.units.items())))
        for r in orch.telemetry.records if not r.failed
    )


def main():
    print("== serial baseline (shards=None)")
    serial = build()
    submit_workload(serial)
    serial.run()
    serial_trace = trace(serial)
    print(f"   completed={len(serial_trace)}  mean ACT={serial.telemetry.mean_act():.3f}s")
    serial.close()

    print("\n== two worker processes over localhost TCP")
    a, b = spawn_worker(), spawn_worker()
    try:
        addrs = [("127.0.0.1", worker_port(a)), ("127.0.0.1", worker_port(b))]
        print(f"   workers listening on {addrs[0][1]} and {addrs[1][1]}")
        orch = build(shards=2, plan_mode="remote", transport=socket_fleet(addrs))
        submit_workload(orch)
        # virtual time 4.0: hard-kill worker B mid-run.  Its shard falls
        # back to inline planning (the plan core is shared, so plans are
        # identical) and the client backs off reconnect attempts on the
        # dead endpoint in rounds, not wall time.
        orch.loop.call_after(4.0, b.kill)
        orch.run()
        remote_trace = trace(orch)
        w = orch.telemetry.wire_summary()
        print(f"   completed={len(remote_trace)}  mean ACT={orch.telemetry.mean_act():.3f}s")
        print(f"   wire rounds={w['rounds']:.0f}  worker losses={w['worker_losses']:.0f}  "
              f"reconnects={w['reconnects']:.0f}  inline fallback parts={w['inline_parts']:.0f}")
        orch.close()
    finally:
        for proc in (a, b):
            proc.kill()
            proc.wait(timeout=10)

    assert remote_trace == serial_trace, "multi-host trace diverged from serial!"
    print("\n== launch traces bit-identical to serial, worker death and all")


if __name__ == "__main__":
    main()
