"""Serve multiple (real JAX) reward models on one GPU pool under EOE.

The paper's §6.3 story: 10 reward services that a static deployment
would give 40 dedicated GPUs can share a small pool under ARL-Tangram's
evict-on-execution manager.  Here three small models share a 2-node pool;
requests execute REAL scoring inference; the DES accounts occupancy,
restore overhead, and elastic DoP.

Run: PYTHONPATH=src python examples/serve_reward_models.py
"""

import numpy as np
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.action import Action, ResourceRequest
from repro.core.cluster import paper_testbed
from repro.rl.driver import build_tangram
from repro.rl.tasks import GPU_ELASTICITY
from repro.serving.reward_service import deploy_reward_service


def main() -> None:
    services = {
        name: deploy_reward_service(name, get_config(arch).reduced())
        for name, arch in (
            ("judge", "llama3.2-1b"),
            ("teacher0", "smollm-360m"),
            ("teacher1", "glm4-9b"),
        )
    }
    cluster = paper_testbed(cpu_nodes=1, gpu_nodes=2)
    tangram = build_tangram(cluster, services=list(services), service_state_gb=1.0)

    rng = np.random.default_rng(0)
    names = list(services)
    results = {}

    def score_fn(svc_name, tokens, idx):
        def run(dop: int) -> float:
            import time

            t = time.perf_counter()
            results[idx] = float(services[svc_name].score(jnp.asarray(tokens))[0])
            return time.perf_counter() - t

        return run

    for i in range(24):
        svc = names[i % len(names)]
        tokens = rng.integers(0, 256, size=(1, 16)).astype(np.int32)
        tangram.submit(
            Action(
                name=f"reward:{svc}",
                cost={"gpu": ResourceRequest("gpu", (1, 2, 4, 8))},
                key_resource="gpu",
                elasticity=GPU_ELASTICITY,
                base_duration=0.05,
                duration_sampler=score_fn(svc, tokens, i),
                service=svc,
                trajectory_id=f"req{i}",
            ),
            delay=0.05 * i,
        )
    end = tangram.run()
    tel = tangram.telemetry
    gpu = tangram.managers["gpu"]
    print(f"served {len(results)} real scoring requests over {end:.1f}s virtual time")
    print(f"mean ACT {tel.mean_act()*1e3:.1f}ms  p99 {tel.p(0.99)*1e3:.1f}ms")
    print(f"EOE hit rate {gpu.hit_rate():.0%}  restores {gpu.stats['misses']} "
          f"({gpu.stats['restore_s']:.1f}s restore time)")
    print(f"pool: {cluster.total_devices} GPUs for {len(services)} services "
          f"(static baseline would pin {4*len(services)})")
    print(f"sample scores: { {k: round(v, 2) for k, v in list(results.items())[:4]} }")


if __name__ == "__main__":
    main()
