"""End-to-end agentic RL: GRPO training with rewards through ARL-Tangram.

A tiny policy model generates groups of completions; a (real, JAX)
judge model scores them — each scoring call is an ARL-Tangram *action*
on the GPU pool with elastic DoP and EOE service caching; group-relative
advantages drive a GRPO update.  This is the paper's Figure-2 loop at
laptop scale with real compute in the reward path.

Run: PYTHONPATH=src python examples/agentic_rl_e2e.py --steps 5
"""

import argparse

import numpy as np

from repro.configs import get_config
from repro.core.cluster import paper_testbed
from repro.rl.driver import LiveGrpoDriver, build_tangram


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--group", type=int, default=4)
    args = ap.parse_args()

    policy_cfg = get_config("smollm-360m").reduced()
    judge_cfg = get_config("llama3.2-1b").reduced()
    driver = LiveGrpoDriver(policy_cfg, judge_cfg, group_size=args.group)

    rng = np.random.default_rng(0)
    for step in range(args.steps):
        cluster = paper_testbed(cpu_nodes=1, gpu_nodes=1)
        tangram = build_tangram(cluster, services=["judge"], service_state_gb=1.0)
        prompts = rng.integers(0, policy_cfg.vocab_size, size=(args.batch, 8)).astype(
            np.int32
        )
        rep = driver.run_step(prompts, tangram)
        gpu = tangram.managers["gpu"]
        print(
            f"step {step}: grpo_loss={rep.grpo_loss:+.4f} "
            f"mean_reward={rep.mean_reward:.2f} mean_ACT={rep.mean_act:.3f}s "
            f"EOE_hits={gpu.stats['hits']}/{gpu.stats['hits']+gpu.stats['misses']} "
            f"rollout={rep.rollout_wall_s:.1f}s update={rep.update_wall_s:.1f}s",
            flush=True,
        )


if __name__ == "__main__":
    main()
