"""One remote plan/commit round, end to end.

Runs the same mixed workload through three control planes —

1. the serial round loop (``shards=None``),
2. out-of-process planning over the full wire codec path
   (``plan_mode="remote"``, loopback transport), and
3. real worker OS processes (``transport="process"``),

then proves the launch traces are bit-identical and prints the honest
accounting: modeled critical-path decision latency next to (never mixed
with) the measured serialization overhead.  Finishes with a live
sub-queue migration between partition replicas.

Referenced from docs/architecture.md and docs/wire-protocol.md; see
those pages for what each moving part is.

Run:  PYTHONPATH=src python examples/remote_round.py
"""

from repro.core.action import Action, AmdahlElasticity, ResourceRequest, fixed
from repro.core.fairqueue import FairSharePolicy
from repro.core.managers.base import ResourceManager
from repro.core.orchestrator import Orchestrator
from repro.core.simulator import EventLoop

POOLS = 4


def build(shards=None, **kw):
    loop = EventLoop()
    managers = {f"pool{k}": ResourceManager(f"pool{k}", 4) for k in range(POOLS)}
    fs = FairSharePolicy(weights={"heavy": 2.0, "light": 1.0})
    return Orchestrator(managers, loop=loop, fair_share=fs, shards=shards, **kw)


def submit_workload(orch):
    futs = []
    for i in range(48):
        pool = f"pool{i % POOLS}"
        task = "heavy" if i % 3 else "light"
        if i % 2:
            a = Action(
                name="reward", cost={pool: ResourceRequest(pool, (1, 2, 4))},
                key_resource=pool, elasticity=AmdahlElasticity(0.08),
                base_duration=2.0 + 0.25 * (i % 5), task_id=task,
                trajectory_id=f"t{i}",
            )
        else:
            a = Action(
                name="tool", cost={pool: fixed(pool, 1)},
                base_duration=0.5 + 0.1 * (i % 3), task_id=task,
                trajectory_id=f"t{i}",
            )
        # wave arrivals: batches land on every pool at one timestamp, so
        # rounds are genuinely multi-partition and the plan phase shards
        futs.append(orch.submit(a, delay=0.5 * (i // 8)))
    return futs


def trace(orch):
    return sorted(
        (r.name, r.task_id, r.trajectory_id, round(r.submit, 9),
         round(r.start, 9), round(r.finish, 9),
         tuple(sorted(r.units.items())))
        for r in orch.telemetry.records if not r.failed
    )


def run(label, **kw):
    orch = build(**kw)
    futs = submit_workload(orch)
    orch.run()
    assert all(f.done() for f in futs)
    t = orch.telemetry
    print(f"\n== {label}")
    print(f"   completed={len(t.records)}  mean ACT={t.mean_act():.3f}s  "
          f"rounds={orch.stats['rounds']} (sharded={orch.stats['sharded_rounds']})")
    if t.wire_rounds:
        w = t.wire_summary()
        print(f"   critical-path plan: {t.plan_critical_s * 1e3:.2f} ms total")
        print(f"   wire overhead (separate!): encode {w['encode_s'] * 1e3:.2f} ms, "
              f"decode {w['decode_s'] * 1e3:.2f} ms, "
              f"{w['bytes'] / 1024:.0f} KiB over {t.wire_rounds:.0f} rounds")
    orch.close()
    return trace(orch)


def demo_migration():
    print("\n== sub-queue migration (pool0 -> pool1 replica)")
    orch = build()
    # pile both tenants' backlog onto pool0; pool1..3 idle
    for i in range(16):
        task = "heavy" if i % 2 else "light"
        orch.submit(Action(
            name="tool", cost={"pool0": fixed("pool0", 1)}, base_duration=1.0,
            task_id=task, trajectory_id=f"m{i}",
        ))
    orch.run(until=0.01)
    depths = lambda: {p: len(orch._queues.get(p) or ()) for p in ("pool0", "pool1")}
    print(f"   before: depths={depths()}")
    moved = orch.rebalance(["pool0", "pool1"])
    print(f"   rebalance moved {moved} queued action(s) "
          f"({orch.telemetry.migrations} migration(s), "
          f"{orch.telemetry.migration_wall_s * 1e6:.0f} us control-plane cost)")
    print(f"   after:  depths={depths()}")
    orch.run()
    pools = {p for r in orch.telemetry.records for p in r.units}
    print(f"   drained on pools: {sorted(pools)}  "
          f"(WFQ tags + virtual clock carried by the TaskShard)")


def main():
    serial = run("serial round loop (shards=None)")
    loopback = run("remote plans, loopback wire (shards=2)",
                   shards=2, plan_mode="remote")
    process = run("remote plans, worker processes (shards=2)",
                  shards=2, plan_mode="remote", transport="process")
    assert loopback == serial, "loopback remote trace diverged!"
    assert process == serial, "process remote trace diverged!"
    print("\n== launch traces: serial == loopback == process  (bit-identical)")
    demo_migration()


if __name__ == "__main__":
    main()
