"""End-to-end driver: pre-train a ~100M-class llama-family model on the
synthetic Markov stream for a few hundred steps (CPU-friendly sizes).

The model is the smollm-360m architecture at width 512 (same family,
~65M params with the tied 49k vocab) — the "~100M model, few hundred
steps" end-to-end deliverable.  Loss must fall from ~ln(V) toward the
stream's entropy floor ln(branching).

Run: PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.training import (
    AdamWConfig,
    DataConfig,
    MarkovTextStream,
    init_train_state,
    make_train_step,
    save_checkpoint,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="results/train_lm.npz")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("smollm-360m"),
        num_layers=12,
        d_model=512,
        num_heads=8,
        num_kv_heads=4,
        head_dim=64,
        d_ff=1536,
        dtype="float32",
        name="smollm-100m-class",
    )
    api = build_model(cfg)
    print(f"model: {cfg.name}  params={api.param_count()/1e6:.1f}M")

    state = init_train_state(api, jax.random.PRNGKey(0))
    opt = AdamWConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps)
    step = jax.jit(make_train_step(api, opt))
    data = MarkovTextStream(
        DataConfig(cfg.vocab_size, args.seq, args.batch, seed=0, branching=4,
                   active_vocab=2048)
    )
    floor = data.entropy_floor()

    t0 = time.time()
    for i, batch in zip(range(args.steps), data):
        state, m = step(state, {"tokens": jnp.asarray(batch["tokens"][:, : args.seq])})
        if i % 10 == 0 or i == args.steps - 1:
            print(
                f"step {i:4d}  loss {float(m['loss']):.3f}  "
                f"(floor {floor:.3f})  lr {float(m['lr']):.2e}  "
                f"gnorm {float(m['grad_norm']):.2f}  "
                f"{(time.time()-t0)/(i+1):.2f}s/step",
                flush=True,
            )
    save_checkpoint(args.ckpt, state.params, step=args.steps)
    print(f"saved {args.ckpt}")


if __name__ == "__main__":
    main()
