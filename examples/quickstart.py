"""Quickstart: ARL-Tangram in 60 lines.

Builds the paper's testbed (CPU + GPU pools + rate-limited APIs),
submits a small mixed burst of actions — elastic CPU test runs, GPU
reward-model calls with EOE caching, quota'd API calls — and prints the
ACT telemetry and scheduling decisions.

Run: PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (
    Action,
    AmdahlElasticity,
    EventLoop,
    ResourceRequest,
    Tangram,
    fixed,
    paper_testbed,
)
from repro.core.managers.basic import BasicResourceManager
from repro.core.managers.cpu import CpuManager
from repro.core.managers.gpu import GpuManager, ServiceSpec


def main() -> None:
    cluster = paper_testbed(cpu_nodes=2, cores_per_node=64, gpu_nodes=2)
    loop = EventLoop()
    tangram = Tangram(
        {
            "cpu": CpuManager(cluster.cpu_nodes),
            "gpu": GpuManager(
                cluster.gpu_nodes,
                [ServiceSpec("judge", 40.0), ServiceSpec("teacher0", 40.0)],
            ),
            "google_search": BasicResourceManager(cluster.apis[0], loop.clock),
        },
        loop=loop,
    )

    # an AI-coding style trajectory: tools then an elastic reward
    for i in range(8):
        tangram.trajectory_start(f"traj{i}", {"traj_mem_gb": 4.0})
        tangram.submit(
            Action(name="tool:exec", cost={"cpu": fixed("cpu", 1)},
                   base_duration=1.0, trajectory_id=f"traj{i}"),
            delay=0.2 * i,
        )
        tangram.submit(
            Action(
                name="reward:tests",
                cost={"cpu": ResourceRequest("cpu", (1, 2, 4, 8, 16, 32))},
                key_resource="cpu",
                elasticity=AmdahlElasticity(0.05),
                base_duration=30.0,
                trajectory_id=f"traj{i}",
            ),
            delay=0.2 * i + 2.0,
        )
    # reward-model calls multiplexing one GPU pool (EOE)
    for i in range(8):
        tangram.submit(
            Action(
                name="reward:judge",
                cost={"gpu": ResourceRequest("gpu", (1, 2, 4, 8))},
                key_resource="gpu",
                elasticity=AmdahlElasticity(0.15),
                base_duration=4.0,
                service="judge" if i % 2 else "teacher0",
                trajectory_id=f"g{i}",
            ),
            delay=0.5 * i,
        )
    # rate-limited search calls
    for i in range(6):
        tangram.submit(
            Action(name="tool:google_search", cost={"google_search": fixed("google_search")},
                   base_duration=2.0, trajectory_id=f"s{i}"),
            delay=0.1 * i,
        )

    end = tangram.run()
    tel = tangram.telemetry
    print(f"simulated {len(tel.records)} actions in {end:.1f}s of virtual time")
    print(f"mean ACT: {tel.mean_act():.2f}s   p99: {tel.p(0.99):.2f}s")
    print(f"breakdown: {tel.breakdown()}")
    gpu = tangram.managers["gpu"]
    print(f"EOE cache hit rate: {gpu.hit_rate():.0%}  ({gpu.stats})")
    by_stage = tel.by_stage()
    for stage, act in sorted(by_stage.items()):
        print(f"  {stage:10s} mean ACT {act:6.2f}s")


if __name__ == "__main__":
    main()
