"""Shared helpers for the paper-figure benchmark harnesses."""

from __future__ import annotations

import sys
import time
from typing import Dict, Iterable


def emit(rows: Iterable[Dict[str, object]], header: str) -> None:
    """Print a CSV block (``name,us_per_call,derived`` style per brief)."""
    print(f"# {header}")
    rows = list(rows)
    if not rows:
        return
    keys = list(rows[0].keys())
    print(",".join(keys))
    for r in rows:
        print(",".join(_fmt(r[k]) for k in keys))
    sys.stdout.flush()


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
