"""Scheduler micro-benchmarks: decision latency (us/call) vs queue depth.

The paper's constraint: action durations go down to ~1 ms, so the
scheduling window is tiny; Table 1 attributes <3% overhead to the
system.  This harness measures the Python control-plane directly:

* ``schedule_*``     — one cold full reschedule per call, measured for
  both the dense vectorized DPArrange (default) and the dict-based
  reference DP (``*_ref`` rows), plus a ``*_dense_speedup`` ratio;
* ``churn_*``        — steady-state churn against a WARM orchestrator
  (interleaved submissions + completions), incremental rounds vs full
  rescheduling, reporting per-event decision latency and the speedup.

``main`` additionally writes ``BENCH_scheduler.json`` (per-scenario
ns/op + mean ACT, machine-readable for CI trending) and, with
``--check``, exits non-zero if the dense path is slower than the
reference on the queue-128 scenario — the CI smoke guard for the
fast path.
"""

from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

from benchmarks.common import emit
from repro.core.action import Action, AmdahlElasticity, ResourceRequest, fixed
from repro.core.cluster import CpuNodeSpec
from repro.core.managers.cpu import CpuManager
from repro.core.orchestrator import Orchestrator
from repro.core.scheduler import ElasticScheduler


def _mk_waiting(n: int, scalable_frac: float = 0.3):
    out = []
    for i in range(n):
        if i % max(1, int(1 / max(scalable_frac, 1e-9))) == 0:
            out.append(
                Action(
                    name="reward",
                    cost={"cpu": ResourceRequest("cpu", (1, 2, 4, 8, 16, 32))},
                    key_resource="cpu",
                    elasticity=AmdahlElasticity(0.05),
                    base_duration=10.0 + i,
                    trajectory_id=f"t{i}",
                )
            )
        else:
            out.append(
                Action(name="tool", cost={"cpu": fixed("cpu", 1)},
                       base_duration=1.0, trajectory_id=f"t{i}")
            )
    return out


def run(scale: float = 1.0) -> List[Dict[str, object]]:
    rows = []
    for depth in (1, 2, 3):
        for n in (8, 32, 128):
            waiting = _mk_waiting(n)
            timings: Dict[str, float] = {}
            for variant in ("dense", "ref"):
                mgr = {"cpu": CpuManager([CpuNodeSpec("n0", cores=256)])}
                sched = ElasticScheduler(depth=depth)
                sched.use_dense = variant == "dense"
                iters = max(3, int(30 * scale))
                t0 = time.perf_counter()
                for _ in range(iters):
                    sched.schedule(waiting, [], mgr, 0.0)
                us = (time.perf_counter() - t0) / iters * 1e6
                timings[variant] = us
                suffix = "" if variant == "dense" else "_ref"
                rows.append(
                    {
                        "name": f"schedule_depth{depth}_queue{n}{suffix}",
                        "us_per_call": us,
                        "derived": f"depth={depth};queue={n};dp={variant}",
                    }
                )
            rows.append(
                {
                    "name": f"schedule_depth{depth}_queue{n}_dense_speedup",
                    "us_per_call": timings["ref"] / max(1e-9, timings["dense"]),
                    "derived": f"depth={depth};queue={n};x_ref_over_dense",
                }
            )
    return rows


# The churn tool fleet: DeepSearch-style rate-limited services plus local
# utilities — agentic workloads multiplex MANY resource types, which is
# what per-type queue partitioning exploits.
CHURN_APIS = (
    "google_search",
    "web_fetch",
    "pdf_parse",
    "embed",
    "code_exec",
    "translate",
)


def _churn_action(i: int) -> Action:
    """Mixed agentic-RL action stream (the paper's MOPD+Search shape):
    deep scalable cpu/gpu reward backlogs plus a high-frequency stream
    of short rate-limited tool/api calls (DeepSearch)."""
    kind = i % 8
    if kind == 0:  # scalable cpu reward
        return Action(
            name="reward",
            cost={"cpu": ResourceRequest("cpu", (1, 2, 4, 8))},
            key_resource="cpu",
            elasticity=AmdahlElasticity(0.05),
            base_duration=5.0 + (i % 7),
            trajectory_id=f"c{i}",
        )
    if kind == 1:  # rigid cpu tool call
        return Action(
            name="tool",
            cost={"cpu": fixed("cpu", 1)},
            base_duration=0.5 + 0.1 * (i % 5),
            trajectory_id=f"c{i}",
        )
    if kind == 2:  # gpu reward-model scoring (scalable TP)
        return Action(
            name="rm:score",
            cost={"gpu": ResourceRequest("gpu", (1, 2, 4))},
            key_resource="gpu",
            elasticity=AmdahlElasticity(0.15),
            base_duration=1.0 + 0.25 * (i % 4),
            service="rm0",
            trajectory_id=f"c{i}",
        )
    api = CHURN_APIS[i % len(CHURN_APIS)]
    return Action(
        name=f"api:{api}",
        cost={api: fixed(api, 1)},
        base_duration=0.3 + 0.2 * (i % 3),
        trajectory_id=f"c{i}",
    )


class _SeedOrchestrator(Orchestrator):
    """The seed Tangram control plane, reconstructed for comparison: ONE
    global FCFS queue (no resource partitioning) and a full reschedule of
    the entire problem on every event — the pre-refactor
    ``Tangram._tick`` decision path."""

    @staticmethod
    def _partition_of(action: Action) -> str:
        return "*"


def _run_churn(mode: str, queue: int, events: int):
    """Warm orchestrator under steady-state churn: the queue is primed to
    ``queue`` depth against pools smaller than demand, then every
    completion triggers one replacement submission, holding depth
    roughly constant while ``events`` actions flow through.  Each event
    touches ONE resource partition — the scenario the incremental engine
    (dirty tracking + admission cursor + DP memo) is built for.

    ``mode``: "seed" (global queue, full reschedule per event),
    "full" (partitioned queues, every partition rescheduled per event),
    or "incremental" (dirty tracking + caches)."""
    from repro.core.cluster import ApiResourceSpec, GpuNodeSpec
    from repro.core.managers.basic import BasicResourceManager
    from repro.core.managers.gpu import GpuManager, ServiceSpec
    from repro.core.simulator import EventLoop

    loop = EventLoop()
    managers: Dict[str, object] = {
        "cpu": CpuManager([CpuNodeSpec("n0", cores=32)]),
        "gpu": GpuManager([GpuNodeSpec("g0")], [ServiceSpec("rm0", 40.0)]),
    }
    for api in CHURN_APIS:
        managers[api] = BasicResourceManager(
            ApiResourceSpec(api, mode="concurrency", max_concurrency=3), loop.clock
        )
    cls = _SeedOrchestrator if mode == "seed" else Orchestrator
    orch = cls(
        managers,
        loop=loop,
        policy=ElasticScheduler(),
        incremental=(mode == "incremental"),
    )
    counter = [queue]
    done_since_wave = [0]
    wave = max(8, queue // 4)

    def refill(_fut) -> None:
        # wave arrivals (paper §6: rollout batches land together): every
        # ``wave`` completions trigger one same-timestamp submission
        # burst, so the queue repeatedly sees freed capacity against deep
        # backlog — the regime where a full reschedule rebuilds the
        # whole window/DP and the incremental path reuses it.
        done_since_wave[0] += 1
        if done_since_wave[0] < wave or counter[0] >= queue + events:
            return
        done_since_wave[0] = 0
        for _ in range(wave):
            if counter[0] >= queue + events:
                break
            i = counter[0]
            counter[0] += 1
            fut = orch.submit(_churn_action(i))
            fut.add_done_callback(refill)

    for i in range(queue):
        fut = orch.submit(_churn_action(i), delay=0.001 * i)
        fut.add_done_callback(refill)
    # warm-up: let the priming burst enqueue and the first launches land,
    # so the measurement covers only steady-state churn rounds.
    orch.run(until=0.001 * queue + 0.05)
    warm_records = len(orch.telemetry.records)
    orch.telemetry.sched_wall_s = 0.0
    warm_stats = dict(orch.stats)
    t0 = time.perf_counter()
    orch.run()
    wall = time.perf_counter() - t0
    n_events = len(orch.telemetry.records) - warm_records
    return {
        "wall_s": wall,
        "sched_us_per_event": orch.telemetry.sched_wall_s / max(1, n_events) * 1e6,
        "events": n_events,
        "rounds": orch.stats["rounds"] - warm_stats["rounds"],
        "partition_runs": orch.stats["partition_runs"] - warm_stats["partition_runs"],
        # decision QUALITY: the seed's global FCFS head-of-line blocking
        # makes its rounds cheap precisely because it schedules less —
        # mean ACT exposes that pathology alongside the latency numbers.
        "mean_act": orch.telemetry.mean_act(),
    }


def run_churn(scale: float = 1.0) -> List[Dict[str, object]]:
    rows: List[Dict[str, object]] = []
    for queue in (32, 128):
        events = max(64, int(256 * scale))
        results = {
            mode: _run_churn(mode, queue=queue, events=events)
            for mode in ("seed", "full", "incremental")
        }
        inc_us = max(1e-9, results["incremental"]["sched_us_per_event"])
        for mode, r in results.items():
            rows.append(
                {
                    "name": f"churn_queue{queue}_{mode}",
                    "us_per_call": r["sched_us_per_event"],
                    "mean_act": r["mean_act"],
                    "derived": (
                        f"queue={queue};events={r['events']};rounds={r['rounds']};"
                        f"partition_runs={r['partition_runs']}"
                    ),
                }
            )
        rows.append(
            {
                "name": f"churn_queue{queue}_speedup_vs_seed",
                "us_per_call": results["seed"]["sched_us_per_event"] / inc_us,
                "mean_act": "",
                "derived": f"queue={queue};x_seed_over_incremental",
            }
        )
        rows.append(
            {
                "name": f"churn_queue{queue}_speedup_vs_full",
                "us_per_call": results["full"]["sched_us_per_event"] / inc_us,
                "mean_act": "",
                "derived": f"queue={queue};x_full_over_incremental",
            }
        )
    return rows


CHECK_SCENARIO = "schedule_depth2_queue128"


def write_json(rows: List[Dict[str, object]], path: str) -> None:
    """Machine-readable per-scenario results: ns/op + mean ACT."""
    scenarios: Dict[str, Dict[str, object]] = {}
    for r in rows:
        us = float(r["us_per_call"])  # type: ignore[arg-type]
        name = str(r["name"])
        is_ratio = "speedup" in name
        scenarios[name] = {
            "ns_per_op": None if is_ratio else us * 1e3,
            "us_per_call": None if is_ratio else us,
            "ratio": us if is_ratio else None,
            "mean_act": (
                float(r["mean_act"])  # type: ignore[arg-type]
                if r.get("mean_act") not in (None, "")
                else None
            ),
            "derived": r.get("derived"),
        }
    with open(path, "w") as f:
        json.dump({"scenarios": scenarios}, f, indent=2, sort_keys=True)
        f.write("\n")


def check_dense_fast_path(rows: List[Dict[str, object]]) -> None:
    """CI guard: the dense DP must not be slower than the reference on
    the queue-128 scenario (the acceptance target is >= 3x, but a smoke
    run at low scale is noisy, so the hard gate is parity)."""
    by_name = {r["name"]: float(r["us_per_call"]) for r in rows}  # type: ignore[arg-type]
    dense = by_name[CHECK_SCENARIO]
    ref = by_name[f"{CHECK_SCENARIO}_ref"]
    speedup = ref / max(1e-9, dense)
    print(f"# dense-DP check: {CHECK_SCENARIO} dense={dense:.0f}us "
          f"ref={ref:.0f}us speedup={speedup:.2f}x")
    if dense > ref:
        raise SystemExit(
            f"dense DP slower than reference on {CHECK_SCENARIO}: "
            f"{dense:.0f}us > {ref:.0f}us"
        )


def main(
    scale: float = 1.0,
    json_path: Optional[str] = "BENCH_scheduler.json",
    check: bool = False,
) -> None:
    sched_rows = run(scale)
    emit(sched_rows, "scheduler decision latency (dense vs reference DP)")
    churn_rows = run_churn(scale)
    emit(churn_rows, "steady-state churn decision latency (warm orchestrator)")
    if json_path:
        write_json(sched_rows + churn_rows, json_path)
    if check:
        check_dense_fast_path(sched_rows)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--json", default="BENCH_scheduler.json",
                    help="output path for machine-readable results ('' = skip)")
    ap.add_argument("--check", action="store_true",
                    help="fail if the dense DP is slower than the reference "
                         f"on {CHECK_SCENARIO}")
    args = ap.parse_args()
    main(args.scale, args.json or None, args.check)
