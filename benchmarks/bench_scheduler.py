"""Scheduler micro-benchmarks: decision latency (us/call) vs queue depth.

The paper's constraint: action durations go down to ~1 ms, so the
scheduling window is tiny; Table 1 attributes <3% overhead to the
system.  This harness measures the Python control-plane directly.
"""

from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.common import emit
from repro.core.action import Action, AmdahlElasticity, ResourceRequest, fixed
from repro.core.cluster import CpuNodeSpec
from repro.core.managers.cpu import CpuManager
from repro.core.scheduler import ElasticScheduler


def _mk_waiting(n: int, scalable_frac: float = 0.3):
    out = []
    for i in range(n):
        if i % max(1, int(1 / max(scalable_frac, 1e-9))) == 0:
            out.append(
                Action(
                    name="reward",
                    cost={"cpu": ResourceRequest("cpu", (1, 2, 4, 8, 16, 32))},
                    key_resource="cpu",
                    elasticity=AmdahlElasticity(0.05),
                    base_duration=10.0 + i,
                    trajectory_id=f"t{i}",
                )
            )
        else:
            out.append(
                Action(name="tool", cost={"cpu": fixed("cpu", 1)},
                       base_duration=1.0, trajectory_id=f"t{i}")
            )
    return out


def run(scale: float = 1.0) -> List[Dict[str, object]]:
    rows = []
    for depth in (1, 2, 3):
        for n in (8, 32, 128):
            mgr = {"cpu": CpuManager([CpuNodeSpec("n0", cores=256)])}
            sched = ElasticScheduler(depth=depth)
            waiting = _mk_waiting(n)
            iters = max(3, int(30 * scale))
            t0 = time.perf_counter()
            for _ in range(iters):
                sched.schedule(waiting, [], mgr, 0.0)
            us = (time.perf_counter() - t0) / iters * 1e6
            rows.append(
                {
                    "name": f"schedule_depth{depth}_queue{n}",
                    "us_per_call": us,
                    "derived": f"depth={depth};queue={n}",
                }
            )
    return rows


def main(scale: float = 1.0) -> None:
    emit(run(scale), "scheduler decision latency")


if __name__ == "__main__":
    main()
