"""Scheduler micro-benchmarks: decision latency (us/call) vs queue depth.

The paper's constraint: action durations go down to ~1 ms, so the
scheduling window is tiny; Table 1 attributes <3% overhead to the
system.  This harness measures the Python control-plane directly:

* ``schedule_*``     — one cold full reschedule per call, measured for
  both the dense vectorized DPArrange (default) and the dict-based
  reference DP (``*_ref`` rows), plus a ``*_dense_speedup`` ratio;
* ``churn_*``        — steady-state churn against a WARM orchestrator
  (interleaved submissions + completions), incremental rounds vs full
  rescheduling, reporting per-event decision latency and the speedup;
* ``shard_churn_*``  — synchronized fleet churn (many pools dirty per
  round), the serial round loop vs the sharded plan/commit engine
  (``--shards N``): critical-path decision latency, speedup, and the
  launch-trace identity bit (``--suite shards`` + ``--check`` is the CI
  shard-smoke gate);
* ``remote_churn_*`` — the same fleet churn with the plan phase running
  in shard workers over the wire codecs (``--suite remote``):
  trace identity vs serial, the modeled critical path, and the
  serialization bill (encode+decode us/event, bytes/round) reported as
  its own rows — wire overhead is never folded into decision latency
  (``--check`` is the CI remote-smoke gate).

``main`` additionally writes ``BENCH_scheduler.json`` (per-scenario
ns/op + mean ACT, machine-readable for CI trending) and, with
``--check``, exits non-zero if the dense path is slower than the
reference on the queue-128 scenario — the CI smoke guard for the
fast path.
"""

from __future__ import annotations

import json
import statistics
import time
from typing import Dict, List, Optional

from benchmarks.common import emit
from repro.core import scenarios
from repro.core.action import Action, AmdahlElasticity, ResourceRequest, fixed
from repro.core.cluster import CpuNodeSpec
from repro.core.managers.base import ResourceManager
from repro.core.managers.cpu import CpuManager
from repro.core.orchestrator import Orchestrator
from repro.core.scheduler import ElasticScheduler


def _mk_waiting(n: int, scalable_frac: float = 0.3):
    out = []
    for i in range(n):
        if i % max(1, int(1 / max(scalable_frac, 1e-9))) == 0:
            out.append(
                Action(
                    name="reward",
                    cost={"cpu": ResourceRequest("cpu", (1, 2, 4, 8, 16, 32))},
                    key_resource="cpu",
                    elasticity=AmdahlElasticity(0.05),
                    base_duration=10.0 + i,
                    trajectory_id=f"t{i}",
                )
            )
        else:
            out.append(
                Action(name="tool", cost={"cpu": fixed("cpu", 1)},
                       base_duration=1.0, trajectory_id=f"t{i}")
            )
    return out


def run(scale: float = 1.0) -> List[Dict[str, object]]:
    rows = []
    for depth in (1, 2, 3):
        for n in (8, 32, 128):
            waiting = _mk_waiting(n)
            timings: Dict[str, float] = {}
            for variant in ("dense", "ref"):
                mgr = {"cpu": CpuManager([CpuNodeSpec("n0", cores=256)])}
                sched = ElasticScheduler(depth=depth)
                sched.use_dense = variant == "dense"
                iters = max(3, int(30 * scale))
                t0 = time.perf_counter()
                for _ in range(iters):
                    sched.schedule(waiting, [], mgr, 0.0)
                us = (time.perf_counter() - t0) / iters * 1e6
                timings[variant] = us
                suffix = "" if variant == "dense" else "_ref"
                rows.append(
                    {
                        "name": f"schedule_depth{depth}_queue{n}{suffix}",
                        "us_per_call": us,
                        "derived": f"depth={depth};queue={n};dp={variant}",
                    }
                )
            rows.append(
                {
                    "name": f"schedule_depth{depth}_queue{n}_dense_speedup",
                    "us_per_call": timings["ref"] / max(1e-9, timings["dense"]),
                    "derived": f"depth={depth};queue={n};x_ref_over_dense",
                }
            )
    return rows


# The churn workload (DeepSearch-style rate-limited services plus local
# utilities — agentic workloads multiplex MANY resource types, which is
# what per-type queue partitioning exploits) is declared as a
# ScenarioSpec in repro.core.scenarios (``churn_spec``).  The frozen
# pre-factory Python generator it replaced is pinned in
# tests/test_scenarios.py, where an equivalence test proves the spec
# reproduces its traces bit-identically.
CHURN_APIS = scenarios.CHURN_APIS


class _SeedOrchestrator(Orchestrator):
    """The seed Tangram control plane, reconstructed for comparison: ONE
    global FCFS queue (no resource partitioning) and a full reschedule of
    the entire problem on every event — the pre-refactor
    ``Tangram._tick`` decision path."""

    @staticmethod
    def _partition_of(action: Action) -> str:
        return "*"


def _run_churn(mode: str, queue: int, events: int):
    """Warm orchestrator under steady-state churn: the queue is primed to
    ``queue`` depth against pools smaller than demand, then every
    completion triggers one replacement submission, holding depth
    roughly constant while ``events`` actions flow through.  Each event
    touches ONE resource partition — the scenario the incremental engine
    (dirty tracking + admission cursor + DP memo) is built for.

    ``mode``: "seed" (global queue, full reschedule per event),
    "full" (partitioned queues, every partition rescheduled per event),
    or "incremental" (dirty tracking + caches)."""
    from repro.core.simulator import EventLoop

    spec = scenarios.churn_spec(queue=queue, events=events)
    loop = EventLoop()
    managers = scenarios.build_managers(spec, loop)
    cls = _SeedOrchestrator if mode == "seed" else Orchestrator
    orch = cls(
        managers,
        loop=loop,
        policy=ElasticScheduler(),
        incremental=(mode == "incremental"),
    )
    # closed-loop wave arrivals (paper §6: rollout batches land
    # together): every ``wave`` completions trigger one same-timestamp
    # submission burst, so the queue repeatedly sees freed capacity
    # against deep backlog — the regime where a full reschedule rebuilds
    # the whole window/DP and the incremental path reuses it.
    scenarios.install_scenario(spec, orch)
    # warm-up: let the priming burst enqueue and the first launches land,
    # so the measurement covers only steady-state churn rounds.
    orch.run(until=0.001 * queue + 0.05)
    warm_records = len(orch.telemetry.records)
    orch.telemetry.sched_wall_s = 0.0
    warm_stats = dict(orch.stats)
    t0 = time.perf_counter()
    orch.run()
    wall = time.perf_counter() - t0
    n_events = len(orch.telemetry.records) - warm_records
    return {
        "wall_s": wall,
        "sched_us_per_event": orch.telemetry.sched_wall_s / max(1, n_events) * 1e6,
        "events": n_events,
        "rounds": orch.stats["rounds"] - warm_stats["rounds"],
        "partition_runs": orch.stats["partition_runs"] - warm_stats["partition_runs"],
        # decision QUALITY: the seed's global FCFS head-of-line blocking
        # makes its rounds cheap precisely because it schedules less —
        # mean ACT exposes that pathology alongside the latency numbers.
        "mean_act": orch.telemetry.mean_act(),
    }


def run_churn(scale: float = 1.0) -> List[Dict[str, object]]:
    rows: List[Dict[str, object]] = []
    for queue in (32, 128):
        events = max(64, int(256 * scale))
        results = {
            mode: _run_churn(mode, queue=queue, events=events)
            for mode in ("seed", "full", "incremental")
        }
        inc_us = max(1e-9, results["incremental"]["sched_us_per_event"])
        for mode, r in results.items():
            rows.append(
                {
                    "name": f"churn_queue{queue}_{mode}",
                    "us_per_call": r["sched_us_per_event"],
                    "mean_act": r["mean_act"],
                    "derived": (
                        f"queue={queue};events={r['events']};rounds={r['rounds']};"
                        f"partition_runs={r['partition_runs']}"
                    ),
                }
            )
        rows.append(
            {
                "name": f"churn_queue{queue}_speedup_vs_seed",
                "us_per_call": results["seed"]["sched_us_per_event"] / inc_us,
                "mean_act": "",
                "derived": f"queue={queue};x_seed_over_incremental",
            }
        )
        rows.append(
            {
                "name": f"churn_queue{queue}_speedup_vs_full",
                "us_per_call": results["full"]["sched_us_per_event"] / inc_us,
                "mean_act": "",
                "derived": f"queue={queue};x_full_over_incremental",
            }
        )
    return rows


# ---------------------------------------------------------------------------
# Sharded-rounds scenario: synchronized fleet churn (the control-plane
# scale wall the plan/commit engine removes)
# ---------------------------------------------------------------------------

#: Independent external resource pools in the fleet-churn scenario.  The
#: fleet is symmetric — every wave lands the same action multiset on
#: every pool at the same virtual instant, so completions coalesce
#: across pools and (nearly) every scheduling round re-plans many dirty
#: partitions: the regime where the serial round loop's decision latency
#: grows with fleet size and the sharded engine's critical path stays
#: flat.
SHARD_POOLS = 8


def _run_shard_churn(
    shards: Optional[int], queue: int = 128, waves: int = 16,
    cores: int = 8, period_s: float = 4.0,
    plan_mode: str = "inline", transport="loopback",
    wire_codec: str = "json", commit_mode: str = "client", pre_run=None,
):
    """Steady-state churn over ``SHARD_POOLS`` independent pools, each
    smaller than its demand so a deep backlog persists: every wave
    submits ``queue / SHARD_POOLS`` actions per pool at one timestamp,
    and the symmetric workload keeps cross-pool completions coalesced —
    every round is a genuinely multi-partition round.  ``shards=None``
    is the serial round loop; ``shards=N`` the plan/commit engine, whose
    charged decision latency is the critical path (max per-shard plan +
    serialized commit — see repro.core.shards).  ``plan_mode="remote"``
    sends the plan phase through the wire codecs to shard workers
    (``transport``: "loopback" = in-process workers behind the full
    encode/decode path, "process" = real worker OS processes, or a
    ``shard_idx -> ShardTransport`` factory for socket fleets).
    ``commit_mode="worker"`` moves the commit phase worker-side too
    (two-phase prepare/ack over fused ``plan_commit`` frames).
    ``pre_run(orch)`` runs before the clock starts — the chaos suite's
    hook for scheduling virtual-time worker kills."""
    from repro.core.simulator import EventLoop

    spec = scenarios.fleet_churn_spec(
        queue=queue, waves=waves, cores=cores, period_s=period_s,
        pools=SHARD_POOLS,
    )
    loop = EventLoop()
    managers = scenarios.build_managers(spec, loop)
    orch = Orchestrator(
        managers, loop=loop, policy=ElasticScheduler(), incremental=True,
        shards=shards, plan_mode=plan_mode, transport=transport,
        wire_codec=wire_codec, commit_mode=commit_mode,
    )
    if pre_run is not None:
        pre_run(orch)
    scenarios.install_scenario(spec, orch)
    # warm-up: the first wave primes queues, caches, and pool state;
    # reset EVERY shard counter so the reported latency, wall, balance,
    # and conflict figures all cover the same post-warm-up window
    orch.run(until=period_s - 0.1)
    warm_records = len(orch.telemetry.records)
    orch.telemetry.sched_wall_s = 0.0
    orch.telemetry.plan_wall_s = 0.0
    orch.telemetry.plan_critical_s = 0.0
    orch.telemetry.commit_conflicts = 0
    orch.telemetry.shards = {}
    orch.telemetry.reset_wire()
    orch.run()
    n_events = len(orch.telemetry.records) - warm_records
    trace = sorted(
        (r.name, r.trajectory_id, round(r.submit, 9), round(r.start, 9),
         round(r.finish, 9), tuple(sorted(r.units.items())), r.failed)
        for r in orch.telemetry.records
    )
    orch.close()
    return {
        "sched_us_per_event": orch.telemetry.sched_wall_s / max(1, n_events) * 1e6,
        "events": n_events,
        "rounds": orch.stats["rounds"],
        "sharded_rounds": orch.stats["sharded_rounds"],
        "mean_act": orch.telemetry.mean_act(),
        "trace": trace,
        "summary": orch.telemetry.shard_summary(),
        "wire": orch.telemetry.wire_summary(),
        "commit_wall_s": orch.telemetry.commit_wall_s,
    }


def run_shards(scale: float = 1.0, shards: int = 4) -> List[Dict[str, object]]:
    """Sharded-round rows: serial vs ``--shards N`` decision latency on
    the queue-128 fleet churn, the speedup, trace identity, and shard
    balance.  The sharded latency is the modeled critical path (max
    per-shard plan + commit — what a fleet of per-shard workers pays);
    the real in-process plan wall is reported alongside, never
    conflated."""
    queue = 128
    waves = max(6, int(16 * scale))
    serial = _run_shard_churn(None, queue=queue, waves=waves)
    sharded = _run_shard_churn(shards, queue=queue, waves=waves)
    identical = serial["trace"] == sharded["trace"]
    speedup = serial["sched_us_per_event"] / max(
        1e-9, sharded["sched_us_per_event"]
    )
    summ = sharded["summary"]
    rows: List[Dict[str, object]] = [
        {
            "name": f"shard_churn_queue{queue}_serial",
            "us_per_call": serial["sched_us_per_event"],
            "mean_act": serial["mean_act"],
            "derived": f"queue={queue};events={serial['events']};rounds={serial['rounds']}",
        },
        {
            "name": f"shard_churn_queue{queue}_shards{shards}",
            "us_per_call": sharded["sched_us_per_event"],
            "mean_act": sharded["mean_act"],
            "derived": (
                f"queue={queue};events={sharded['events']};"
                f"sharded_rounds={sharded['sharded_rounds']};"
                f"plan_wall_s={summ.get('plan_wall_s', 0.0):.4f};"
                f"imbalance={summ.get('imbalance', 1.0):.3f};"
                f"conflicts={summ.get('commit_conflicts', 0.0):.0f}"
            ),
        },
        {
            "name": f"shard_churn_queue{queue}_speedup",
            "us_per_call": speedup,
            "mean_act": "",
            "derived": f"x_serial_over_shards{shards};critical-path model",
        },
        {
            "name": f"shard_churn_queue{queue}_traces_identical",
            "us_per_call": 1.0 if identical else 0.0,
            "mean_act": "",
            "derived": "1=launch traces bit-identical to the serial round loop",
        },
    ]
    return rows


#: Committed bytes-per-round baseline for the queue-128 fleet-churn
#: remote suite (deltas + interning + list deltas).  The CI remote-smoke
#: gate fails a regression above this — the pre-delta protocol shipped
#: ~174KB/round, so the ceiling also enforces the >=5x reduction (it sits
#: at ~10x).  Measured steady state: ~13.1KB/round with the json codec,
#: ~8.4KB with binary; the headroom absorbs machine noise in round
#: coalescing, not protocol regressions.
REMOTE_BYTES_PER_ROUND_BASELINE = 18_000

#: CI ceiling on the remote suite's SERIALIZED wire overhead (client
#: encode + client decode + worker codec, summed as if nothing
#: overlapped) relative to the modeled critical-path decision latency.
#: Measured: ~5x with the json codec (down from ~23x before the
#: delta/interning protocol) — the denominator shrank again when
#: resident worker plan state made per-shard plans cheaper, so the
#: serialized ratio reads worse even as both sides got faster.  The 7x
#: ceiling is the regression rail on raw codec cost.
REMOTE_WIRE_LATENCY_RATIO = 7.0

#: CI ceiling on the PIPELINED wire overhead — the overlap-aware
#: critical path (head request encode + slowest worker codec + response
#: decode; everything else hides behind worker compute and other
#: shards' encodes) — relative to the same decision latency.  This is
#: the honest "what the wire adds to a round" figure once dispatch is
#: pipelined, and it must stay comparable to decision cost, never a
#: multiple of it.  Measured: ~1.5x with the json codec.
REMOTE_WIRE_PIPELINED_RATIO = 3.0

#: CI floor on the client encode-memo hit rate (act-cache, queue-cache,
#: and byte-segment consultations per round).  Steady-state churn sits
#: near ~0.89; a drop below 0.80 means encode work started tracking
#: state size again instead of state *change*.
REMOTE_MEMO_HIT_RATE_FLOOR = 0.80

#: CI collapse-bound on the commit-offload ratio: the client-serial
#: commit wall divided by what commit costs the round in worker-owned
#: mode (max per-worker commit wall + whatever residual serial commit
#: the client still pays on non-fused rounds).  Structurally this
#: tracks the shard count (workers commit their partitions in parallel;
#: the serial walk sums them), measured ~1.5x at full scale with 4
#: shards over 8 pools — but at smoke scale the worker's post-commit
#: fingerprint bill is a fixed cost the tiny walk cannot amortize, so
#: the ratio hovers near 1.0-1.3x and a *win* floor would flake.  The
#: smoke gate only refuses collapse (worker-owned commit grossly
#: slower than the serial walk it replaces); the "commit actually left
#: the client's critical path" proof is the residual share below.
REMOTE_COMMIT_OFFLOAD_FLOOR = 0.9

#: CI ceiling on the residual client-serial commit wall in worker-owned
#: mode, as a share of the client-serial run's commit wall.  Fused
#: rounds never touch the client's serial commit walk, so the residual
#: is only what non-fused (single-partition / declined) rounds still
#: pay — measured ~0.0 on the symmetric churn.  A climb means rounds
#: quietly stopped fusing.
REMOTE_COMMIT_RESIDUAL_SHARE = 0.2


def run_remote(
    scale: float = 1.0, shards: int = 4, transport: str = "loopback",
    wire_codec: str = "json",
) -> List[Dict[str, object]]:
    """Remote-plan rows on the queue-128 fleet churn: plan-over-wire vs
    the serial loop, trace identity, and the wire bill.  Serialization
    overhead is charged to its own rows, never into the modeled
    critical-path decision latency — the two costs answer different
    questions (what a worker fleet's decisions cost vs what shipping
    them costs).  The wire bill is reported per component — client
    encode, client decode, worker codec (the worker's own parse+encode
    bill), transport wall, bytes/round — so the two sides' codec costs
    are separate rows and never conflated (the old single row summed
    client codec AND the worker-reported codec bill, which is how
    1.1ms/event of client codec read as 2.07ms/event of 'wire')."""
    queue = 128
    waves = max(6, int(16 * scale))
    serial = _run_shard_churn(None, queue=queue, waves=waves)
    remote = _run_shard_churn(
        shards, queue=queue, waves=waves, plan_mode="remote",
        transport=transport, wire_codec=wire_codec,
    )
    worker = _run_shard_churn(
        shards, queue=queue, waves=waves, plan_mode="remote",
        transport=transport, wire_codec=wire_codec, commit_mode="worker",
    )
    identical = serial["trace"] == remote["trace"]
    worker_identical = serial["trace"] == worker["trace"]
    wire = remote["wire"] or {
        "rounds": 0.0, "encode_s": 0.0, "decode_s": 0.0,
        "worker_codec_s": 0.0, "transport_s": 0.0, "bytes": 0.0,
        "fallbacks": 0.0,
    }
    events = max(1, remote["events"])
    encode_us = wire["encode_s"] / events * 1e6
    decode_us = wire["decode_s"] / events * 1e6
    worker_codec_us = wire.get("worker_codec_s", 0.0) / events * 1e6
    transport_us = wire["transport_s"] / events * 1e6
    wire_us_per_event = encode_us + decode_us + worker_codec_us
    pipelined_us = wire.get("overlap_s", 0.0) / events * 1e6
    bytes_per_round = wire["bytes"] / max(1.0, wire["rounds"])
    memo_hits = wire.get("memo_hits", 0.0)
    memo_misses = wire.get("memo_misses", 0.0)
    memo_rate = memo_hits / max(1.0, memo_hits + memo_misses)
    resident_patches = wire.get("worker_resident_patches", 0.0)
    resident_rebuilds = wire.get("worker_resident_rebuilds", 0.0)
    resident_hits = wire.get("worker_resident_hits", 0.0)
    rows: List[Dict[str, object]] = [
        {
            "name": f"remote_churn_queue{queue}_serial",
            "us_per_call": serial["sched_us_per_event"],
            "mean_act": serial["mean_act"],
            "derived": f"queue={queue};events={serial['events']};rounds={serial['rounds']}",
        },
        {
            "name": f"remote_churn_queue{queue}_shards{shards}_{transport}",
            "us_per_call": remote["sched_us_per_event"],
            "mean_act": remote["mean_act"],
            "derived": (
                f"queue={queue};events={remote['events']};"
                f"sharded_rounds={remote['sharded_rounds']};"
                f"wire_rounds={wire['rounds']:.0f};critical-path model "
                f"(wire overhead charged separately)"
            ),
        },
        {
            "name": f"remote_churn_queue{queue}_wire_overhead",
            "us_per_call": wire_us_per_event,
            "mean_act": "",
            "derived": (
                f"us/event of client encode+decode plus worker codec,"
                f" serialized-sum model (no overlap credited);"
                f"codec={wire_codec};"
                f"bytes_per_round={bytes_per_round:.0f};"
                f"fallbacks={wire.get('fallbacks', 0.0):.0f}"
            ),
        },
        {
            "name": f"remote_churn_queue{queue}_wire_overhead_pipelined",
            "us_per_call": pipelined_us,
            "mean_act": "",
            "derived": (
                "us/event on the overlap-aware critical path: head"
                " request encode + slowest worker codec + response"
                " decode (the rest hides behind worker compute under"
                " pipelined dispatch);"
                f"frames={wire.get('frames', 0.0):.0f}"
            ),
        },
        {
            "name": f"remote_churn_queue{queue}_wire_memo_hit_rate",
            "us_per_call": memo_rate,
            "mean_act": "",
            "derived": (
                f"client encode-memo consultations;hits={memo_hits:.0f};"
                f"misses={memo_misses:.0f}"
            ),
        },
        {
            "name": f"remote_churn_queue{queue}_worker_resident_state",
            "us_per_call": wire.get("worker_reset_s", 0.0) / events * 1e6,
            "mean_act": "",
            "derived": (
                "us/event of in-place state refresh + copy-on-plan;"
                f"hits={resident_hits:.0f};patches={resident_patches:.0f};"
                f"rebuilds={resident_rebuilds:.0f};"
                f"rebuild_s={wire.get('worker_rebuild_s', 0.0):.4f};"
                f"intern_patches={wire.get('worker_intern_patches', 0.0):.0f}"
            ),
        },
        {
            "name": f"remote_churn_queue{queue}_wire_client_encode",
            "us_per_call": encode_us,
            "mean_act": "",
            "derived": "us/event; client-side request serialization",
        },
        {
            "name": f"remote_churn_queue{queue}_wire_client_decode",
            "us_per_call": decode_us,
            "mean_act": "",
            "derived": "us/event; client-side response parse + plan re-bind",
        },
        {
            "name": f"remote_churn_queue{queue}_wire_worker_codec",
            "us_per_call": worker_codec_us,
            "mean_act": "",
            "derived": "us/event; worker-reported parse+encode bill",
        },
        {
            "name": f"remote_churn_queue{queue}_wire_transport",
            "us_per_call": transport_us,
            "mean_act": "",
            "derived": (
                f"us/event; dispatch->gather wall (worker compute+IPC,"
                f" overlapped);transport_wall_s={wire['transport_s']:.4f}"
            ),
        },
        {
            "name": f"remote_churn_queue{queue}_traces_identical",
            "us_per_call": 1.0 if identical else 0.0,
            "mean_act": "",
            "derived": "1=remote-plan launch traces bit-identical to serial",
        },
    ]

    # -- commit-phase split: client-serial vs worker-owned two-phase --
    wwire = worker["wire"] or {}
    wevents = max(1, worker["events"])
    serial_commit_us = remote["commit_wall_s"] / events * 1e6
    worker_commit_us = wwire.get("commit_critical_s", 0.0) / wevents * 1e6
    residual_us = worker["commit_wall_s"] / wevents * 1e6
    apply_us = wwire.get("commit_apply_s", 0.0) / wevents * 1e6
    offload = serial_commit_us / max(1e-9, worker_commit_us + residual_us)
    rows += [
        {
            "name": f"remote_churn_queue{queue}_commit_worker",
            "us_per_call": worker["sched_us_per_event"],
            "mean_act": worker["mean_act"],
            "derived": (
                f"critical-path model, commit_mode=worker;"
                f"prepares={wwire.get('prepares', 0.0):.0f};"
                f"acks={wwire.get('commit_acks', 0.0):.0f};"
                f"aborts={wwire.get('commit_aborts', 0.0):.0f};"
                f"inline={wwire.get('commit_inline_rounds', 0.0):.0f};"
                f"resends={wwire.get('fallbacks', 0.0):.0f};"
                f"diverged={wwire.get('commit_diverged', 0.0):.0f}"
            ),
        },
        {
            "name": f"remote_churn_queue{queue}_commit_traces_identical",
            "us_per_call": 1.0 if worker_identical else 0.0,
            "mean_act": "",
            "derived": "1=worker-owned commit launch traces bit-identical to serial",
        },
        {
            "name": f"remote_churn_queue{queue}_commit_serial_wall",
            "us_per_call": serial_commit_us,
            "mean_act": "",
            "derived": (
                "us/event the client pays walking every partition's commit"
                " serially (client-serial commit mode, serialized model)"
            ),
        },
        {
            "name": f"remote_churn_queue{queue}_commit_worker_critical",
            "us_per_call": worker_commit_us,
            "mean_act": "",
            "derived": (
                "us/event of the worker-parallel commit critical path (max"
                " per-worker commit wall, pipelined model);"
                f"residual_serial_us={residual_us:.2f};"
                f"client_apply_us={apply_us:.2f}"
            ),
        },
        {
            "name": f"remote_churn_queue{queue}_commit_offload_speedup",
            "us_per_call": offload,
            "mean_act": "",
            "derived": (
                "x_serial_commit_wall_over_worker_critical_plus_residual;"
                f"floor={REMOTE_COMMIT_OFFLOAD_FLOOR}"
            ),
        },
    ]
    return rows


def check_remote(rows: List[Dict[str, object]]) -> None:
    """CI remote-smoke gates on the queue-128 fleet churn: (a) remote-
    plan launch traces bit-identical to the serial round loop; (b) the
    wire was actually exercised (a refactor that silently stops
    sharding rounds must not pass vacuously); (c) the serialized wire
    overhead stays within REMOTE_WIRE_LATENCY_RATIO of the modeled
    critical-path decision latency, and the pipelined (overlap-aware)
    overhead within the tighter REMOTE_WIRE_PIPELINED_RATIO; (d)
    bytes/round stays under the committed
    REMOTE_BYTES_PER_ROUND_BASELINE; (e) the client encode-memo hit
    rate stays above REMOTE_MEMO_HIT_RATE_FLOOR; (f) steady-state runs
    take zero full-content fallbacks (recovery is for faults, not for a
    protocol that forgets its own state); (g) the commit-mode matrix:
    worker-owned commit's launch trace is bit-identical to serial, its
    steady-state run takes zero fallbacks and zero aborts, the two-phase
    rail was really exercised (prepares > 0), the commit-offload ratio
    has not collapsed (REMOTE_COMMIT_OFFLOAD_FLOOR), and the residual
    client-serial commit wall stays a sliver of the serial walk
    (REMOTE_COMMIT_RESIDUAL_SHARE — commit left the client's critical
    path)."""
    by_name = {str(r["name"]): r for r in rows}
    identical_row = by_name["remote_churn_queue128_traces_identical"]
    identical = float(identical_row["us_per_call"])  # type: ignore[arg-type]
    overhead_row = by_name["remote_churn_queue128_wire_overhead"]
    wire_us = float(overhead_row["us_per_call"])  # type: ignore[arg-type]
    pipelined_row = by_name["remote_churn_queue128_wire_overhead_pipelined"]
    pipelined_us = float(pipelined_row["us_per_call"])  # type: ignore[arg-type]
    memo_row = by_name["remote_churn_queue128_wire_memo_hit_rate"]
    memo_rate = float(memo_row["us_per_call"])  # type: ignore[arg-type]
    critical_us = 0.0
    wire_rounds = 0.0
    bytes_per_round = 0.0
    fallbacks = 0.0
    for r in rows:
        derived = str(r.get("derived", ""))
        if "wire_rounds=" in derived:
            wire_rounds = float(derived.split("wire_rounds=")[1].split(";")[0])
            critical_us = float(r["us_per_call"])  # type: ignore[arg-type]
        if "bytes_per_round=" in derived:
            bytes_per_round = float(
                derived.split("bytes_per_round=")[1].split(";")[0]
            )
        if "fallbacks=" in derived:
            fallbacks = float(derived.split("fallbacks=")[1].split(";")[0])
    print(
        f"# remote check: traces_identical={identical:.0f} "
        f"wire_rounds={wire_rounds:.0f} "
        f"wire_overhead={wire_us:.1f}us/event "
        f"pipelined={pipelined_us:.1f}us/event "
        f"critical={critical_us:.1f}us/event "
        f"bytes_per_round={bytes_per_round:.0f} "
        f"memo_hit_rate={memo_rate:.3f} fallbacks={fallbacks:.0f}"
    )
    if identical != 1.0:
        raise SystemExit("remote-plan fleet-churn launch trace diverged from serial")
    if wire_rounds <= 0:
        raise SystemExit("remote suite never exercised the wire (no sharded rounds)")
    if wire_us > REMOTE_WIRE_LATENCY_RATIO * critical_us:
        raise SystemExit(
            f"serialized wire overhead {wire_us:.1f}us/event exceeds "
            f"{REMOTE_WIRE_LATENCY_RATIO:.0f}x the critical-path decision "
            f"latency {critical_us:.1f}us/event"
        )
    if pipelined_us > REMOTE_WIRE_PIPELINED_RATIO * critical_us:
        raise SystemExit(
            f"pipelined wire overhead {pipelined_us:.1f}us/event exceeds "
            f"{REMOTE_WIRE_PIPELINED_RATIO:.0f}x the critical-path decision "
            f"latency {critical_us:.1f}us/event"
        )
    if bytes_per_round > REMOTE_BYTES_PER_ROUND_BASELINE:
        raise SystemExit(
            f"bytes/round {bytes_per_round:.0f} regressed above the committed "
            f"baseline {REMOTE_BYTES_PER_ROUND_BASELINE}"
        )
    if memo_rate < REMOTE_MEMO_HIT_RATE_FLOOR:
        raise SystemExit(
            f"encode-memo hit rate {memo_rate:.3f} fell below the floor "
            f"{REMOTE_MEMO_HIT_RATE_FLOOR}"
        )
    if fallbacks > 0:
        raise SystemExit(
            f"{fallbacks:.0f} full-content fallback(s) in a steady-state run "
            "(cache budgets or mirror determinism regressed)"
        )

    # -- commit-mode matrix gates (worker-owned vs client-serial) --
    commit_flag = float(
        by_name["remote_churn_queue128_commit_traces_identical"]["us_per_call"]  # type: ignore[arg-type]
    )
    wk_derived = str(by_name["remote_churn_queue128_commit_worker"]["derived"])

    def _field(key: str) -> float:
        return float(wk_derived.split(f"{key}=")[1].split(";")[0])

    prepares = _field("prepares")
    resends = _field("resends")
    aborts = _field("aborts")
    diverged = _field("diverged")
    offload = float(
        by_name["remote_churn_queue128_commit_offload_speedup"]["us_per_call"]  # type: ignore[arg-type]
    )
    serial_wall_us = float(
        by_name["remote_churn_queue128_commit_serial_wall"]["us_per_call"]  # type: ignore[arg-type]
    )
    crit_derived = str(
        by_name["remote_churn_queue128_commit_worker_critical"]["derived"]
    )
    residual_us = float(crit_derived.split("residual_serial_us=")[1].split(";")[0])
    print(
        f"# commit check: traces_identical={commit_flag:.0f} "
        f"prepares={prepares:.0f} resends={resends:.0f} aborts={aborts:.0f} "
        f"offload={offload:.2f}x residual={residual_us:.2f}us"
    )
    if commit_flag != 1.0:
        raise SystemExit("worker-owned commit launch trace diverged from serial")
    if prepares <= 0:
        raise SystemExit(
            "worker-owned commit never sent a prepare (two-phase rail idle "
            "— every round fell back to client-serial commit)"
        )
    if resends > 0:
        raise SystemExit(
            f"{resends:.0f} full-content fallback(s) in the steady-state "
            "worker-owned commit run"
        )
    if aborts > 0 or diverged > 0:
        raise SystemExit(
            f"steady-state worker-owned commit took {aborts:.0f} abort(s) / "
            f"{diverged:.0f} divergence(s) — conflict-free churn must "
            "prepare-and-confirm cleanly"
        )
    if offload < REMOTE_COMMIT_OFFLOAD_FLOOR:
        raise SystemExit(
            f"commit-offload ratio {offload:.2f}x collapsed below "
            f"{REMOTE_COMMIT_OFFLOAD_FLOOR}x — worker-owned commit costs "
            "grossly more than the serial walk it replaces"
        )
    if residual_us > REMOTE_COMMIT_RESIDUAL_SHARE * serial_wall_us:
        raise SystemExit(
            f"residual client-serial commit wall {residual_us:.2f}us/event "
            f"exceeds {REMOTE_COMMIT_RESIDUAL_SHARE:.0%} of the serial "
            f"commit wall {serial_wall_us:.2f}us/event — rounds stopped "
            "fusing their commits"
        )


def check_shards(rows: List[Dict[str, object]], shards: int = 4) -> None:
    """CI shard-smoke gates on the queue-128 fleet churn: (a) sharded
    launch traces bit-identical to the serial round loop (the workload
    is conflict-free by construction); (b) critical-path decision
    latency >= 1.5x better than serial."""
    by_name = {r["name"]: float(r["us_per_call"]) for r in rows}  # type: ignore[arg-type]
    speedup = by_name["shard_churn_queue128_speedup"]
    identical = by_name["shard_churn_queue128_traces_identical"]
    print(f"# shard check: speedup={speedup:.2f}x traces_identical={identical:.0f}")
    if identical != 1.0:
        raise SystemExit("sharded fleet-churn launch trace diverged from serial")
    if speedup < 1.5:
        raise SystemExit(
            f"sharded decision latency only {speedup:.2f}x better than serial (< 1.5x)"
        )


# ---------------------------------------------------------------------------
# Chaos suite: the fleet churn over real TCP sockets under kill/restart
# storms and packet-level fault schedules (--suite chaos is the CI
# chaos-smoke gate)
# ---------------------------------------------------------------------------

#: Virtual times of the kill-storm's server-side connection drops.  All
#: after the warm-up window (the wire counters reset at ~4s) so every
#: loss lands in the measured figures; the horizon filter in run_chaos
#: keeps low --scale runs meaningful.
# The chaos fault schedules live in their ScenarioSpecs
# (repro.core.scenarios.chaos_*_spec): kill times all land after the
# warm-up window; the packet-fault indices start at 3 so no fault burns
# inside the window where the telemetry is reset.  The amnesia plan is
# separate: silent worker replacement exercises the stale-ref storm
# (typed protocol errors + full re-send), not the transport-loss rail,
# and the gate checks the two stay distinguishable.
CHAOS_KILL_TIMES = scenarios.chaos_storm_spec().kill_times()
CHAOS_FAULT_PLAN = scenarios.chaos_packet_spec().packet_plan()
CHAOS_AMNESIA_PLAN = scenarios.chaos_amnesia_spec().packet_plan()


def run_chaos(scale: float = 1.0, shards: int = 4) -> List[Dict[str, object]]:
    """Chaos rows: the queue-128 fleet churn planned over real TCP
    socket workers while the harness kills connections (worker death +
    reconnect-to-a-blank-worker) and injects packet-level faults
    (dropped requests/responses, mid-frame truncation, silent worker
    amnesia).  Every scenario's launch trace must stay bit-identical to
    the serial round loop — fault tolerance is allowed to cost wire
    time, never correctness."""
    from repro.core.transport import (
        SocketTransport,
        WorkerServer,
        chaos_fleet,
        socket_fleet,
    )

    queue = 128
    waves = max(6, int(16 * scale))
    horizon = waves * 4.0
    serial = _run_shard_churn(None, queue=queue, waves=waves)

    # (a) kill/restart storm: server-side connection drops at fixed
    # virtual times; the endpoint stays up so clients reconnect
    with WorkerServer() as srv:
        kill_times = [t for t in CHAOS_KILL_TIMES if t < horizon]

        def schedule_kills(orch: Orchestrator) -> None:
            for t in kill_times:
                orch.loop.call_after(t, srv.kill_connections)

        storm = _run_shard_churn(
            shards, queue=queue, waves=waves, plan_mode="remote",
            transport=socket_fleet([srv.addr]), pre_run=schedule_kills,
        )

    # (b) mixed packet faults: deterministic per-shard schedules
    with WorkerServer() as srv:
        fault_fac = chaos_fleet(
            lambda i: SocketTransport(srv.addr), CHAOS_FAULT_PLAN
        )
        faulted = _run_shard_churn(
            shards, queue=queue, waves=waves, plan_mode="remote",
            transport=fault_fac,
        )
        faults_fired = sum(p.faults_fired for p in fault_fac.plans.values())

    # (c) stale-ref storm: pure amnesia — silent worker replacement must
    # surface as typed protocol errors absorbed by full re-sends, with
    # ZERO transport losses (the rails must not blur together)
    with WorkerServer() as srv:
        amn_fac = chaos_fleet(
            lambda i: SocketTransport(srv.addr), CHAOS_AMNESIA_PLAN
        )
        amnesia = _run_shard_churn(
            shards, queue=queue, waves=waves, plan_mode="remote",
            transport=amn_fac,
        )
        amnesia_fired = sum(p.faults_fired for p in amn_fac.plans.values())

    def _flag(run) -> float:
        return 1.0 if run["trace"] == serial["trace"] else 0.0

    storm_wire = storm["wire"]
    fault_wire = faulted["wire"]
    amn_wire = amnesia["wire"]
    rows: List[Dict[str, object]] = [
        {
            "name": "chaos_kill_storm_traces_identical",
            "us_per_call": _flag(storm),
            "mean_act": storm["mean_act"],
            "derived": (
                f"kills={len(kill_times)};events={storm['events']};"
                f"serial_events={serial['events']};"
                "1=launch trace bit-identical to serial under the storm"
            ),
        },
        {
            "name": "chaos_kill_storm_worker_losses",
            "us_per_call": storm_wire.get("worker_losses", 0.0),
            "mean_act": "",
            "derived": (
                f"reconnects={storm_wire.get('reconnects', 0.0):.0f};"
                f"inline_parts={storm_wire.get('inline_parts', 0.0):.0f};"
                "losses must be > 0 or the storm was vacuous"
            ),
        },
        {
            "name": "chaos_packet_faults_traces_identical",
            "us_per_call": _flag(faulted),
            "mean_act": faulted["mean_act"],
            "derived": (
                f"faults_fired={faults_fired};"
                f"losses={fault_wire.get('worker_losses', 0.0):.0f};"
                f"resends={fault_wire.get('fallbacks', 0.0):.0f};"
                "drops+truncation+amnesia on scheduled request indices"
            ),
        },
        {
            "name": "chaos_amnesia_traces_identical",
            "us_per_call": _flag(amnesia),
            "mean_act": amnesia["mean_act"],
            "derived": (
                f"faults_fired={amnesia_fired};"
                f"resends={amn_wire.get('fallbacks', 0.0):.0f};"
                f"losses={amn_wire.get('worker_losses', 0.0):.0f};"
                "silent worker swaps -> typed stale-ref + full re-send"
            ),
        },
        {
            "name": "chaos_amnesia_full_resends",
            "us_per_call": amn_wire.get("fallbacks", 0.0),
            "mean_act": "",
            "derived": "full-content recovery rounds absorbed by the client",
        },
    ]
    return rows


def check_chaos(rows: List[Dict[str, object]]) -> None:
    """CI chaos-smoke gates: (a) every chaos scenario's launch trace is
    bit-identical to serial (which also proves zero lost / doubled
    launches — the trace is the complete launch ledger); (b) the storm
    really stormed (worker losses > 0); (c) the amnesia run really
    exercised the stale-ref rail (full re-sends > 0) WITHOUT transport
    losses (the two recovery rails stay distinguishable)."""
    by_name = {str(r["name"]): r for r in rows}
    for flag_name in (
        "chaos_kill_storm_traces_identical",
        "chaos_packet_faults_traces_identical",
        "chaos_amnesia_traces_identical",
    ):
        row = by_name[flag_name]
        if float(row["us_per_call"]) != 1.0:  # type: ignore[arg-type]
            raise SystemExit(f"{flag_name}: launch trace diverged from serial")
    losses = float(by_name["chaos_kill_storm_worker_losses"]["us_per_call"])  # type: ignore[arg-type]
    resends = float(by_name["chaos_amnesia_full_resends"]["us_per_call"])  # type: ignore[arg-type]
    amn_derived = str(by_name["chaos_amnesia_traces_identical"]["derived"])
    amn_losses = float(amn_derived.split("losses=")[1].split(";")[0])
    print(
        f"# chaos check: all traces identical; kill-storm losses={losses:.0f} "
        f"amnesia resends={resends:.0f} amnesia losses={amn_losses:.0f}"
    )
    if losses <= 0:
        raise SystemExit("kill storm recorded no worker losses (vacuous storm)")
    if resends <= 0:
        raise SystemExit("amnesia storm drove no full re-sends (stale-ref rail idle)")
    if amn_losses > 0:
        raise SystemExit(
            "amnesia storm surfaced as transport losses — the stale-ref rail "
            "and the loss rail blurred together"
        )


# ---------------------------------------------------------------------------
# Nightly-scale chaos (`--suite chaos --scale large`): 8 real worker OS
# processes, O(100k) actions, periodic worker-process kill/respawn — in
# BOTH commit modes.  Non-blocking (scheduled/manual workflow), so the
# 2-4-worker CI-scale gates above stay fast.
# ---------------------------------------------------------------------------

#: 784 waves x 128 actions/wave ~= 100k actions (the ROADMAP scale
#: target for the storm harness).
CHAOS_LARGE_WAVES = 784
CHAOS_LARGE_WORKERS = 8

#: One worker-process kill/respawn every this many virtual seconds
#: (round-robin over the fleet) — ~85 full process deaths per run.
CHAOS_LARGE_KILL_PERIOD_S = 37.0


def _spawn_worker_proc(port: int = 0):
    """One real shard-worker OS process (``tools/shard_worker.py``);
    returns ``(proc, port)`` once the endpoint is listening (the
    entrypoint prints ``PORT <n>`` when bound)."""
    import os
    import subprocess
    import sys
    from pathlib import Path

    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root / "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    proc = subprocess.Popen(
        [sys.executable, str(root / "tools" / "shard_worker.py"),
         "--port", str(port)],
        stdout=subprocess.PIPE, env=env, text=True,
    )
    line = (proc.stdout.readline() or "").strip()
    if not line.startswith("PORT "):
        proc.kill()
        raise RuntimeError(f"shard worker failed to start: {line!r}")
    return proc, int(line.split()[1])


def run_chaos_large(
    waves: int = CHAOS_LARGE_WAVES, workers: int = CHAOS_LARGE_WORKERS,
) -> List[Dict[str, object]]:
    """The storm at fleet scale: the queue-128 churn over ``workers``
    real worker OS processes, with a worker process hard-killed and
    respawned on its port every ``CHAOS_LARGE_KILL_PERIOD_S`` virtual
    seconds (round-robin), run once under client-serial commit and once
    under worker-owned two-phase commit.  A killed process takes its
    entire resident state — plan caches, intern tables, authoritative
    manager replicas and their leases — so every respawn exercises the
    loss rail AND the blank-worker re-grant rail at full depth.  Both
    storms' launch traces must stay bit-identical to the serial loop."""
    from repro.core.transport import socket_fleet

    queue = 128
    horizon = waves * 4.0
    serial = _run_shard_churn(None, queue=queue, waves=waves)
    expected = serial["events"]

    def storm(commit_mode: str):
        procs: List[object] = []
        ports: List[int] = []
        try:
            for _ in range(workers):
                p, port = _spawn_worker_proc()
                procs.append(p)
                ports.append(port)
            kill_times = []
            t = 5.0
            while t < horizon:
                kill_times.append(t)
                t += CHAOS_LARGE_KILL_PERIOD_S
            counter = [0]

            def _kill_next() -> None:
                idx = counter[0] % workers
                counter[0] += 1
                procs[idx].kill()
                procs[idx].wait()
                try:
                    procs[idx].stdout.close()
                except OSError:
                    pass
                procs[idx], _ = _spawn_worker_proc(ports[idx])

            def pre(orch: Orchestrator) -> None:
                for kt in kill_times:
                    orch.loop.call_after(kt, _kill_next)

            run = _run_shard_churn(
                workers, queue=queue, waves=waves, plan_mode="remote",
                transport=socket_fleet([("127.0.0.1", pt) for pt in ports]),
                commit_mode=commit_mode, pre_run=pre,
            )
            return run, len(kill_times)
        finally:
            for p in procs:
                try:
                    p.kill()
                    p.wait()
                    p.stdout.close()
                except OSError:
                    pass

    client, kills = storm("client")
    owned, _ = storm("worker")
    cwire = client["wire"] or {}
    owire = owned["wire"] or {}
    rows: List[Dict[str, object]] = [
        {
            "name": "chaos_large_client_traces_identical",
            "us_per_call": 1.0 if client["trace"] == serial["trace"] else 0.0,
            "mean_act": client["mean_act"],
            "derived": (
                f"workers={workers};kills={kills};events={client['events']};"
                f"expected={expected};"
                "client-serial commit over real worker processes"
            ),
        },
        {
            "name": "chaos_large_worker_traces_identical",
            "us_per_call": 1.0 if owned["trace"] == serial["trace"] else 0.0,
            "mean_act": owned["mean_act"],
            "derived": (
                f"workers={workers};kills={kills};events={owned['events']};"
                f"expected={expected};"
                f"prepares={owire.get('prepares', 0.0):.0f};"
                f"regrants={owire.get('lease_regrants', 0.0):.0f};"
                f"adoptions={owire.get('lease_adoptions', 0.0):.0f};"
                "worker-owned two-phase commit over real worker processes"
            ),
        },
        {
            "name": "chaos_large_worker_losses",
            "us_per_call": (
                cwire.get("worker_losses", 0.0)
                + owire.get("worker_losses", 0.0)
            ),
            "mean_act": "",
            "derived": (
                f"client_losses={cwire.get('worker_losses', 0.0):.0f};"
                f"owned_losses={owire.get('worker_losses', 0.0):.0f};"
                f"reconnects={cwire.get('reconnects', 0.0) + owire.get('reconnects', 0.0):.0f};"
                "process deaths absorbed across both storms"
            ),
        },
        {
            "name": "chaos_large_sched_us_worker_commit",
            "us_per_call": owned["sched_us_per_event"],
            "mean_act": "",
            "derived": (
                f"critical-path model under the storm;"
                f"serial={serial['sched_us_per_event']:.1f}us/event;"
                f"client_commit={client['sched_us_per_event']:.1f}us/event"
            ),
        },
    ]
    return rows


def check_chaos_large(rows: List[Dict[str, object]]) -> None:
    """Nightly gates: both storms' traces bit-identical to serial at
    O(100k)-action scale; the storms really killed worker processes;
    the two-phase rail carried real prepare traffic; the run covered
    the full workload (no silently truncated horizon)."""
    by_name = {str(r["name"]): r for r in rows}

    def _field(row: str, key: str) -> float:
        return float(str(by_name[row]["derived"]).split(f"{key}=")[1].split(";")[0])

    for flag_name in (
        "chaos_large_client_traces_identical",
        "chaos_large_worker_traces_identical",
    ):
        if float(by_name[flag_name]["us_per_call"]) != 1.0:  # type: ignore[arg-type]
            raise SystemExit(f"{flag_name}: launch trace diverged from serial")
        events = _field(flag_name, "events")
        expected = _field(flag_name, "expected")
        if events < expected:
            raise SystemExit(
                f"{flag_name}: run covered {events:.0f}/{expected:.0f} events"
            )
    losses = float(by_name["chaos_large_worker_losses"]["us_per_call"])  # type: ignore[arg-type]
    prepares = _field("chaos_large_worker_traces_identical", "prepares")
    kills = _field("chaos_large_worker_traces_identical", "kills")
    print(
        f"# chaos-large check: traces identical; kills={kills:.0f}/storm "
        f"losses={losses:.0f} prepares={prepares:.0f}"
    )
    if losses <= 0:
        raise SystemExit("large storm recorded no worker losses (vacuous)")
    if prepares <= 0:
        raise SystemExit("large storm never exercised the two-phase rail")


# ---------------------------------------------------------------------------
# Telemetry-driven rebalance on an asymmetric fleet (rows ride in the
# remote suite's BENCH_remote.json; the gate is part of --suite remote)
# ---------------------------------------------------------------------------

#: The rebalanced run's mean ACT must beat the no-rebalance run by at
#: least this factor on the skewed fleet (measured ~3x; the floor
#: absorbs workload-shape drift, not policy regressions).
REBALANCE_ACT_WIN_FLOOR = 1.2


def _run_rebalance_fleet(
    rebalance: bool, pools: int = 4, cores: int = 2, n: int = 96,
    duration: float = 2.0, period_s: float = 1.0,
) -> Dict[str, float]:
    """A replica fleet with every submission keyed to pool0 — the
    asymmetric worst case the cadence exists for.  Virtual-time ACT and
    makespan, plus the migration bill, with and without the policy."""
    from repro.core.fairqueue import FairSharePolicy
    from repro.core.simulator import EventLoop

    loop = EventLoop()
    managers = {f"pool{k}": ResourceManager(f"pool{k}", cores) for k in range(pools)}
    fair = FairSharePolicy(weights={"a": 2.0, "b": 1.0, "c": 1.0, "d": 1.0})
    orch = Orchestrator(managers, loop=loop, fair_share=fair)
    if rebalance:
        orch.enable_rebalance(sorted(managers), period_s=period_s)
    for i in range(n):
        orch.submit(Action(
            name=f"w{i}", cost={"pool0": fixed("pool0", 1)},
            base_duration=duration, task_id="abcd"[i % 4],
            trajectory_id=f"t{i}",
        ))
    orch.run()
    recs = orch.telemetry.records
    out = {
        "act": sum(r.finish - r.submit for r in recs) / max(1, len(recs)),
        "makespan": max((r.finish for r in recs), default=0.0),
        "ticks": float(orch.telemetry.rebalance_ticks),
        "moves": float(orch.telemetry.rebalance_moves),
        "migrated": float(orch.telemetry.migrated_actions),
        "migration_wall_s": orch.telemetry.migration_wall_s,
    }
    orch.close()
    return out


def run_rebalance(scale: float = 1.0) -> List[Dict[str, object]]:
    """Rebalance rows: mean ACT on the skewed 4-pool fleet with the
    cadence off vs on, the win factor, and the migration bill (moves,
    migrated actions, detach/merge wall) so the cost side of the trade
    is committed next to the win."""
    n = max(48, int(96 * scale))
    off = _run_rebalance_fleet(False, n=n)
    on = _run_rebalance_fleet(True, n=n)
    win = off["act"] / max(1e-9, on["act"])
    return [
        {
            "name": "rebalance_fleet4_act_off",
            "us_per_call": off["act"],
            "mean_act": off["act"],
            "derived": (
                f"virtual-s mean ACT, all load keyed to pool0, no policy;"
                f"makespan={off['makespan']:.2f}"
            ),
        },
        {
            "name": "rebalance_fleet4_act_on",
            "us_per_call": on["act"],
            "mean_act": on["act"],
            "derived": (
                f"virtual-s mean ACT under the telemetry cadence;"
                f"makespan={on['makespan']:.2f};ticks={on['ticks']:.0f};"
                f"moves={on['moves']:.0f};migrated={on['migrated']:.0f};"
                f"migration_wall_s={on['migration_wall_s']:.4f}"
            ),
        },
        {
            "name": "rebalance_fleet4_act_speedup",
            "us_per_call": win,
            "mean_act": "",
            "derived": (
                f"x_no_rebalance_over_rebalanced;floor={REBALANCE_ACT_WIN_FLOOR}"
            ),
        },
    ]


def check_rebalance(rows: List[Dict[str, object]]) -> None:
    """Remote-suite gate: the cadence must buy a real ACT win on the
    skewed fleet (>= REBALANCE_ACT_WIN_FLOOR) through actual migrations
    — zero moves with a passing ratio would mean the scenario stopped
    exercising the policy."""
    by_name = {str(r["name"]): r for r in rows}
    win = float(by_name["rebalance_fleet4_act_speedup"]["us_per_call"])  # type: ignore[arg-type]
    derived = str(by_name["rebalance_fleet4_act_on"]["derived"])
    moves = float(derived.split("moves=")[1].split(";")[0])
    print(f"# rebalance check: act_win={win:.2f}x moves={moves:.0f}")
    if moves <= 0:
        raise SystemExit("rebalance scenario made no migrations (vacuous)")
    if win < REBALANCE_ACT_WIN_FLOOR:
        raise SystemExit(
            f"rebalance ACT win {win:.2f}x fell below the floor "
            f"{REBALANCE_ACT_WIN_FLOOR}x"
        )


# ---------------------------------------------------------------------------
# Multi-tenant fairness scenario (2 heavy + 2 light tasks, wave arrivals)
# ---------------------------------------------------------------------------

#: Configured fair-share weights; targets are w_i / sum(w).
FAIRNESS_WEIGHTS = scenarios.FAIRNESS_WEIGHTS
FAIRNESS_HORIZON_S = 90.0  # saturated measurement window (virtual seconds)


# The tenant mix (heavy tasks bursting long scalable reward jobs +
# TP-scalable GPU scoring, light tasks streaming short rigid tool calls
# — the exact shape where cross-task FCFS starves the light tenants
# behind a heavy wave) is declared in ``scenarios.fairness_spec``; the
# frozen pre-factory generator is pinned in tests/test_scenarios.py
# with a trace-equivalence test.


def _run_fairness(fair: bool, horizon: float, tasks=None):
    """Saturated multi-tenant churn: every task keeps a queued backlog
    through ``horizon`` via wave refills (each task's completions refill
    in same-timestamp bursts — the paper's rollout-batch arrival shape)."""
    from repro.core.simulator import EventLoop

    spec = scenarios.fairness_spec(horizon_s=horizon, tasks=tasks)
    loop = EventLoop()
    managers = scenarios.build_managers(spec, loop)
    fs = scenarios.build_fair_share(spec) if fair else None
    orch = Orchestrator(managers, loop=loop, policy=ElasticScheduler(), fair_share=fs)
    scenarios.install_scenario(spec, orch)
    orch.run(until=horizon * 2)
    return orch


def _fairness_trace(orch: Orchestrator):
    return sorted(
        (r.name, r.task_id, r.trajectory_id, round(r.submit, 9), round(r.start, 9),
         round(r.finish, 9), tuple(sorted(r.units.items())), r.failed)
        for r in orch.telemetry.records
    )


def run_fairness(scale: float = 1.0) -> List[Dict[str, object]]:
    """Multi-tenant fairness rows: weighted-share tracking error, light-
    tenant interference vs the FCFS ablation, and the single-task
    launch-trace equivalence bit.  The DES wall cost is negligible, so
    ``scale`` only ever lengthens the saturated window (never shortens
    it below the share-quantum granularity the 10% gate needs)."""
    horizon = FAIRNESS_HORIZON_S * max(1.0, scale)
    fair = _run_fairness(True, horizon)
    fcfs = _run_fairness(False, horizon)

    wsum = sum(FAIRNESS_WEIGHTS.values())
    share = fair.telemetry.task_share("cpu", until=horizon)
    rows: List[Dict[str, object]] = []
    max_err = 0.0
    for task, w in FAIRNESS_WEIGHTS.items():
        target = w / wsum
        got = share.get(task, 0.0)
        max_err = max(max_err, abs(got - target) / target)
        rows.append(
            {
                "name": f"fairness_share_cpu_{task}",
                "us_per_call": got,
                "mean_act": fair.telemetry.mean_act(task),
                "derived": f"target={target:.4f};weight={w}",
            }
        )
    rows.append(
        {
            "name": "fairness_share_maxerr",
            "us_per_call": max_err,
            "mean_act": "",
            "derived": "max relative |share-target|/target over tasks",
        }
    )

    light_fair = statistics.fmean(
        fair.telemetry.mean_act(t) for t in ("light0", "light1")
    )
    light_fcfs = statistics.fmean(
        fcfs.telemetry.mean_act(t) for t in ("light0", "light1")
    )
    rows.append(
        {"name": "fairness_light_act_wfq", "us_per_call": light_fair,
         "mean_act": light_fair, "derived": "light-tenant mean ACT, WFQ"}
    )
    rows.append(
        {"name": "fairness_light_act_fcfs", "us_per_call": light_fcfs,
         "mean_act": light_fcfs, "derived": "light-tenant mean ACT, FCFS ablation"}
    )
    rows.append(
        {
            "name": "fairness_interference_speedup",
            "us_per_call": light_fcfs / max(1e-9, light_fair),
            "mean_act": "",
            "derived": "x_fcfs_light_act_over_wfq",
        }
    )

    # single-task equivalence: the fairness layer must be a bit-identical
    # no-op when only one tenant exists (WFQ order == FCFS order).
    single_fair = _run_fairness(True, horizon / 3, tasks=["heavy0"])
    single_fcfs = _run_fairness(False, horizon / 3, tasks=["heavy0"])
    identical = _fairness_trace(single_fair) == _fairness_trace(single_fcfs)
    rows.append(
        {
            "name": "fairness_single_task_equivalent",
            "us_per_call": 1.0 if identical else 0.0,
            "mean_act": "",
            "derived": "1=launch traces identical to the FCFS path",
        }
    )
    return rows


def check_fairness(rows: List[Dict[str, object]]) -> None:
    """CI fairness-smoke gates: (a) weighted shares within 10% of target
    under saturation; (b) single-task launch traces identical to the
    FCFS path.  The DES is deterministic, so these are hard gates."""
    by_name = {r["name"]: float(r["us_per_call"]) for r in rows}  # type: ignore[arg-type]
    err = by_name["fairness_share_maxerr"]
    speedup = by_name["fairness_interference_speedup"]
    equiv = by_name["fairness_single_task_equivalent"]
    print(f"# fairness check: share_maxerr={err:.3f} "
          f"light_interference_speedup={speedup:.2f}x single_task_equiv={equiv:.0f}")
    if err > 0.10:
        raise SystemExit(f"weighted shares off target by {err:.1%} (> 10%)")
    if equiv != 1.0:
        raise SystemExit("single-task fairness run diverged from the FCFS path")


# ---------------------------------------------------------------------------
# Generated suite: spec-driven scenarios from the scenario factory
# (repro.core.scenarios), the differential replay rail, and the
# wave-forming gate result
# ---------------------------------------------------------------------------

#: Wave-forming gate floors (CI).  Measured on the generated
#: deep-congestion scenario (24-deep burst of near-linear scalable
#: actions, DoP up to 32, against 48 cores): the gated config
#: (``estimate_units="dp_avg"`` + ``eviction_search="exhaustive"`` +
#: ``dop_floor=8``) wins ~1.21x mean ACT, while on the mid-congestion
#: control (3-deep, absorbable near max DoP) it is exactly a no-op
#: (1.000x) — the separation EXPERIMENTS.md's hand-written scenarios
#: could not produce.  The DES is deterministic, so the floors sit just
#: under the measured values.
GEN_GATE_DEEP_FLOOR = 1.12
GEN_GATE_MID_BAND = (0.95, 1.08)
GEN_GATE_SEPARATION_FLOOR = 1.10

#: Live-mode compression: the live smoke runs the virtual scenario at a
#: quarter of its virtual timescale (real seconds of kernel work).
GEN_LIVE_TIME_SCALE = 0.25


def _run_spec_sim(spec, gated: bool = False, time_scale: float = 1.0,
                  compiled=None):
    """One DES run of a scenario spec on the generic spec-driven path
    (managers, fair share, and the optionally-gated scheduler all built
    from the spec)."""
    from repro.core.simulator import EventLoop

    compiled = compiled or scenarios.compile_scenario(
        spec, time_scale=time_scale)
    loop = EventLoop()
    orch = Orchestrator(
        scenarios.build_managers(spec, loop),
        loop=loop,
        policy=scenarios.build_policy(spec, gated=gated),
        fair_share=scenarios.build_fair_share(spec),
        incremental=True,
    )
    scenarios.install_scenario(compiled, orch)
    horizon = spec.arrival.horizon_s
    orch.run(until=horizon * 2 * time_scale if horizon else None)
    return orch


def _spec_rows(spec, prefix: str) -> List[Dict[str, object]]:
    """Rows for one externally-supplied spec file (``--spec``): the
    deterministic stream fingerprint, the run, and — when the spec
    carries scheduler-knob overrides — the gated-vs-baseline ACT win."""
    compiled = scenarios.compile_scenario(spec)
    base = _run_spec_sim(spec, compiled=compiled)
    acts = [r.finish - r.submit for r in base.telemetry.records]
    acts.sort()
    p99 = acts[int(0.99 * (len(acts) - 1))] if acts else 0.0
    rows: List[Dict[str, object]] = [
        {
            "name": f"{prefix}_events",
            "us_per_call": float(len(base.telemetry.records)),
            "mean_act": base.telemetry.mean_act(),
            "derived": (
                f"fingerprint={compiled.fingerprint()[:12]};"
                f"p99_act={p99:.3f};seed={spec.seed}"
            ),
        },
    ]
    if spec.policy:
        gated = _run_spec_sim(spec, gated=True, compiled=compiled)
        rows.append(
            {
                "name": f"{prefix}_gate_win",
                "us_per_call": base.telemetry.mean_act()
                / max(1e-9, gated.telemetry.mean_act()),
                "mean_act": gated.telemetry.mean_act(),
                "derived": f"policy={sorted(spec.policy)};"
                           "x_baseline_act_over_gated",
            }
        )
    return rows


def run_generated(scale: float = 1.0, spec_path: Optional[str] = None,
                  live: bool = False) -> List[Dict[str, object]]:
    """Generated-suite rows.

    Default set (the committed ``BENCH_generated.json`` baseline):

    * ``generated_stream_bitidentical`` — the replay rail: every
      registered scenario compiled twice produces byte-identical event
      streams, and survives the wire-dict codec round trip;
    * ``generated_fleet_us_per_event`` — decision latency on the
      spec-driven fleet churn (the latency trend row);
    * ``generated_gate_win_deep`` / ``_mid`` / ``_separation`` — the
      wave-forming gate result on the generated deep-congestion
      scenario vs its mid-congestion control;
    * ``generated_heavy_tail`` / ``generated_diurnal`` — the
      production-shaped open-loop scenarios (Pareto tool latencies,
      sinusoid-modulated Poisson arrivals), reported informationally;
    * ``generated_live_structural_identical`` (``--live``) — the same
      compiled stream run in sim and in live mode (real JAX kernel
      work on emulated XLA host devices), per-pool launch order
      compared structurally, live timing reported in ``derived`` only.

    ``--spec FILE`` appends rows for an externally-supplied scenario
    file instead of requiring a new Python function."""
    rows: List[Dict[str, object]] = []

    # (a) the bit-identical replay rail, over every registered builder
    stable = True
    fp = ""
    for name, builder in sorted(scenarios.SCENARIO_BUILDERS.items()):
        spec = builder()
        c1 = scenarios.compile_scenario(spec)
        c2 = scenarios.compile_scenario(spec)
        rt = scenarios.decode_scenario(scenarios.encode_scenario(spec))
        c3 = scenarios.compile_scenario(rt)
        if not (c1.stream_bytes() == c2.stream_bytes() == c3.stream_bytes()):
            stable = False
        if name == "deep_congestion":
            fp = c1.fingerprint()[:12]
    rows.append(
        {
            "name": "generated_stream_bitidentical",
            "us_per_call": 1.0 if stable else 0.0,
            "mean_act": "",
            "derived": (
                f"builders={len(scenarios.SCENARIO_BUILDERS)};"
                f"deep_fingerprint={fp};"
                "1=same spec+seed -> byte-identical stream, codec-stable"
            ),
        }
    )

    # (b) decision latency on the spec-driven fleet churn
    waves = max(6, int(16 * scale))
    fleet = _run_shard_churn(None, queue=128, waves=waves)
    rows.append(
        {
            "name": "generated_fleet_us_per_event",
            "us_per_call": fleet["sched_us_per_event"],
            "mean_act": fleet["mean_act"],
            "derived": f"spec=fleet_churn;queue=128;waves={waves};"
                       f"events={fleet['events']}",
        }
    )

    # (c) the wave-forming gate: deep vs mid congestion
    wins = {}
    for label, mk in (("deep", scenarios.deep_congestion_spec),
                      ("mid", scenarios.mid_congestion_spec)):
        spec = mk()
        base = _run_spec_sim(spec)
        gated = _run_spec_sim(spec, gated=True)
        win = base.telemetry.mean_act() / max(1e-9, gated.telemetry.mean_act())
        wins[label] = win
        rows.append(
            {
                "name": f"generated_gate_win_{label}",
                "us_per_call": win,
                "mean_act": gated.telemetry.mean_act(),
                "derived": (
                    f"baseline_act={base.telemetry.mean_act():.2f};"
                    f"gated_act={gated.telemetry.mean_act():.2f};"
                    "x_baseline_act_over_gated"
                ),
            }
        )
    rows.append(
        {
            "name": "generated_gate_separation",
            "us_per_call": wins["deep"] / max(1e-9, wins["mid"]),
            "mean_act": "",
            "derived": "x_deep_win_over_mid_win;"
                       "the gate engages under deep congestion only",
        }
    )

    # (d) production-shaped open-loop scenarios (informational rows)
    for name, mk in (("heavy_tail", scenarios.heavy_tail_spec),
                     ("diurnal", scenarios.diurnal_spec)):
        rows += _spec_rows(mk(), f"generated_{name}")

    # (e) the sim-vs-live differential rail
    if live:
        from repro.core.live import run_live_scenario

        spec = scenarios.live_smoke_spec()
        compiled = scenarios.compile_scenario(
            spec, time_scale=GEN_LIVE_TIME_SCALE)
        sim = _run_spec_sim(spec, compiled=compiled)
        sim_trace = scenarios.structural_trace(sim.telemetry.records)
        t0 = time.perf_counter()
        live_orch = run_live_scenario(compiled)
        wall = time.perf_counter() - t0
        live_trace = scenarios.structural_trace(live_orch.telemetry.records)
        acts = [r.finish - r.submit for r in live_orch.telemetry.records]
        live_act = statistics.fmean(acts) if acts else 0.0
        rows.append(
            {
                "name": "generated_live_structural_identical",
                "us_per_call": 1.0 if sim_trace == live_trace else 0.0,
                "mean_act": sim.telemetry.mean_act(),
                "derived": (
                    f"live_mean_act_s={live_act:.3f};live_wall_s={wall:.1f};"
                    f"records={len(live_orch.telemetry.records)};"
                    f"time_scale={GEN_LIVE_TIME_SCALE};"
                    "1=per-pool launch order identical sim vs live "
                    "(real kernel work; live timing never compared)"
                ),
            }
        )

    # (f) an externally-supplied spec file
    if spec_path:
        spec = scenarios.load_scenario(spec_path)
        rows += _spec_rows(spec, f"generated_spec_{spec.name}")
    return rows


def check_generated(rows: List[Dict[str, object]],
                    live: bool = False) -> None:
    """CI scenario-smoke gates: the replay rail holds bit-identically,
    the wave-forming gate wins under deep congestion, stays a no-op
    under mid congestion, separates the two regimes — and, with
    ``--live``, the live run's launch order matches the sim's."""
    by_name = {r["name"]: float(r["us_per_call"]) for r in rows}  # type: ignore[arg-type]
    deep = by_name["generated_gate_win_deep"]
    mid = by_name["generated_gate_win_mid"]
    sep = by_name["generated_gate_separation"]
    print(f"# generated check: bitidentical="
          f"{by_name['generated_stream_bitidentical']:.0f} "
          f"gate_deep={deep:.3f}x gate_mid={mid:.3f}x sep={sep:.3f}x")
    if by_name["generated_stream_bitidentical"] != 1.0:
        raise SystemExit("scenario compilation is not byte-deterministic")
    if deep < GEN_GATE_DEEP_FLOOR:
        raise SystemExit(
            f"wave-forming gate win {deep:.3f}x under deep congestion "
            f"(< {GEN_GATE_DEEP_FLOOR}x floor)")
    lo, hi = GEN_GATE_MID_BAND
    if not (lo <= mid <= hi):
        raise SystemExit(
            f"gate not a no-op under mid congestion: {mid:.3f}x outside "
            f"[{lo}, {hi}]")
    if sep < GEN_GATE_SEPARATION_FLOOR:
        raise SystemExit(
            f"deep/mid separation {sep:.3f}x < "
            f"{GEN_GATE_SEPARATION_FLOOR}x floor")
    if live:
        flag = by_name.get("generated_live_structural_identical")
        if flag != 1.0:
            raise SystemExit(
                "live-mode launch order diverged from the sim "
                f"(flag={flag})")


CHECK_SCENARIO = "schedule_depth2_queue128"


def write_json(rows: List[Dict[str, object]], path: str) -> None:
    """Machine-readable per-scenario results: ns/op + mean ACT."""
    scenarios: Dict[str, Dict[str, object]] = {}
    for r in rows:
        us = float(r["us_per_call"])  # type: ignore[arg-type]
        name = str(r["name"])
        # fairness_* rows and flag rows carry dimensionless metrics
        # (shares, flags, ratios), not latencies — keep them out of the
        # ns_per_op trend.
        # chaos_* rows are flags/counts and rebalance_* rows virtual-time
        # ACTs — none of them are wall-clock latencies either.
        # generated_* rows are flags/ratios/virtual figures too, except
        # the explicit us_per_event latency trend row.
        is_ratio = (
            "speedup" in name
            or name.startswith("fairness_")
            or name.startswith("chaos_")
            or name.startswith("rebalance_")
            or (name.startswith("generated_") and "us_per" not in name)
            or name.endswith("_traces_identical")
        )
        scenarios[name] = {
            "ns_per_op": None if is_ratio else us * 1e3,
            "us_per_call": None if is_ratio else us,
            "ratio": us if is_ratio else None,
            "mean_act": (
                float(r["mean_act"])  # type: ignore[arg-type]
                if r.get("mean_act") not in (None, "")
                else None
            ),
            "derived": r.get("derived"),
        }
    with open(path, "w") as f:
        json.dump({"scenarios": scenarios}, f, indent=2, sort_keys=True)
        f.write("\n")


def check_dense_fast_path(rows: List[Dict[str, object]]) -> None:
    """CI guard: the dense DP must not be slower than the reference on
    the queue-128 scenario (the acceptance target is >= 3x, but a smoke
    run at low scale is noisy, so the hard gate is parity)."""
    by_name = {r["name"]: float(r["us_per_call"]) for r in rows}  # type: ignore[arg-type]
    dense = by_name[CHECK_SCENARIO]
    ref = by_name[f"{CHECK_SCENARIO}_ref"]
    speedup = ref / max(1e-9, dense)
    print(f"# dense-DP check: {CHECK_SCENARIO} dense={dense:.0f}us "
          f"ref={ref:.0f}us speedup={speedup:.2f}x")
    if dense > ref:
        raise SystemExit(
            f"dense DP slower than reference on {CHECK_SCENARIO}: "
            f"{dense:.0f}us > {ref:.0f}us"
        )


_SUITE_JSON = {
    "latency": "BENCH_scheduler.json",
    "fairness": "BENCH_fairness.json",
    "shards": "BENCH_shards.json",
    "remote": "BENCH_remote.json",
    "chaos": "BENCH_chaos.json",
    "generated": "BENCH_generated.json",
}


def main(
    scale: float = 1.0,
    json_path: Optional[str] = None,
    check: bool = False,
    suite: str = "latency",
    shards: int = 4,
    transport: str = "loopback",
    spec: Optional[str] = None,
    live: bool = False,
) -> None:
    if scale == "large" and suite != "chaos":
        raise SystemExit("--scale large is only meaningful with --suite chaos")
    if json_path is None:
        json_path = (
            "BENCH_chaos_large.json" if scale == "large"
            else _SUITE_JSON[suite]
        )
    if suite == "chaos" and scale == "large":
        large_rows = run_chaos_large()
        emit(large_rows,
             "nightly-scale chaos: 8 worker processes, O(100k) actions")
        if json_path:
            write_json(large_rows, json_path)
        if check:
            check_chaos_large(large_rows)
        return
    if suite == "remote":
        remote_rows = run_remote(scale, shards=shards, transport=transport)
        remote_rows += run_rebalance(scale)
        emit(remote_rows, "remote plan-over-wire vs the serial round loop")
        if json_path:
            write_json(remote_rows, json_path)
        if check:
            check_remote(remote_rows)
            check_rebalance(remote_rows)
        return
    if suite == "chaos":
        chaos_rows = run_chaos(scale, shards=shards)
        emit(chaos_rows, "fleet churn over TCP under kill storms and packet faults")
        if json_path:
            write_json(chaos_rows, json_path)
        if check:
            check_chaos(chaos_rows)
        return
    if suite == "generated":
        gen_rows = run_generated(scale, spec_path=spec, live=live)
        emit(gen_rows,
             "generated scenarios: replay rail, wave-forming gate, live mode")
        if json_path:
            write_json(gen_rows, json_path)
        if check:
            check_generated(gen_rows, live=live)
        return
    if suite == "fairness":
        fairness_rows = run_fairness(scale)
        emit(fairness_rows, "multi-tenant fairness (WFQ vs FCFS ablation)")
        if json_path:
            write_json(fairness_rows, json_path)
        if check:
            check_fairness(fairness_rows)
        return
    if suite == "shards":
        shard_rows = run_shards(scale, shards=shards)
        emit(shard_rows, "sharded plan/commit rounds vs the serial round loop")
        if json_path:
            write_json(shard_rows, json_path)
        if check:
            check_shards(shard_rows, shards=shards)
        return
    sched_rows = run(scale)
    emit(sched_rows, "scheduler decision latency (dense vs reference DP)")
    churn_rows = run_churn(scale)
    emit(churn_rows, "steady-state churn decision latency (warm orchestrator)")
    shard_rows = run_shards(scale, shards=shards)
    emit(shard_rows, "sharded plan/commit rounds vs the serial round loop")
    if json_path:
        write_json(sched_rows + churn_rows + shard_rows, json_path)
    if check:
        check_dense_fast_path(sched_rows)


if __name__ == "__main__":
    import argparse

    def _scale_arg(v: str):
        # float multiplier, or the literal "large": the chaos suite's
        # nightly scale (8 worker processes, O(100k) actions)
        return v if v == "large" else float(v)

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=_scale_arg, default=1.0,
                    help="workload multiplier, or 'large' with --suite "
                         "chaos for the nightly 8-process O(100k)-action "
                         "storm (writes BENCH_chaos_large.json)")
    ap.add_argument("--json", default=None,
                    help="output path for machine-readable results ('' = skip; "
                         "default: BENCH_scheduler.json for the latency suite, "
                         "BENCH_fairness.json for the fairness suite)")
    ap.add_argument("--check", action="store_true",
                    help="fail the suite's CI gate: dense-DP parity on "
                         f"{CHECK_SCENARIO} (latency suite), the weighted-"
                         "share / single-task-equivalence gates (fairness), "
                         "the >=1.5x-speedup / trace-identity gates "
                         "(shards), or the trace-identity / wire-exercised "
                         "gates (remote)")
    ap.add_argument("--suite",
                    choices=("latency", "fairness", "shards", "remote",
                             "chaos", "generated"),
                    default="latency",
                    help="latency = decision-latency scenarios (default); "
                         "fairness = multi-tenant weighted-share scenario; "
                         "shards = sharded plan/commit rounds vs serial; "
                         "remote = plan-over-wire shard workers vs serial "
                         "(plus the asymmetric-fleet rebalance rows), with "
                         "serialization overhead reported separately; "
                         "chaos = socket-fleet churn under kill/restart "
                         "storms and packet-level fault injection; "
                         "generated = spec-driven scenarios from the "
                         "scenario factory (replay rail, wave-forming "
                         "gate, optional --live kernel runs)")
    ap.add_argument("--spec", default=None,
                    help="generated suite: path to a scenario spec file "
                         "(JSON envelope, see docs/scenarios.md) to bench "
                         "in addition to the registered scenarios — a new "
                         "workload is a spec file, not a Python function")
    ap.add_argument("--live", action="store_true",
                    help="generated suite: also run the live-mode smoke "
                         "(real JAX kernel work on emulated XLA host "
                         "devices under RealClock) and gate sim-vs-live "
                         "launch-order equivalence")
    ap.add_argument("--shards", type=int, default=4,
                    help="shard count for the fleet-churn scenario (the "
                         "plan/commit engine's parallel planners)")
    ap.add_argument("--transport", choices=("loopback", "process"),
                    default="loopback",
                    help="remote suite: loopback = in-process workers behind "
                         "the full wire codec path (deterministic, the CI "
                         "gate); process = real worker OS processes")
    args = ap.parse_args()
    if args.json is None:
        # per-suite defaults keep any suite from overwriting another
        # suite's tracked baseline (the nightly large storm writes its
        # own file — it has no committed CI-scale baseline to protect)
        args.json = ("BENCH_chaos_large.json" if args.scale == "large"
                     else _SUITE_JSON[args.suite])
    if args.live and args.suite == "generated":
        # set the emulated-device flag before ANY jax import (the core
        # import chain is jax-free, so this is still early enough here)
        from repro.core.live import ensure_host_devices

        ensure_host_devices(len(scenarios.live_smoke_spec().pools))
    main(args.scale, args.json, args.check, args.suite, args.shards,
         args.transport, args.spec, args.live)
