"""Benchmark entry point: one harness per paper table/figure.

``python -m benchmarks.run [--scale 0.25] [--only fig6,...]``

Prints CSV blocks per harness; the roofline block reads the dry-run
artifacts under results/dryrun (produce them with
``python -m repro.launch.dryrun --all --mesh both``).
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0,
                    help="workload scale factor (CI smoke: 0.1)")
    ap.add_argument("--only", default=None, help="comma list of harness names")
    args = ap.parse_args()

    from benchmarks import (
        bench_scheduler,
        fig6_act,
        fig7_breakdown,
        fig8_scalability,
        fig9_elastic,
        roofline,
        table1_overhead,
    )

    harnesses = {
        "fig6": fig6_act.main,
        "fig7": fig7_breakdown.main,
        "fig8": fig8_scalability.main,
        "fig9": fig9_elastic.main,
        "table1": table1_overhead.main,
        "scheduler": bench_scheduler.main,
        "roofline": roofline.main,
    }
    only = set(args.only.split(",")) if args.only else None
    for name, fn in harnesses.items():
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        fn(args.scale)
        print(f"# [{name}] done in {time.perf_counter()-t0:.1f}s\n", flush=True)


if __name__ == "__main__":
    main()
