"""Paper Fig. 9: elastic scheduling ablation — dynamic DoP (Alg. 1) vs
fixed DoP=4 / DoP=16 on the coding reward trace.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from benchmarks.common import emit
from repro.core.action import ResourceRequest
from repro.core.cluster import paper_testbed
from repro.rl.driver import run_tangram_step
from repro.rl.tasks import make_coding_workload


def _fix_dop(trajs, dop: int):
    """Clamp every scalable action to a single fixed DoP."""
    out = []
    for spec in trajs:
        new_reward = []
        for tmpl in spec.reward:
            orig_build = tmpl.build

            def build(task_id, traj_id, _orig=orig_build, _dop=dop):
                a = _orig(task_id, traj_id)
                if a.key_resource == "cpu":
                    a.cost["cpu"] = ResourceRequest("cpu", (_dop,))
                return a

            new_reward.append(dataclasses.replace(tmpl, build=build))
        out.append(dataclasses.replace(spec, reward=new_reward))
    return out


def run(scale: float = 1.0) -> List[Dict[str, object]]:
    rows = []
    for batch, cores_per_node in ((256, 256), (1280, 256), (1280, 128)):
        cluster = paper_testbed(cpu_nodes=5, cores_per_node=cores_per_node, gpu_nodes=1)
        trajs = make_coding_workload(int(batch * scale), arrival_spread_s=30)
        elastic, _ = run_tangram_step(trajs, cluster)
        fixed4, _ = run_tangram_step(_fix_dop(trajs, 4), cluster)
        fixed16, _ = run_tangram_step(_fix_dop(trajs, 16), cluster)
        rows.append(
            {
                "batch": batch,
                "cores": cores_per_node * 5,
                "elastic_act_s": elastic.mean_act,
                "dop4_act_s": fixed4.mean_act,
                "dop16_act_s": fixed16.mean_act,
                "vs_dop4_x": fixed4.mean_act / elastic.mean_act,
                "vs_dop16_x": fixed16.mean_act / elastic.mean_act,
            }
        )
    return rows


def main(scale: float = 1.0) -> None:
    emit(run(scale), "fig9: elastic vs fixed DoP (coding reward trace)")


if __name__ == "__main__":
    main()
