"""Paper Fig. 7: per-stage trajectory-duration breakdown (generation /
tool invocation / reward), normalized to ARL-Tangram's total.
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import emit
from repro.core.cluster import paper_testbed
from repro.rl.driver import run_baseline_step, run_tangram_step
from repro.rl.tasks import make_coding_workload, make_deepsearch_workload, make_mopd_workload


def run(scale: float = 1.0) -> List[Dict[str, object]]:
    cluster = paper_testbed()
    rows = []
    for name, make, n in (
        ("coding", make_coding_workload, 640),
        ("deepsearch", make_deepsearch_workload, 256),
        ("mopd", make_mopd_workload, 256),
    ):
        trajs = make(int(n * scale), arrival_spread_s=30)
        tg_stats, _ = run_tangram_step(trajs, cluster)
        bl_stats, _ = run_baseline_step(trajs, cluster)
        total_tg = sum(tg_stats.stage_durations.values()) or 1.0
        for system, st in (("tangram", tg_stats), ("baseline", bl_stats)):
            rows.append(
                {
                    "workload": name,
                    "system": system,
                    "gen_norm": st.stage_durations["gen"] / total_tg,
                    "tool_norm": st.stage_durations["tool"] / total_tg,
                    "reward_norm": st.stage_durations["reward"] / total_tg,
                    "tool_speedup_x": (
                        bl_stats.stage_durations["tool"]
                        / max(1e-9, tg_stats.stage_durations["tool"])
                    ),
                    "reward_speedup_x": (
                        bl_stats.stage_durations["reward"]
                        / max(1e-9, tg_stats.stage_durations["reward"])
                    ),
                }
            )
    return rows


def main(scale: float = 1.0) -> None:
    emit(run(scale), "fig7: stage breakdown (normalized to Tangram total)")


if __name__ == "__main__":
    main()
