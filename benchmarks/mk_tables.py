"""Emit the EXPERIMENTS.md markdown tables from results/dryrun artifacts.

Usage: PYTHONPATH=src python -m benchmarks.mk_tables [tag]
"""

import glob
import json
import os
import sys

DIR = "results/dryrun"


def rows(mesh_suffix):
    out = []
    for path in sorted(glob.glob(os.path.join(DIR, f"*_{mesh_suffix}.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def fmt_s(x):
    return f"{x:.4f}" if x < 10 else f"{x:.1f}"


def roofline_table(mesh_suffix):
    print("| arch | shape | compute s | memory s | collective s | dominant |"
          " 6ND/HLO | state GB/dev |")
    print("|---|---|---:|---:|---:|---|---:|---:|")
    for r in rows(mesh_suffix):
        if not r.get("ok"):
            print(f"| {r['arch']} | {r['shape']} | - | - | - | ERROR | - | - |")
            continue
        rf = r["roofline"]
        mfr = rf.get("model_flops_ratio")
        mfr_s = f"{mfr:.3f}" if mfr is not None else "n/a"
        print(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rf['compute_s'])} |"
            f" {fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} |"
            f" {rf['dominant']} | {mfr_s} |"
            f" {r.get('state_bytes_per_dev', 0)/1e9:.2f} |"
        )


def dryrun_table(mesh_suffix):
    print("| arch | shape | mesh | fsdp | lower s | compile s | "
          "arg GB/dev | temp GB/dev | collectives |")
    print("|---|---|---|---|---:|---:|---:|---:|---:|")
    for r in rows(mesh_suffix):
        if not r.get("ok"):
            print(f"| {r['arch']} | {r['shape']} | {r.get('mesh','?')} |"
                  f" - | - | - | - | - | ERROR: {r.get('error','')[:60]} |")
            continue
        ma = r.get("memory_analysis", {})
        col = r.get("collectives", {})
        ncol = int(col.get("count", 0))
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['fsdp']} |"
            f" {r['lower_s']:.1f} | {r['compile_s']:.1f} |"
            f" {ma.get('argument_size_in_bytes', 0)/1e9:.2f} |"
            f" {ma.get('temp_size_in_bytes', 0)/1e9:.2f} |"
            f" {ncol} |"
        )


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "single"
    mode = sys.argv[2] if len(sys.argv) > 2 else "roofline"
    if mode == "roofline":
        roofline_table(which)
    else:
        dryrun_table(which)
