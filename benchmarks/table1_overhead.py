"""Paper Table 1: ACT breakdown — execution / queueing / system overhead
for Coding (CPU-intensive) and MOPD (GPU-intensive) at two batch sizes.
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import emit
from repro.core.cluster import paper_testbed
from repro.rl.driver import run_tangram_step
from repro.rl.tasks import make_coding_workload, make_mopd_workload


def run(scale: float = 1.0) -> List[Dict[str, object]]:
    rows = []
    for name, make, batches in (
        ("coding", make_coding_workload, (1280, 1536)),
        ("mopd", make_mopd_workload, (512, 1024)),
    ):
        for batch in batches:
            cluster = paper_testbed()
            trajs = make(int(batch * scale), arrival_spread_s=30)
            stats, tg = run_tangram_step(trajs, cluster)
            b = stats.breakdown
            rows.append(
                {
                    "workload": name,
                    "batch": batch,
                    "exec_s": b["exec"],
                    "queue_s": b["queue"],
                    "sys_overhead_s": b["overhead"],
                    "overhead_pct_of_exec": 100.0 * b["overhead"] / max(1e-9, b["exec"]),
                    "sched_us_per_invocation": 1e6
                    * tg.telemetry.sched_wall_s
                    / max(1, tg.telemetry.sched_invocations),
                }
            )
    return rows


def main(scale: float = 1.0) -> None:
    emit(run(scale), "table1: ACT breakdown (exec / queue / sys overhead)")


if __name__ == "__main__":
    main()
