"""§Roofline: collate the dry-run JSONs into the per-(arch x shape)
roofline table (terms in seconds, dominant bottleneck, 6ND ratio).

Reads results/dryrun/*.json produced by ``repro.launch.dryrun``; does NOT
itself touch jax (so it can run inside benchmarks with 1 device).
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks.common import emit

RESULTS_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun")


def load_records(mesh: str = "single") -> List[Dict[str, object]]:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, f"*_{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if not rec.get("ok"):
            rows.append(
                {
                    "arch": rec.get("arch"),
                    "shape": rec.get("shape"),
                    "ok": False,
                    "compute_ms": float("nan"),
                    "memory_ms": float("nan"),
                    "collective_ms": float("nan"),
                    "dominant": "ERROR",
                    "model_flops_ratio": float("nan"),
                    "state_gb_per_dev": float("nan"),
                }
            )
            continue
        r = rec["roofline"]
        rows.append(
            {
                "arch": rec["arch"],
                "shape": rec["shape"],
                "ok": True,
                "compute_ms": 1e3 * r["compute_s"],
                "memory_ms": 1e3 * r["memory_s"],
                "collective_ms": 1e3 * r["collective_s"],
                "dominant": r["dominant"],
                "model_flops_ratio": r.get("model_flops_ratio") or float("nan"),
                "state_gb_per_dev": rec.get("state_bytes_per_dev", 0) / 1e9,
            }
        )
    return rows


def main(scale: float = 1.0) -> None:
    rows = load_records("single")
    if rows:
        emit(rows, "roofline: per (arch x shape) on 16x16 (from dry-run artifacts)")
    else:
        print("# roofline: no dry-run artifacts found (run repro.launch.dryrun --all)")
    multi = load_records("multi")
    if multi:
        ok = sum(1 for r in multi if r["ok"])
        print(f"# multi-pod (2x16x16): {ok}/{len(multi)} combinations compile OK")
    opt = load_records("single_opt")
    if opt:
        emit(opt, "roofline (post-hillclimb, tag=opt): per (arch x shape) on 16x16")
        mopt = load_records("multi_opt")
        if mopt:
            ok = sum(1 for r in mopt if r["ok"])
            print(f"# multi-pod post-hillclimb: {ok}/{len(mopt)} combinations compile OK")


if __name__ == "__main__":
    main()
