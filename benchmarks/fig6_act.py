"""Paper Fig. 6: average ACT over time windows + RL step durations,
ARL-Tangram vs workload-specific baselines, for AI-Coding / DeepSearch /
MOPD / MOPD+Search.
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import emit
from repro.core.cluster import paper_testbed
from repro.rl.driver import run_baseline_step, run_tangram_step
from repro.rl.tasks import (
    make_coding_workload,
    make_deepsearch_workload,
    make_mopd_workload,
)

BATCHES = {"coding": 1280, "deepsearch": 512, "mopd": 512}


def _workload(name: str, scale: float = 1.0):
    if name == "coding":
        return make_coding_workload(int(BATCHES["coding"] * scale), arrival_spread_s=60)
    if name == "deepsearch":
        return make_deepsearch_workload(int(BATCHES["deepsearch"] * scale), arrival_spread_s=30)
    if name == "mopd":
        return make_mopd_workload(int(BATCHES["mopd"] * scale), arrival_spread_s=20)
    if name == "mopd+search":
        return make_mopd_workload(
            int(BATCHES["mopd"] * scale / 2), arrival_spread_s=20
        ) + make_deepsearch_workload(int(BATCHES["deepsearch"] * scale / 2), arrival_spread_s=20)
    raise KeyError(name)


def run(scale: float = 1.0) -> List[Dict[str, object]]:
    cluster = paper_testbed()
    rows = []
    for name in ("coding", "deepsearch", "mopd", "mopd+search"):
        trajs = _workload(name, scale)
        tg_stats, tg = run_tangram_step(trajs, cluster)
        bl_stats, _ = run_baseline_step(trajs, cluster)
        timeline = tg.telemetry.act_timeline(window=max(1.0, tg_stats.step_duration / 8))
        rows.append(
            {
                "workload": name,
                "tangram_mean_act_s": tg_stats.mean_act,
                "baseline_mean_act_s": bl_stats.mean_act,
                "act_improvement_x": bl_stats.mean_act / tg_stats.mean_act,
                "tangram_step_s": tg_stats.step_duration,
                "baseline_step_s": bl_stats.step_duration,
                "step_speedup_x": bl_stats.step_duration / tg_stats.step_duration,
                "tangram_fail": tg_stats.failure_rate,
                "baseline_fail": bl_stats.failure_rate,
                "act_windows": len(timeline),
            }
        )
    return rows


def main(scale: float = 1.0) -> None:
    emit(run(scale), "fig6: ACT + step duration, Tangram vs baselines")


if __name__ == "__main__":
    main()
