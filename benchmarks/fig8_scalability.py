"""Paper Fig. 8: scalability in RL batch size and resource capacity.

(a) CPU: coding workload, ACT vs batch {128..1536} at fixed 1280 cores,
    and ACT vs cores {768, 1280} at fixed batch; vs the k8s baseline.
(b) GPU: MOPD-style reward serving, ACT vs batch vs SGLang-static and
    ServerlessLLM; plus GPUs-needed-for-equal-ACT (resource saving).
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import emit
from repro.core.cluster import paper_testbed
from repro.rl.driver import run_baseline_step, run_tangram_step
from repro.rl.tasks import make_coding_workload, make_mopd_workload


def run_cpu(scale: float = 1.0) -> List[Dict[str, object]]:
    rows = []
    # 1280 cores across five nodes (paper Fig. 8a)
    for batch in (128, 512, 1280, 1536):
        cluster = paper_testbed(cpu_nodes=5, cores_per_node=256, gpu_nodes=1)
        trajs = make_coding_workload(int(batch * scale), arrival_spread_s=30)
        tg, _ = run_tangram_step(trajs, cluster)
        bl, _ = run_baseline_step(trajs, cluster)
        rows.append(
            {
                "sweep": "batch",
                "batch": batch,
                "cores": 1280,
                "tangram_act_s": tg.mean_act,
                "k8s_act_s": bl.mean_act,
                "improvement_x": bl.mean_act / tg.mean_act,
                "k8s_fail": bl.failure_rate,
            }
        )
    for cores_per_node in (154, 256):  # ~768 vs 1280 total cores
        cluster = paper_testbed(cpu_nodes=5, cores_per_node=cores_per_node, gpu_nodes=1)
        trajs = make_coding_workload(int(1280 * scale), arrival_spread_s=30)
        tg, _ = run_tangram_step(trajs, cluster)
        bl, _ = run_baseline_step(trajs, cluster)
        rows.append(
            {
                "sweep": "capacity",
                "batch": 1280,
                "cores": cores_per_node * 5,
                "tangram_act_s": tg.mean_act,
                "k8s_act_s": bl.mean_act,
                "improvement_x": bl.mean_act / tg.mean_act,
                "k8s_fail": bl.failure_rate,
            }
        )
    return rows


def run_gpu(scale: float = 1.0) -> List[Dict[str, object]]:
    rows = []
    for batch in (256, 512, 1024):
        cluster = paper_testbed(cpu_nodes=1, gpu_nodes=5)
        trajs = make_mopd_workload(
            int(batch * scale), n_teachers=10, arrival_spread_s=10
        )
        tg, _ = run_tangram_step(trajs, cluster)
        st, _ = run_baseline_step(trajs, cluster, gpu_baseline="static")
        sl, _ = run_baseline_step(trajs, cluster, gpu_baseline="serverless")
        rows.append(
            {
                "sweep": "batch",
                "batch": batch,
                "gpus": cluster.total_devices,
                "tangram_act_s": tg.mean_act,
                "sglang_act_s": st.mean_act,
                "serverless_act_s": sl.mean_act,
                "vs_sglang_x": st.mean_act / tg.mean_act,
                "vs_serverless_x": sl.mean_act / tg.mean_act,
                "serverless_fail": sl.failure_rate,
            }
        )
    # resource saving: GPUs needed by Tangram to match the static
    # baseline's ACT with 10 services x 4 GPUs (= 40 GPUs over-provisioned)
    base_cluster = paper_testbed(cpu_nodes=1, gpu_nodes=5)
    trajs = make_mopd_workload(int(512 * scale), n_teachers=10, arrival_spread_s=10)
    static, _ = run_baseline_step(trajs, base_cluster, gpu_baseline="static")
    target = static.mean_act
    for nodes in (1, 2, 3, 5):
        cluster = paper_testbed(cpu_nodes=1, gpu_nodes=nodes)
        tg, _ = run_tangram_step(trajs, cluster)
        rows.append(
            {
                "sweep": "saving",
                "batch": 512,
                "gpus": nodes * 8,
                "tangram_act_s": tg.mean_act,
                "sglang_act_s": target,
                "vs_sglang_x": target / tg.mean_act,
                "serverless_act_s": float("nan"),
                "vs_serverless_x": float("nan"),
                "serverless_fail": 0.0,
            }
        )
    return rows


def main(scale: float = 1.0) -> None:
    emit(run_cpu(scale), "fig8a: CPU scalability (coding vs k8s)")
    emit(run_gpu(scale), "fig8b: GPU scalability + resource saving (MOPD)")


if __name__ == "__main__":
    main()
