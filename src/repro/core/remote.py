"""Out-of-process shard workers: the plan phase over the wire.

The sharded round engine (:mod:`repro.core.shards`) proved a round can
be split into side-effect-free per-shard *plan* phases over manager
snapshots plus one serialized validated *commit*.  This module moves the
plan phase out of the orchestrator's process:

* :class:`RemoteShardWorker` — the worker side: decodes a plan request
  (policy config, manager snapshots, queue contents), runs the **same**
  plan core the in-process engine runs
  (:func:`repro.core.shards.plan_partition` — one implementation, zero
  drift), and returns serialized :class:`~repro.core.shards.PartitionPlan`
  payloads.  Stateless across requests except for caches keyed by
  content fingerprint (snapshot bases for structural deltas, interned
  action payloads, policy config, duration history) — every cache is a
  byte-budget LRU, and a worker can be restarted at any time: the next
  request that names state it no longer holds gets a *typed* error and
  the client re-primes it with full content.
* :class:`ShardTransport` — the byte-level boundary, deliberately tiny
  (``submit``/``recv``/``close`` over opaque byte frames): anything that
  can move bytes (a pipe, a socket, an RPC stack) can carry shards.
  :class:`LoopbackTransport` runs the worker in-process but pushes every
  payload through the full encode/decode path — the determinism rail
  proving wire fidelity without process overhead;
  :class:`ProcessTransport` runs the worker in a real OS process over a
  ``multiprocessing`` pipe.
* :class:`RemoteRoundClient` — the orchestrator side: builds per-shard
  requests, dispatches to every worker, gathers, and re-binds decoded
  decisions to the **live** Action objects for the unchanged
  single-threaded commit.  Conflict rollback and the retry rail are
  exactly the in-process ones — the commit phase cannot tell where a
  plan was computed.

Three mechanisms keep the wire bill proportional to *what changed*,
not to fleet size (all additive within ``WIRE_VERSION`` 1 — a worker
still accepts the plain full-payload forms):

* **structural snapshot deltas** — an unchanged snapshot travels as
  ``{"ref": fp}``; a changed one travels as a ``snapshot_delta``
  envelope (per-manager structural diff, fingerprint-verified on
  reconstruction) whenever the worker holds the base, and only falls
  back to the full payload when it does not;
* **compact binary framing** — requests/responses are
  :func:`repro.core.wire.encode_frame` byte frames; ``codec="binary"``
  packs tag/varint values with frame-level string interning, while
  ``codec="json"`` keeps the UTF-8 JSON text path as the v1
  compatibility reference (a worker answers in the codec it was asked
  in — the first frame byte says which).  json is the default: the C
  ``json`` module costs ~2x less CPU per event than the pure-Python
  binary packer, while binary ships ~1.6x fewer bytes — pick binary
  when the transport, not the codec, is the bottleneck;
* **cross-round interning** — action payloads travel once as
  ``{"idef": fp, "val": ...}`` and afterwards as ``{"iref": fp}``
  references into a bounded LRU intern table the client mirrors
  deterministically (same budget, same touch order); a lifecycle
  transition travels as a **patch-define** (``{"idef", "base", "d"}``)
  cloning the interned base with the changed fields applied.  A missed
  reference — worker restart, budget divergence — produces a typed
  ``stale_intern`` error and one full re-send, never a wrong plan.

Three more take the wire off the critical path (this, too, all within
``WIRE_VERSION`` 1):

* **encode memoization** — the client caches the encoded *bytes* of
  fingerprint-stable sections (full action defines, full snapshots,
  policy/fairness/history configs) and splices them into request frames
  (:class:`~repro.core.wire.Encoded`): the same content sent to N
  workers is serialized once, and encode time tracks bytes that
  actually change, not state size;
* **resident worker plan state** — each worker keeps one long-lived
  plan-capable manager replica per resource type, refreshed in place
  from structural deltas (``apply_state``) with a cheap copy-on-plan
  for the families planning mutates — decode-time structures stay warm
  instead of being rebuilt every request;
* **pipelined dispatch** — requests are submitted as soon as each frame
  is encoded, so shard i+1's encode overlaps shard i's worker compute;
  response-encode cost is carried off the reported plan path, and
  same-instant frames coalesce into one accounting round.

Accounting is honest by construction: the modeled critical-path
decision latency stays ``max(per-shard plan) + commit`` with per-shard
plan cost *measured on the worker* (what a dedicated worker pays), and
every serialization cost — client encode, client decode, worker codec,
transport wall, bytes, fallback re-sends — is recorded separately in
``Telemetry.wire_*`` so wire overhead is never laundered into decision
latency (``bench_scheduler --suite remote`` reports each component,
side by side).

No pickle crosses the boundary: requests and responses are
:func:`repro.core.wire.encode_frame` byte frames (JSON text or the
tagged binary codec — both self-describing).
"""

from __future__ import annotations

import atexit
import inspect
import math
import time
import weakref
from typing import TYPE_CHECKING, Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro.core import wire
from repro.core.action import Action, ActionState
from repro.core.shards import (
    PartitionPlan,
    SnapshotMap,
    classify_after_commit,
    commit_decision,
    duration_of,
    plan_partition,
    quota_reservations,
)

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.orchestrator import Orchestrator

#: Byte budget of the worker-side caches (intern table, snapshot bases)
#: and of the client's per-worker intern mirror.  Client and worker
#: MUST agree on the intern budget for the mirror to predict evictions
#: exactly; a divergence is recoverable (typed error + full re-send)
#: but costs a round trip.
CACHE_BUDGET_BYTES = 8 << 20

#: Typed error codes the client recovers from by re-sending that
#: worker's request with full content (cleared fingerprint/intern
#: state).  Anything else is a real protocol failure and raises.
RECOVERABLE_CODES = frozenset(
    {"stale_ref", "stale_base", "delta_mismatch", "stale_intern", "stale_epoch"}
)

#: Ceiling on the round-based reconnect backoff after worker loss: a
#: down worker is retried after skipping 0, 1, 3, 7, ... rounds, capped
#: here.  Round-based (not wall-clock) so recovery behaviour is
#: deterministic under the virtual-time DES harness.
MAX_BACKOFF_ROUNDS = 7


class ProtocolStateError(wire.WireError):
    """The worker lacks state the request referenced (evicted cache,
    restarted worker, stale base).  Carries a machine-readable ``code``
    so the client can distinguish "re-send full content" from a real
    schema violation."""

    def __init__(self, code: str, message: str, **extra: Any) -> None:
        super().__init__(message)
        self.code = code
        self.extra = extra


# ---------------------------------------------------------------------------
# the worker side
# ---------------------------------------------------------------------------


class _WaitingView:
    """Truthiness + ``head()`` over a remaining-waiting list — the queue
    shape :func:`repro.core.shards.classify_after_commit` expects,
    without a live PartitionQueue (the worker only ever sees the wire's
    already-service-ordered lists)."""

    __slots__ = ("_acts",)

    def __init__(self, acts: Sequence[Action]) -> None:
        self._acts = acts

    def __bool__(self) -> bool:
        return bool(self._acts)

    def head(self) -> Optional[Action]:
        return self._acts[0] if self._acts else None


class RemoteShardWorker:
    """Executes serialized plan requests; lives wherever the transport
    puts it (the orchestrator's process for loopback, a separate OS
    process for :class:`ProcessTransport`, a remote host once an RPC
    transport exists).

    Per-request inputs arrive in full, as ``{"ref": fp}`` references,
    as ``snapshot_delta`` structural diffs against a cached base, or as
    ``{"iref": fp}`` intern references.  Manager state is *resident*:
    one long-lived plan-capable replica per resource type, tagged with
    the fingerprint of the state it embodies.  A request whose snapshot
    fingerprint matches reuses the replica as-is; a changed snapshot is
    applied **in place** (:meth:`~repro.core.managers.base.
    ResourceManager.apply_state`) so decode-time structures (the DP
    duration memos riding interned actions, allocator shells, node-state
    objects) stay warm; only a topology change rebuilds from scratch.
    Planning still never dirties the resident: families whose plan phase
    mutates them (``plan_mutates`` — the CPU manager's trajectory
    binding) are planned over a throwaway ``snapshot()`` clone taken
    once per request, the *plan-scope reset*.  All byte caches are
    byte-budget LRUs (:class:`~repro.core.wire.LruBytes`): a long run
    cannot grow worker memory without bound, and an eviction surfaces as
    a typed error the client answers with a full re-send.  (The resident
    table itself holds exactly one live manager per resource type —
    bounded by the managed fleet, not by history.)"""

    def __init__(self, cache_budget: int = CACHE_BUDGET_BYTES,
                 plan_delay_s: float = 0.0) -> None:
        # straggler injection (scenario fault schedules): a positive
        # delay is real wall time slept inside each partition's plan
        # window, so the per-partition ``wall_s`` the worker reports —
        # and hence the client's plan-cost EWMA that feeds the rebalance
        # cadence — honestly reflects the slow worker.
        self.plan_delay_s = plan_delay_s
        self._policy: Optional[Any] = None
        self._policy_fp: Optional[str] = None
        self._fair_share: Optional[Any] = None
        self._fair_share_fp: Optional[str] = None
        self._history_fp: Optional[str] = None
        self._history_avg: Dict[str, float] = {}
        # rtype -> (fingerprint, full snapshot envelope): the delta base
        self._snap_cache = wire.LruBytes(cache_budget)
        # rtype -> (fingerprint, live manager replica): resident plan
        # state — one replica per resource type, refreshed in place
        # (bounded by the fleet, so not an LRU)
        self._resident: Dict[str, Tuple[str, Any]] = {}
        # per-request cache-effectiveness counters, returned in the
        # plan response ("cache") so the client can aggregate hit rates
        self._stats: Dict[str, float] = self._fresh_stats()
        # fingerprint -> resolved action payload (cross-round interning)
        self._interns = wire.LruBytes(cache_budget)
        # (list fp, [(member fp, Action)]): the executing-list delta
        # base — sized by the live running set, so inherently bounded
        self._exec_cache: Optional[Tuple[str, List[Tuple[str, Action]]]] = None
        # part -> (list fp, [(member fp, Action)]): waiting-list delta
        # bases, each replaced wholesale — bounded by the live queues
        self._part_cache: Dict[str, Tuple[str, List[Tuple[str, Action]]]] = {}
        # dumps() cost of the previous response, folded into the NEXT
        # response's codec_s (we cannot time a serialization inside the
        # payload it produces; carrying it forward keeps the aggregate
        # wire bill honest without double-serializing)
        self._carry_dump_s = 0.0
        # worker-owned commit: rtype -> ownership-lease epoch.  A
        # ``plan_commit`` asserting an epoch this table does not hold is
        # refused with a typed ``stale_epoch`` error BEFORE any replica
        # mutation — a restarted worker (amnesia) can therefore never
        # double-launch on stale state.
        self._leases: Dict[str, int] = {}
        # pre-round replica states of the last UNCONFIRMED plan_commit:
        # rtype -> (fingerprint, full snapshot envelope).  Dropped on
        # confirm (the client verified and adopted the outcome);
        # restored on an explicit ``commit_decide`` abort or implicitly
        # when the next frame arrives without a confirm (the client
        # never acked — deterministic abort, never a half-applied round)
        self._stash: Optional[Dict[str, Tuple[str, Dict[str, Any]]]] = None

    @staticmethod
    def _fresh_stats() -> Dict[str, float]:
        """Zeroed per-request cache counters (every key is summable, so
        the client folds responses straight into a run-wide aggregate)."""
        return {
            "intern_hits": 0,
            "intern_defs": 0,
            "intern_patches": 0,
            "snap_refs": 0,
            "snap_deltas": 0,
            "snap_fulls": 0,
            "resident_hits": 0,
            "resident_patches": 0,
            "resident_rebuilds": 0,
            "rebuild_s": 0.0,
            "reset_s": 0.0,
        }

    # ------------------------------------------------------------------
    def handle_bytes(self, request: bytes) -> bytes:
        """One plan round-trip: byte frame in, byte frame out, answered
        in the codec the request arrived in.  Any
        :class:`~repro.core.wire.WireError` (or other failure) is
        returned as an ``error`` payload rather than raised — the
        transport stays alive and the client decides what to do; a
        :class:`ProtocolStateError` additionally carries its ``code``
        so the client knows a full re-send recovers it."""
        codec = wire.frame_codec(request)
        try:
            t0 = time.perf_counter()
            payload = wire.decode_frame(request)
            parse_s = time.perf_counter() - t0
            body = self._handle(payload, parse_s)
            t1 = time.perf_counter()
            blob = wire.encode_frame(body, codec)
            self._carry_dump_s += time.perf_counter() - t1
            return blob
        except Exception as e:  # noqa: BLE001 - protocol boundary
            err: Dict[str, Any] = {"error": f"{type(e).__name__}: {e}"}
            if isinstance(e, ProtocolStateError):
                err["code"] = e.code
                err.update(e.extra)
            return wire.encode_frame(wire.envelope("error", err), codec)

    def handle(self, request: str) -> str:
        """String-frame convenience wrapper (UTF-8 JSON in and out)."""
        return self.handle_bytes(request.encode("utf-8")).decode("utf-8")

    # ------------------------------------------------------------------
    def _snapshot(self, rtype: str, snap: Any) -> Tuple[str, Dict[str, Any]]:
        """Materialize one (fingerprint, full snapshot envelope) pair
        from whichever form it arrived in (full / ``{"ref": fp}`` /
        ``snapshot_delta``), and keep the cache pointing at the newest
        base.  The fingerprint is what the resident-replica layer keys
        on, so it rides along instead of being recomputed."""
        if isinstance(snap, dict) and "ref" in snap:
            cached = self._snap_cache.get(rtype)
            if cached is None or cached[0] != snap["ref"]:
                raise ProtocolStateError(
                    "stale_ref",
                    f"snapshot ref for {rtype!r} does not match cached state",
                )
            self._stats["snap_refs"] += 1
            return cached
        if isinstance(snap, dict) and snap.get("kind") == "snapshot_delta":
            d = wire.expect(snap, "snapshot_delta")
            base_fp = d.get("base")
            cached = self._snap_cache.get(rtype)
            if cached is None or cached[0] != base_fp:
                raise ProtocolStateError(
                    "stale_base",
                    f"snapshot delta base for {rtype!r} does not match cached state",
                )
            try:
                full = wire.apply_snapshot_delta(d, cached[1])
            except wire.WireError as e:
                # the base is unusable (corrupt or mis-diffed) — drop it
                # so the recovery round re-primes from a full snapshot
                self._snap_cache.pop(rtype)
                raise ProtocolStateError("delta_mismatch", str(e)) from None
            fp = str(d.get("fp"))
            self._snap_cache.put(rtype, (fp, full), wire.payload_nbytes(full))
            self._stats["snap_deltas"] += 1
            return fp, full
        fp = wire.fingerprint(snap)
        self._snap_cache.put(rtype, (fp, snap), wire.payload_nbytes(snap))
        self._stats["snap_fulls"] += 1
        return fp, snap

    def _manager(self, rtype: str, fp: str, full: Dict[str, Any]) -> Any:
        """The resident replica for ``rtype`` at state ``fp``: reused
        as-is on a fingerprint match, refreshed **in place** when the
        family supports it (keeping decode-time structures warm), rebuilt
        from the full envelope only on a topology change or first
        sight.  Timing lands in the per-request stats so rebuild-vs-reset
        cost is auditable from the client."""
        st = self._stats
        res = self._resident.get(rtype)
        if res is not None and res[0] == fp:
            st["resident_hits"] += 1
            return res[1]
        if res is not None:
            t0 = time.perf_counter()
            if res[1].apply_state(full["state"]):
                st["resident_patches"] += 1
                st["reset_s"] += time.perf_counter() - t0
                self._resident[rtype] = (fp, res[1])
                return res[1]
        t0 = time.perf_counter()
        mgr = wire.decode_snapshot(full)
        st["resident_rebuilds"] += 1
        st["rebuild_s"] += time.perf_counter() - t0
        self._resident[rtype] = (fp, mgr)
        return mgr

    def _resolve_action(self, node: Any, missing: List[str]) -> Optional[Action]:
        """One wire entry of an action list: an intern reference (table
        lookup; a miss collects into ``missing``), an intern definition
        (decode once, cache the Action under its fingerprint with the
        sender's byte accounting), a patch-define (clone the interned
        base with the mutable-field diff applied — a missing base is
        exactly a missed reference), or a plain envelope (legacy form —
        decoded fresh, never cached)."""
        if isinstance(node, dict):
            if "iref" in node and len(node) == 1:
                a = self._interns.get(str(node["iref"]))
                if a is None:
                    missing.append(str(node["iref"]))
                else:
                    self._stats["intern_hits"] += 1
                return a
            if "idef" in node and "base" in node:
                base = self._interns.get(str(node["base"]))
                if base is None:
                    # the recovery full re-send defines the NEW
                    # fingerprint from scratch, so that is what we
                    # report missing — not the base we happen to lack
                    missing.append(str(node["idef"]))
                    return None
                a = wire.patch_action(base, node.get("d") or {})
                nbytes = node.get("n") or wire.payload_nbytes(node.get("d"))
                self._interns.put(str(node["idef"]), a, int(nbytes))
                self._stats["intern_patches"] += 1
                return a
            if "idef" in node and "val" in node:
                a = wire.decode_action(node["val"])
                nbytes = node.get("n") or wire.payload_nbytes(node["val"])
                self._interns.put(str(node["idef"]), a, int(nbytes))
                self._stats["intern_defs"] += 1
                return a
        return wire.decode_action(node)

    def _exec_pairs(
        self, nodes: Sequence[Any], missing: List[str]
    ) -> List[Tuple[str, Optional[Action]]]:
        """Resolve action nodes into (fingerprint, Action) pairs — the
        fingerprint rides the intern envelope when there is one and is
        computed only for plain legacy envelopes."""
        pairs: List[Tuple[str, Optional[Action]]] = []
        for node in nodes:
            a = self._resolve_action(node, missing)
            if isinstance(node, dict) and "iref" in node and len(node) == 1:
                fp = str(node["iref"])
            elif isinstance(node, dict) and "idef" in node:
                fp = str(node["idef"])
            else:
                fp = wire.fingerprint(node)
            pairs.append((fp, a))
        return pairs

    def _resolve_list(
        self,
        node: Any,
        cached: Optional[Tuple[str, List[Tuple[str, Action]]]],
        missing: List[str],
        what: str,
    ) -> Tuple[List[Optional[Action]], Any]:
        """One action list (executing set or a partition's waiting
        queue) in any wire form: legacy plain list, ``ref`` (unchanged),
        ``delta`` (removals by member fingerprint + positional inserts
        into the kept order), or ``full``.  Returns (actions, commit):
        the caller applies ``commit`` to its cache slot only after the
        request's atomic missing-intern check passes, so a failed
        request never leaves a half-resolved list behind — ``False``
        means drop the slot (legacy form), ``None`` means keep it.

        A reconstructed delta is verified against the sender's list
        fingerprint; a mismatch is a typed, recoverable error — the
        client re-sends full content, never plans on a wrong queue.
        These caches are bounded by construction: each slot holds
        exactly one live list (replaced wholesale), never history."""
        if isinstance(node, list):
            # legacy form: a plain per-action list, uncached
            return [self._resolve_action(a, missing) for a in node], False
        if not isinstance(node, dict):
            raise wire.WireError(f"plan_request: malformed {what} entry")
        kind = str(node.get("k", ""))
        if kind == "ref":
            if cached is None or cached[0] != str(node.get("fp")):
                raise ProtocolStateError(
                    "stale_ref", f"{what} ref does not match cached list"
                )
            return [a for _, a in cached[1]], None
        if kind == "full":
            pairs = self._exec_pairs(node.get("items", []), missing)
            if missing:
                return [a for _, a in pairs], None
            return [a for _, a in pairs], (str(node.get("fp")), pairs)
        if kind == "delta":
            if cached is None or cached[0] != str(node.get("base")):
                raise ProtocolStateError(
                    "stale_base", f"{what} delta base does not match cached list"
                )
            inserts = [
                (int(pos), self._exec_pairs([n], missing)[0])
                for pos, n in node.get("ins", [])
            ]
            if missing:
                return [], None
            rm = {str(f) for f in node.get("rm", [])}
            pairs = [(f, a) for f, a in cached[1] if f not in rm]
            for pos, pair in inserts:  # ascending: client emits in order
                pairs.insert(pos, pair)
            fp = str(node.get("fp"))
            if wire.list_fingerprint([f for f, _ in pairs]) != fp:
                raise ProtocolStateError(
                    "delta_mismatch",
                    f"{what} delta did not reproduce the sender's list",
                )
            return [a for _, a in pairs], (fp, pairs)
        raise wire.WireError(f"plan_request: unknown {what} form {kind!r}")

    def _handle(self, payload: Any, parse_s: float = 0.0) -> Dict[str, Any]:
        """Dispatch one decoded frame by kind: ``plan_request`` (one
        plan round), ``plan_commit`` (a fused plan+commit round against
        the leased authoritative replicas — the two-phase commit's
        *prepare*, answered by the ``plan_commit_response`` ack),
        ``commit_decide`` (the explicit commit/abort verdict for an
        unconfirmed prepared round, also the fence/revocation vehicle),
        ``plan_batch`` (several plan/plan_commit requests processed in
        arrival order against the evolving cache state — one frame, one
        framing overhead), or ``drain`` (flush the carried response-dump
        cost so a run's LAST response encode is billed before the
        transport closes)."""
        kind = payload.get("kind") if isinstance(payload, dict) else None
        if kind == "drain":
            wire.expect(payload, "drain")
            codec_s = parse_s + self._carry_dump_s
            self._carry_dump_s = 0.0
            return wire.envelope("drain_response", {"codec_s": codec_s})
        if kind == "commit_decide":
            return self._commit_decide(payload)
        if kind == "plan_batch":
            batch = wire.expect(payload, "plan_batch")
            resps = [
                (
                    self._plan_commit(r, parse_s if i == 0 else 0.0)
                    if isinstance(r, dict) and r.get("kind") == "plan_commit"
                    else self._plan(r, parse_s if i == 0 else 0.0)
                )
                for i, r in enumerate(batch.get("reqs", []))
            ]
            return wire.envelope("plan_batch_response", {"resps": resps})
        if kind == "plan_commit":
            return self._plan_commit(payload, parse_s)
        return self._plan(payload, parse_s)

    def _decode_plan_request(self, req: Dict[str, Any]) -> Dict[str, Any]:
        """The decode preamble shared by ``plan_request`` and
        ``plan_commit``: sync policy/fairness/history, reconstruct
        snapshots and refresh the resident replicas, resolve every
        interned action list atomically.  Returns a context dict with
        the *plan view* managers (``plan_mutates`` families copied), the
        resident authoritative replicas as ``(fp, full, mgr)`` triples,
        the resolved waiting/executing lists, and the preamble's codec
        wall — the caller adds its own encode cost on top."""
        self._stats = self._fresh_stats()
        t_codec = time.perf_counter()

        if req.get("policy") is not None:
            self._policy = wire.decode_policy(req["policy"])
            self._policy_fp = wire.fingerprint(req["policy"])
        if self._policy is None:
            # a restarted worker sees a policy-omitted request: typed
            # and recoverable — the client's full re-send carries it
            raise ProtocolStateError(
                "stale_ref", "plan_request before any policy was sent"
            )

        fs = req.get("fair_share", {"ref": self._fair_share_fp})
        if not (isinstance(fs, dict) and "ref" in fs):
            self._fair_share = wire.decode_fair_share(fs)
            self._fair_share_fp = wire.fingerprint(fs)
        elif fs["ref"] != self._fair_share_fp:
            raise ProtocolStateError(
                "stale_ref", "fair_share ref does not match cached state"
            )

        hist = req.get("history")
        if hist is not None:
            if isinstance(hist, dict) and "ref" in hist:
                if hist["ref"] != self._history_fp:
                    raise ProtocolStateError(
                        "stale_ref", "history ref does not match cached state"
                    )
            else:
                self._history_avg = {
                    str(k): float(v) for k, v in hist.get("avg", {}).items()
                }
                self._history_fp = wire.fingerprint(hist)
            # apply the cached table even on a ref hit: a policy refresh
            # above rebuilt a FRESH policy (empty history), and an
            # unchanged-history ref must still repopulate it — otherwise
            # unprofiled actions price at the default and remote plans
            # silently diverge from serial ones
            history = getattr(self._policy, "history", None)
            if history is not None:
                history._avg = dict(self._history_avg)

        if req.get("reset_interns"):
            # recovery round: the client cleared its mirror, so drop the
            # table too — both sides restart from the same empty state
            self._interns.clear()
            self._exec_cache = None
            self._part_cache.clear()

        # resident replicas: fingerprint hit -> reuse, state change ->
        # in-place refresh, topology change -> rebuild.  The plan-scope
        # reset is a throwaway snapshot() of exactly the families whose
        # plan phase mutates them, taken ONCE per request and shared
        # across this request's partitions — matching the one-decode-
        # per-request semantics the rebuild path had.
        managers: Dict[str, Any] = {}
        resident: Dict[str, Tuple[str, Dict[str, Any], Any]] = {}
        for rtype, snap in req.get("snapshots", {}).items():
            rt = str(rtype)
            fp, full = self._snapshot(rt, snap)
            mgr = self._manager(rt, fp, full)
            resident[rt] = (fp, full, mgr)
            if type(mgr).plan_mutates:
                t_reset = time.perf_counter()
                mgr = mgr.snapshot()
                self._stats["reset_s"] += time.perf_counter() - t_reset
            managers[rt] = mgr

        # resolve interned actions BEFORE planning over any of them: a
        # stale reference must fail the whole request atomically (one
        # typed error naming every missing payload), never plan with a
        # partial queue.  The intern table holds *decoded* Action
        # objects, so a referenced action costs a dict lookup instead of
        # a full decode — and its ``_dp_durs`` duration memo persists
        # across the rounds it stays queued, exactly as a live action's
        # does on the serial path (the memo depends only on immutable
        # fields, so reuse is sound; any mutable-field change produces a
        # new fingerprint and a fresh decode).
        missing: List[str] = []
        executing, exec_commit = self._resolve_list(
            req.get("executing", []), self._exec_cache, missing, "executing"
        )
        waiting_by_part: Dict[str, List[Action]] = {}
        part_commits: List[Tuple[str, Any]] = []
        for p in req.get("partitions", []):
            part = str(p["part"])
            acts, commit = self._resolve_list(
                p.get("waiting", []),
                self._part_cache.get(part),
                missing,
                f"partition {part!r}",
            )
            waiting_by_part[part] = acts
            if commit is not None:
                part_commits.append((part, commit))
        if missing:
            raise ProtocolStateError(
                "stale_intern",
                f"{len(missing)} interned payload(s) not in table",
                missing=sorted(set(missing)),
            )
        if exec_commit is False:
            self._exec_cache = None
        elif exec_commit is not None:
            self._exec_cache = exec_commit
        for part, commit in part_commits:
            if commit is False:
                self._part_cache.pop(part, None)
            else:
                self._part_cache[part] = commit
        return {
            "managers": managers,
            "resident": resident,
            "waiting_by_part": waiting_by_part,
            "executing": executing,
            "now": float(req.get("now", 0.0)),
            "incremental": bool(req.get("incremental", True)),
            "shard": int(req.get("shard", 0)),
            "codec_s": time.perf_counter() - t_codec,
        }

    def _plan(self, payload: Any, parse_s: float = 0.0) -> Dict[str, Any]:
        req = wire.expect(payload, "plan_request")
        ctx = self._decode_plan_request(req)
        managers = ctx["managers"]
        shard = ctx["shard"]

        t_plan = time.perf_counter()
        plans = []
        for part, waiting in ctx["waiting_by_part"].items():
            p = plan_partition(
                part,
                waiting,
                ctx["executing"],
                managers,
                self._policy,
                self._fair_share,
                ctx["now"],
                ctx["incremental"],
                shard=shard,
            )
            if self.plan_delay_s > 0.0:
                t_straggle = time.perf_counter()
                time.sleep(self.plan_delay_s)
                p.wall_s += time.perf_counter() - t_straggle
            plans.append(p)
        plan_s = time.perf_counter() - t_plan

        t_enc = time.perf_counter()
        plan_payloads = [wire.encode_plan(p) for p in plans]
        codec_s = ctx["codec_s"] + parse_s + self._carry_dump_s + (
            time.perf_counter() - t_enc
        )
        self._carry_dump_s = 0.0
        body = {
            "shard": shard,
            "plans": plan_payloads,
            "plan_s": plan_s,
            "codec_s": codec_s,
            "cache": self._stats,
        }
        return wire.envelope("plan_response", body)

    # -- worker-owned two-phase commit ---------------------------------
    def _restore_stash(self) -> int:
        """Abort the unconfirmed prepared round: rebuild every touched
        replica from its stashed pre-round snapshot (the existing
        decode rail — byte-identical state, no half-applied commits
        survive).  Returns the number of replicas restored."""
        stash, self._stash = self._stash, None
        if not stash:
            return 0
        for rt, (fp, full) in stash.items():
            self._resident[rt] = (fp, wire.decode_snapshot(full))
            self._snap_cache.put(rt, (fp, full), wire.payload_nbytes(full))
        return len(stash)

    def _commit_decide(self, payload: Any) -> Dict[str, Any]:
        """The coordinator's explicit verdict on the unconfirmed
        prepared round: ``commit=True`` finalizes it (drop the stash),
        ``commit=False`` deterministically aborts it (restore the
        pre-round replica states).  ``revoke`` lists rtypes whose
        ownership lease is withdrawn (handoff fence / adoption after a
        presumed loss) — a later ``plan_commit`` asserting the revoked
        epoch gets a typed ``stale_epoch`` refusal."""
        req = wire.expect(payload, "commit_decide")
        restored = 0
        if bool(req.get("commit", False)):
            self._stash = None
        else:
            restored = self._restore_stash()
        for rt in req.get("revoke", []):
            self._leases.pop(str(rt), None)
        return wire.envelope(
            "commit_decide_response",
            {"restored": restored, "leases": len(self._leases)},
        )

    def _plan_commit(self, payload: Any, parse_s: float = 0.0) -> Dict[str, Any]:
        """One fused plan+commit round — the two-phase exchange's
        *prepare*.  The worker validates its ownership leases (epoch
        assertions fail typed BEFORE any mutation), stashes the
        pre-round replica states, then runs up to ``max_passes``
        dependent fixpoint passes entirely locally: plan the dirty
        partitions (same plan core), commit each pass's intents against
        the **authoritative resident replicas** in global sorted
        partition order through the same shared commit core the
        client-serial engine uses (:func:`repro.core.shards.
        commit_decision`), re-dirty via the shared classification, and
        feed the next pass.  Conflicts are resolved worker-side: a
        refused intent rolls back through ``release_unlaunched`` and
        its partition stays queued — exactly the client-serial rail.
        The response is the *ack*: per-pass plans + committed outcomes
        plus the post-commit replica fingerprints the coordinator
        verifies its replay against."""
        req = wire.expect(payload, "plan_commit")
        commit_req = req.get("commit") or {}

        # 1) settle the previous round's stash: an explicit confirm
        # finalizes it; any new frame without one means the coordinator
        # never adopted that round — deterministic implicit abort.
        if commit_req.get("confirm"):
            self._stash = None
        elif self._stash is not None:
            self._restore_stash()

        # 2) ownership leases — validated before ANY replica mutation,
        # so a stale-epoch worker (restart amnesia, fenced handoff) can
        # never double-launch: it refuses typed and the coordinator
        # re-grants.
        stale: List[str] = []
        for node in commit_req.get("leases", []):
            rt, epoch, fresh, _fp = wire.decode_lease(node)
            if fresh:
                self._leases[rt] = epoch
            elif self._leases.get(rt) != epoch:
                stale.append(rt)
        if stale:
            raise ProtocolStateError(
                "stale_epoch",
                f"{len(stale)} ownership lease(s) stale or not held",
                rtypes=sorted(stale),
            )

        # 3) shared decode preamble (same rails as plan_request)
        ctx = self._decode_plan_request(req)
        resident = ctx["resident"]
        now = ctx["now"]
        shard = ctx["shard"]
        t_codec_extra = 0.0

        # 4) stash pre-round state for the abort rail
        self._stash = {rt: (fp, full) for rt, (fp, full, _m) in resident.items()}
        replicas = {rt: m for rt, (_fp, _full, m) in resident.items()}

        max_passes = max(1, int(commit_req.get("max_passes", 1)))
        tick = float(commit_req.get("tick", 0.0005))
        history = getattr(self._policy, "history", None)
        waiting = {p: list(acts) for p, acts in ctx["waiting_by_part"].items()}
        exec_view = list(ctx["executing"])

        passes_out: List[Dict[str, Any]] = []
        plan_s_total = 0.0
        commit_s_total = 0.0
        # pass 1 plans every partition the frame carried (empty ones
        # included — the coordinator's replay needs their plans for the
        # same watch-list bookkeeping the client-serial path performs);
        # later passes re-plan only the re-dirtied set
        keys = sorted(waiting)
        for _pass in range(max_passes):
            if not keys:
                break
            t_plan = time.perf_counter()
            plan_view: Dict[str, Any] = {}
            for rt, m in replicas.items():
                plan_view[rt] = m.snapshot() if type(m).plan_mutates else m
            plans = [
                plan_partition(
                    part,
                    waiting[part],
                    exec_view,
                    plan_view,
                    self._policy,
                    self._fair_share,
                    now,
                    ctx["incremental"],
                    shard=shard,
                )
                for part in keys
            ]
            plan_s_total += time.perf_counter() - t_plan

            t_commit = time.perf_counter()
            outcomes: List[Dict[str, Any]] = []
            next_keys: List[str] = []
            for plan in plans:  # keys sorted -> global sorted commit order
                part = plan.part
                acts = waiting.get(part, [])
                launched_rows: List[Tuple[int, Dict[str, int]]] = []
                failed = 0
                if plan.planned and acts and plan.result is not None:
                    quota_pending = quota_reservations(
                        plan.result.decisions, replicas, self._fair_share
                    )
                    launched_uids = set()
                    for decision in plan.result.decisions:
                        granted = commit_decision(
                            decision, replicas, self._fair_share, quota_pending
                        )
                        if granted is None:
                            failed += 1
                            continue
                        units, allocs = granted
                        a = decision.action
                        overhead = tick + sum(al.overhead for al in allocs)
                        key_units = units.get(a.key_resource or "", None)
                        dur = duration_of(a, key_units, history)
                        # the launched action joins the next pass's
                        # executing view as a CLONE — interned Actions
                        # are shared across rounds and must never be
                        # mutated worker-side
                        exec_view.append(
                            wire.patch_action(
                                a,
                                {
                                    "state": ActionState.RUNNING.value,
                                    "start_time": now,
                                    "finish_time": now + overhead + dur,
                                    "sys_overhead": overhead,
                                },
                            )
                        )
                        launched_uids.add(a.uid)
                        launched_rows.append((a.uid, units))
                    if launched_uids:
                        waiting[part] = acts = [
                            x for x in acts if x.uid not in launched_uids
                        ]
                evicted = 0 if plan.result is None else plan.result.evicted
                cls = classify_after_commit(
                    _WaitingView(acts), evicted, failed, plan.held, replicas
                )
                if cls == "dirty":
                    next_keys.append(part)
                outcomes.append(
                    wire.encode_commit_outcome(part, launched_rows, failed, plan.held)
                )
            commit_s_total += time.perf_counter() - t_commit

            t_enc = time.perf_counter()
            passes_out.append(
                {
                    "plans": [wire.encode_plan(p) for p in plans],
                    "outcomes": outcomes,
                }
            )
            t_codec_extra += time.perf_counter() - t_enc
            keys = next_keys

        # 5) post-commit fingerprints: the resident replicas now embody
        # the committed state; re-key them (and the delta bases) so the
        # next round's refs/deltas match WITHOUT re-shipping the state —
        # the whole point of worker-owned commit.  The fp computation is
        # worker commit cost and is billed as such.
        t_fp = time.perf_counter()
        fps: Dict[str, str] = {}
        for rt, m in replicas.items():
            full = wire.encode_snapshot(m)
            fp = wire.fingerprint(full)
            self._resident[rt] = (fp, m)
            self._snap_cache.put(rt, (fp, full), wire.payload_nbytes(full))
            fps[rt] = fp
        commit_s_total += time.perf_counter() - t_fp

        codec_s = (
            ctx["codec_s"] + parse_s + self._carry_dump_s + t_codec_extra
        )
        self._carry_dump_s = 0.0
        body = {
            "shard": shard,
            "passes": passes_out,
            "more": bool(keys),
            "fps": fps,
            "plan_s": plan_s_total,
            "commit_s": commit_s_total,
            "codec_s": codec_s,
            "cache": self._stats,
        }
        return wire.envelope("plan_commit_response", body)


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------


class ShardTransport:
    """Byte-boundary to one shard worker.

    The contract is a single in-flight request per transport:
    ``submit(request)`` hands the worker a byte frame, ``recv()``
    blocks for its response.  The client overlaps workers by submitting
    to all transports before receiving from any.  Implementations move
    opaque byte frames only — never pickled objects — so an RPC
    transport can slot in without touching the protocol."""

    def submit(self, request: bytes) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def recv(self) -> bytes:  # pragma: no cover - interface
        raise NotImplementedError

    def close(self) -> None:  # pragma: no cover - interface
        pass

    def __enter__(self) -> "ShardTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @staticmethod
    def _as_bytes(request) -> bytes:
        """Coerce a str frame to UTF-8 (JSON text is a legal frame)."""
        return request.encode("utf-8") if isinstance(request, str) else request


class LoopbackTransport(ShardTransport):
    """In-process worker behind the full wire codec path.

    Every request and response crosses :func:`repro.core.wire.
    encode_frame` / :func:`~repro.core.wire.decode_frame` exactly as
    over a real transport — loopback proves plan-over-wire fidelity
    (and measures serialization cost) deterministically, without
    process scheduling noise.  The worker computes during
    :meth:`submit`; :meth:`recv` just returns."""

    def __init__(self) -> None:
        self._worker = RemoteShardWorker()
        self._response: Optional[bytes] = None

    def submit(self, request: bytes) -> None:
        self._response = self._worker.handle_bytes(self._as_bytes(request))

    def recv(self) -> bytes:
        resp, self._response = self._response, None
        if resp is None:
            raise RuntimeError("recv() without a submitted request")
        return resp


def _worker_main(conn) -> None:
    """Entry point of a :class:`ProcessTransport` worker process: serve
    plan requests off the pipe until the empty shutdown frame (or EOF).
    Module-level so it is importable under any multiprocessing start
    method (spawn pickles the callable by reference, never by value)."""
    worker = RemoteShardWorker()
    while True:
        try:
            blob = conn.recv_bytes()
        except (EOFError, OSError):
            break
        if not blob:
            break
        conn.send_bytes(worker.handle_bytes(blob))
    conn.close()


#: Every live ProcessTransport, swept at interpreter exit: a transport
#: abandoned without close() (test failure paths, leaked orchestrators)
#: must not leave worker processes behind.  Daemonic workers die with
#: the parent anyway, but only at hard exit — the sweep (and __del__)
#: reaps them as soon as the transport is collected or atexit runs.
_LIVE_PROCESS_TRANSPORTS: "weakref.WeakSet[ProcessTransport]" = weakref.WeakSet()


def _sweep_process_transports() -> None:  # pragma: no cover - atexit path
    for t in list(_LIVE_PROCESS_TRANSPORTS):
        try:
            t.close()
        except Exception:  # noqa: BLE001 - exit path, best effort
            pass


atexit.register(_sweep_process_transports)


class ProcessTransport(ShardTransport):
    """A shard worker in a separate OS process over a multiprocessing
    pipe.  Frames are opaque bytes (``send_bytes``/``recv_bytes`` — no
    object pickling); an empty frame is the shutdown signal (a real
    frame is never empty: JSON text has at least one byte and binary
    frames start with the magic byte).  Workers are daemonic: they can
    never outlive the orchestrator — and they do not linger either:
    ``close()`` is idempotent, runs from ``__del__`` when a transport
    is garbage-collected unclosed, and an atexit sweep reaps any still
    alive at interpreter exit.  A dead worker (killed process, broken
    pipe) surfaces as :class:`~repro.core.wire.TransportError`
    (``"reset"``) so the round client's loss-fallback rail handles it
    like any other carrier."""

    def __init__(self, start_method: Optional[str] = None) -> None:
        import multiprocessing as mp

        if start_method is None:
            start_method = (
                "fork" if "fork" in mp.get_all_start_methods() else "spawn"
            )
        ctx = mp.get_context(start_method)
        self._closed = False
        self._conn, child = ctx.Pipe()
        self._proc = ctx.Process(target=_worker_main, args=(child,), daemon=True)
        self._proc.start()
        child.close()
        _LIVE_PROCESS_TRANSPORTS.add(self)

    def submit(self, request: bytes) -> None:
        try:
            self._conn.send_bytes(self._as_bytes(request))
        except (OSError, ValueError) as e:
            raise wire.TransportError(
                "reset", f"shard worker pipe broken at submit: {e}"
            ) from None

    def recv(self) -> bytes:
        try:
            return self._conn.recv_bytes()
        except EOFError:
            raise wire.TransportError(
                "truncated_frame", "shard worker died holding the request"
            ) from None
        except (OSError, ValueError) as e:
            raise wire.TransportError(
                "reset", f"shard worker pipe broken at recv: {e}"
            ) from None

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        _LIVE_PROCESS_TRANSPORTS.discard(self)
        try:
            self._conn.send_bytes(b"")
        except (OSError, ValueError):
            pass
        try:
            self._conn.close()
        except (OSError, ValueError):
            pass
        self._proc.join(timeout=5)
        if self._proc.is_alive():  # pragma: no cover - defensive
            self._proc.terminate()

    def __del__(self) -> None:
        try:
            self.close()
        except Exception:  # noqa: BLE001 - interpreter teardown
            pass


_TRANSPORTS = {"loopback": LoopbackTransport, "process": ProcessTransport}


def _per_shard(factory: Callable) -> Callable[[int], "ShardTransport"]:
    """Normalize a transport callable to ``shard_idx -> transport``.

    Fleet factories (:func:`repro.core.transport.socket_fleet`) take
    the shard index; plain transport classes and zero-argument
    factories (``LoopbackTransport``, test doubles) do not — probe the
    signature once and wrap the latter so each shard still gets its
    own instance."""
    try:
        inspect.signature(factory).bind(0)
    except TypeError:
        return lambda shard_idx: factory()
    except ValueError:  # uninspectable (C callable): assume new-style
        pass
    return factory


# ---------------------------------------------------------------------------
# the orchestrator side
# ---------------------------------------------------------------------------


def _nk(x: Any) -> Any:
    """NaN-stable cache-key atom (NaN != NaN would defeat every hit)."""
    return None if isinstance(x, float) and math.isnan(x) else x


class _ActEnc:
    """One action's cached wire identity.

    The fingerprint and byte estimate are computed from the mutable-field
    key alone; the full envelope (``payload``) is materialized lazily —
    only when some worker actually needs a full define.  ``prev_fp`` /
    ``patch`` remember the previous version of this uid and the field
    diff against it, so a lifecycle transition can travel as a
    patch-define to any worker still holding the old version."""

    __slots__ = ("key", "fp", "nbytes", "action", "payload", "prev_fp", "patch")

    def __init__(
        self,
        key: tuple,
        fp: str,
        nbytes: int,
        action: Action,
        prev_fp: Optional[str],
        patch: Optional[Dict[str, Any]],
    ) -> None:
        self.key = key
        self.fp = fp
        self.nbytes = nbytes
        self.action = action
        self.payload: Optional[Dict[str, Any]] = None
        self.prev_fp = prev_fp
        self.patch = patch


class RemoteRoundClient:
    """Drives one remote plan phase per sharded round.

    Owns one transport (one worker) per shard index, created lazily.
    Per worker it tracks the fingerprints of the policy config, fairness
    config, duration history, and each manager snapshot it last sent —
    unchanged payloads travel as ``{"ref": fp}``, changed snapshots as
    structural :func:`~repro.core.wire.encode_snapshot_delta` diffs
    against the worker's cached base — plus a deterministic mirror of
    the worker's intern table, so repeated action payloads travel as
    ``{"iref": fp}`` references and mutated ones as patch-defines
    against the version the worker still holds.  Encoded action
    payloads are cached across rounds keyed on the mutable field tuple,
    so an unchanged action costs neither encode CPU nor wire bytes; the
    encoded *byte segments* of full sections are memoized by
    fingerprint and spliced into frames, so even a changed round only
    serializes what actually changed.

    Recovery: a typed worker error in :data:`RECOVERABLE_CODES` (cache
    eviction, worker restart, delta base mismatch) resets that worker's
    sent-state and re-sends its request with full content, exactly
    once per round — counted in ``Telemetry.wire_fallbacks``, never a
    silently wrong plan.

    Worker loss: any :class:`~repro.core.wire.TransportError` (dead
    process, dropped socket, read timeout, truncated frame) marks that
    worker down and plans its partitions **inline** for the round —
    through the same :func:`repro.core.shards.plan_partition` core over
    fresh manager snapshots, so the round's plans (and the launch
    trace) are identical to what the worker would have produced.  The
    failed transport is torn down and rebuilt lazily; reconnection is
    retried with bounded round-based exponential backoff (skip 0, 1,
    3, then at most :data:`MAX_BACKOFF_ROUNDS` rounds between
    attempts), and a worker that answers again is re-primed through
    the existing full-resend + ``reset_interns`` rail.  Losses,
    reconnects, and inline-planned partitions are counted in
    ``Telemetry.wire_worker_losses`` / ``wire_reconnects`` /
    ``wire_inline_parts`` — a loss is never silent and never a lost or
    double launch.

    ``transport`` is either a registered name (``"loopback"`` /
    ``"process"``) or a callable: a ``shard_idx -> ShardTransport``
    factory (e.g. :func:`repro.core.transport.socket_fleet` for a
    multi-host fleet), or a zero-argument factory/transport class —
    each shard still gets its own instance."""

    def __init__(
        self,
        orch: "Orchestrator",
        transport: Union[str, Callable[[int], ShardTransport]] = "loopback",
        codec: str = "json",
    ) -> None:
        if callable(transport):
            self._factory: Callable[[int], ShardTransport] = _per_shard(transport)
            self.transport_kind = getattr(transport, "__name__", "custom")
        else:
            named = _TRANSPORTS.get(transport)
            if named is None:
                raise ValueError(
                    f"unknown transport {transport!r} (have {sorted(_TRANSPORTS)})"
                )
            self._factory = lambda shard_idx: named()
            self.transport_kind = transport
        if codec not in wire.WIRE_CODECS:
            raise ValueError(
                f"unknown wire codec {codec!r} (have {list(wire.WIRE_CODECS)})"
            )
        self.orch = orch
        self.codec = codec
        self._transports: List[Optional[ShardTransport]] = []
        # worker-loss state: shard_idx -> [consecutive_failures,
        # rounds_to_skip]; presence marks the worker down (next
        # successful round-trip clears it and counts a reconnect)
        self._down: Dict[int, List[int]] = {}
        # workers whose next request must carry reset_interns (their
        # mirror was cleared after a loss; the worker we reach next —
        # fresh or survivor — must drop its table to stay in sync)
        self._need_intern_reset: set = set()
        self._sent: List[Dict[str, Any]] = []  # per-worker fingerprint state
        self._mirrors: List[wire.LruBytes] = []  # per-worker intern mirrors
        # client-side delta bases: rtype -> (fp, full snapshot envelope)
        self._prev_snaps: Dict[str, Tuple[str, Dict[str, Any]]] = {}
        # uid -> _ActEnc: re-encoding an unchanged action is pure waste
        # — skip it entirely (payload materialized lazily, see _ActEnc)
        self._act_cache: Dict[int, _ActEnc] = {}
        # fingerprint-keyed pre-encoded byte segments ("a:"/"s:"/"p:"/
        # "f:"/"h:" + fp), spliced into request frames instead of
        # re-serializing the payload tree; governed by the same byte
        # budget as every other wire cache
        self._segments = wire.LruBytes(CACHE_BUDGET_BYTES)
        # per-round encode-memo consultations (act cache, queue cache,
        # segment cache) — flushed to Telemetry after each round
        self._memo_hits = 0
        self._memo_misses = 0
        # last scheduling instant a wire round was accounted at: frames
        # for the same instant merge into one accounting round
        self._last_now: Optional[float] = None
        # slot -> (payload, fp): policy/fairness/history digest memo
        self._shared_cache: Dict[str, Tuple[Any, str]] = {}
        # uid -> frozenset of managed rtypes its cost touches (immutable
        # per action) — drives the per-shard executing subset
        self._act_rsets: Dict[int, frozenset] = {}
        # part -> (queue.version, {uid: action}, enc, fps, list fp,
        # rtypes, {uid: queue tag}): whole-partition encoded view,
        # exact while the version holds; on a version change, members
        # with surviving tags reuse their encodings (see plan_round)
        self._queue_cache: Dict[str, tuple] = {}
        # uids seen executing last round: a member of two consecutive
        # executing sets was not mutated in between (transitions always
        # move an action out of the set for at least one round)
        self._exec_prev_uids: set = set()

    # ------------------------------------------------------------------
    def close(self) -> None:
        # flush each worker's carried response-dump cost before closing:
        # the LAST plan response's encode was timed but never reported
        # (it rides the NEXT response by design) — a drain round-trip
        # folds that tail into the telemetry so a finished run's wire
        # bill is complete.  A worker that cannot answer (already dead,
        # mid-restart test transport) just loses its tail.
        tel = getattr(self.orch, "telemetry", None)
        for t in self._transports:
            if t is None:  # down worker: nothing to drain or close
                continue
            try:
                blob = wire.encode_frame(wire.envelope("drain", {}), self.codec)
                t.submit(blob)
                resp = t.recv()
                payload = wire.decode_frame(resp)
                if (
                    tel is not None
                    and isinstance(payload, dict)
                    and payload.get("kind") == "drain_response"
                ):
                    tel.wire_worker_codec_s += float(payload.get("codec_s", 0.0))
                    tel.wire_bytes += len(blob) + len(resp)
                    tel.wire_frames += 1
            except Exception:  # noqa: BLE001 - best-effort flush
                pass
            t.close()
        self._transports.clear()
        self._sent.clear()
        self._mirrors.clear()
        self._prev_snaps.clear()
        self._act_cache.clear()
        self._shared_cache.clear()
        self._queue_cache.clear()
        self._exec_prev_uids.clear()
        self._act_rsets.clear()
        self._segments.clear()
        self._last_now = None
        self._down.clear()
        self._need_intern_reset.clear()

    def _ensure_slots(self, n: int) -> None:
        while len(self._transports) < n:
            self._transports.append(None)
            self._sent.append({"snaps": {}})
            self._mirrors.append(wire.LruBytes(CACHE_BUDGET_BYTES))

    def _transport(self, i: int) -> ShardTransport:
        self._ensure_slots(i + 1)
        t = self._transports[i]
        if t is None:
            t = self._transports[i] = self._factory(i)
        return t

    def _reset_worker(self, i: int) -> None:
        """Forget everything we believe worker ``i`` holds; the next
        request built for it carries full content (and tells the worker
        to drop its intern table so the mirror restarts in sync)."""
        self._sent[i] = {"snaps": {}}
        self._mirrors[i].clear()

    # -- worker-loss rail ----------------------------------------------
    def _note_worker_loss(self, i: int) -> None:
        """Record a transport failure on worker ``i``: tear the
        transport down (rebuilt lazily on the next attempt), reset the
        client's view of the worker (mirror/sent state may have been
        mutated mid-encode), and advance the round-based backoff."""
        self.orch.telemetry.wire_worker_losses += 1
        t = None
        if i < len(self._transports):
            t, self._transports[i] = self._transports[i], None
        if t is not None:
            try:
                t.close()
            except Exception:  # noqa: BLE001 - already failing
                pass
        self._reset_worker(i)
        self._need_intern_reset.add(i)
        state = self._down.get(i)
        if state is None:
            self._down[i] = [1, 0]  # retry on the very next round
        else:
            state[0] += 1
            state[1] = min(2 ** (state[0] - 1) - 1, MAX_BACKOFF_ROUNDS)

    def _skip_down_worker(self, i: int) -> bool:
        """True when worker ``i`` is in a backoff window this round (the
        skip counter is consumed; at zero the caller attempts the
        normal path — that attempt IS the reconnect probe)."""
        state = self._down.get(i)
        if state is None or state[1] <= 0:
            return False
        state[1] -= 1
        return True

    def _note_worker_ok(self, i: int) -> None:
        """A full round-trip succeeded: clear loss state (counting a
        reconnect if the worker had been down) and the pending
        intern-reset flag."""
        self._need_intern_reset.discard(i)
        if self._down.pop(i, None) is not None:
            self.orch.telemetry.wire_reconnects += 1

    def _plan_inline(
        self, shard_idx: int, parts_enc: Sequence[tuple]
    ) -> Tuple[List[PartitionPlan], float]:
        """Plan a lost worker's partitions locally — the loss-fallback
        rail.  Runs the identical plan core over fresh manager
        snapshots (exactly what an in-process shard does), so the plans
        this round commits are the ones the worker would have returned:
        worker loss costs local plan CPU, never trace divergence."""
        orch = self.orch
        t0 = time.perf_counter()
        snapshots = SnapshotMap(orch.managers)
        plans = [
            orch._plan_partition(entry[0], snapshots, shard=shard_idx)
            for entry in parts_enc
        ]
        plan_s = time.perf_counter() - t0
        orch.telemetry.wire_inline_parts += len(plans)
        orch.telemetry.note_shard_round(shard_idx, len(plans), plan_s)
        return plans, plan_s

    # ------------------------------------------------------------------
    def _segment(self, skey: str, payload: Any) -> wire.Encoded:
        """The pre-encoded byte segment for a fingerprint-keyed payload:
        encoded at most once per content version, then spliced verbatim
        into every frame that carries it (all workers this round, every
        later full re-send while it lives in the budget)."""
        seg = self._segments.get(skey)
        if seg is not None:
            self._memo_hits += 1
            return seg
        self._memo_misses += 1
        seg = wire.encode_segment(payload, self.codec)
        self._segments.put(skey, seg, len(seg))
        return seg

    def _encode_action_cached(self, a: Action) -> _ActEnc:
        """The cached wire identity of one action, re-keyed only when a
        mutable field changed since the cached round.  Truly immutable
        fields (elasticity, ids) never re-key; the scalar metadata
        slice does, because planning reads it — and so does the cost
        *targeting* (rtype set + key_resource), because ``migrate_task``
        retargets those in place and a stale-cost reference would plan
        a migrated action against its pre-handoff pool.  A re-key
        computes the *field diff* against the previous version — the
        payload a patch-define ships — and defers the full envelope
        until some worker needs one; a retarget re-key forces a full
        define instead (the patch schema does not carry cost).
        Counting: an unchanged key is a memo hit, a re-key or a first
        sighting is a miss."""
        meta = a.metadata
        mkey: tuple = ()
        if meta:
            pairs = [
                (k, _nk(v))
                for k, v in meta.items()
                if not k.startswith("_") and isinstance(v, wire._SCALARS)
            ]
            if pairs:
                pairs.sort()
                mkey = tuple(pairs)
        key = (
            a.state.value,
            a.attempts,
            _nk(a.submit_time),
            _nk(a.start_time),
            _nk(a.finish_time),
            a.sys_overhead,
            mkey,
            (a.key_resource, tuple(sorted(a.cost))),
        )
        hit = self._act_cache.get(a.uid)
        if hit is not None and hit.key == key:
            self._memo_hits += 1
            return hit
        self._memo_misses += 1
        prev_fp: Optional[str] = None
        patch: Optional[Dict[str, Any]] = None
        if hit is not None:
            prev_fp = hit.fp
            patch = {}
            old = hit.key
            if old[0] != key[0]:
                patch["state"] = a.state.value
            if old[1] != key[1]:
                patch["attempts"] = a.attempts
            for i, field in (
                (2, "submit_time"),
                (3, "start_time"),
                (4, "finish_time"),
                (5, "sys_overhead"),
            ):
                if old[i] != key[i]:
                    patch[field] = getattr(a, field)
            if old[6] != mkey:
                patch["metadata"] = wire._wire_metadata(meta)
            if old[7] != key[7]:
                # a migration retargeted the cost vector: the patch
                # schema has no cost field, so ship a full define
                patch = None
        # identity hashes the uid plus the mutable-field key: immutable
        # fields can never differ for a uid, so this is exactly as
        # collision-free as hashing the whole payload at a fraction of
        # the canonicalization cost (uids are process-unique, and a
        # fresh client re-DEFINES everything it sends, so a warm worker
        # table can never alias a previous client's entries)
        fp = wire.fingerprint(["act", a.uid, key])
        # schema-based size estimate for intern byte budgeting — the
        # define ships it ("n"), so both tables account identically
        # without a serialization pass per encode
        nbytes = 300 + 60 * len(a.cost) + 24 * len(mkey)
        for s in (a.name, a.task_id, a.trajectory_id, a.key_resource, a.service):
            if isinstance(s, str):
                nbytes += len(s)
        enc = _ActEnc(key, fp, nbytes, a, prev_fp, patch)
        self._act_cache[a.uid] = enc
        return enc

    def _wire_action(self, mirror: wire.LruBytes, enc: _ActEnc) -> Any:
        """Intern decision for one action on one worker: a reference if
        the mirror says the worker holds this version, a patch-define if
        it holds the immediately-previous version, a full define (as a
        memoized byte segment) otherwise.  Mirror touches replicate the
        worker's table touches in the same order with the same byte
        accounting, so evictions match — a miss probe does not reorder
        either table."""
        if mirror.get(enc.fp) is not None:
            return wire.intern_ref(enc.fp)
        if (
            enc.patch is not None
            and enc.prev_fp is not None
            and mirror.get(enc.prev_fp) is not None
        ):
            mirror.put(enc.fp, True, enc.nbytes)
            return wire.intern_patch(enc.fp, enc.prev_fp, enc.patch, enc.nbytes)
        mirror.put(enc.fp, True, enc.nbytes)
        if enc.payload is None:
            enc.payload = wire.encode_action(enc.action)
        return self._segment(
            "a:" + enc.fp, wire.intern_def(enc.fp, enc.payload, enc.nbytes)
        )

    def _wire_list(
        self,
        mirror: wire.LruBytes,
        prev: Optional[Tuple[str, List[str]]],
        enc: List[_ActEnc],
        fps: List[str],
        lfp: str,
    ) -> Dict[str, Any]:
        """One action list as the cheapest wire form the worker can
        reconstruct: a bare reference when unchanged since last send, a
        removals-plus-positional-inserts delta when the kept members'
        relative order survived (always true for tag-ordered queues —
        tags are fixed at admission — and for the dict-ordered executing
        set), else the full list.  ``prev`` is (list fp, member fps)
        from the last send to this worker."""
        if prev is not None and prev[0] == lfp:
            return {"k": "ref", "fp": lfp}
        if prev is not None:
            prev_fps = prev[1]
            cur_set = set(fps)
            prev_set = set(prev_fps)
            kept = [f for f in prev_fps if f in cur_set]
            ins: List[Tuple[int, _ActEnc]] = []
            ki, ok = 0, True
            for i, e in enumerate(enc):
                f = e.fp
                if ki < len(kept) and f == kept[ki]:
                    ki += 1
                elif f not in prev_set:
                    ins.append((i, e))
                else:
                    ok = False  # kept members reordered — delta can't say it
                    break
            if ok and ki == len(kept):
                return {
                    "k": "delta",
                    "base": prev[0],
                    "fp": lfp,
                    "rm": [f for f in prev_fps if f not in cur_set],
                    "ins": [[i, self._wire_action(mirror, e)] for i, e in ins],
                }
        return {
            "k": "full",
            "fp": lfp,
            "items": [self._wire_action(mirror, e) for e in enc],
        }

    # ------------------------------------------------------------------
    def plan_round(
        self, groups: Sequence[Sequence[str]]
    ) -> Tuple[List[PartitionPlan], float]:
        """Plan every shard's partitions on its worker; returns the
        decoded plans (decisions re-bound to live actions) plus the
        round's critical-path plan cost: the max worker-measured plan
        time.  Dispatch is pipelined — every request is submitted before
        any response is awaited, so worker compute overlaps."""
        orch = self.orch
        telemetry = orch.telemetry
        # worker startup (process fork/spawn, socket objects) happens
        # here, outside the serialization accounting — a deployment cost
        # paid once, not a per-round wire cost.  Workers in a backoff
        # window keep their slot but get no transport built.
        self._ensure_slots(len(groups))
        for shard_idx in range(len(groups)):
            state = self._down.get(shard_idx)
            if state is None or state[1] <= 0:
                self._transport(shard_idx)
        t_round = time.perf_counter()

        # ---- encode phase (client-side serialization cost) ------------
        ctx = self._encode_round(groups)
        plans: List[PartitionPlan] = ctx["plans"]
        by_uid: Dict[int, Action] = ctx["by_uid"]
        shard_parts = ctx["shard_parts"]
        executing_enc = ctx["executing_enc"]
        exec_rsets = ctx["exec_rsets"]
        seen_uids = ctx["seen_uids"]
        shared = ctx["shared"]
        encode_s = ctx["encode_s"]
        nbytes = 0

        # ---- pipelined dispatch (encode shard i+1 while i is in
        # flight) -------------------------------------------------------
        # each request is submitted the moment its frame exists, so a
        # process-backed worker parses and plans shard i while the
        # client is still encoding shard i+1 — only the HEAD request's
        # encode is inherently serial with worker compute.  encode_s
        # stays the pure-encode sum and transport_s the submit+recv
        # wall sum, so the components remain comparable with the
        # serialized model; the overlap-aware critical path is reported
        # separately (overlap_s).
        requests: List[Tuple[int, Any, Any]] = []
        # workers lost this round (transport failure at any point) —
        # their partitions fall back to inline planning below
        lost: List[Tuple[int, Any]] = []
        transport_s = 0.0
        e_head = 0.0
        for shard_idx, parts_enc, rtypes in shard_parts:
            if self._skip_down_worker(shard_idx):
                lost.append((shard_idx, parts_enc))
                continue
            t0 = time.perf_counter()
            exec_sub = self._exec_subset(ctx, rtypes)
            blob = wire.encode_frame(
                self._request(
                    shard_idx, parts_enc, rtypes, exec_sub, shared,
                    reset_interns=shard_idx in self._need_intern_reset,
                ),
                self.codec,
            )
            t1 = time.perf_counter()
            encode_s += t1 - t0
            if not requests:
                e_head = t1 - t0
            nbytes += len(blob)
            try:
                self._transport(shard_idx).submit(blob)
            except wire.TransportError:
                transport_s += time.perf_counter() - t1
                self._note_worker_loss(shard_idx)
                lost.append((shard_idx, parts_enc))
                continue
            transport_s += time.perf_counter() - t1
            requests.append((shard_idx, (parts_enc, exec_sub), rtypes))
        # drop encode-cache entries for actions that left the system —
        # everything alive was just seen, so this is exact (runs while
        # the workers compute, off any per-request path)
        encode_s += self._prune_caches(seen_uids)

        # ---- gather (in submit order) ---------------------------------
        responses: List[Tuple[int, Any, Any, bytes]] = []
        for shard_idx, rctx, rtypes in requests:
            t0 = time.perf_counter()
            try:
                blob = self._transport(shard_idx).recv()
            except wire.TransportError:
                transport_s += time.perf_counter() - t0
                self._note_worker_loss(shard_idx)
                lost.append((shard_idx, rctx[0]))
                continue
            transport_s += time.perf_counter() - t0
            responses.append((shard_idx, rctx, rtypes, blob))

        # ---- decode phase (client-side cost; worker codec separate) ---
        t_dec = time.perf_counter()
        critical = 0.0
        decode_s = 0.0
        worker_codec_s = 0.0
        max_codec = 0.0
        for shard_idx, rctx, rtypes, blob in responses:
            nbytes += len(blob)
            payload = wire.decode_frame(blob)
            if isinstance(payload, dict) and payload.get("kind") == "error":
                parts_enc, exec_sub = rctx
                try:
                    payload, extra = self._recover(
                        shard_idx, payload, parts_enc, rtypes, exec_sub, shared
                    )
                except wire.TransportError:
                    self._note_worker_loss(shard_idx)
                    lost.append((shard_idx, parts_enc))
                    continue
                nbytes += extra
            resp = wire.expect(payload, "plan_response")
            plan_s = float(resp.get("plan_s", 0.0))
            codec_s = float(resp.get("codec_s", 0.0))
            worker_codec_s += codec_s
            max_codec = max(max_codec, codec_s)
            cache = resp.get("cache")
            if cache:
                telemetry.note_worker_cache(cache)
            shard_plans = [wire.decode_plan(p, by_uid) for p in resp["plans"]]
            critical = max(critical, plan_s)
            telemetry.note_shard_round(shard_idx, len(shard_plans), plan_s)
            plans.extend(shard_plans)
            self._note_worker_ok(shard_idx)
        decode_s += time.perf_counter() - t_dec

        # ---- loss fallback: plan lost workers' partitions inline ------
        # (same plan core over fresh snapshots — identical plans, so the
        # launch trace cannot diverge; the local plan cost is charged to
        # the round's critical path, where it actually ran)
        for shard_idx, parts_enc in lost:
            shard_plans, plan_s = self._plan_inline(shard_idx, parts_enc)
            critical = max(critical, plan_s)
            plans.extend(shard_plans)

        telemetry.plan_critical_s += critical
        telemetry.plan_wall_s += time.perf_counter() - t_round
        # overlap-aware wire critical path of this round: only the head
        # request's encode is serial with worker compute, the slowest
        # worker's codec bill gates the last response, and the client
        # decode tail is serial again.  Frames fired at the SAME
        # scheduling instant (multi-pass rounds coalesced by the round
        # engine) merge into the previous accounting round.
        overlap_s = e_head + max_codec + decode_s
        new_round = self._last_now is None or orch.now != self._last_now
        self._last_now = orch.now
        telemetry.note_wire_round(
            encode_s,
            transport_s,
            decode_s,
            nbytes,
            worker_codec_s,
            overlap_s=overlap_s,
            frames=len(requests),
            new_round=new_round,
        )
        telemetry.note_wire_memo(self._memo_hits, self._memo_misses)
        self._memo_hits = 0
        self._memo_misses = 0
        return plans, critical

    def _encode_round(self, groups: Sequence[Sequence[str]]) -> Dict[str, Any]:
        """The round's encode phase, shared by the plan-only path
        (:meth:`plan_round`) and the worker-owned fused plan+commit path
        (:class:`WorkerCommitEngine`): memo-encode the executing set and
        every non-empty partition queue, group them per shard, and
        encode the shard-independent payloads once.  Returns the round
        context — empty partitions come back as ``planned=False`` plans
        in ``plans`` (resolved client-side, off the wire)."""
        orch = self.orch
        t_enc = time.perf_counter()
        plans: List[PartitionPlan] = []
        by_uid: Dict[int, Action] = {}
        shard_parts: List[Tuple[int, list, set]] = []
        union_rtypes: set = set()
        executing = list(orch._executing.values())
        exec_prev = self._exec_prev_uids
        act_cache = self._act_cache
        rsets = self._act_rsets
        executing_enc: List[_ActEnc] = []
        exec_rsets = []
        for a in executing:
            hit = act_cache.get(a.uid)
            if hit is not None and a.uid in exec_prev:
                # two consecutive executing sets: not mutated in between
                # — skip even the key computation
                self._memo_hits += 1
                executing_enc.append(hit)
            else:
                executing_enc.append(self._encode_action_cached(a))
            rs = rsets.get(a.uid)
            if rs is None:
                rs = frozenset(r for r in a.cost if r in orch.managers)
                rsets[a.uid] = rs
            exec_rsets.append(rs)
        seen_uids = {a.uid for a in executing}
        self._exec_prev_uids = seen_uids.copy()
        nbytes = 0
        for shard_idx, group in enumerate(groups):
            parts_enc: List[Tuple[str, List[_ActEnc], List[str], str]] = []
            rtypes: set = set()
            for part in group:
                queue = orch._queues.get(part)
                if not queue:
                    # nothing to plan — resolved client-side, off the wire
                    plans.append(
                        PartitionPlan(part, planned=False, shard=shard_idx)
                    )
                    continue
                # queue.version gates a whole-partition encode cache:
                # membership mutations bump it, and the plan-then-commit
                # discipline guarantees queued actions only mutate
                # alongside a queue operation (retry = remove + push),
                # so an unchanged version means the encoded view is
                # still exact — the common idle partition costs O(1)
                # instead of O(depth) per round
                cached = self._queue_cache.get(part)
                if cached is not None and cached[0] == queue.version:
                    # section-level memo hit: one consultation covered
                    # the whole partition's encoded view
                    self._memo_hits += 1
                    _, members, enc, fps, lfp, part_rtypes, tags = cached
                else:
                    # version changed: re-enumerate, but re-key only the
                    # members whose queue tag moved — a surviving tag
                    # means the action was never removed/re-pushed, and
                    # queued actions only mutate alongside a queue op,
                    # so its cached encoding is still exact
                    waiting = queue.ordered()
                    prev_tags = cached[6] if cached is not None else {}
                    act_cache = self._act_cache
                    members = {a.uid: a for a in waiting}
                    tag_of = queue.tag_of
                    tags = {uid: tag_of(uid) for uid in members}
                    enc = []
                    for a in waiting:
                        uid = a.uid
                        hit = act_cache.get(uid)
                        if hit is not None and prev_tags.get(uid) == tags[uid]:
                            self._memo_hits += 1
                            enc.append(hit)
                        else:
                            enc.append(self._encode_action_cached(a))
                    fps = [e.fp for e in enc]
                    lfp = wire.list_fingerprint(fps)
                    part_rtypes = frozenset(
                        r for a in waiting for r in a.cost if r in orch.managers
                    )
                    self._queue_cache[part] = (
                        queue.version, members, enc, fps, lfp, part_rtypes, tags,
                    )
                by_uid.update(members)
                seen_uids.update(members)
                rtypes |= part_rtypes
                if part in orch.managers:
                    rtypes.add(part)
                parts_enc.append((part, enc, fps, lfp))
            if parts_enc:
                shard_parts.append((shard_idx, parts_enc, rtypes))
                union_rtypes |= rtypes
        # shard-independent payloads (policy config, fairness, history,
        # manager snapshots + their structural deltas) are encoded +
        # fingerprinted ONCE per round and shared across every worker's
        # request — only the per-worker ref/delta/full decision differs
        shared = self._encode_shared(union_rtypes)
        return {
            "plans": plans,
            "by_uid": by_uid,
            "shard_parts": shard_parts,
            "executing_enc": executing_enc,
            "exec_rsets": exec_rsets,
            "seen_uids": seen_uids,
            "shared": shared,
            "encode_s": time.perf_counter() - t_enc,
        }

    @staticmethod
    def _exec_subset(ctx: Dict[str, Any], rtypes: set) -> Tuple[list, List[str], str]:
        """One worker's executing-set view: only the in-flight actions
        whose cost touches the shard's resource types — planning
        consults the in-flight set strictly through per-rtype filters,
        so the subset plans identically while the fan-out (and the
        define traffic behind it) shrinks by the shard count."""
        sub_enc = [
            e
            for rs, e in zip(ctx["exec_rsets"], ctx["executing_enc"])
            if not rtypes.isdisjoint(rs)
        ]
        sub_fps = [e.fp for e in sub_enc]
        return (sub_enc, sub_fps, wire.list_fingerprint(sub_fps))

    def _prune_caches(self, seen_uids: set) -> float:
        """Drop encode-cache entries for actions that left the system —
        everything alive was just seen, so this is exact (runs while the
        workers compute, off any per-request path).  Returns the wall
        spent, billed to the round's encode phase."""
        t0 = time.perf_counter()
        rsets = self._act_rsets
        if len(self._act_cache) > len(seen_uids):
            for uid in [u for u in self._act_cache if u not in seen_uids]:
                del self._act_cache[uid]
        if len(rsets) > len(seen_uids):
            for uid in [u for u in rsets if u not in seen_uids]:
                del rsets[uid]
        return time.perf_counter() - t0

    # ------------------------------------------------------------------
    def _recover(
        self,
        shard_idx: int,
        error: Dict[str, Any],
        parts_enc: Any,
        rtypes: set,
        exec_sub: Any,
        shared: Dict[str, Any],
    ) -> Tuple[Any, int]:
        """One full-content retry for a recoverable typed error (the
        worker lost cached state: eviction, restart, stale base).  The
        retry's encode/transport cost lands in the decode phase's wall
        — recovery is rare and charged where it happens, not smeared.
        A second failure is a real protocol error and raises."""
        if error.get("code") not in RECOVERABLE_CODES:
            raise RuntimeError(
                f"remote shard worker {shard_idx} failed: {error.get('error')}"
            )
        self.orch.telemetry.wire_fallbacks += 1
        self._reset_worker(shard_idx)
        req = self._request(
            shard_idx, parts_enc, rtypes, exec_sub, shared,
            reset_interns=True,
        )
        blob = wire.encode_frame(req, self.codec)
        t = self._transport(shard_idx)
        t.submit(blob)
        resp = t.recv()
        payload = wire.decode_frame(resp)
        if isinstance(payload, dict) and payload.get("kind") == "error":
            raise RuntimeError(
                f"remote shard worker {shard_idx} failed after full re-send: "
                f"{payload.get('error')}"
            )
        return payload, len(blob) + len(resp)

    # ------------------------------------------------------------------
    def _encode_shared(self, rtypes: set) -> Dict[str, Any]:
        """Encode + fingerprint the shard-independent request inputs
        once per round: the policy / fairness / history configs and one
        snapshot per needed resource type, plus — when the previous
        round's snapshot is known — the structural delta against it.
        ``_request`` then makes the per-worker ref-vs-delta-vs-full call
        against each worker's sent-state."""
        orch = self.orch
        policy_payload = wire.encode_policy(orch.policy)
        fs_payload = wire.encode_fair_share(orch.fair_share)
        hist = getattr(orch.policy, "history", None)
        hist_payload = None if hist is None else {"avg": dict(hist._avg)}
        snaps: Dict[str, Tuple[Dict[str, Any], str, Optional[str], Optional[Dict[str, Any]]]] = {}
        for rtype in sorted(rtypes):
            snap = wire.encode_snapshot(orch.managers[rtype])
            prev = self._prev_snaps.get(rtype)
            prev_fp: Optional[str] = None
            delta: Optional[Dict[str, Any]] = None
            if prev is not None and prev[1] == snap:
                # unchanged since last round: reuse the cached digest
                # instead of re-hashing the whole snapshot (the common
                # case for idle managers dominates fingerprint cost)
                snaps[rtype] = (snap, prev[0], prev[0], None)
                continue
            fp = wire.fingerprint(snap)
            if prev is not None:
                prev_fp = prev[0]
                if prev_fp != fp:
                    delta = wire.encode_snapshot_delta(
                        orch.managers[rtype],
                        prev[1]["state"],
                        snap["state"],
                        prev_fp,
                        fp,
                    )
            self._prev_snaps[rtype] = (fp, snap)
            snaps[rtype] = (snap, fp, prev_fp, delta)
        return {
            "policy": self._shared_fp("policy", policy_payload),
            "fair_share": self._shared_fp("fair_share", fs_payload),
            "history": (
                None
                if hist_payload is None
                else self._shared_fp("history", hist_payload)
            ),
            "snaps": snaps,
        }

    def _shared_fp(self, slot: str, payload: Any) -> Tuple[Any, str]:
        """(payload, fingerprint) with the digest memoized by payload
        equality — policy/fairness/history configs rarely change, so
        re-hashing them every round is pure waste."""
        cached = self._shared_cache.get(slot)
        if cached is not None and cached[0] == payload:
            return cached
        entry = (payload, wire.fingerprint(payload))
        self._shared_cache[slot] = entry
        return entry

    def _request(
        self,
        shard_idx: int,
        parts_enc: List[Tuple[str, List[Tuple[str, Dict[str, Any], int]]]],
        rtypes: set,
        exec_sub: Tuple[List[Tuple[str, Dict[str, Any], int]], List[str], str],
        shared: Dict[str, Any],
        reset_interns: bool = False,
    ) -> Dict[str, Any]:
        """One worker's plan request: unchanged policy/fairness/history
        payloads travel as fingerprint references, snapshots as
        ref/structural-delta/full (cheapest form the worker can
        reconstruct from), and every action as an intern define or
        reference against this worker's mirrored table."""
        orch = self.orch
        sent = self._sent[shard_idx]
        mirror = self._mirrors[shard_idx]

        # full payloads travel as memoized byte segments keyed on the
        # fingerprint delta-suppression already computed — the same
        # content sent to N workers (or re-sent after a fallback) is
        # serialized once and spliced N times.  refs and deltas stay
        # plain: they are tiny and never repeat.
        policy_payload, policy_fp = shared["policy"]
        policy = (
            None
            if sent.get("policy") == policy_fp
            else self._segment("p:" + policy_fp, policy_payload)
        )
        sent["policy"] = policy_fp

        fs_payload, fs_fp = shared["fair_share"]
        fair_share: Any = (
            {"ref": fs_fp}
            if sent.get("fair_share") == fs_fp
            else self._segment("f:" + fs_fp, fs_payload)
        )
        sent["fair_share"] = fs_fp

        history: Any = None
        if shared["history"] is not None:
            hist_payload, hist_fp = shared["history"]
            history = (
                {"ref": hist_fp}
                if sent.get("history") == hist_fp
                else self._segment("h:" + hist_fp, hist_payload)
            )
            sent["history"] = hist_fp

        snapshots: Dict[str, Any] = {}
        for rtype in sorted(rtypes):
            snap, fp, prev_fp, delta = shared["snaps"][rtype]
            sent_fp = sent["snaps"].get(rtype)
            if sent_fp == fp:
                snapshots[rtype] = {"ref": fp}
            elif delta is not None and sent_fp == prev_fp:
                snapshots[rtype] = delta
            else:
                snapshots[rtype] = self._segment("s:" + fp, snap)
            sent["snaps"][rtype] = fp

        # action lists travel as cross-round list deltas (ref / delta /
        # full — see _wire_list).  Intern decisions inside them follow
        # the worker's resolution order (executing first, then
        # partitions in request order) so the mirror's LRU touches line
        # up exactly.
        executing_enc, exec_fps, exec_fp = exec_sub
        executing_wire = self._wire_list(
            mirror, sent.get("exec"), executing_enc, exec_fps, exec_fp
        )
        sent["exec"] = (exec_fp, exec_fps)

        parts = []
        sent_parts: Dict[str, Tuple[str, List[str]]] = sent.setdefault("parts", {})
        for part, enc, fps, lfp in parts_enc:
            node = self._wire_list(mirror, sent_parts.get(part), enc, fps, lfp)
            sent_parts[part] = (lfp, fps)
            parts.append({"part": part, "waiting": node})

        body: Dict[str, Any] = {
            "shard": shard_idx,
            "now": orch.now,
            "incremental": orch.incremental,
            "policy": policy,
            "fair_share": fair_share,
            "history": history,
            "snapshots": snapshots,
            "executing": executing_wire,
            "partitions": parts,
        }
        if reset_interns:
            body["reset_interns"] = True
        return wire.envelope("plan_request", body)


# ---------------------------------------------------------------------------
# the worker-owned commit engine (coordinator side)
# ---------------------------------------------------------------------------

# module-level on purpose: remote -> orchestrator -> shards completes
# without a cycle (neither orchestrator nor shards imports this module
# at module level; the orchestrator constructs the engine lazily)
from repro.core.orchestrator import SCHED_TICK_S, CommitEngine  # noqa: E402


class WorkerCommitEngine(CommitEngine):
    """Two-phase worker-owned commit: each remote worker holds the
    *authoritative* manager replicas for the resource types it owns
    under epoch-stamped ownership leases, and a whole fixpoint pass —
    plan AND commit, up to ``commit_max_passes`` dependent passes — runs
    in one fused ``plan_commit`` exchange per owner worker.

    The exchange is prepare → intent/ack → commit|abort:

    * **prepare** — the ordinary plan request, promoted to a
      ``plan_commit`` frame carrying the round's ownership leases, the
      pass budget, and the previous round's confirm.  The worker
      validates every lease epoch *before* touching a replica (a
      restarted worker's amnesia surfaces as a typed ``stale_epoch``,
      never a double-launch), stashes the pre-round replica states, and
      commits its passes locally on the shared commit core.
    * **ack** — the response: per-pass plans + committed outcomes + the
      post-commit replica fingerprints.  The coordinator *replays* the
      plans through the unchanged client-serial walk
      (``Orchestrator._commit_partition``) in global sorted partition
      order — the launch trace is identical to client-serial **by
      construction**, because it is produced by the same code over the
      same plans — then verifies its post-commit state against the
      worker's fingerprints and cross-checks launched uids against the
      reported outcomes.
    * **commit|abort** — a verified round's confirm rides the next
      fused frame (or an explicit ``commit_decide``); any divergence,
      fence, or un-adopted trailing pass aborts the worker's stash back
      to its pre-round state — the coordinator's replay remains the
      authority, so a worker abort costs wire state, never trace
      damage.

    Rounds the engine cannot own outright decline to the client-serial
    walk (counted in ``commit_inline_rounds``): a partition whose commit
    footprint spans owners, a worker in its loss backoff window, or
    real-latency charging (worker plan walls are not the client's).
    Worker loss mid-prepare rides the ordinary loss rail plus lease
    *adoption*: the coordinator bumps the orphaned epochs and commits
    the partitions inline from fallback plans — zero lost launches, and
    a zombie's late ack can never land."""

    mode = "worker"

    def __init__(self, orch: "Orchestrator", client: RemoteRoundClient) -> None:
        super().__init__(orch)
        self.client = client
        # rtype -> current ownership epoch; bumped on every revocation,
        # regrant, or adoption, so exactly one holder is ever current
        self._epochs: Dict[str, int] = {}
        # shard -> {rtype: epoch} that worker currently holds
        self._granted: Dict[int, Dict[str, int]] = {}
        # shards with a verified-but-unconfirmed prepared round; the
        # confirm rides the next fused frame or a commit_decide flush
        self._pending_confirm: set = set()
        # shard -> leased rtypes of the round currently in flight (the
        # open prepare window a reentrant fence targets)
        self._inflight: Dict[int, frozenset] = {}
        self._fence_aborts: set = set()
        self._deferred_revokes: set = set()
        self._round_open = False
        # part -> (queue.version, footprint rtypes, any duration sampler)
        self._foot_cache: Dict[str, Tuple[int, frozenset, bool]] = {}
        # static ownership map: managed rtypes striped over shards in
        # sorted order — deterministic and derivable by every participant
        self._owner_idx: Dict[str, int] = {
            rt: i for i, rt in enumerate(sorted(orch.managers))
        }

    # -- eligibility ----------------------------------------------------
    def _footprint(self, part: str) -> Tuple[frozenset, bool]:
        """The rtypes committing ``part`` can touch — every queued
        action's managed cost rtypes plus the partition's own manager —
        and whether any queued action carries a host-local duration
        sampler.  Version-gated on the partition queue, so idle
        partitions cost O(1) per round."""
        orch = self.orch
        queue = orch._queues.get(part)
        if not queue:
            return frozenset(), False
        hit = self._foot_cache.get(part)
        if hit is not None and hit[0] == queue.version:
            return hit[1], hit[2]
        managed = orch.managers
        foot = set()
        sampler = False
        for a in queue.ordered():
            if a.duration_sampler is not None:
                sampler = True
            for r in a.cost:
                if r in managed:
                    foot.add(r)
        if part in managed:
            foot.add(part)
        entry = (queue.version, frozenset(foot), sampler)
        self._foot_cache[part] = entry
        return entry[1], entry[2]

    def _decline(self) -> None:
        """Fall back to the ordinary plan_round + client-serial commit
        for this round.  The stash protocol is settled first: a plain
        plan_request never consumes a confirm, and the NEXT fused
        frame's implicit abort must never restore a round the
        coordinator already adopted."""
        self._flush_confirms()
        self.orch.telemetry.commit_inline_rounds += 1
        return None

    def fused_round(self, keys: Sequence[str]) -> Optional[bool]:
        orch = self.orch
        client = self.client
        n = int(orch.shards or 1)
        if orch.charge_real_sched_latency:
            # per-partition plan walls measured on the worker are not
            # the client-serial walls this mode charges — decline
            return self._decline()
        # group each dirty partition under the single worker owning its
        # whole commit footprint; a cross-owner footprint makes the
        # round ineligible (the client-serial walk is the correct rail)
        groups: List[List[str]] = [[] for _ in range(n)]
        lease_rts: List[set] = [set() for _ in range(n)]
        sampler = False
        owner_idx = self._owner_idx
        for part in keys:
            foot, has_sampler = self._footprint(part)
            sampler = sampler or has_sampler
            owners = {owner_idx[rt] % n for rt in foot}
            if len(owners) > 1:
                return self._decline()
            owner = owners.pop() if owners else 0
            groups[owner].append(part)
            lease_rts[owner] |= foot
        # a worker inside its loss-backoff window cannot hold
        # authoritative state this round; the serial walk adopts
        for shard in range(n):
            if groups[shard]:
                state = client._down.get(shard)
                if state is not None and state[1] > 0:
                    return self._decline()
        passes_cap = max(1, int(orch.commit_max_passes))
        if sampler or orch.history is not getattr(orch.policy, "history", None):
            # host-local samplers never cross the wire, and a detached
            # history table would price pass>=2 plans off a different
            # estimate — one pass per wire round is still exact (commit
            # itself never consults durations)
            passes_cap = 1
        self._round_open = True
        try:
            return self._fused(groups, lease_rts, passes_cap)
        finally:
            self._round_open = False
            self._inflight.clear()
            self._fence_aborts.clear()
            if self._deferred_revokes:
                rts, self._deferred_revokes = self._deferred_revokes, set()
                self.fence(sorted(rts))

    # -- the fused round ------------------------------------------------
    def _arm(
        self, req: Dict[str, Any], shard: int, rts: set, passes_cap: int
    ) -> None:
        """Promote one worker's encoded plan request into the fused
        ``plan_commit`` frame: ownership leases for the rtypes this
        round touches (fresh grants where the worker does not hold the
        current epoch), the fixpoint pass budget, the virtual scheduling
        tick launch overhead charges, and the previous prepared round's
        confirm when one is pending."""
        telemetry = self.orch.telemetry
        granted = self._granted.setdefault(shard, {})
        leases = []
        for rt in sorted(rts):
            epoch = self._epochs.setdefault(rt, 0)
            if granted.get(rt) == epoch:
                leases.append(wire.encode_lease(rt, epoch))
            else:
                granted[rt] = epoch
                telemetry.wire_lease_grants += 1
                leases.append(wire.encode_lease(rt, epoch, fresh=True))
        req["kind"] = "plan_commit"
        commit: Dict[str, Any] = {
            "leases": leases,
            "max_passes": passes_cap,
            "tick": SCHED_TICK_S,
        }
        if shard in self._pending_confirm:
            commit["confirm"] = True
            self._pending_confirm.discard(shard)
        req["commit"] = commit

    def _lose(self, shard: int) -> None:
        """Transport loss on a preparing/prepared worker: the ordinary
        loss rail plus ownership *adoption* — every lease the worker
        held is revoked by epoch bump (a zombie's late ack can never
        land) and the round's partitions fall back to inline plans
        committed by the coordinator: orphaned intents are adopted,
        never lost."""
        self.client._note_worker_loss(shard)
        self._pending_confirm.discard(shard)
        self._inflight.pop(shard, None)
        granted = self._granted.pop(shard, None)
        if granted:
            for rt in granted:
                self._epochs[rt] = self._epochs.get(rt, 0) + 1
            self.orch.telemetry.wire_lease_adoptions += len(granted)

    def _abort_worker(self, shard: int) -> None:
        """Explicitly abort a worker's unconfirmed prepared round
        (restores its pre-round replicas) and revoke every lease it
        holds.  Loss during the abort just rides the adoption rail."""
        client = self.client
        self.orch.telemetry.wire_commit_aborts += 1
        granted = self._granted.pop(shard, {})
        for rt in granted:
            self._epochs[rt] = self._epochs.get(rt, 0) + 1
        self._pending_confirm.discard(shard)
        body = {"commit": False, "revoke": sorted(granted)}
        try:
            t = client._transport(shard)
            t.submit(
                wire.encode_frame(wire.envelope("commit_decide", body), client.codec)
            )
            wire.expect(wire.decode_frame(t.recv()), "commit_decide_response")
        except (wire.TransportError, wire.WireError):
            client._note_worker_loss(shard)

    def _recover_fused(
        self,
        shard: int,
        error: Dict[str, Any],
        parts_enc: Any,
        rtypes: set,
        exec_sub: Any,
        shared: Dict[str, Any],
        rts: set,
        passes_cap: int,
    ) -> Tuple[Any, int]:
        """One full-content retry of a fused frame after a recoverable
        typed error.  ``stale_epoch`` is the ownership rail's answer to
        amnesia (restarted worker, fenced handoff): the coordinator
        re-grants every lease fresh at the current epoch alongside the
        full state re-send — the worker never plans or commits on stale
        ownership.  A second failure is a real protocol error."""
        code = error.get("code")
        if code not in RECOVERABLE_CODES:
            raise RuntimeError(
                f"remote shard worker {shard} failed: {error.get('error')}"
            )
        telemetry = self.orch.telemetry
        client = self.client
        if code == "stale_epoch":
            telemetry.wire_lease_regrants += len(error.get("rtypes") or ()) or 1
        else:
            telemetry.wire_fallbacks += 1
        client._reset_worker(shard)
        self._granted.pop(shard, None)  # everything re-grants fresh
        req = client._request(
            shard, parts_enc, rtypes, exec_sub, shared, reset_interns=True
        )
        self._arm(req, shard, rts, passes_cap)
        blob = wire.encode_frame(req, client.codec)
        t = client._transport(shard)
        t.submit(blob)
        resp = t.recv()
        payload = wire.decode_frame(resp)
        if isinstance(payload, dict) and payload.get("kind") == "error":
            raise RuntimeError(
                f"remote shard worker {shard} failed after full re-send: "
                f"{payload.get('error')}"
            )
        return payload, len(blob) + len(resp)

    def _fused(
        self,
        groups: List[List[str]],
        lease_rts: List[set],
        passes_cap: int,
    ) -> bool:
        orch = self.orch
        client = self.client
        telemetry = orch.telemetry
        client._ensure_slots(len(groups))
        for shard in range(len(groups)):
            if groups[shard]:
                client._transport(shard)  # startup outside the accounting
        t_round = time.perf_counter()

        # ---- encode + pipelined dispatch (same rails as plan_round) ---
        ctx = client._encode_round(groups)
        shared = ctx["shared"]
        by_uid = ctx["by_uid"]
        encode_s = ctx["encode_s"]
        nbytes = 0
        requests: List[Tuple[int, Any, Any, set]] = []
        lost: List[Tuple[int, Any]] = []
        transport_s = 0.0
        e_head = 0.0
        for shard, parts_enc, rtypes in ctx["shard_parts"]:
            t0 = time.perf_counter()
            exec_sub = client._exec_subset(ctx, rtypes)
            req = client._request(
                shard, parts_enc, rtypes, exec_sub, shared,
                reset_interns=shard in client._need_intern_reset,
            )
            self._arm(req, shard, lease_rts[shard], passes_cap)
            blob = wire.encode_frame(req, client.codec)
            t1 = time.perf_counter()
            encode_s += t1 - t0
            if not requests:
                e_head = t1 - t0
            nbytes += len(blob)
            try:
                client._transport(shard).submit(blob)
            except wire.TransportError:
                transport_s += time.perf_counter() - t1
                self._lose(shard)
                lost.append((shard, parts_enc))
                continue
            transport_s += time.perf_counter() - t1
            self._inflight[shard] = frozenset(lease_rts[shard])
            requests.append((shard, parts_enc, exec_sub, rtypes))
        encode_s += client._prune_caches(ctx["seen_uids"])

        # ---- gather (in submit order) ---------------------------------
        responses = []
        for shard, parts_enc, exec_sub, rtypes in requests:
            t0 = time.perf_counter()
            try:
                blob = client._transport(shard).recv()
            except wire.TransportError:
                transport_s += time.perf_counter() - t0
                self._lose(shard)
                lost.append((shard, parts_enc))
                continue
            transport_s += time.perf_counter() - t0
            responses.append((shard, parts_enc, exec_sub, rtypes, blob))

        # ---- decode ---------------------------------------------------
        t_dec = time.perf_counter()
        acks: List[Tuple[int, List[List[PartitionPlan]], Dict[str, Any]]] = []
        decode_s = 0.0
        worker_codec_s = 0.0
        max_codec = 0.0
        max_plan = 0.0
        max_commit = 0.0
        for shard, parts_enc, exec_sub, rtypes, blob in responses:
            nbytes += len(blob)
            payload = wire.decode_frame(blob)
            if isinstance(payload, dict) and payload.get("kind") == "error":
                try:
                    payload, extra = self._recover_fused(
                        shard, payload, parts_enc, rtypes, exec_sub, shared,
                        lease_rts[shard], passes_cap,
                    )
                except wire.TransportError:
                    self._lose(shard)
                    lost.append((shard, parts_enc))
                    continue
                nbytes += extra
            resp = wire.expect(payload, "plan_commit_response")
            codec_s = float(resp.get("codec_s", 0.0))
            worker_codec_s += codec_s
            max_codec = max(max_codec, codec_s)
            plan_s = float(resp.get("plan_s", 0.0))
            max_plan = max(max_plan, plan_s)
            max_commit = max(max_commit, float(resp.get("commit_s", 0.0)))
            cache = resp.get("cache")
            if cache:
                telemetry.note_worker_cache(cache)
            passes = [
                [wire.decode_plan(p, by_uid) for p in pas.get("plans", [])]
                for pas in resp.get("passes", [])
            ]
            telemetry.note_shard_round(
                shard, len(passes[0]) if passes else 0, plan_s
            )
            client._note_worker_ok(shard)
            acks.append((shard, passes, resp))
        decode_s += time.perf_counter() - t_dec
        telemetry.plan_wall_s += time.perf_counter() - t_round

        # ---- loss/fence fallback plans --------------------------------
        # a lost worker's partitions are planned inline and committed by
        # the coordinator below — identical plans from the same core, so
        # adoption of orphaned intents cannot bend the trace.  A FENCED
        # shard's partitions are NOT adopted at all (a handoff moved
        # state under them); they re-dirty and replan next round.
        fallback_plans: List[PartitionPlan] = []
        fallback_parts: set = set()
        for shard, parts_enc in lost:
            if shard in self._fence_aborts:
                orch._dirty.update(e[0] for e in parts_enc)
                continue
            shard_plans, plan_s = client._plan_inline(shard, parts_enc)
            max_plan = max(max_plan, plan_s)
            fallback_plans.extend(shard_plans)
            fallback_parts.update(p.part for p in shard_plans)

        # ---- adopt: replay the committed passes through the unchanged
        # client-serial walk, pass by pass in global sorted partition
        # order — the same plans through the same commit core in the
        # same order IS the client-serial trace ------------------------
        t_apply = time.perf_counter()
        conflicts = 0
        adopted = 0
        diverged = False
        while True:
            k = adopted
            pass_plans: List[PartitionPlan] = []
            expected_uids: set = set()
            for shard, passes, resp in acks:
                if shard in self._fence_aborts or k >= len(passes):
                    continue
                pass_plans.extend(passes[k])
                for out in resp["passes"][k].get("outcomes", []):
                    _, rows, _, _ = wire.decode_commit_outcome(out)
                    expected_uids.update(uid for uid, _ in rows)
            if k == 0:
                pass_plans.extend(ctx["plans"])
                pass_plans.extend(fallback_plans)
            if not pass_plans:
                break
            if k > 0:
                # a dependent pass is adopted only when the re-dirtied
                # set the workers planned against matches live state
                # exactly; any residue (fallbacks, divergence) stops
                # adoption — the leftover dirty set replans next round
                expected = sorted({p.part for p in pass_plans})
                dirty_now = sorted(
                    x for x in orch._dirty if orch._queues.get(x)
                )
                if expected != dirty_now:
                    break
                orch._dirty.clear()
            pass_plans.sort(key=lambda p: p.part)
            before = set(orch._executing)
            for plan in pass_plans:
                conflicts += orch._commit_partition(plan)
            adopted += 1
            launched = set(orch._executing) - before
            missing = expected_uids - launched
            extra = {
                uid
                for uid in launched - expected_uids
                if orch._partition_of(orch._executing[uid])
                not in fallback_parts
            }
            if missing or extra:
                # a worker's committed outcome does not match the
                # authoritative replay (e.g. an action withdrawn between
                # prepare and adopt): stop adopting — the replay stands,
                # the diverged stashes abort below
                telemetry.wire_commit_diverged += 1
                diverged = True
                break

        # ---- verify + settle ------------------------------------------
        for shard, passes, resp in acks:
            if shard in self._fence_aborts:
                self._abort_worker(shard)
                orch._dirty.update(groups[shard])
                continue
            if diverged or len(passes) > adopted:
                # un-adopted trailing passes (or a diverged outcome):
                # the worker's replicas ran ahead of the adopted state —
                # restore them to pre-round; the snapshot rail re-syncs
                self._abort_worker(shard)
                continue
            fps = resp.get("fps") or {}
            post: Dict[str, Tuple[str, Dict[str, Any]]] = {}
            match = True
            for rt, want in fps.items():
                snap = wire.encode_snapshot(orch.managers[rt])
                afp = wire.fingerprint(snap)
                post[rt] = (afp, snap)
                if afp != want:
                    match = False
            if not match:
                telemetry.wire_commit_diverged += 1
                self._abort_worker(shard)
                continue
            # verified: the worker's post-commit replicas ARE next
            # round's state — pre-warm the delta bases so the committed
            # state is never re-shipped (the wire leaves the commit
            # path), and hold the confirm for the next fused frame
            sent = client._sent[shard]["snaps"]
            for rt, (afp, snap) in post.items():
                client._prev_snaps[rt] = (afp, snap)
                sent[rt] = afp
            self._pending_confirm.add(shard)
        self._inflight.clear()
        apply_s = time.perf_counter() - t_apply

        # ---- accounting (mirrors plan_round's wire rails) -------------
        overlap_s = e_head + max_codec + decode_s
        new_round = client._last_now is None or orch.now != client._last_now
        client._last_now = orch.now
        telemetry.note_wire_round(
            encode_s,
            transport_s,
            decode_s,
            nbytes,
            worker_codec_s,
            overlap_s=overlap_s,
            frames=len(requests),
            new_round=new_round,
        )
        telemetry.note_wire_memo(client._memo_hits, client._memo_misses)
        client._memo_hits = 0
        client._memo_misses = 0
        telemetry.plan_critical_s += max_plan
        telemetry.note_commit_round(
            max_commit, apply_s, prepares=len(requests), acks=len(acks)
        )
        # the modeled decision latency of a fused round: the slowest
        # worker's plan + commit — the client's replay/verify is mirror
        # maintenance off the decision path (commit_apply_s), which is
        # exactly the resource-efficiency claim this engine exists for
        telemetry.sched_wall_s += max_plan + max_commit
        if conflicts:
            telemetry.commit_conflicts += conflicts
        return conflicts > 0

    # -- protocol settlement --------------------------------------------
    def _flush_confirms(self) -> None:
        """Finalize every verified-but-unconfirmed prepared round with
        an explicit ``commit_decide``: plain plan_request frames never
        settle a stash, and the next fused frame's implicit abort must
        never restore a round the coordinator already adopted."""
        client = self.client
        for shard in sorted(self._pending_confirm):
            try:
                t = client._transport(shard)
                t.submit(
                    wire.encode_frame(
                        wire.envelope("commit_decide", {"commit": True}),
                        client.codec,
                    )
                )
                wire.expect(wire.decode_frame(t.recv()), "commit_decide_response")
            except (wire.TransportError, wire.WireError):
                self._pending_confirm.discard(shard)
                self._lose(shard)
        self._pending_confirm.clear()

    def fence(self, rtypes: Optional[Sequence[str]] = None) -> int:
        """Fence ownership covering ``rtypes`` (None = all) before a
        handoff (``migrate_task``/``rebalance``): any open prepare
        window touching them is deterministically aborted — its ack is
        never adopted and the worker restores its pre-round replicas —
        pending verified rounds are finalized (the coordinator already
        applied them), and the covered leases are revoked by epoch bump
        so a stale holder can never ack again.  Returns the number of
        fenced in-flight intents."""
        rset = None if rtypes is None else set(rtypes)
        fenced = 0
        for shard, leased in self._inflight.items():
            if shard in self._fence_aborts:
                continue
            if rset is None or not rset.isdisjoint(leased):
                self._fence_aborts.add(shard)
                fenced += 1
        self.orch.telemetry.wire_fenced_intents += fenced
        if self._round_open:
            # reentrant call (a handoff fired from inside the round's
            # own gather): no wire traffic here — interleaved frames
            # would desynchronize the FIFO transports.  The round's
            # finale aborts the fenced shards; the revokes run after.
            if rset is not None:
                self._deferred_revokes |= rset
            else:
                for granted in self._granted.values():
                    self._deferred_revokes |= set(granted)
            return fenced
        client = self.client
        for shard in sorted(self._granted):
            granted = self._granted[shard]
            revoke = sorted(rt for rt in granted if rset is None or rt in rset)
            pending = shard in self._pending_confirm
            if not revoke and not pending:
                continue
            for rt in revoke:
                del granted[rt]
                self._epochs[rt] = self._epochs.get(rt, 0) + 1
            self._pending_confirm.discard(shard)
            body: Dict[str, Any] = {"commit": bool(pending), "revoke": revoke}
            try:
                t = client._transport(shard)
                t.submit(
                    wire.encode_frame(
                        wire.envelope("commit_decide", body), client.codec
                    )
                )
                wire.expect(wire.decode_frame(t.recv()), "commit_decide_response")
            except (wire.TransportError, wire.WireError):
                client._note_worker_loss(shard)
        return fenced

    def close(self) -> None:
        """Settle the protocol (confirm flushes) and drop all ownership
        state; idempotent."""
        self._flush_confirms()
        self._granted.clear()
        self._inflight.clear()
        self._epochs.clear()
        self._foot_cache.clear()
