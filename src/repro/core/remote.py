"""Out-of-process shard workers: the plan phase over the wire.

The sharded round engine (:mod:`repro.core.shards`) proved a round can
be split into side-effect-free per-shard *plan* phases over manager
snapshots plus one serialized validated *commit*.  This module moves the
plan phase out of the orchestrator's process:

* :class:`RemoteShardWorker` — the worker side: decodes a plan request
  (policy config, manager snapshots, queue contents), runs the **same**
  plan core the in-process engine runs
  (:func:`repro.core.shards.plan_partition` — one implementation, zero
  drift), and returns serialized :class:`~repro.core.shards.PartitionPlan`
  payloads.  Stateless across requests except for caches keyed by
  content fingerprint (snapshot deltas, policy config, duration
  history) — a worker can be restarted at any time and the next request
  re-primes it.
* :class:`ShardTransport` — the byte-level boundary, deliberately tiny
  (``submit``/``recv``/``close`` over UTF-8 JSON): anything that can
  move bytes (a pipe, a socket, an RPC stack) can carry shards.
  :class:`LoopbackTransport` runs the worker in-process but pushes every
  payload through the full encode/decode path — the determinism rail
  proving wire fidelity without process overhead;
  :class:`ProcessTransport` runs the worker in a real OS process over a
  ``multiprocessing`` pipe.
* :class:`RemoteRoundClient` — the orchestrator side: builds per-shard
  requests (suppressing unchanged snapshots/policy/history as
  ``{"ref": fingerprint}`` deltas), dispatches to every worker, gathers,
  and re-binds decoded decisions to the **live** Action objects for the
  unchanged single-threaded commit.  Conflict rollback and the retry
  rail are exactly the in-process ones — the commit phase cannot tell
  where a plan was computed.

Accounting is honest by construction: the modeled critical-path
decision latency stays ``max(per-shard plan) + commit`` with per-shard
plan cost *measured on the worker* (what a dedicated worker pays), and
every serialization cost — client encode, client decode + worker codec,
transport wall, bytes — is recorded separately in
``Telemetry.wire_*`` so wire overhead is never laundered into decision
latency (``bench_scheduler --suite remote`` reports both, side by
side).

No pickle crosses the boundary: requests and responses are
:func:`repro.core.wire.dumps` strings (Python-dialect JSON), moved as
UTF-8 bytes.
"""

from __future__ import annotations

import time
from typing import TYPE_CHECKING, Any, Dict, List, Optional, Sequence, Tuple

from repro.core import wire
from repro.core.action import Action
from repro.core.shards import PartitionPlan, plan_partition

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.orchestrator import Orchestrator


# ---------------------------------------------------------------------------
# the worker side
# ---------------------------------------------------------------------------


class RemoteShardWorker:
    """Executes serialized plan requests; lives wherever the transport
    puts it (the orchestrator's process for loopback, a separate OS
    process for :class:`ProcessTransport`, a remote host once an RPC
    transport exists).

    Per-request inputs arrive either in full or as ``{"ref": fp}``
    references to content the worker already holds (snapshot states,
    policy config, duration history).  Snapshot *states* are cached,
    but a fresh plan-capable manager is rebuilt from the cached state on
    every request — planning mutates its managers (admission cursors,
    the CPU manager's trajectory binding), so decoded snapshots are
    single-use exactly like in-process ones.
    """

    def __init__(self) -> None:
        self._policy: Optional[Any] = None
        self._policy_fp: Optional[str] = None
        self._fair_share: Optional[Any] = None
        self._fair_share_fp: Optional[str] = None
        self._history_fp: Optional[str] = None
        self._history_avg: Dict[str, float] = {}
        self._snap_cache: Dict[str, Tuple[str, Dict[str, Any]]] = {}
        # dumps() cost of the previous response, folded into the NEXT
        # response's codec_s (we cannot time a serialization inside the
        # payload it produces; carrying it forward keeps the aggregate
        # wire bill honest without double-serializing)
        self._carry_dump_s = 0.0

    # ------------------------------------------------------------------
    def handle(self, request: str) -> str:
        """One plan round-trip: wire string in, wire string out.  Any
        :class:`~repro.core.wire.WireError` (or other failure) is
        returned as an ``error`` payload rather than raised — the
        transport stays alive and the client decides what to do."""
        try:
            t0 = time.perf_counter()
            payload = wire.loads(request)
            parse_s = time.perf_counter() - t0
            body = self._handle(payload, parse_s)
            t1 = time.perf_counter()
            blob = wire.dumps(body)
            self._carry_dump_s += time.perf_counter() - t1
            return blob
        except Exception as e:  # noqa: BLE001 - protocol boundary
            return wire.dumps(
                wire.envelope("error", {"error": f"{type(e).__name__}: {e}"})
            )

    def _handle(self, payload: Any, parse_s: float = 0.0) -> Dict[str, Any]:
        req = wire.expect(payload, "plan_request")
        t_codec = time.perf_counter()

        if req.get("policy") is not None:
            self._policy = wire.decode_policy(req["policy"])
            self._policy_fp = wire.fingerprint(req["policy"])
        if self._policy is None:
            raise wire.WireError("plan_request before any policy was sent")

        fs = req.get("fair_share", {"ref": self._fair_share_fp})
        if not (isinstance(fs, dict) and "ref" in fs):
            self._fair_share = wire.decode_fair_share(fs)
            self._fair_share_fp = wire.fingerprint(fs)
        elif fs["ref"] != self._fair_share_fp:
            raise wire.WireError("fair_share ref does not match cached state")

        hist = req.get("history")
        if hist is not None:
            if isinstance(hist, dict) and "ref" in hist:
                if hist["ref"] != self._history_fp:
                    raise wire.WireError("history ref does not match cached state")
            else:
                self._history_avg = {
                    str(k): float(v) for k, v in hist.get("avg", {}).items()
                }
                self._history_fp = wire.fingerprint(hist)
            # apply the cached table even on a ref hit: a policy refresh
            # above rebuilt a FRESH policy (empty history), and an
            # unchanged-history ref must still repopulate it — otherwise
            # unprofiled actions price at the default and remote plans
            # silently diverge from serial ones
            history = getattr(self._policy, "history", None)
            if history is not None:
                history._avg = dict(self._history_avg)

        managers: Dict[str, Any] = {}
        for rtype, snap in req.get("snapshots", {}).items():
            if isinstance(snap, dict) and "ref" in snap:
                cached = self._snap_cache.get(rtype)
                if cached is None or cached[0] != snap["ref"]:
                    raise wire.WireError(
                        f"snapshot ref for {rtype!r} does not match cached state"
                    )
                snap = cached[1]
            else:
                self._snap_cache[rtype] = (wire.fingerprint(snap), snap)
            managers[str(rtype)] = wire.decode_snapshot(snap)

        executing = [wire.decode_action(a) for a in req.get("executing", [])]
        waiting_by_part: Dict[str, List[Action]] = {
            str(p["part"]): [wire.decode_action(a) for a in p.get("waiting", [])]
            for p in req.get("partitions", [])
        }
        codec_s = time.perf_counter() - t_codec

        now = float(req.get("now", 0.0))
        incremental = bool(req.get("incremental", True))
        shard = int(req.get("shard", 0))

        t_plan = time.perf_counter()
        plans = [
            plan_partition(
                part,
                waiting,
                executing,
                managers,
                self._policy,
                self._fair_share,
                now,
                incremental,
                shard=shard,
            )
            for part, waiting in waiting_by_part.items()
        ]
        plan_s = time.perf_counter() - t_plan

        t_enc = time.perf_counter()
        plan_payloads = [wire.encode_plan(p) for p in plans]
        codec_s += parse_s + self._carry_dump_s + (time.perf_counter() - t_enc)
        self._carry_dump_s = 0.0
        body = {
            "shard": shard,
            "plans": plan_payloads,
            "plan_s": plan_s,
            "codec_s": codec_s,
        }
        return wire.envelope("plan_response", body)


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------


class ShardTransport:
    """Byte-boundary to one shard worker.

    The contract is a single in-flight request per transport:
    ``submit(request)`` hands the worker a wire string, ``recv()``
    blocks for its response.  The client overlaps workers by submitting
    to all transports before receiving from any.  Implementations move
    UTF-8 JSON only — never pickled objects — so an RPC transport can
    slot in without touching the protocol."""

    def submit(self, request: str) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def recv(self) -> str:  # pragma: no cover - interface
        raise NotImplementedError

    def close(self) -> None:  # pragma: no cover - interface
        pass


class LoopbackTransport(ShardTransport):
    """In-process worker behind the full wire codec path.

    Every request and response crosses :func:`repro.core.wire.dumps` /
    :func:`~repro.core.wire.loads` exactly as over a real transport —
    loopback proves plan-over-wire fidelity (and measures serialization
    cost) deterministically, without process scheduling noise.  The
    worker computes during :meth:`submit`; :meth:`recv` just returns."""

    def __init__(self) -> None:
        self._worker = RemoteShardWorker()
        self._response: Optional[str] = None

    def submit(self, request: str) -> None:
        self._response = self._worker.handle(request)

    def recv(self) -> str:
        resp, self._response = self._response, None
        if resp is None:
            raise RuntimeError("recv() without a submitted request")
        return resp


def _worker_main(conn) -> None:
    """Entry point of a :class:`ProcessTransport` worker process: serve
    plan requests off the pipe until the empty shutdown frame (or EOF).
    Module-level so it is importable under any multiprocessing start
    method (spawn pickles the callable by reference, never by value)."""
    worker = RemoteShardWorker()
    while True:
        try:
            blob = conn.recv_bytes()
        except (EOFError, OSError):
            break
        if not blob:
            break
        conn.send_bytes(worker.handle(blob.decode("utf-8")).encode("utf-8"))
    conn.close()


class ProcessTransport(ShardTransport):
    """A shard worker in a separate OS process over a multiprocessing
    pipe.  Frames are UTF-8 wire strings (``send_bytes``/``recv_bytes``
    — no object pickling); an empty frame is the shutdown signal.
    Workers are daemonic: they can never outlive the orchestrator."""

    def __init__(self, start_method: Optional[str] = None) -> None:
        import multiprocessing as mp

        if start_method is None:
            start_method = (
                "fork" if "fork" in mp.get_all_start_methods() else "spawn"
            )
        ctx = mp.get_context(start_method)
        self._conn, child = ctx.Pipe()
        self._proc = ctx.Process(target=_worker_main, args=(child,), daemon=True)
        self._proc.start()
        child.close()

    def submit(self, request: str) -> None:
        self._conn.send_bytes(request.encode("utf-8"))

    def recv(self) -> str:
        return self._conn.recv_bytes().decode("utf-8")

    def close(self) -> None:
        try:
            self._conn.send_bytes(b"")
            self._conn.close()
        except (OSError, ValueError):
            pass
        self._proc.join(timeout=5)
        if self._proc.is_alive():  # pragma: no cover - defensive
            self._proc.terminate()


_TRANSPORTS = {"loopback": LoopbackTransport, "process": ProcessTransport}


# ---------------------------------------------------------------------------
# the orchestrator side
# ---------------------------------------------------------------------------


class RemoteRoundClient:
    """Drives one remote plan phase per sharded round.

    Owns one transport (one worker) per shard index, created lazily.
    Tracks, per worker, the fingerprints of the policy config, fairness
    config, duration history, and each manager snapshot it last sent, so
    unchanged payloads travel as ``{"ref": fp}`` deltas — the worker
    rebuilds from its cache and the wire carries only what moved."""

    def __init__(self, orch: "Orchestrator", transport: str = "loopback") -> None:
        factory = _TRANSPORTS.get(transport)
        if factory is None:
            raise ValueError(
                f"unknown transport {transport!r} (have {sorted(_TRANSPORTS)})"
            )
        self.orch = orch
        self.transport_kind = transport
        self._factory = factory
        self._transports: List[ShardTransport] = []
        self._sent: List[Dict[str, Any]] = []  # per-worker fingerprint state

    # ------------------------------------------------------------------
    def close(self) -> None:
        for t in self._transports:
            t.close()
        self._transports.clear()
        self._sent.clear()

    def _transport(self, i: int) -> ShardTransport:
        while len(self._transports) <= i:
            self._transports.append(self._factory())
            self._sent.append({"snaps": {}})
        return self._transports[i]

    # ------------------------------------------------------------------
    def plan_round(
        self, groups: Sequence[Sequence[str]]
    ) -> Tuple[List[PartitionPlan], float]:
        """Plan every shard's partitions on its worker; returns the
        decoded plans (decisions re-bound to live actions) plus the
        round's critical-path plan cost: the max worker-measured plan
        time.  Dispatch is pipelined — every request is submitted before
        any response is awaited, so worker compute overlaps."""
        orch = self.orch
        telemetry = orch.telemetry
        # worker startup (process fork/spawn) happens here, outside the
        # serialization accounting — it is a deployment cost paid once,
        # not a per-round wire cost
        for shard_idx in range(len(groups)):
            self._transport(shard_idx)
        t_round = time.perf_counter()

        # ---- encode phase (client-side serialization cost) ------------
        t_enc = time.perf_counter()
        plans: List[PartitionPlan] = []
        by_uid: Dict[int, Action] = {}
        shard_parts: List[Tuple[int, List[Dict[str, Any]], set]] = []
        union_rtypes: set = set()
        executing = list(orch._executing.values())
        executing_payload = [wire.encode_action(a) for a in executing]
        nbytes = 0
        for shard_idx, group in enumerate(groups):
            parts: List[Dict[str, Any]] = []
            rtypes: set = set()
            for part in group:
                queue = orch._queues.get(part)
                if not queue:
                    # nothing to plan — resolved client-side, off the wire
                    plans.append(
                        PartitionPlan(part, planned=False, shard=shard_idx)
                    )
                    continue
                waiting = queue.ordered()
                for a in waiting:
                    by_uid[a.uid] = a
                    rtypes.update(r for r in a.cost if r in orch.managers)
                if part in orch.managers:
                    rtypes.add(part)
                parts.append(
                    {
                        "part": part,
                        "waiting": [wire.encode_action(a) for a in waiting],
                    }
                )
            if parts:
                shard_parts.append((shard_idx, parts, rtypes))
                union_rtypes |= rtypes
        # shard-independent payloads (policy config, fairness, history,
        # manager snapshots) are encoded + fingerprinted ONCE per round
        # and shared across every worker's request — only the per-worker
        # ref-vs-full decision differs
        shared = self._encode_shared(union_rtypes)
        requests: List[Tuple[int, str]] = [
            (shard_idx,
             wire.dumps(self._request(shard_idx, parts, rtypes,
                                      executing_payload, shared)))
            for shard_idx, parts, rtypes in shard_parts
        ]
        encode_s = time.perf_counter() - t_enc

        # ---- dispatch + gather (worker compute overlaps) --------------
        t_tx = time.perf_counter()
        for shard_idx, blob in requests:
            nbytes += len(blob)
            self._transport(shard_idx).submit(blob)
        responses: List[Tuple[int, str]] = [
            (shard_idx, self._transport(shard_idx).recv())
            for shard_idx, _ in requests
        ]
        transport_s = time.perf_counter() - t_tx

        # ---- decode phase (client-side + worker-reported codec cost) --
        t_dec = time.perf_counter()
        critical = 0.0
        decode_s = 0.0
        for shard_idx, blob in responses:
            nbytes += len(blob)
            payload = wire.loads(blob)
            if isinstance(payload, dict) and payload.get("kind") == "error":
                raise RuntimeError(
                    f"remote shard worker {shard_idx} failed: "
                    f"{payload.get('error')}"
                )
            resp = wire.expect(payload, "plan_response")
            plan_s = float(resp.get("plan_s", 0.0))
            decode_s += float(resp.get("codec_s", 0.0))
            shard_plans = [wire.decode_plan(p, by_uid) for p in resp["plans"]]
            critical = max(critical, plan_s)
            telemetry.note_shard_round(shard_idx, len(shard_plans), plan_s)
            plans.extend(shard_plans)
        decode_s += time.perf_counter() - t_dec

        telemetry.plan_critical_s += critical
        telemetry.plan_wall_s += time.perf_counter() - t_round
        telemetry.note_wire_round(encode_s, transport_s, decode_s, nbytes)
        return plans, critical

    # ------------------------------------------------------------------
    def _encode_shared(self, rtypes: set) -> Dict[str, Any]:
        """Encode + fingerprint the shard-independent request inputs
        once per round: the policy / fairness / history configs and one
        snapshot per needed resource type.  ``_request`` then only makes
        the per-worker full-vs-``{"ref": fp}`` call against each
        worker's sent-state."""
        orch = self.orch
        policy_payload = wire.encode_policy(orch.policy)
        fs_payload = wire.encode_fair_share(orch.fair_share)
        hist = getattr(orch.policy, "history", None)
        hist_payload = None if hist is None else {"avg": dict(hist._avg)}
        snaps: Dict[str, Tuple[Dict[str, Any], str]] = {}
        for rtype in sorted(rtypes):
            snap = wire.encode_snapshot(orch.managers[rtype])
            snaps[rtype] = (snap, wire.fingerprint(snap))
        return {
            "policy": (policy_payload, wire.fingerprint(policy_payload)),
            "fair_share": (fs_payload, wire.fingerprint(fs_payload)),
            "history": (
                None
                if hist_payload is None
                else (hist_payload, wire.fingerprint(hist_payload))
            ),
            "snaps": snaps,
        }

    def _request(
        self,
        shard_idx: int,
        parts: List[Dict[str, Any]],
        rtypes: set,
        executing_payload: List[Dict[str, Any]],
        shared: Dict[str, Any],
    ) -> Dict[str, Any]:
        """One worker's plan request, with unchanged policy/fairness/
        history/snapshot payloads replaced by fingerprint references."""
        orch = self.orch
        sent = self._sent[shard_idx]

        policy_payload, policy_fp = shared["policy"]
        policy = None if sent.get("policy") == policy_fp else policy_payload
        sent["policy"] = policy_fp

        fs_payload, fs_fp = shared["fair_share"]
        fair_share: Any = (
            {"ref": fs_fp} if sent.get("fair_share") == fs_fp else fs_payload
        )
        sent["fair_share"] = fs_fp

        history: Any = None
        if shared["history"] is not None:
            hist_payload, hist_fp = shared["history"]
            history = (
                {"ref": hist_fp} if sent.get("history") == hist_fp else hist_payload
            )
            sent["history"] = hist_fp

        snapshots: Dict[str, Any] = {}
        for rtype in sorted(rtypes):
            snap, fp = shared["snaps"][rtype]
            if sent["snaps"].get(rtype) == fp:
                snapshots[rtype] = {"ref": fp}
            else:
                snapshots[rtype] = snap
                sent["snaps"][rtype] = fp

        return wire.envelope(
            "plan_request",
            {
                "shard": shard_idx,
                "now": orch.now,
                "incremental": orch.incremental,
                "policy": policy,
                "fair_share": fair_share,
                "history": history,
                "snapshots": snapshots,
                "executing": executing_payload,
                "partitions": parts,
            },
        )
