"""Live mode: the scenario harness on real time and real work.

The DES benches model an action as a duration; live mode *runs* it — a
real payload (JAX kernel work from :mod:`repro.kernels.ops`) on a
thread-pool worker, against :class:`~repro.core.simulator.RealClock`,
over a fleet of emulated XLA host devices
(``--xla_force_host_platform_device_count``, so CI exercises a
multi-device fleet on plain CPU).

The control plane is unchanged: :class:`LiveOrchestrator` overrides
exactly one method (``_schedule_completion`` — the seam
:class:`~repro.core.orchestrator.Orchestrator` exposes for this) so a
launch dispatches the payload instead of arming a virtual timer, and
completion happens when the work actually returns.  Everything else —
queues, scheduler, managers, fairness, telemetry — is the same code the
sim runs, which is what makes the **differential replay rail** honest:
the same compiled :class:`~repro.core.scenarios.CompiledScenario` drives
both modes, and the live run's launch trace must be *structurally*
equivalent to the sim's (same per-pool launch order; real timing is
reported separately, never compared — see
:func:`repro.core.scenarios.structural_trace`).
"""

from __future__ import annotations

import heapq
import itertools
import os
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional

from repro.core.action import Action
from repro.core.orchestrator import Orchestrator
from repro.core.scenarios import ActionTemplate, CompiledScenario
from repro.core.simulator import RealClock, _Event


class LiveModeError(RuntimeError):
    """Live-mode environment failure (devices unavailable, jax imported
    too early to emulate the requested fleet, ...)."""


def ensure_host_devices(n: int) -> list:
    """Return ``n`` emulated XLA host devices, setting
    ``--xla_force_host_platform_device_count`` if jax has not been
    imported yet.  The bench CLI calls this before any jax import; a
    caller who imported jax first (fixing the device count at 1) gets a
    typed error, not a silently single-device run."""
    import sys

    flag = f"--xla_force_host_platform_device_count={n}"
    if "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        if "--xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (flags + " " + flag).strip()
    import jax

    devices = jax.devices()
    if len(devices) < n:
        raise LiveModeError(
            f"live mode needs {n} host devices, jax sees {len(devices)} "
            f"(set XLA_FLAGS={flag} before the first jax import)"
        )
    return list(devices[:n])


class LiveEventLoop:
    """The event loop on wall time.

    Same surface as :class:`~repro.core.simulator.EventLoop` (``call_at``
    / ``call_after`` / ``cancel`` / ``run`` / ``pending`` / ``clock``),
    but timers fire at real instants and worker threads hand completions
    back with :meth:`post` (callbacks always execute on the loop thread,
    so the orchestrator stays single-threaded exactly as in sim mode).
    ``run`` drains until there are no timers, no posted callbacks, and
    no retained in-flight work."""

    def __init__(self) -> None:
        self.clock = RealClock()
        self._heap: List[_Event] = []
        self._seq = itertools.count()
        self._posted: deque = deque()
        self._cond = threading.Condition()
        self._inflight = 0

    # -- scheduling (loop thread) --------------------------------------
    def call_at(self, when: float, callback: Callable[[], None]) -> _Event:
        # real time moved on while the caller computed `when`; a
        # slightly-past deadline just means "as soon as possible"
        ev = _Event(when=when, seq=next(self._seq), callback=callback)
        with self._cond:
            heapq.heappush(self._heap, ev)
            self._cond.notify()
        return ev

    def call_after(self, delay: float, callback: Callable[[], None]) -> _Event:
        return self.call_at(self.clock.now() + max(0.0, delay), callback)

    def cancel(self, ev: _Event) -> None:
        ev.cancelled = True

    def pending(self) -> int:
        with self._cond:
            return sum(1 for e in self._heap if not e.cancelled)

    # -- worker-thread handoff -----------------------------------------
    def retain(self) -> None:
        """Mark one unit of off-loop work in flight (keeps ``run`` from
        exiting while a payload is still executing)."""
        with self._cond:
            self._inflight += 1

    def release(self) -> None:
        with self._cond:
            self._inflight -= 1
            self._cond.notify()

    def post(self, callback: Callable[[], None]) -> None:
        """Enqueue a callback from any thread; it runs on the loop
        thread ahead of timer events."""
        with self._cond:
            self._posted.append(callback)
            self._cond.notify()

    # -- the loop -------------------------------------------------------
    def run(self, until: Optional[float] = None,
            max_events: int = 1_000_000) -> float:
        n = 0
        while True:
            cb: Optional[Callable[[], None]] = None
            with self._cond:
                while True:
                    now = self.clock.now()
                    if until is not None and now >= until:
                        return now
                    if self._posted:
                        cb = self._posted.popleft()
                        break
                    while self._heap and self._heap[0].cancelled:
                        heapq.heappop(self._heap)
                    if self._heap and self._heap[0].when <= now:
                        cb = heapq.heappop(self._heap).callback
                        break
                    if not self._heap and self._inflight == 0:
                        return now
                    deadline = self._heap[0].when if self._heap else None
                    timeout = (None if deadline is None
                               else max(0.0, deadline - now))
                    if until is not None:
                        wall = max(0.0, until - now)
                        timeout = wall if timeout is None else min(timeout, wall)
                    self._cond.wait(timeout)
            cb()
            n += 1
            if n >= max_events:
                raise RuntimeError(f"event budget exceeded ({max_events})")


class LiveOrchestrator(Orchestrator):
    """The orchestrator on real work: launches dispatch the action's
    payload (``action.fn``, or a real sleep of the modeled duration) to
    a thread pool, and completion fires when the payload returns.  All
    other lifecycle paths — withdraw, deadline/retry, telemetry — are
    the inherited sim-mode code."""

    def __init__(self, managers, *, loop: Optional[LiveEventLoop] = None,
                 max_workers: int = 8, **kwargs) -> None:
        super().__init__(managers, loop=loop or LiveEventLoop(), **kwargs)
        self._pool = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="live-action")

    def _schedule_completion(self, action: Action, duration: float,
                             overhead: float) -> None:
        # the modeled finish is only an estimate; the real one is
        # stamped when the payload returns
        action.finish_time = self.now + overhead + duration
        loop = self.loop
        loop.retain()

        def work() -> None:
            t0 = time.monotonic()
            try:
                if action.fn is not None:
                    action.fn()
                else:
                    time.sleep(duration)
            finally:
                real_s = time.monotonic() - t0
                loop.post(lambda: self._on_live_done(action, real_s))

        self._pool.submit(work)

    def _on_live_done(self, action: Action, real_s: float) -> None:
        try:
            if action.uid not in self._executing:
                return  # withdrawn (timeout/cancel) while the work ran
            action.finish_time = self.now
            self._complete(action, real_s)
        finally:
            self.loop.release()

    def close(self) -> None:
        self._pool.shutdown(wait=True)
        super().close()


# ---------------------------------------------------------------------------
# Kernel payloads: real JAX work per emulated device
# ---------------------------------------------------------------------------


def kernel_payload_factory(
    devices: list, pool_device: Dict[str, int], *, rows: int = 64,
    cols: int = 64,
) -> Callable[[ActionTemplate], Callable[[], None]]:
    """Payloads that spin a real Pallas kernel (``rmsnorm_op``,
    interpret mode — CPU-safe) on the template's pool's device until
    the template's (time-scaled) duration has elapsed.  Call
    :func:`warm_devices` first: the first call per device pays that
    device's jit compile, which would otherwise distort the run."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import rmsnorm_op

    def factory(template: ActionTemplate) -> Callable[[], None]:
        dev = devices[pool_device.get(template.rtype, 0) % len(devices)]
        target_s = template.base_duration

        def fn() -> None:
            x = jax.device_put(jnp.ones((rows, cols), jnp.float32), dev)
            w = jax.device_put(jnp.ones((cols,), jnp.float32), dev)
            t0 = time.monotonic()
            out = None
            while time.monotonic() - t0 < target_s:
                out = rmsnorm_op(x, w, interpret=True)
            if out is not None:
                jax.block_until_ready(out)

        return fn

    return factory


def warm_devices(devices: list, *, rows: int = 8, cols: int = 64) -> None:
    """One kernel call per device before the timed run (per-device jit
    specialization: each device's first call recompiles)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import rmsnorm_op

    for dev in devices:
        x = jax.device_put(jnp.ones((rows, cols), jnp.float32), dev)
        w = jax.device_put(jnp.ones((cols,), jnp.float32), dev)
        jax.block_until_ready(rmsnorm_op(x, w, interpret=True))


# ---------------------------------------------------------------------------
# The live runner (what the bench + CI smoke call)
# ---------------------------------------------------------------------------


def run_live_scenario(
    compiled: CompiledScenario,
    *,
    devices: Optional[list] = None,
    max_workers: Optional[int] = None,
    wall_limit_s: float = 300.0,
    use_kernels: bool = True,
):
    """Run a compiled scenario in live mode; returns the orchestrator
    (telemetry carries the real-time records).  ``use_kernels=False``
    substitutes real sleeps for kernel work (same structural trace,
    no jax dependency — the fallback when jax is unavailable)."""
    from repro.core.scenarios import build_fair_share, build_managers, \
        install_scenario
    from repro.core.scheduler import ElasticScheduler

    spec = compiled.spec
    loop = LiveEventLoop()
    managers = build_managers(spec, loop)
    orch = LiveOrchestrator(
        managers,
        loop=loop,
        policy=ElasticScheduler(),
        fair_share=build_fair_share(spec),
        incremental=True,
        max_workers=max_workers or max(4, 2 * len(spec.pools)),
    )
    payload = None
    if use_kernels:
        devs = devices or ensure_host_devices(len(spec.pools))
        warm_devices(devs)
        pool_device = {p.name: i for i, p in enumerate(spec.pools)}
        payload = kernel_payload_factory(devs, pool_device)
    install_scenario(compiled, orch, payload=payload)
    orch.run(until=wall_limit_s)
    orch.close()
    return orch
