"""ACT / utilization telemetry shared by ARL-Tangram and the baselines."""

from __future__ import annotations

import math
import statistics
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class ActionRecord:
    name: str
    task_id: str
    trajectory_id: str
    submit: float
    start: float
    finish: float
    sys_overhead: float
    units: Dict[str, int]
    failed: bool = False
    retries: int = 0

    @property
    def queue_dur(self) -> float:
        return self.start - self.submit

    @property
    def exec_dur(self) -> float:
        return self.finish - self.start - self.sys_overhead

    @property
    def act(self) -> float:
        return self.finish - self.submit


@dataclass
class ShardStats:
    """Per-shard plan-phase accounting for the sharded round engine.

    ``plan_s`` is the shard's own plan time — the cost a dedicated
    per-shard worker pays.  Exact when shards are planned inline
    (nothing else runs while one is measured); an upper bound (includes
    GIL waits) under the in-process thread pool."""

    rounds: int = 0
    partitions: int = 0
    plan_s: float = 0.0


@dataclass
class Telemetry:
    records: List[ActionRecord] = field(default_factory=list)
    sched_invocations: int = 0
    sched_wall_s: float = 0.0
    # -- action-lifecycle counters (orchestrator-maintained) ---------------
    timeouts: int = 0  # deadline expiries (each retry re-arms the deadline)
    retries: int = 0  # re-queues at the FCFS head after a timeout
    cancellations: int = 0
    # -- sharded-round counters (RoundExecutor-maintained) ------------------
    shards: Dict[int, ShardStats] = field(default_factory=dict)
    plan_wall_s: float = 0.0  # real wall clock of parallel plan phases
    plan_critical_s: float = 0.0  # sum of per-round max shard plan CPU
    # planned launches refused by live state during a sharded (plan/
    # commit) round's commit phase; refusals in serial-path rounds show
    # up only in the orchestrator's launch_failures stat
    commit_conflicts: int = 0
    # -- auto plan-mode decisions (plan_mode="auto") -------------------------
    # per-round inline/threads picks from the measured plan-cost EWMA;
    # the EWMA itself is exported so the decision is auditable
    plan_mode_rounds: Dict[str, int] = field(default_factory=dict)
    plan_cost_ewma_s: float = 0.0  # last per-partition plan-cost EWMA
    # -- wire counters (remote plan mode, repro.core.remote) -----------------
    # serialization overhead is accounted SEPARATELY from the modeled
    # critical-path decision latency so the two are never conflated:
    # encode/decode are orchestrator-side wall, worker_codec_s is the
    # worker-reported parse+encode cost (its side of the bill — kept
    # apart from client decode so the two sides are never conflated
    # either), bytes count both directions, transport_s is the full
    # dispatch->gather wall of remote plan phases (worker compute +
    # IPC + codec, overlapped across workers), and fallbacks counts
    # full-content re-sends after a recoverable typed worker error
    # (cache eviction / worker restart / stale delta base)
    wire_encode_s: float = 0.0
    wire_decode_s: float = 0.0
    wire_worker_codec_s: float = 0.0
    wire_transport_s: float = 0.0
    wire_bytes: int = 0
    wire_rounds: int = 0
    wire_fallbacks: int = 0
    # worker-loss rail (transport failures, repro.core.remote): losses
    # counts transport-level failures (dead worker, dropped socket,
    # timeout, truncated frame), inline_parts the partitions planned
    # locally on the loss-fallback path those rounds, reconnects the
    # workers that answered again after being down — every loss is
    # visible, never laundered into a silent retry
    wire_worker_losses: int = 0
    wire_reconnects: int = 0
    wire_inline_parts: int = 0
    # overlap-aware critical path of pipelined dispatch: per round, the
    # head request's encode + the slowest worker's codec bill + the
    # round's decode — the part of the wire bill that CANNOT hide behind
    # worker compute or other shards' encodes.  Reported beside the
    # serialized sums above, never in place of them.
    wire_overlap_s: float = 0.0
    # frames sent (a coalesced same-instant batch is one accounting
    # round but several frames; wire_frames >= wire_rounds)
    wire_frames: int = 0
    # client-side encode memoization: one hit/miss per cache
    # consultation (action segment, snapshot segment, shared-section
    # segment) — the steady-state hit rate is the CI-gated floor
    wire_memo_hits: int = 0
    wire_memo_misses: int = 0
    # worker-reported cache effectiveness, aggregated over plan
    # responses (intern/snapshot/resident-state hit counters plus
    # rebuild-vs-reset wall time); keys documented in remote.py
    wire_worker_cache: Dict[str, float] = field(default_factory=dict)
    # -- two-phase worker-owned commit (repro.core.remote) -------------------
    # the commit wall is split three ways so the critical-path model
    # stays honest: commit_wall_s is the CLIENT-side serial commit wall
    # (the client-serial engine's whole bill; in worker mode the
    # client's mirror replay lands in commit_apply_s instead),
    # commit_critical_s is the modeled worker-parallel commit critical
    # path (per fused round, the max worker-reported commit wall — what
    # the owning workers actually measured committing authoritatively),
    # and commit_apply_s is the client's mirror-apply + fingerprint-
    # verify wall (DES bookkeeping, never charged to decision latency).
    commit_wall_s: float = 0.0
    commit_critical_s: float = 0.0
    commit_apply_s: float = 0.0
    # two-phase frame counters: prepares dispatched (fused plan_commit
    # frames), acks verified clean, aborts decided (divergence, fence,
    # or mismatched fixpoint passes)
    wire_prepares: int = 0
    wire_commit_acks: int = 0
    wire_commit_aborts: int = 0
    # ownership-lease lifecycle: grants (first issue to a worker),
    # regrants (stale_epoch answered with a re-grant + full state),
    # adoptions (orphaned leases taken back inline after worker loss
    # mid-prepare), and fenced intents (handoff aborted an open window)
    wire_lease_grants: int = 0
    wire_lease_regrants: int = 0
    wire_lease_adoptions: int = 0
    wire_fenced_intents: int = 0
    # rounds the worker-owned engine declined (cross-owner footprints,
    # down workers, samplers) and committed client-serial instead
    commit_inline_rounds: int = 0
    # worker-committed state that failed client fingerprint verification
    # (the divergence rail: abort + regrant; client state stands)
    wire_commit_diverged: int = 0
    # -- sub-queue migration (Orchestrator.migrate_task/rebalance) -----------
    migrations: int = 0  # detach->merge moves between partition replicas
    migrated_actions: int = 0
    migration_wall_s: float = 0.0  # control-plane cost of the moves
    # telemetry-driven rebalance cadence (Orchestrator.enable_rebalance)
    rebalance_ticks: int = 0  # policy evaluations on the cadence
    rebalance_moves: int = 0  # sub-queue migrations those ticks ordered

    def record(self, rec: ActionRecord) -> None:
        self.records.append(rec)

    def note_plan_mode(self, mode: str, ewma_s: Optional[float]) -> None:
        """Log one auto plan-mode decision (and the EWMA that drove it)."""
        self.plan_mode_rounds[mode] = self.plan_mode_rounds.get(mode, 0) + 1
        if ewma_s is not None:
            self.plan_cost_ewma_s = ewma_s

    def note_migration(self, actions: int, wall_s: float) -> None:
        self.migrations += 1
        self.migrated_actions += actions
        self.migration_wall_s += wall_s

    def note_wire_round(
        self,
        encode_s: float,
        transport_s: float,
        decode_s: float,
        nbytes: int,
        worker_codec_s: float = 0.0,
        overlap_s: float = 0.0,
        frames: int = 1,
        new_round: bool = True,
    ) -> None:
        """One remote plan round's serialization accounting.

        ``new_round=False`` merges a same-instant frame batch into the
        previous accounting round: every cost still accrues, frames
        still count, but ``wire_rounds`` does not advance — so per-round
        derived figures (bytes/round) reflect scheduling instants, not
        frame count."""
        if new_round:
            self.wire_rounds += 1
        self.wire_frames += frames
        self.wire_encode_s += encode_s
        self.wire_transport_s += transport_s
        self.wire_decode_s += decode_s
        self.wire_worker_codec_s += worker_codec_s
        self.wire_overlap_s += overlap_s
        self.wire_bytes += nbytes

    def note_wire_memo(self, hits: int, misses: int) -> None:
        """Client encode-memo consultations for one round."""
        self.wire_memo_hits += hits
        self.wire_memo_misses += misses

    def note_worker_cache(self, stats: Dict[str, float]) -> None:
        """Fold one worker plan-response's cache counters into the
        run-wide aggregate (all keys are summable counts or seconds)."""
        acc = self.wire_worker_cache
        for k, v in stats.items():
            acc[k] = acc.get(k, 0.0) + float(v)

    def note_commit_round(
        self, worker_commit_s: float, apply_s: float, prepares: int, acks: int
    ) -> None:
        """One fused worker-owned commit round's accounting: the modeled
        worker-parallel commit critical path (max worker commit wall),
        the client mirror-apply/verify wall, and the frame counts."""
        self.commit_critical_s += worker_commit_s
        self.commit_apply_s += apply_s
        self.wire_prepares += prepares
        self.wire_commit_acks += acks

    def reset_wire(self) -> None:
        """Zero every wire + commit-phase counter (bench warm-up
        discards)."""
        self.commit_wall_s = 0.0
        self.commit_critical_s = 0.0
        self.commit_apply_s = 0.0
        self.wire_prepares = 0
        self.wire_commit_acks = 0
        self.wire_commit_aborts = 0
        self.wire_lease_grants = 0
        self.wire_lease_regrants = 0
        self.wire_lease_adoptions = 0
        self.wire_fenced_intents = 0
        self.commit_inline_rounds = 0
        self.wire_commit_diverged = 0
        self.wire_encode_s = 0.0
        self.wire_decode_s = 0.0
        self.wire_worker_codec_s = 0.0
        self.wire_transport_s = 0.0
        self.wire_bytes = 0
        self.wire_rounds = 0
        self.wire_fallbacks = 0
        self.wire_worker_losses = 0
        self.wire_reconnects = 0
        self.wire_inline_parts = 0
        self.wire_overlap_s = 0.0
        self.wire_frames = 0
        self.wire_memo_hits = 0
        self.wire_memo_misses = 0
        self.wire_worker_cache = {}

    def wire_summary(self) -> Dict[str, float]:
        """Aggregate wire overhead of remote plan phases ({} when the
        round engine never left the process)."""
        if not self.wire_rounds:
            return {}
        out = {
            "rounds": float(self.wire_rounds),
            "frames": float(self.wire_frames),
            "encode_s": self.wire_encode_s,
            "decode_s": self.wire_decode_s,
            "worker_codec_s": self.wire_worker_codec_s,
            "transport_s": self.wire_transport_s,
            "overlap_s": self.wire_overlap_s,
            "bytes": float(self.wire_bytes),
            "fallbacks": float(self.wire_fallbacks),
            "worker_losses": float(self.wire_worker_losses),
            "reconnects": float(self.wire_reconnects),
            "inline_parts": float(self.wire_inline_parts),
            "memo_hits": float(self.wire_memo_hits),
            "memo_misses": float(self.wire_memo_misses),
        }
        consulted = self.wire_memo_hits + self.wire_memo_misses
        if consulted:
            out["memo_hit_rate"] = self.wire_memo_hits / consulted
        if self.wire_prepares or self.wire_lease_grants:
            out["prepares"] = float(self.wire_prepares)
            out["commit_acks"] = float(self.wire_commit_acks)
            out["commit_aborts"] = float(self.wire_commit_aborts)
            out["lease_grants"] = float(self.wire_lease_grants)
            out["lease_regrants"] = float(self.wire_lease_regrants)
            out["lease_adoptions"] = float(self.wire_lease_adoptions)
            out["fenced_intents"] = float(self.wire_fenced_intents)
            out["commit_inline_rounds"] = float(self.commit_inline_rounds)
            out["commit_diverged"] = float(self.wire_commit_diverged)
            out["commit_critical_s"] = self.commit_critical_s
            out["commit_apply_s"] = self.commit_apply_s
        for k, v in sorted(self.wire_worker_cache.items()):
            out[f"worker_{k}"] = float(v)
        return out

    def note_shard_round(self, shard: int, partitions: int, plan_s: float) -> None:
        st = self.shards.setdefault(shard, ShardStats())
        st.rounds += 1
        st.partitions += partitions
        st.plan_s += plan_s

    def shard_summary(self) -> Dict[str, float]:
        """Aggregate shard balance: total/critical plan cost and the
        imbalance ratio (max shard plan time over the mean — 1.0 is a
        perfectly balanced fleet)."""
        if not self.shards:
            return {}
        costs = [s.plan_s for s in self.shards.values()]
        mean = statistics.fmean(costs)
        return {
            "shards": float(len(costs)),
            "plan_total_s": sum(costs),
            "plan_critical_s": self.plan_critical_s,
            "plan_wall_s": self.plan_wall_s,
            "imbalance": max(costs) / mean if mean > 0 else 1.0,
            "commit_conflicts": float(self.commit_conflicts),
        }

    # -- aggregates ---------------------------------------------------------
    def mean_act(self, task_id: Optional[str] = None) -> float:
        """Mean ACT; ``task_id`` restricts to one tenant's actions."""
        ok = [
            r.act
            for r in self.records
            if not r.failed and (task_id is None or r.task_id == task_id)
        ]
        return statistics.fmean(ok) if ok else math.nan

    # -- multi-tenant breakdowns -------------------------------------------
    def task_share(
        self, rtype: Optional[str] = None, until: Optional[float] = None
    ) -> Dict[str, float]:
        """Share of allocated resource-seconds per task (unit-seconds of
        ``rtype``, or of all resources when None), normalized to sum to
        1 over the recorded actions.  Under saturation this is the
        quantity weighted fair queueing drives toward ``w_i / sum w``;
        ``until`` restricts to actions finished by that time (use it to
        measure shares inside the saturated window — over a fully
        drained run the share is fixed by total work, not policy)."""
        acc: Dict[str, float] = {}
        for r in self.records:
            if r.failed or (until is not None and r.finish > until):
                continue
            units = r.units.get(rtype, 0) if rtype is not None else sum(r.units.values())
            if units <= 0:
                continue
            acc[r.task_id] = acc.get(r.task_id, 0.0) + units * max(0.0, r.exec_dur)
        total = sum(acc.values())
        if total <= 0:
            return {}
        return {t: v / total for t, v in acc.items()}

    def max_queue_dur(self, task_id: Optional[str] = None) -> float:
        """Worst observed queueing delay (recorded starvation age)."""
        qs = [
            r.queue_dur
            for r in self.records
            if not r.failed and (task_id is None or r.task_id == task_id)
        ]
        return max(qs) if qs else math.nan

    def per_task(self, rtype: Optional[str] = None) -> Dict[str, Dict[str, float]]:
        """One summary row per task: mean ACT, share-of-allocation,
        worst queueing delay (starvation age), and completed count."""
        share = self.task_share(rtype)
        tasks = sorted({r.task_id for r in self.records})
        return {
            t: {
                "mean_act": self.mean_act(t),
                "share": share.get(t, 0.0),
                "max_queue_dur": self.max_queue_dur(t),
                "completed": float(
                    sum(1 for r in self.records if r.task_id == t and not r.failed)
                ),
            }
            for t in tasks
        }

    def p(self, q: float) -> float:
        ok = sorted(r.act for r in self.records if not r.failed)
        if not ok:
            return math.nan
        idx = min(len(ok) - 1, int(q * len(ok)))
        return ok[idx]

    def breakdown(self) -> Dict[str, float]:
        ok = [r for r in self.records if not r.failed]
        if not ok:
            return {"exec": math.nan, "queue": math.nan, "overhead": math.nan}
        return {
            "exec": statistics.fmean(r.exec_dur for r in ok),
            "queue": statistics.fmean(r.queue_dur for r in ok),
            "overhead": statistics.fmean(r.sys_overhead for r in ok),
        }

    def failure_rate(self) -> float:
        if not self.records:
            return 0.0
        return sum(r.failed for r in self.records) / len(self.records)

    def act_timeline(self, window: float) -> List[Tuple[float, float]]:
        """Mean ACT per consecutive time window (paper Fig. 6)."""
        ok = sorted((r for r in self.records if not r.failed), key=lambda r: r.finish)
        out: List[Tuple[float, float]] = []
        if not ok:
            return out
        lo = ok[0].finish
        bucket: List[float] = []
        for r in ok:
            while r.finish >= lo + window:
                if bucket:
                    out.append((lo + window / 2, statistics.fmean(bucket)))
                    bucket = []
                lo += window
            bucket.append(r.act)
        if bucket:
            out.append((lo + window / 2, statistics.fmean(bucket)))
        return out

    def by_stage(self, stage_key: str = "stage") -> Dict[str, float]:
        """Mean ACT grouped by a metadata stage label (Fig. 7)."""
        groups: Dict[str, List[float]] = {}
        for r in self.records:
            if r.failed:
                continue
            stage = r.name.split(":")[0]
            groups.setdefault(stage, []).append(r.act)
        return {k: statistics.fmean(v) for k, v in groups.items()}
