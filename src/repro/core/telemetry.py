"""ACT / utilization telemetry shared by ARL-Tangram and the baselines."""

from __future__ import annotations

import math
import statistics
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass
class ActionRecord:
    name: str
    task_id: str
    trajectory_id: str
    submit: float
    start: float
    finish: float
    sys_overhead: float
    units: Dict[str, int]
    failed: bool = False
    retries: int = 0

    @property
    def queue_dur(self) -> float:
        return self.start - self.submit

    @property
    def exec_dur(self) -> float:
        return self.finish - self.start - self.sys_overhead

    @property
    def act(self) -> float:
        return self.finish - self.submit


@dataclass
class Telemetry:
    records: List[ActionRecord] = field(default_factory=list)
    sched_invocations: int = 0
    sched_wall_s: float = 0.0
    # -- action-lifecycle counters (orchestrator-maintained) ---------------
    timeouts: int = 0  # deadline expiries (each retry re-arms the deadline)
    retries: int = 0  # re-queues at the FCFS head after a timeout
    cancellations: int = 0

    def record(self, rec: ActionRecord) -> None:
        self.records.append(rec)

    # -- aggregates ---------------------------------------------------------
    def mean_act(self) -> float:
        ok = [r.act for r in self.records if not r.failed]
        return statistics.fmean(ok) if ok else math.nan

    def p(self, q: float) -> float:
        ok = sorted(r.act for r in self.records if not r.failed)
        if not ok:
            return math.nan
        idx = min(len(ok) - 1, int(q * len(ok)))
        return ok[idx]

    def breakdown(self) -> Dict[str, float]:
        ok = [r for r in self.records if not r.failed]
        if not ok:
            return {"exec": math.nan, "queue": math.nan, "overhead": math.nan}
        return {
            "exec": statistics.fmean(r.exec_dur for r in ok),
            "queue": statistics.fmean(r.queue_dur for r in ok),
            "overhead": statistics.fmean(r.sys_overhead for r in ok),
        }

    def failure_rate(self) -> float:
        if not self.records:
            return 0.0
        return sum(r.failed for r in self.records) / len(self.records)

    def act_timeline(self, window: float) -> List[Tuple[float, float]]:
        """Mean ACT per consecutive time window (paper Fig. 6)."""
        ok = sorted((r for r in self.records if not r.failed), key=lambda r: r.finish)
        out: List[Tuple[float, float]] = []
        if not ok:
            return out
        lo = ok[0].finish
        bucket: List[float] = []
        for r in ok:
            while r.finish >= lo + window:
                if bucket:
                    out.append((lo + window / 2, statistics.fmean(bucket)))
                    bucket = []
                lo += window
            bucket.append(r.act)
        if bucket:
            out.append((lo + window / 2, statistics.fmean(bucket)))
        return out

    def by_stage(self, stage_key: str = "stage") -> Dict[str, float]:
        """Mean ACT grouped by a metadata stage label (Fig. 7)."""
        groups: Dict[str, List[float]] = {}
        for r in self.records:
            if r.failed:
                continue
            stage = r.name.split(":")[0]
            groups.setdefault(stage, []).append(r.act)
        return {k: statistics.fmean(v) for k, v in groups.items()}
