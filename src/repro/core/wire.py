"""Versioned wire serialization for the distributed round engine.

The plan/commit engine (:mod:`repro.core.shards`) made arrangement
side-effect-free over manager snapshots; this module is what lets those
plans leave the process: plain ``dataclass <-> dict`` codecs — **no
pickle anywhere** — for every object that crosses the plan/commit
boundary:

* :class:`~repro.core.action.Action` (and its nested
  :class:`~repro.core.action.ResourceRequest` /
  :class:`~repro.core.action.Elasticity` models),
* :class:`~repro.core.scheduler.ScheduleResult` /
  :class:`~repro.core.scheduler.Decision` — decisions travel as
  ``(uid, units)`` pairs and are re-bound to the *live* Action objects
  at decode (the commit phase never trusts a remote object graph),
* :class:`~repro.core.shards.PartitionPlan`,
* :class:`~repro.core.fairqueue.TaskShard` (sub-queue migration),
* manager ``snapshot()`` payloads for all four manager families
  (``snapshot_state``/``restore_snapshot`` on the managers; this module
  owns the envelope + the impl registry),
* scheduling-policy and :class:`~repro.core.fairqueue.FairSharePolicy`
  configuration (so a remote worker can construct an equivalent
  policy).

Schema and compatibility rules (see ``docs/wire-protocol.md``):

* every top-level payload is an **envelope**
  ``{"v": WIRE_VERSION, "kind": "<type>", ...fields}``;
* decoders reject a payload whose ``v`` differs from their own
  :data:`WIRE_VERSION` or whose ``kind`` is not the expected one — a
  version bump is a breaking change by definition;
* decoders **ignore unknown fields** (additive evolution within a
  version is compatible); encoders always emit every schema field;
* a malformed payload raises :class:`WireError`, never a bare
  ``KeyError``/``TypeError`` — schema violations are protocol errors.

Doctest — an action survives the round trip identically:

>>> from repro.core.action import Action, fixed
>>> a = Action(name="tool", cost={"cpu": fixed("cpu", 2)},
...            base_duration=1.5, task_id="t0", trajectory_id="tr0")
>>> b = decode_action(encode_action(a))
>>> (b.uid, b.name, b.cost["cpu"].units) == (a.uid, "tool", (2,))
True
>>> encode_action(b) == encode_action(a)
True
"""

from __future__ import annotations

import hashlib
import json
import math
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple, Type

from repro.core.action import (
    Action,
    ActionState,
    AmdahlElasticity,
    Elasticity,
    LinearElasticity,
    ResourceRequest,
    TableElasticity,
)
from repro.core.fairqueue import FairSharePolicy, TaskShard
from repro.core.managers.base import ResourceManager
from repro.core.scheduler import Decision, ScheduleResult

#: Wire protocol version.  Decoders accept exactly this version; any
#: breaking change to a payload schema must bump it.
WIRE_VERSION = 1


class WireError(ValueError):
    """A payload violated the wire schema (wrong version/kind/field)."""


# ---------------------------------------------------------------------------
# envelope helpers
# ---------------------------------------------------------------------------


def envelope(kind: str, body: Dict[str, Any]) -> Dict[str, Any]:
    """Wrap ``body`` in the versioned envelope all top-level payloads use."""
    out = {"v": WIRE_VERSION, "kind": kind}
    out.update(body)
    return out


def expect(payload: Any, kind: str) -> Dict[str, Any]:
    """Validate the envelope of ``payload`` and return it.

    Raises :class:`WireError` on a non-dict payload, a version mismatch,
    or a kind mismatch — the three ways an incompatible peer shows up.
    """
    if not isinstance(payload, dict):
        raise WireError(f"{kind}: payload must be a dict, got {type(payload).__name__}")
    v = payload.get("v")
    if v != WIRE_VERSION:
        raise WireError(f"{kind}: wire version {v!r} != supported {WIRE_VERSION}")
    got = payload.get("kind")
    if got != kind:
        raise WireError(f"expected kind {kind!r}, got {got!r}")
    return payload


def _field(payload: Mapping[str, Any], kind: str, name: str) -> Any:
    try:
        return payload[name]
    except KeyError:
        raise WireError(f"{kind}: missing required field {name!r}") from None


def fingerprint(payload: Any) -> str:
    """Stable content hash of a JSON-able payload (delta suppression:
    a sender may replace an unchanged payload with ``{"ref": fp}``)."""
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha1(blob.encode()).hexdigest()


def dumps(payload: Any) -> str:
    """Serialize a payload to its wire string (Python-dialect JSON:
    ``NaN``/``Infinity`` literals are legal — unprofiled durations and
    unset timestamps travel as NaN)."""
    return json.dumps(payload, separators=(",", ":"))


def loads(blob: str) -> Any:
    """Parse a wire string produced by :func:`dumps`."""
    try:
        return json.loads(blob)
    except json.JSONDecodeError as e:
        raise WireError(f"malformed wire payload: {e}") from None


# ---------------------------------------------------------------------------
# actions (nested: resource requests, elasticity models)
# ---------------------------------------------------------------------------


def encode_request(req: ResourceRequest) -> Dict[str, Any]:
    return {"rtype": req.rtype, "units": list(req.units)}


def decode_request(payload: Mapping[str, Any]) -> ResourceRequest:
    if not isinstance(payload, Mapping):
        raise WireError("resource request must be a dict")
    return ResourceRequest(
        str(_field(payload, "request", "rtype")),
        tuple(int(u) for u in _field(payload, "request", "units")),
    )


def encode_elasticity(e: Elasticity) -> Dict[str, Any]:
    """Elasticity models travel by *name*, never by code: only the three
    library models are wire-legal.  A custom subclass must be registered
    here (and versioned) before it can cross a process boundary."""
    if isinstance(e, AmdahlElasticity):
        return {"model": "amdahl", "serial": e.serial}
    if isinstance(e, TableElasticity):
        return {"model": "table", "knots": [[int(m), float(r)] for m, r in e.table]}
    if isinstance(e, LinearElasticity):
        return {"model": "linear"}
    raise WireError(f"elasticity model {type(e).__name__} is not wire-serializable")


def decode_elasticity(payload: Mapping[str, Any]) -> Elasticity:
    model = _field(payload, "elasticity", "model")
    if model == "amdahl":
        return AmdahlElasticity(serial=float(_field(payload, "elasticity", "serial")))
    if model == "table":
        knots = _field(payload, "elasticity", "knots")
        return TableElasticity(tuple((int(m), float(r)) for m, r in knots))
    if model == "linear":
        return LinearElasticity()
    raise WireError(f"unknown elasticity model {model!r}")


#: JSON-scalar types allowed in wire-transported action metadata.
_SCALARS = (str, int, float, bool, type(None))


def _wire_metadata(meta: Mapping[str, Any]) -> Dict[str, Any]:
    """The JSON-scalar, non-private slice of an action's metadata.

    Planning reads only scalar hints (``traj_mem_gb``); derived caches
    (underscore keys, e.g. the ``_dp_durs`` duration memo) are local and
    recomputed on the far side, and non-scalar payloads never cross."""
    return {
        k: v
        for k, v in meta.items()
        if not k.startswith("_") and isinstance(v, _SCALARS)
    }


def encode_action(a: Action) -> Dict[str, Any]:
    """Encode the schedulable surface of an action.

    Execution payloads (``fn``, ``duration_sampler``) are host-local by
    design and do NOT cross — planning never calls them, and the commit
    phase re-binds decisions to the live Action that still carries them.
    """
    return envelope(
        "action",
        {
            "uid": a.uid,
            "name": a.name,
            "cost": {r: encode_request(req) for r, req in a.cost.items()},
            "key_resource": a.key_resource,
            "elasticity": None if a.elasticity is None else encode_elasticity(a.elasticity),
            "base_duration": a.base_duration,
            "task_id": a.task_id,
            "trajectory_id": a.trajectory_id,
            "weight": a.weight,
            "service": a.service,
            "timeout_s": a.timeout_s,
            "max_retries": a.max_retries,
            "state": a.state.value,
            "submit_time": a.submit_time,
            "start_time": a.start_time,
            "finish_time": a.finish_time,
            "sys_overhead": a.sys_overhead,
            "attempts": a.attempts,
            "metadata": _wire_metadata(a.metadata),
        },
    )


def decode_action(payload: Mapping[str, Any]) -> Action:
    p = expect(payload, "action")
    cost = {
        str(r): decode_request(req) for r, req in _field(p, "action", "cost").items()
    }
    el = p.get("elasticity")
    a = Action(
        name=str(_field(p, "action", "name")),
        cost=cost,
        key_resource=p.get("key_resource"),
        elasticity=None if el is None else decode_elasticity(el),
        base_duration=p.get("base_duration"),
        task_id=str(p.get("task_id", "task0")),
        trajectory_id=str(p.get("trajectory_id", "traj0")),
        weight=p.get("weight"),
        service=p.get("service"),
        timeout_s=p.get("timeout_s"),
        max_retries=int(p.get("max_retries", 0)),
        metadata=dict(p.get("metadata", {})),
        uid=int(_field(p, "action", "uid")),
    )
    try:
        a.state = ActionState(p.get("state", "pending"))
    except ValueError:
        raise WireError(f"action: unknown state {p.get('state')!r}") from None
    a.submit_time = float(p.get("submit_time", math.nan))
    a.start_time = float(p.get("start_time", math.nan))
    a.finish_time = float(p.get("finish_time", math.nan))
    a.sys_overhead = float(p.get("sys_overhead", 0.0))
    a.attempts = int(p.get("attempts", 0))
    return a


# ---------------------------------------------------------------------------
# plans (decisions travel as uid references, re-bound at decode)
# ---------------------------------------------------------------------------


def encode_decision(d: Decision) -> Dict[str, Any]:
    return {"uid": d.action.uid, "units": {r: int(u) for r, u in d.units.items()}}


def encode_schedule_result(r: ScheduleResult) -> Dict[str, Any]:
    return {
        "decisions": [encode_decision(d) for d in r.decisions],
        "objective": r.objective,
        "evicted": r.evicted,
    }


def decode_schedule_result(
    payload: Mapping[str, Any], by_uid: Mapping[int, Action]
) -> ScheduleResult:
    decisions: List[Decision] = []
    for d in _field(payload, "schedule_result", "decisions"):
        uid = int(_field(d, "decision", "uid"))
        action = by_uid.get(uid)
        if action is None:
            raise WireError(f"decision references unknown action uid {uid}")
        decisions.append(
            Decision(action, {str(r): int(u) for r, u in d.get("units", {}).items()})
        )
    return ScheduleResult(
        decisions=decisions,
        objective=float(payload.get("objective", 0.0)),
        evicted=int(payload.get("evicted", 0)),
    )


def encode_plan(plan: "Any") -> Dict[str, Any]:
    """Encode a :class:`~repro.core.shards.PartitionPlan` (imported by
    duck type to keep this module cycle-free with shards.py)."""
    return envelope(
        "partition_plan",
        {
            "part": plan.part,
            "held": plan.held,
            "wall_s": plan.wall_s,
            "shard": plan.shard,
            "planned": plan.planned,
            "result": (
                None if plan.result is None else encode_schedule_result(plan.result)
            ),
        },
    )


def decode_plan(payload: Mapping[str, Any], by_uid: Mapping[int, Action]) -> "Any":
    from repro.core.shards import PartitionPlan

    p = expect(payload, "partition_plan")
    result = p.get("result")
    return PartitionPlan(
        part=str(_field(p, "partition_plan", "part")),
        result=None if result is None else decode_schedule_result(result, by_uid),
        held=int(p.get("held", 0)),
        wall_s=float(p.get("wall_s", 0.0)),
        shard=int(p.get("shard", 0)),
        planned=bool(p.get("planned", True)),
    )


# ---------------------------------------------------------------------------
# sub-queue migration: TaskShard
# ---------------------------------------------------------------------------


def encode_task_shard(shard: TaskShard) -> Dict[str, Any]:
    """A detached WFQ sub-queue in transit between partition replicas.

    Entries keep their original ``(vstart, seq)`` tags — the whole point
    of the detach/merge seam is that tags are self-contained, so the
    receiving replica only needs a monotone clock sync to drain fairly.
    """
    return envelope(
        "task_shard",
        {
            "task_id": shard.task_id,
            "finish_tag": shard.finish_tag,
            "vtime": shard.vtime,
            "entries": [
                {"key": [key[0], key[1]], "action": encode_action(a)}
                for key, a in shard.entries
            ],
        },
    )


def decode_task_shard(payload: Mapping[str, Any]) -> TaskShard:
    p = expect(payload, "task_shard")
    entries: List[Tuple[Tuple[float, int], Action]] = []
    for e in _field(p, "task_shard", "entries"):
        key = _field(e, "task_shard entry", "key")
        if not isinstance(key, (list, tuple)) or len(key) != 2:
            raise WireError(f"task_shard: malformed tag {key!r}")
        entries.append(
            ((float(key[0]), int(key[1])), decode_action(_field(e, "task_shard entry", "action")))
        )
    return TaskShard(
        task_id=str(_field(p, "task_shard", "task_id")),
        entries=entries,
        finish_tag=float(p.get("finish_tag", 0.0)),
        vtime=float(p.get("vtime", 0.0)),
    )


# ---------------------------------------------------------------------------
# manager snapshots
# ---------------------------------------------------------------------------

#: Wire-impl registry: payload ``impl`` tag -> manager class that can
#: rebuild a plan-capable snapshot from the state dict.  Populated
#: lazily to avoid importing every manager at module load.
_SNAPSHOT_IMPLS: Optional[Dict[str, Type[ResourceManager]]] = None


def _snapshot_impls() -> Dict[str, Type[ResourceManager]]:
    global _SNAPSHOT_IMPLS
    if _SNAPSHOT_IMPLS is None:
        from repro.core.managers.basic import BasicResourceManager
        from repro.core.managers.cpu import CpuManager
        from repro.core.managers.gpu import GpuManager

        _SNAPSHOT_IMPLS = {
            ResourceManager.wire_impl: ResourceManager,
            CpuManager.wire_impl: CpuManager,
            GpuManager.wire_impl: GpuManager,
            BasicResourceManager.wire_impl: BasicResourceManager,
        }
    return _SNAPSHOT_IMPLS


def encode_snapshot(manager: ResourceManager) -> Dict[str, Any]:
    """Serialize a manager's plan-phase free state.

    Dispatches on the manager's ``wire_impl`` tag; a custom subclass
    inherits its family's codec, which round-trips exactly the plan
    surface (:meth:`ResourceManager.snapshot` contract) — overridden
    placement behaviour stays host-side, where placement happens.
    """
    impl = getattr(manager, "wire_impl", None)
    if impl not in _snapshot_impls():
        raise WireError(
            f"manager {type(manager).__name__} has no wire snapshot impl"
        )
    return envelope(
        "snapshot",
        {"rtype": manager.rtype, "impl": impl, "state": manager.snapshot_state()},
    )


def decode_snapshot(payload: Mapping[str, Any]) -> ResourceManager:
    """Rebuild a plan-capable manager snapshot from its wire payload.

    The returned object supports the plan surface only (the same
    contract as :meth:`ResourceManager.snapshot`); calling placement on
    it is a programming error, exactly as for in-process snapshots.
    """
    p = expect(payload, "snapshot")
    impl = _field(p, "snapshot", "impl")
    cls = _snapshot_impls().get(impl)
    if cls is None:
        raise WireError(f"unknown snapshot impl {impl!r}")
    return cls.restore_snapshot(_field(p, "snapshot", "state"))


# ---------------------------------------------------------------------------
# policy configuration (so a remote worker builds an equivalent policy)
# ---------------------------------------------------------------------------


def encode_fair_share(fs: Optional[FairSharePolicy]) -> Optional[Dict[str, Any]]:
    if fs is None:
        return None
    return {
        "weights": dict(fs.weights),
        "default_weight": fs.default_weight,
        "quota": dict(fs.quota),
        "preempt_scalable": fs.preempt_scalable,
        "share_slack": fs.share_slack,
    }


def decode_fair_share(payload: Optional[Mapping[str, Any]]) -> Optional[FairSharePolicy]:
    if payload is None:
        return None
    return FairSharePolicy(
        weights={str(k): float(v) for k, v in payload.get("weights", {}).items()},
        default_weight=float(payload.get("default_weight", 1.0)),
        quota={str(k): float(v) for k, v in payload.get("quota", {}).items()},
        preempt_scalable=bool(payload.get("preempt_scalable", True)),
        share_slack=float(payload.get("share_slack", 0.1)),
    )


def encode_policy(policy: Any) -> Dict[str, Any]:
    """Policy config by *name + knobs* — code never crosses the wire.

    Only the library policies are wire-legal; a custom policy must be
    registered here before the remote plan phase can run it.
    """
    from repro.core.baselines import FcfsPolicy, StaticDopPolicy
    from repro.core.scheduler import ElasticScheduler

    if isinstance(policy, ElasticScheduler):
        return envelope(
            "policy",
            {
                "type": "elastic",
                "depth": policy.depth,
                "candidate_limit": policy.candidate_limit,
                "estimate_units": policy.estimate_units,
                "eviction_search": policy.eviction_search,
                "cache_dp": policy.cache_dp,
                "use_dense": policy.use_dense,
                "dense_backend": policy.dense_backend,
                "dop_floor": policy.dop_floor,
                "floor_pressure": policy.floor_pressure,
                # the policy's OWN fairness knobs (may be set even when
                # the orchestrator runs plain FCFS queues)
                "fair_share": encode_fair_share(policy.fair_share),
            },
        )
    if isinstance(policy, StaticDopPolicy):  # subclass of Fcfs — test first
        return envelope(
            "policy",
            {"type": "static_dop", "dop": policy.dop,
             "candidate_limit": policy.candidate_limit},
        )
    if isinstance(policy, FcfsPolicy):
        return envelope(
            "policy", {"type": "fcfs", "candidate_limit": policy.candidate_limit}
        )
    raise WireError(f"policy {type(policy).__name__} is not wire-serializable")


def decode_policy(payload: Mapping[str, Any]) -> Any:
    from repro.core.baselines import FcfsPolicy, StaticDopPolicy
    from repro.core.scheduler import ElasticScheduler

    p = expect(payload, "policy")
    ptype = _field(p, "policy", "type")
    if ptype == "elastic":
        policy = ElasticScheduler(
            depth=int(p.get("depth", 2)),
            candidate_limit=int(p.get("candidate_limit", 128)),
            estimate_units=str(p.get("estimate_units", "min")),
            cache_dp=p.get("cache_dp"),
        )
        policy.eviction_search = str(p.get("eviction_search", "greedy"))
        policy.use_dense = bool(p.get("use_dense", True))
        policy.dense_backend = p.get("dense_backend")
        policy.dop_floor = p.get("dop_floor")
        fp = p.get("floor_pressure", math.inf)
        policy.floor_pressure = math.inf if fp is None else float(fp)
        policy.fair_share = decode_fair_share(p.get("fair_share"))
        return policy
    if ptype == "static_dop":
        return StaticDopPolicy(
            dop=int(p.get("dop", 4)),
            candidate_limit=int(p.get("candidate_limit", 128)),
        )
    if ptype == "fcfs":
        return FcfsPolicy(candidate_limit=int(p.get("candidate_limit", 128)))
    raise WireError(f"unknown policy type {ptype!r}")


# ---------------------------------------------------------------------------
# convenience: uid index over live actions (commit-side re-binding)
# ---------------------------------------------------------------------------


def uid_index(actions: Sequence[Action]) -> Dict[int, Action]:
    """uid -> live Action map used to re-bind decoded decisions."""
    return {a.uid: a for a in actions}
