"""Versioned wire serialization for the distributed round engine.

The plan/commit engine (:mod:`repro.core.shards`) made arrangement
side-effect-free over manager snapshots; this module is what lets those
plans leave the process: plain ``dataclass <-> dict`` codecs — **no
pickle anywhere** — for every object that crosses the plan/commit
boundary:

* :class:`~repro.core.action.Action` (and its nested
  :class:`~repro.core.action.ResourceRequest` /
  :class:`~repro.core.action.Elasticity` models),
* :class:`~repro.core.scheduler.ScheduleResult` /
  :class:`~repro.core.scheduler.Decision` — decisions travel as
  ``(uid, units)`` pairs and are re-bound to the *live* Action objects
  at decode (the commit phase never trusts a remote object graph),
* :class:`~repro.core.shards.PartitionPlan`,
* :class:`~repro.core.fairqueue.TaskShard` (sub-queue migration),
* manager ``snapshot()`` payloads for all four manager families
  (``snapshot_state``/``restore_snapshot`` on the managers; this module
  owns the envelope + the impl registry),
* scheduling-policy and :class:`~repro.core.fairqueue.FairSharePolicy`
  configuration (so a remote worker can construct an equivalent
  policy).

Schema and compatibility rules (see ``docs/wire-protocol.md``):

* every top-level payload is an **envelope**
  ``{"v": WIRE_VERSION, "kind": "<type>", ...fields}``;
* decoders reject a payload whose ``v`` differs from their own
  :data:`WIRE_VERSION` or whose ``kind`` is not the expected one — a
  version bump is a breaking change by definition;
* decoders **ignore unknown fields** (additive evolution within a
  version is compatible); encoders always emit every schema field;
* a malformed payload raises :class:`WireError`, never a bare
  ``KeyError``/``TypeError`` — schema violations are protocol errors.

Doctest — an action survives the round trip identically:

>>> from repro.core.action import Action, fixed
>>> a = Action(name="tool", cost={"cpu": fixed("cpu", 2)},
...            base_duration=1.5, task_id="t0", trajectory_id="tr0")
>>> b = decode_action(encode_action(a))
>>> (b.uid, b.name, b.cost["cpu"].units) == (a.uid, "tool", (2,))
True
>>> encode_action(b) == encode_action(a)
True
"""

from __future__ import annotations

import copy
import hashlib
import json
import math
import struct
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple, Type

from repro.core.action import (
    Action,
    ActionState,
    AmdahlElasticity,
    Elasticity,
    LinearElasticity,
    ResourceRequest,
    TableElasticity,
)
from repro.core.fairqueue import FairSharePolicy, TaskShard
from repro.core.managers.base import ResourceManager
from repro.core.scheduler import Decision, ScheduleResult

#: Wire protocol version.  Decoders accept exactly this version; any
#: breaking change to a payload schema must bump it.
WIRE_VERSION = 1


class WireError(ValueError):
    """A payload violated the wire schema (wrong version/kind/field)."""


#: Hard ceiling on a single transport frame (either direction).  A
#: length prefix above it is rejected before any allocation — a
#: corrupted or hostile prefix must never make a receiver try to
#: buffer gigabytes.  Generous vs real traffic: the delta protocol
#: keeps steady-state rounds in the tens of KB.
MAX_FRAME_BYTES = 64 << 20


class TransportError(WireError):
    """A transport-level failure moving a frame (not a schema error).

    Carries a machine-readable ``code`` so the round client can treat
    every transport as one failure domain:

    ==================  ====================================================
    code                meaning
    ==================  ====================================================
    ``connect``         could not reach the peer (refused / DNS / timeout)
    ``read_timeout``    peer reachable but no frame within the read timeout
    ``truncated_frame`` peer closed (or died) mid-frame
    ``frame_too_large`` length prefix exceeds :data:`MAX_FRAME_BYTES`
    ``reset``           connection reset / broken pipe / worker died
    ``closed``          this transport was already closed locally
    ==================  ====================================================

    Every code is recovered the same way by
    :class:`~repro.core.remote.RemoteRoundClient`: the worker's
    partitions fall back to inline planning for the round, the
    transport is torn down, and reconnection is retried with bounded
    round-based backoff."""

    def __init__(self, code: str, message: str) -> None:
        super().__init__(message)
        self.code = code


# ---------------------------------------------------------------------------
# envelope helpers
# ---------------------------------------------------------------------------


def envelope(kind: str, body: Dict[str, Any]) -> Dict[str, Any]:
    """Wrap ``body`` in the versioned envelope all top-level payloads use."""
    out = {"v": WIRE_VERSION, "kind": kind}
    out.update(body)
    return out


def expect(payload: Any, kind: str) -> Dict[str, Any]:
    """Validate the envelope of ``payload`` and return it.

    Raises :class:`WireError` on a non-dict payload, a version mismatch,
    or a kind mismatch — the three ways an incompatible peer shows up.
    """
    if not isinstance(payload, dict):
        raise WireError(f"{kind}: payload must be a dict, got {type(payload).__name__}")
    v = payload.get("v")
    if v != WIRE_VERSION:
        raise WireError(f"{kind}: wire version {v!r} != supported {WIRE_VERSION}")
    got = payload.get("kind")
    if got != kind:
        raise WireError(f"expected kind {kind!r}, got {got!r}")
    return payload


def _field(payload: Mapping[str, Any], kind: str, name: str) -> Any:
    try:
        return payload[name]
    except KeyError:
        raise WireError(f"{kind}: missing required field {name!r}") from None


def _canon(obj: Any, out: List[str]) -> None:
    """Append the canonical text of a JSON-able payload to ``out``.

    Canonical form is what makes :func:`fingerprint` a *content* hash
    rather than an encoding hash: dict keys are sorted, ``-0.0``
    collapses to ``0.0``, integral floats hash like the equal int
    (``2.0`` == ``2``), and every NaN maps to one fixed token (NaN
    compares unequal to itself, so repr-based hashing would let two
    equal payloads diverge).  Equal payloads therefore always collide,
    regardless of key order, float spelling, or which side built them.
    """
    if obj is None:
        out.append("n")
    elif obj is True:
        out.append("t")
    elif obj is False:
        out.append("f")
    elif isinstance(obj, int):
        out.append(repr(obj))
    elif isinstance(obj, float):
        if math.isnan(obj):
            out.append("NaN")
        elif obj == 0.0:
            out.append("0")  # -0.0 == 0.0 must collide
        elif math.isinf(obj):
            out.append("Inf" if obj > 0 else "-Inf")
        elif obj.is_integer() and abs(obj) < 2**53:
            out.append(repr(int(obj)))
        else:
            out.append(repr(obj))
    elif isinstance(obj, str):
        # length-prefixed raw text: unambiguous without per-string
        # escaping (json.dumps per leaf dominated fingerprint cost)
        out.append(f"s{len(obj)}:{obj}")
    elif isinstance(obj, (list, tuple)):
        out.append("[")
        for x in obj:
            _canon(x, out)
            out.append(",")
        out.append("]")
    elif isinstance(obj, dict) or isinstance(obj, Mapping):
        out.append("{")
        for k in sorted(obj):
            ks = str(k)
            out.append(f"s{len(ks)}:{ks}")
            out.append(":")
            _canon(obj[k], out)
            out.append(",")
        out.append("}")
    else:
        raise WireError(f"fingerprint: non-JSON-able value {type(obj).__name__}")


def fingerprint(payload: Any) -> str:
    """Stable content hash of a JSON-able payload (delta suppression:
    a sender may replace an unchanged payload with ``{"ref": fp}``).

    Hashes the *canonical form* (see :func:`_canon`): equal payloads
    always produce equal fingerprints even when key order or float
    encoding differ between the two sides."""
    chunks: List[str] = []
    _canon(payload, chunks)
    return hashlib.sha1("".join(chunks).encode()).hexdigest()


def list_fingerprint(member_fps: Sequence[str]) -> str:
    """Order-sensitive digest of a sequence of member fingerprints —
    the identity of an action *list* for cross-round list deltas.  Two
    lists collide exactly when they hold the same members in the same
    order (member fingerprints embed each action's uid, so distinct
    live actions never alias)."""
    return hashlib.sha1("|".join(member_fps).encode()).hexdigest()


def dumps(payload: Any) -> str:
    """Serialize a payload to its wire string (Python-dialect JSON:
    ``NaN``/``Infinity`` literals are legal — unprofiled durations and
    unset timestamps travel as NaN)."""
    return json.dumps(payload, separators=(",", ":"))


def loads(blob: str) -> Any:
    """Parse a wire string produced by :func:`dumps`."""
    try:
        return json.loads(blob)
    except json.JSONDecodeError as e:
        raise WireError(f"malformed wire payload: {e}") from None


# ---------------------------------------------------------------------------
# compact binary framing (codec="binary"; JSON stays the v1 compat path)
# ---------------------------------------------------------------------------

#: First byte of a binary frame.  0xB1 is a UTF-8 *continuation* byte,
#: so no valid JSON text can start with it — :func:`decode_frame` sniffs
#: this one byte to route between the binary codec and the JSON path.
WIRE_MAGIC = 0xB1

#: Wire codec names accepted end to end (Orchestrator ``wire_codec``).
WIRE_CODECS = ("json", "binary")

# value tags of the binary frame body
_T_NULL, _T_FALSE, _T_TRUE = 0x00, 0x01, 0x02
_T_INT, _T_FLOAT, _T_STR = 0x03, 0x04, 0x05
_T_LIST, _T_DICT, _T_SREF = 0x06, 0x07, 0x08
_T_INTS, _T_FLOATS = 0x09, 0x0A
_T_BLOB = 0x0B  # spliced pre-encoded segment (length-prefixed sub-frame)

_F64 = struct.Struct(">d")


class Encoded:
    """A pre-encoded wire segment, splice-ready.

    The encode-memoization layer (remote.RemoteRoundClient) caches the
    *bytes* of sections whose fingerprints it already tracks for delta
    suppression — a full snapshot envelope, an interned action payload,
    the policy config — and assembles request frames by splicing those
    cached segments instead of re-serializing the payload tree.  A
    segment is codec-specific: ``"json"`` holds the exact
    :func:`dumps` text (splicing byte-joins it, so a spliced frame is
    byte-identical to a plain one), ``"binary"`` holds a standalone
    sub-frame body with its *own* string table (frame-level string
    interning is positional, so a segment cannot reuse the enclosing
    frame's table) framed by the :data:`_T_BLOB` tag.  Decoders never
    see the difference: a spliced frame decodes to the identical
    payload tree."""

    __slots__ = ("codec", "blob")

    def __init__(self, codec: str, blob: bytes) -> None:
        self.codec = codec
        self.blob = blob

    def __len__(self) -> int:
        return len(self.blob)


def encode_segment(payload: Any, codec: str = "json") -> Encoded:
    """Pre-encode one payload subtree for frame splicing (see
    :class:`Encoded`)."""
    if codec == "json":
        return Encoded("json", dumps(payload).encode("utf-8"))
    if codec != "binary":
        raise WireError(f"unknown wire codec {codec!r} (have {WIRE_CODECS})")
    out = bytearray()
    _enc_value(payload, out, {})
    return Encoded("binary", bytes(out))


def _uvarint(n: int, out: bytearray) -> None:
    while n > 0x7F:
        out.append((n & 0x7F) | 0x80)
        n >>= 7
    out.append(n)


def _zz_big(n: int) -> int:  # arbitrary-precision zigzag
    return n << 1 if n >= 0 else ((-n) << 1) - 1


def _enc_value(obj: Any, out: bytearray, strings: Dict[str, int]) -> None:
    """One value of the binary frame.  Strings are interned at frame
    level: the first occurrence travels inline (and registers itself in
    the table, on both sides), every repeat is a table reference — the
    hot dict keys (``uid``, ``state``, ...) are paid for once per frame.
    Homogeneous int/float lists pack as columns (no per-element tags)."""
    if obj is None:
        out.append(_T_NULL)
    elif obj is True:
        out.append(_T_TRUE)
    elif obj is False:
        out.append(_T_FALSE)
    elif isinstance(obj, int):
        out.append(_T_INT)
        _uvarint(_zz_big(obj), out)
    elif isinstance(obj, float):
        out.append(_T_FLOAT)
        out += _F64.pack(obj)
    elif isinstance(obj, str):
        idx = strings.get(obj)
        if idx is not None:
            out.append(_T_SREF)
            _uvarint(idx, out)
        else:
            strings[obj] = len(strings)
            raw = obj.encode("utf-8")
            out.append(_T_STR)
            _uvarint(len(raw), out)
            out += raw
    elif isinstance(obj, Encoded):
        if obj.codec != "binary":
            raise WireError(
                f"binary frame: cannot splice a {obj.codec!r} segment"
            )
        out.append(_T_BLOB)
        _uvarint(len(obj.blob), out)
        out += obj.blob
    elif isinstance(obj, (list, tuple)):
        if obj and all(type(x) is int for x in obj):
            out.append(_T_INTS)
            _uvarint(len(obj), out)
            for x in obj:
                _uvarint(_zz_big(x), out)
        elif obj and all(type(x) is float for x in obj):
            out.append(_T_FLOATS)
            _uvarint(len(obj), out)
            for x in obj:
                out += _F64.pack(x)
        else:
            out.append(_T_LIST)
            _uvarint(len(obj), out)
            for x in obj:
                _enc_value(x, out, strings)
    elif isinstance(obj, dict) or isinstance(obj, Mapping):
        out.append(_T_DICT)
        _uvarint(len(obj), out)
        for k, v in obj.items():
            if not isinstance(k, str):
                raise WireError(f"binary frame: non-str dict key {k!r}")
            _enc_value(k, out, strings)
            _enc_value(v, out, strings)
    else:
        raise WireError(
            f"binary frame: unsupported value type {type(obj).__name__}"
        )


class _FrameReader:
    __slots__ = ("blob", "pos", "strings")

    def __init__(self, blob: bytes, pos: int) -> None:
        self.blob = blob
        self.pos = pos
        self.strings: List[str] = []

    def _uvarint(self) -> int:
        n = shift = 0
        blob, pos = self.blob, self.pos
        try:
            while True:
                b = blob[pos]
                pos += 1
                n |= (b & 0x7F) << shift
                if not b & 0x80:
                    break
                shift += 7
        except IndexError:
            raise WireError("binary frame: truncated varint") from None
        self.pos = pos
        return n

    def _unzig(self) -> int:
        n = self._uvarint()
        return (n >> 1) if not n & 1 else -((n + 1) >> 1)

    def value(self) -> Any:
        blob = self.blob
        try:
            tag = blob[self.pos]
        except IndexError:
            raise WireError("binary frame: truncated value") from None
        self.pos += 1
        if tag == _T_NULL:
            return None
        if tag == _T_TRUE:
            return True
        if tag == _T_FALSE:
            return False
        if tag == _T_INT:
            return self._unzig()
        if tag == _T_FLOAT:
            pos = self.pos
            self.pos = pos + 8
            try:
                return _F64.unpack_from(blob, pos)[0]
            except struct.error:
                raise WireError("binary frame: truncated float") from None
        if tag == _T_STR:
            n = self._uvarint()
            pos = self.pos
            self.pos = pos + n
            if self.pos > len(blob):
                raise WireError("binary frame: truncated string")
            s = blob[pos : pos + n].decode("utf-8")
            self.strings.append(s)
            return s
        if tag == _T_SREF:
            idx = self._uvarint()
            try:
                return self.strings[idx]
            except IndexError:
                raise WireError(
                    f"binary frame: string ref {idx} out of range"
                ) from None
        if tag == _T_LIST:
            return [self.value() for _ in range(self._uvarint())]
        if tag == _T_INTS:
            return [self._unzig() for _ in range(self._uvarint())]
        if tag == _T_FLOATS:
            n = self._uvarint()
            pos = self.pos
            self.pos = pos + 8 * n
            try:
                return [
                    _F64.unpack_from(blob, pos + 8 * i)[0] for i in range(n)
                ]
            except struct.error:
                raise WireError("binary frame: truncated float column") from None
        if tag == _T_DICT:
            n = self._uvarint()
            out: Dict[str, Any] = {}
            for _ in range(n):
                k = self.value()
                if not isinstance(k, str):
                    raise WireError("binary frame: non-str dict key")
                out[k] = self.value()
            return out
        if tag == _T_BLOB:
            n = self._uvarint()
            end = self.pos + n
            if end > len(blob):
                raise WireError("binary frame: truncated segment")
            # a segment is a standalone sub-frame: fresh string table
            sub = _FrameReader(blob, self.pos)
            v = sub.value()
            if sub.pos != end:
                raise WireError(
                    f"binary frame: segment length mismatch "
                    f"({sub.pos - self.pos} != {n})"
                )
            self.pos = end
            return v
        raise WireError(f"binary frame: unknown value tag 0x{tag:02x}")


def _json_splice(obj: Any, out: List[bytes]) -> None:
    """Byte-join a payload tree that may contain :class:`Encoded` json
    segments, producing output byte-identical to ``dumps`` over the
    fully materialized tree (same separators, same float spelling, same
    key order) — cached segment bytes are appended verbatim."""
    if isinstance(obj, Encoded):
        if obj.codec != "json":
            raise WireError(f"json frame: cannot splice a {obj.codec!r} segment")
        out.append(obj.blob)
    elif isinstance(obj, dict):
        out.append(b"{")
        first = True
        for k, v in obj.items():
            if not isinstance(k, str):
                raise WireError(f"json splice: non-str dict key {k!r}")
            out.append((b"," if not first else b"") + dumps(k).encode("utf-8") + b":")
            first = False
            _json_splice(v, out)
        out.append(b"}")
    elif isinstance(obj, (list, tuple)):
        out.append(b"[")
        for i, v in enumerate(obj):
            if i:
                out.append(b",")
            _json_splice(v, out)
        out.append(b"]")
    else:
        out.append(dumps(obj).encode("utf-8"))


def encode_frame(payload: Any, codec: str = "json") -> bytes:
    """Serialize a payload to transport bytes in the chosen codec.

    ``"json"`` is the :data:`WIRE_VERSION` = 1 compatibility path
    (UTF-8 :func:`dumps` text, the property-test reference);
    ``"binary"`` is the compact tag/varint frame with frame-level
    string interning and packed int/float columns.  Both decode through
    :func:`decode_frame`, which sniffs the leading byte — binary frames
    start with :data:`WIRE_MAGIC`, which can never begin UTF-8 text.

    A payload may embed :class:`Encoded` segments of the same codec
    (the client's encode-memo cache); the json path splices them by
    byte-join (``dumps`` fails fast on the wrapper type, so segment-free
    frames stay on the C encoder), the binary path by the
    :data:`_T_BLOB` tag.  Either way the frame decodes to the payload
    tree with every segment expanded in place."""
    if codec == "json":
        try:
            return dumps(payload).encode("utf-8")
        except TypeError:
            buf: List[bytes] = []
            _json_splice(payload, buf)
            return b"".join(buf)
    if codec != "binary":
        raise WireError(f"unknown wire codec {codec!r} (have {WIRE_CODECS})")
    out = bytearray([WIRE_MAGIC])
    _enc_value(payload, out, {})
    return bytes(out)


def frame_codec(blob: bytes) -> str:
    """The codec a frame was encoded with (a responder answers in kind)."""
    return "binary" if blob[:1] == bytes([WIRE_MAGIC]) else "json"


def decode_frame(blob: bytes) -> Any:
    """Parse transport bytes from either codec (magic-byte sniffing)."""
    if not blob:
        raise WireError("empty wire frame")
    if blob[0] == WIRE_MAGIC:
        reader = _FrameReader(blob, 1)
        value = reader.value()
        if reader.pos != len(blob):
            raise WireError(
                f"binary frame: {len(blob) - reader.pos} trailing bytes"
            )
        return value
    try:
        text = blob.decode("utf-8")
    except UnicodeDecodeError as e:
        raise WireError(f"malformed wire frame: {e}") from None
    return loads(text)


# ---------------------------------------------------------------------------
# actions (nested: resource requests, elasticity models)
# ---------------------------------------------------------------------------


def encode_request(req: ResourceRequest) -> Dict[str, Any]:
    return {"rtype": req.rtype, "units": list(req.units)}


def decode_request(payload: Mapping[str, Any]) -> ResourceRequest:
    if not isinstance(payload, Mapping):
        raise WireError("resource request must be a dict")
    return ResourceRequest(
        str(_field(payload, "request", "rtype")),
        tuple(int(u) for u in _field(payload, "request", "units")),
    )


def encode_elasticity(e: Elasticity) -> Dict[str, Any]:
    """Elasticity models travel by *name*, never by code: only the three
    library models are wire-legal.  A custom subclass must be registered
    here (and versioned) before it can cross a process boundary."""
    if isinstance(e, AmdahlElasticity):
        return {"model": "amdahl", "serial": e.serial}
    if isinstance(e, TableElasticity):
        return {"model": "table", "knots": [[int(m), float(r)] for m, r in e.table]}
    if isinstance(e, LinearElasticity):
        return {"model": "linear"}
    raise WireError(f"elasticity model {type(e).__name__} is not wire-serializable")


def decode_elasticity(payload: Mapping[str, Any]) -> Elasticity:
    model = _field(payload, "elasticity", "model")
    if model == "amdahl":
        return AmdahlElasticity(serial=float(_field(payload, "elasticity", "serial")))
    if model == "table":
        knots = _field(payload, "elasticity", "knots")
        return TableElasticity(tuple((int(m), float(r)) for m, r in knots))
    if model == "linear":
        return LinearElasticity()
    raise WireError(f"unknown elasticity model {model!r}")


#: JSON-scalar types allowed in wire-transported action metadata.
_SCALARS = (str, int, float, bool, type(None))


def _wire_metadata(meta: Mapping[str, Any]) -> Dict[str, Any]:
    """The JSON-scalar, non-private slice of an action's metadata.

    Planning reads only scalar hints (``traj_mem_gb``); derived caches
    (underscore keys, e.g. the ``_dp_durs`` duration memo) are local and
    recomputed on the far side, and non-scalar payloads never cross."""
    return {
        k: v
        for k, v in meta.items()
        if not k.startswith("_") and isinstance(v, _SCALARS)
    }


def encode_action(a: Action) -> Dict[str, Any]:
    """Encode the schedulable surface of an action.

    Execution payloads (``fn``, ``duration_sampler``) are host-local by
    design and do NOT cross — planning never calls them, and the commit
    phase re-binds decisions to the live Action that still carries them.
    """
    return envelope(
        "action",
        {
            "uid": a.uid,
            "name": a.name,
            "cost": {r: encode_request(req) for r, req in a.cost.items()},
            "key_resource": a.key_resource,
            "elasticity": None if a.elasticity is None else encode_elasticity(a.elasticity),
            "base_duration": a.base_duration,
            "task_id": a.task_id,
            "trajectory_id": a.trajectory_id,
            "weight": a.weight,
            "service": a.service,
            "timeout_s": a.timeout_s,
            "max_retries": a.max_retries,
            "state": a.state.value,
            "submit_time": a.submit_time,
            "start_time": a.start_time,
            "finish_time": a.finish_time,
            "sys_overhead": a.sys_overhead,
            "attempts": a.attempts,
            "metadata": _wire_metadata(a.metadata),
        },
    )


def decode_action(payload: Mapping[str, Any]) -> Action:
    p = expect(payload, "action")
    cost = {
        str(r): decode_request(req) for r, req in _field(p, "action", "cost").items()
    }
    el = p.get("elasticity")
    a = Action(
        name=str(_field(p, "action", "name")),
        cost=cost,
        key_resource=p.get("key_resource"),
        elasticity=None if el is None else decode_elasticity(el),
        base_duration=p.get("base_duration"),
        task_id=str(p.get("task_id", "task0")),
        trajectory_id=str(p.get("trajectory_id", "traj0")),
        weight=p.get("weight"),
        service=p.get("service"),
        timeout_s=p.get("timeout_s"),
        max_retries=int(p.get("max_retries", 0)),
        metadata=dict(p.get("metadata", {})),
        uid=int(_field(p, "action", "uid")),
    )
    try:
        a.state = ActionState(p.get("state", "pending"))
    except ValueError:
        raise WireError(f"action: unknown state {p.get('state')!r}") from None
    a.submit_time = float(p.get("submit_time", math.nan))
    a.start_time = float(p.get("start_time", math.nan))
    a.finish_time = float(p.get("finish_time", math.nan))
    a.sys_overhead = float(p.get("sys_overhead", 0.0))
    a.attempts = int(p.get("attempts", 0))
    return a


#: The mutable action fields a patch-define may carry.  Everything else
#: on the wire surface (uid, cost, elasticity, ids, weights...) is
#: immutable for an action's lifetime, which is exactly why a lifecycle
#: transition can travel as a tiny diff against the previously interned
#: version instead of a full re-define.
PATCH_TIME_FIELDS = ("submit_time", "start_time", "finish_time", "sys_overhead")


def patch_action(base: Action, d: Mapping[str, Any]) -> Action:
    """Materialize a patch-define: a *clone* of the interned ``base``
    action with the diff ``d`` applied.

    The clone is shallow except for ``metadata`` — the interned base is
    shared with every cached list that references it, so it must never
    be mutated in place.  Underscore metadata (the ``_dp_durs`` duration
    memo) carries over: it depends only on immutable fields, exactly the
    reuse argument the intern table itself rests on, and matches the
    serial loop where a live action's memo survives its lifecycle
    transitions.  ``d["metadata"]``, when present, replaces the whole
    wire-visible scalar slice (the client re-sends it on any change)."""
    a = copy.copy(base)
    a.metadata = dict(base.metadata)
    md = d.get("metadata")
    if md is not None:
        kept = {k: v for k, v in a.metadata.items() if k.startswith("_")}
        kept.update(md)
        a.metadata = kept
    st = d.get("state")
    if st is not None:
        try:
            a.state = ActionState(st)
        except ValueError:
            raise WireError(f"action patch: unknown state {st!r}") from None
    if "attempts" in d:
        a.attempts = int(d["attempts"])
    for f in PATCH_TIME_FIELDS:
        if f in d:
            setattr(a, f, float(d[f]))
    return a


# ---------------------------------------------------------------------------
# plans (decisions travel as uid references, re-bound at decode)
# ---------------------------------------------------------------------------


def encode_decision(d: Decision) -> Dict[str, Any]:
    return {"uid": d.action.uid, "units": {r: int(u) for r, u in d.units.items()}}


def encode_schedule_result(r: ScheduleResult) -> Dict[str, Any]:
    return {
        "decisions": [encode_decision(d) for d in r.decisions],
        "objective": r.objective,
        "evicted": r.evicted,
    }


def decode_schedule_result(
    payload: Mapping[str, Any], by_uid: Mapping[int, Action]
) -> ScheduleResult:
    decisions: List[Decision] = []
    for d in _field(payload, "schedule_result", "decisions"):
        uid = int(_field(d, "decision", "uid"))
        action = by_uid.get(uid)
        if action is None:
            raise WireError(f"decision references unknown action uid {uid}")
        decisions.append(
            Decision(action, {str(r): int(u) for r, u in d.get("units", {}).items()})
        )
    return ScheduleResult(
        decisions=decisions,
        objective=float(payload.get("objective", 0.0)),
        evicted=int(payload.get("evicted", 0)),
    )


def encode_plan(plan: "Any") -> Dict[str, Any]:
    """Encode a :class:`~repro.core.shards.PartitionPlan` (imported by
    duck type to keep this module cycle-free with shards.py)."""
    return envelope(
        "partition_plan",
        {
            "part": plan.part,
            "held": plan.held,
            "wall_s": plan.wall_s,
            "shard": plan.shard,
            "planned": plan.planned,
            "result": (
                None if plan.result is None else encode_schedule_result(plan.result)
            ),
        },
    )


def decode_plan(payload: Mapping[str, Any], by_uid: Mapping[int, Action]) -> "Any":
    from repro.core.shards import PartitionPlan

    p = expect(payload, "partition_plan")
    result = p.get("result")
    return PartitionPlan(
        part=str(_field(p, "partition_plan", "part")),
        result=None if result is None else decode_schedule_result(result, by_uid),
        held=int(p.get("held", 0)),
        wall_s=float(p.get("wall_s", 0.0)),
        shard=int(p.get("shard", 0)),
        planned=bool(p.get("planned", True)),
    )


# ---------------------------------------------------------------------------
# worker-owned commit: ownership leases + commit outcomes (additive v1)
# ---------------------------------------------------------------------------


def encode_lease(
    rtype: str, epoch: int, fresh: bool = False, fp: Optional[str] = None
) -> Dict[str, Any]:
    """One epoch-stamped ownership lease over a resource type.

    A lease names the worker that may commit against the authoritative
    replica of ``rtype``.  ``epoch`` increments on every ownership
    change (grant, revocation, fence, adoption after a worker loss) —
    a worker presented with an epoch it does not hold must refuse with
    a typed ``stale_epoch`` error before mutating anything.  ``fresh``
    marks a (re-)grant: the worker adopts the epoch instead of
    asserting it (the authoritative state travels in the same frame
    through the ordinary snapshot rail).  ``fp`` optionally pins the
    snapshot fingerprint the replica must match under this lease."""
    body: Dict[str, Any] = {"rtype": str(rtype), "epoch": int(epoch)}
    if fresh:
        body["fresh"] = True
    if fp is not None:
        body["fp"] = fp
    return body


def decode_lease(payload: Mapping[str, Any]) -> Tuple[str, int, bool, Optional[str]]:
    """Inverse of :func:`encode_lease` →  (rtype, epoch, fresh, fp)."""
    return (
        str(_field(payload, "lease", "rtype")),
        int(_field(payload, "lease", "epoch")),
        bool(payload.get("fresh", False)),
        payload.get("fp"),
    )


def encode_commit_outcome(
    part: str,
    launched: Sequence[Tuple[int, Mapping[str, int]]],
    failed: int,
    held: int,
) -> Dict[str, Any]:
    """One partition's committed outcome inside a ``plan_commit_response``
    pass: which intents launched (uid + the granted unit vector — the
    grant may differ from the planned decision after the quota clamp),
    how many were refused by the committing replicas (conflicts), and
    how many the quota gate held."""
    return {
        "part": str(part),
        "launched": [[int(uid), {r: int(u) for r, u in units.items()}]
                     for uid, units in launched],
        "failed": int(failed),
        "held": int(held),
    }


def decode_commit_outcome(
    payload: Mapping[str, Any],
) -> Tuple[str, List[Tuple[int, Dict[str, int]]], int, int]:
    """Inverse of :func:`encode_commit_outcome` →
    (part, launched, failed, held)."""
    launched = [
        (int(uid), {str(r): int(u) for r, u in units.items()})
        for uid, units in payload.get("launched", [])
    ]
    return (
        str(_field(payload, "commit_outcome", "part")),
        launched,
        int(payload.get("failed", 0)),
        int(payload.get("held", 0)),
    )


# ---------------------------------------------------------------------------
# sub-queue migration: TaskShard
# ---------------------------------------------------------------------------


def encode_task_shard(shard: TaskShard) -> Dict[str, Any]:
    """A detached WFQ sub-queue in transit between partition replicas.

    Entries keep their original ``(vstart, seq)`` tags — the whole point
    of the detach/merge seam is that tags are self-contained, so the
    receiving replica only needs a monotone clock sync to drain fairly.
    """
    return envelope(
        "task_shard",
        {
            "task_id": shard.task_id,
            "finish_tag": shard.finish_tag,
            "vtime": shard.vtime,
            "entries": [
                {"key": [key[0], key[1]], "action": encode_action(a)}
                for key, a in shard.entries
            ],
        },
    )


def decode_task_shard(payload: Mapping[str, Any]) -> TaskShard:
    p = expect(payload, "task_shard")
    entries: List[Tuple[Tuple[float, int], Action]] = []
    for e in _field(p, "task_shard", "entries"):
        key = _field(e, "task_shard entry", "key")
        if not isinstance(key, (list, tuple)) or len(key) != 2:
            raise WireError(f"task_shard: malformed tag {key!r}")
        entries.append(
            ((float(key[0]), int(key[1])), decode_action(_field(e, "task_shard entry", "action")))
        )
    return TaskShard(
        task_id=str(_field(p, "task_shard", "task_id")),
        entries=entries,
        finish_tag=float(p.get("finish_tag", 0.0)),
        vtime=float(p.get("vtime", 0.0)),
    )


# ---------------------------------------------------------------------------
# manager snapshots
# ---------------------------------------------------------------------------

#: Wire-impl registry: payload ``impl`` tag -> manager class that can
#: rebuild a plan-capable snapshot from the state dict.  Populated
#: lazily to avoid importing every manager at module load.
_SNAPSHOT_IMPLS: Optional[Dict[str, Type[ResourceManager]]] = None


def _snapshot_impls() -> Dict[str, Type[ResourceManager]]:
    global _SNAPSHOT_IMPLS
    if _SNAPSHOT_IMPLS is None:
        from repro.core.managers.basic import BasicResourceManager
        from repro.core.managers.cpu import CpuManager
        from repro.core.managers.gpu import GpuManager

        _SNAPSHOT_IMPLS = {
            ResourceManager.wire_impl: ResourceManager,
            CpuManager.wire_impl: CpuManager,
            GpuManager.wire_impl: GpuManager,
            BasicResourceManager.wire_impl: BasicResourceManager,
        }
    return _SNAPSHOT_IMPLS


def encode_snapshot(manager: ResourceManager) -> Dict[str, Any]:
    """Serialize a manager's plan-phase free state.

    Dispatches on the manager's ``wire_impl`` tag; a custom subclass
    inherits its family's codec, which round-trips exactly the plan
    surface (:meth:`ResourceManager.snapshot` contract) — overridden
    placement behaviour stays host-side, where placement happens.
    """
    impl = getattr(manager, "wire_impl", None)
    if impl not in _snapshot_impls():
        raise WireError(
            f"manager {type(manager).__name__} has no wire snapshot impl"
        )
    return envelope(
        "snapshot",
        {"rtype": manager.rtype, "impl": impl, "state": manager.snapshot_state()},
    )


def decode_snapshot(payload: Mapping[str, Any]) -> ResourceManager:
    """Rebuild a plan-capable manager snapshot from its wire payload.

    The returned object supports the plan surface only (the same
    contract as :meth:`ResourceManager.snapshot`); calling placement on
    it is a programming error, exactly as for in-process snapshots.
    """
    p = expect(payload, "snapshot")
    impl = _field(p, "snapshot", "impl")
    cls = _snapshot_impls().get(impl)
    if cls is None:
        raise WireError(f"unknown snapshot impl {impl!r}")
    return cls.restore_snapshot(_field(p, "snapshot", "state"))


# ---------------------------------------------------------------------------
# structural snapshot deltas (wire cost proportional to what changed)
# ---------------------------------------------------------------------------


def encode_snapshot_delta(
    manager: ResourceManager,
    prev_state: Mapping[str, Any],
    cur_state: Mapping[str, Any],
    base_fp: str,
    cur_fp: str,
) -> Dict[str, Any]:
    """Delta envelope: the structural diff ``prev_state -> cur_state``
    for one manager, dispatched to the manager family's
    ``snapshot_delta`` twin.  ``base`` fingerprints the full snapshot
    payload the receiver must already hold; ``fp`` fingerprints the full
    payload the delta must reconstruct — the receiver verifies it, and a
    mismatch (stale or corrupted base) falls back to a full snapshot via
    the typed-error path, never a silently wrong plan."""
    impl = getattr(manager, "wire_impl", None)
    cls = _snapshot_impls().get(impl)
    if cls is None:
        raise WireError(f"manager {type(manager).__name__} has no wire snapshot impl")
    return envelope(
        "snapshot_delta",
        {
            "rtype": manager.rtype,
            "impl": impl,
            "base": base_fp,
            "fp": cur_fp,
            "delta": cls.snapshot_delta(prev_state, cur_state),
        },
    )


def apply_snapshot_delta(
    payload: Mapping[str, Any], base_snapshot: Mapping[str, Any]
) -> Dict[str, Any]:
    """Reconstruct the full ``snapshot`` envelope a delta describes.

    ``base_snapshot`` is the cached full snapshot envelope whose
    fingerprint the sender named in ``base`` (the caller checks that
    before calling).  The reconstruction is fingerprint-verified against
    the delta's ``fp`` — apply never returns a state the sender did not
    hash, so a buggy diff can only fail loudly."""
    p = expect(payload, "snapshot_delta")
    impl = _field(p, "snapshot_delta", "impl")
    cls = _snapshot_impls().get(impl)
    if cls is None:
        raise WireError(f"unknown snapshot impl {impl!r}")
    state = cls.apply_delta(
        _field(base_snapshot, "snapshot", "state"),
        _field(p, "snapshot_delta", "delta"),
    )
    snap = envelope(
        "snapshot",
        {"rtype": str(_field(p, "snapshot_delta", "rtype")), "impl": impl,
         "state": state},
    )
    if fingerprint(snap) != _field(p, "snapshot_delta", "fp"):
        raise WireError(
            f"snapshot delta for {p['rtype']!r} reconstructed a state whose "
            "fingerprint does not match the sender's"
        )
    return snap


# ---------------------------------------------------------------------------
# cross-round payload interning (actions and other repeated payloads)
# ---------------------------------------------------------------------------


def intern_def(fp: str, payload: Any, nbytes: Optional[int] = None) -> Dict[str, Any]:
    """First wire appearance of an interned payload: define-and-use.
    ``fp`` is the canonical fingerprint of the *fully resolved* payload;
    the receiver stores ``payload`` under it and every later round may
    say ``{"iref": fp}`` instead.  ``n`` carries the sender's byte
    accounting so both sides' LRU budgets see identical sizes (the
    receiver falls back to measuring when absent)."""
    out: Dict[str, Any] = {"idef": fp, "val": payload}
    if nbytes is not None:
        out["n"] = int(nbytes)
    return out


def intern_ref(fp: str) -> Dict[str, str]:
    """Reference to a payload the receiver's intern table already holds."""
    return {"iref": fp}


def intern_patch(
    fp: str, base_fp: str, d: Dict[str, Any], nbytes: Optional[int] = None
) -> Dict[str, Any]:
    """Patch-define: intern ``fp`` as the ``base_fp`` payload the
    receiver already holds, with the mutable-field diff ``d`` applied
    (see :func:`patch_action`).  An action's lifecycle transition
    (queued → running, a retry bump) then travels as a handful of
    changed fields instead of a full re-define.  A receiver missing
    ``base_fp`` treats it exactly like a missed ``iref`` — collected
    into the atomic ``stale_intern`` error — and the sender's recovery
    full re-send needs no new machinery."""
    out: Dict[str, Any] = {"idef": fp, "base": base_fp, "d": d}
    if nbytes is not None:
        out["n"] = int(nbytes)
    return out


def resolve_interned(node: Any, table: "LruBytes", missing: List[str]) -> Any:
    """Resolve ``idef``/``iref`` wrappers (recursively) against an
    intern table.  Definitions are stored and unwrapped; references are
    looked up — a miss collects the fingerprint into ``missing`` (and
    yields None) so the caller can answer with one typed ``stale_intern``
    error naming every payload it needs re-sent."""
    if isinstance(node, dict):
        if "iref" in node and len(node) == 1:
            hit = table.get(node["iref"])
            if hit is None:
                missing.append(str(node["iref"]))
            return hit
        if "idef" in node and "val" in node:
            val = resolve_interned(node["val"], table, missing)
            nbytes = node.get("n") or payload_nbytes(val)
            table.put(str(node["idef"]), val, int(nbytes))
            return val
    return node


def payload_nbytes(payload: Any) -> int:
    """Approximate in-memory wire size of a payload (byte-budget LRU
    accounting).  JSON text length is a stable, codec-independent proxy;
    exactness is not needed — the budget bounds growth, it does not
    meter allocations."""
    try:
        return len(json.dumps(payload, separators=(",", ":")))
    except (TypeError, ValueError):
        return 256


class LruBytes:
    """A byte-budget LRU map (worker intern table / snapshot cache, and
    the client's mirror of each worker's table).

    Eviction is deterministic — strict least-recently-*touched* order
    with an exact running byte total — so a client holding a same-budget
    mirror, touching keys in the same order the worker does, predicts
    the worker's evictions exactly.  A divergence (worker restart) is
    not silent: the worker answers a missed ref with a typed error and
    the client re-sends, so the mirror is an optimization, never a
    correctness dependency."""

    def __init__(self, budget_bytes: int = 8 << 20) -> None:
        if budget_bytes <= 0:
            raise ValueError(f"budget_bytes must be > 0, got {budget_bytes}")
        self.budget = int(budget_bytes)
        self._items: Dict[str, Tuple[Any, int]] = {}  # insertion = LRU order
        self._nbytes = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._items)

    def __contains__(self, key: str) -> bool:
        return key in self._items

    @property
    def nbytes(self) -> int:
        return self._nbytes

    def get(self, key: str) -> Any:
        """Value for ``key`` (refreshing its recency), or None."""
        item = self._items.pop(key, None)
        if item is None:
            return None
        self._items[key] = item  # re-append = most recent
        return item[0]

    def put(self, key: str, value: Any, nbytes: int) -> None:
        old = self._items.pop(key, None)
        if old is not None:
            self._nbytes -= old[1]
        self._items[key] = (value, int(nbytes))
        self._nbytes += int(nbytes)
        # evict least-recently-touched until under budget; a single
        # over-budget entry is kept (the table must stay usable)
        while self._nbytes > self.budget and len(self._items) > 1:
            oldest = next(iter(self._items))
            _, freed = self._items.pop(oldest)
            self._nbytes -= freed
            self.evictions += 1

    def pop(self, key: str) -> None:
        item = self._items.pop(key, None)
        if item is not None:
            self._nbytes -= item[1]

    def clear(self) -> None:
        self._items.clear()
        self._nbytes = 0


# ---------------------------------------------------------------------------
# policy configuration (so a remote worker builds an equivalent policy)
# ---------------------------------------------------------------------------


def encode_fair_share(fs: Optional[FairSharePolicy]) -> Optional[Dict[str, Any]]:
    if fs is None:
        return None
    return {
        "weights": dict(fs.weights),
        "default_weight": fs.default_weight,
        "quota": dict(fs.quota),
        "preempt_scalable": fs.preempt_scalable,
        "share_slack": fs.share_slack,
    }


def decode_fair_share(payload: Optional[Mapping[str, Any]]) -> Optional[FairSharePolicy]:
    if payload is None:
        return None
    return FairSharePolicy(
        weights={str(k): float(v) for k, v in payload.get("weights", {}).items()},
        default_weight=float(payload.get("default_weight", 1.0)),
        quota={str(k): float(v) for k, v in payload.get("quota", {}).items()},
        preempt_scalable=bool(payload.get("preempt_scalable", True)),
        share_slack=float(payload.get("share_slack", 0.1)),
    )


def encode_policy(policy: Any) -> Dict[str, Any]:
    """Policy config by *name + knobs* — code never crosses the wire.

    Only the library policies are wire-legal; a custom policy must be
    registered here before the remote plan phase can run it.
    """
    from repro.core.baselines import FcfsPolicy, StaticDopPolicy
    from repro.core.scheduler import ElasticScheduler

    if isinstance(policy, ElasticScheduler):
        return envelope(
            "policy",
            {
                "type": "elastic",
                "depth": policy.depth,
                "candidate_limit": policy.candidate_limit,
                "estimate_units": policy.estimate_units,
                "eviction_search": policy.eviction_search,
                "cache_dp": policy.cache_dp,
                "use_dense": policy.use_dense,
                "dense_backend": policy.dense_backend,
                "dop_floor": policy.dop_floor,
                "floor_pressure": policy.floor_pressure,
                # the policy's OWN fairness knobs (may be set even when
                # the orchestrator runs plain FCFS queues)
                "fair_share": encode_fair_share(policy.fair_share),
            },
        )
    if isinstance(policy, StaticDopPolicy):  # subclass of Fcfs — test first
        return envelope(
            "policy",
            {"type": "static_dop", "dop": policy.dop,
             "candidate_limit": policy.candidate_limit},
        )
    if isinstance(policy, FcfsPolicy):
        return envelope(
            "policy", {"type": "fcfs", "candidate_limit": policy.candidate_limit}
        )
    raise WireError(f"policy {type(policy).__name__} is not wire-serializable")


def decode_policy(payload: Mapping[str, Any]) -> Any:
    from repro.core.baselines import FcfsPolicy, StaticDopPolicy
    from repro.core.scheduler import ElasticScheduler

    p = expect(payload, "policy")
    ptype = _field(p, "policy", "type")
    if ptype == "elastic":
        policy = ElasticScheduler(
            depth=int(p.get("depth", 2)),
            candidate_limit=int(p.get("candidate_limit", 128)),
            estimate_units=str(p.get("estimate_units", "min")),
            cache_dp=p.get("cache_dp"),
        )
        policy.eviction_search = str(p.get("eviction_search", "greedy"))
        policy.use_dense = bool(p.get("use_dense", True))
        policy.dense_backend = p.get("dense_backend")
        policy.dop_floor = p.get("dop_floor")
        fp = p.get("floor_pressure", math.inf)
        policy.floor_pressure = math.inf if fp is None else float(fp)
        policy.fair_share = decode_fair_share(p.get("fair_share"))
        return policy
    if ptype == "static_dop":
        return StaticDopPolicy(
            dop=int(p.get("dop", 4)),
            candidate_limit=int(p.get("candidate_limit", 128)),
        )
    if ptype == "fcfs":
        return FcfsPolicy(candidate_limit=int(p.get("candidate_limit", 128)))
    raise WireError(f"unknown policy type {ptype!r}")


# ---------------------------------------------------------------------------
# convenience: uid index over live actions (commit-side re-binding)
# ---------------------------------------------------------------------------


def uid_index(actions: Sequence[Action]) -> Dict[int, Action]:
    """uid -> live Action map used to re-bind decoded decisions."""
    return {a.uid: a for a in actions}
