"""Multi-tenant fair-share queueing (weighted start-time fair queueing).

The paper's action-level formulation assumes external resources are
*shared across tasks*; with the orchestrator's per-resource partitioned
queues still draining pure FCFS, one task's burst of actions starves
every other task's actions on the same partition — the head-of-line
pathology §3 motivates, reappearing one level up.  This module adds the
fairness layer:

* :class:`FairSharePolicy` — the knob set: per-task ``weights`` (service
  share ∝ weight under saturation), optional hard ``quota`` caps
  (fraction of a partition's capacity a task may hold), and
  ``preempt_scalable`` (a task over its fair share has its scalable
  DoP>1 allocations shrunk before any under-share task's actions are
  deferred — see :meth:`ElasticScheduler._greedy_eviction`).
* :class:`PartitionQueue` — one per scheduling partition: per-task
  sub-queues drained by **start-time fair queueing** (SFQ).  Every
  arrival gets a virtual-time tag ``S = max(V, F_task)`` and
  ``F_task = S + cost / weight``; pick-next is the minimum start tag
  (O(log T) across T task sub-queues); the virtual clock ``V`` advances
  to the tag of the action actually entering service.  Cost is measured
  in resource-seconds (min units × estimated duration), so a task
  burning big/long actions is charged proportionally more virtual time
  than one issuing short probes.

Single-task equivalence (the refactor's safety rail): with one task the
tags are strictly monotone in arrival order, so the drain order — and
therefore the candidate window, the DP input, and the launch trace — is
**bit-identical** to the plain FCFS deque this structure replaced
(equivalence-tested in ``tests/test_fairness.py`` and gated in CI by the
fairness-smoke benchmark).  ``fair=False`` degenerates to exactly the
FCFS deque (tags collapse to the arrival sequence number), which is the
multi-task fairness *ablation*.

The per-task sub-queue is also the unit the ROADMAP's async/distributed
rounds will shard: a sub-queue's tags are self-contained, so a remote
shard only needs the partition's virtual clock to merge.
"""

from __future__ import annotations

import math
from bisect import bisect_left, insort
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.action import Action

#: Floor on weights/costs so a zero never stalls the virtual clock.
_EPS = 1e-9


@dataclass
class FairSharePolicy:
    """Knob set for multi-tenant weighted sharing.

    ``weights``: task_id -> relative weight (default ``default_weight``);
    under saturation a task's service share of each partition tracks
    ``w_i / sum_j w_j`` over the tasks with backlog.  ``quota``:
    task_id -> cap, as a fraction of a partition's capacity, on the
    units a task may hold concurrently — enforced twice: min-unit
    admission is budgeted per round, and elastic grants are clamped
    down to the budget at launch.  Progress rail: a task holding
    nothing always gets one action at min units even when the cap is
    smaller than its min requirement (a sub-min quota degrades to
    one-action-at-a-time, never to a silent permanent hold).
    ``preempt_scalable``: allow the scheduler to
    shrink an over-share task's scalable (DoP>1) allocations to minimum
    units before any under-share task's actions are deferred by
    eviction.  ``share_slack``: relative tolerance band around the
    weighted fair share before a task counts as over-share.
    """

    weights: Dict[str, float] = field(default_factory=dict)
    default_weight: float = 1.0
    quota: Dict[str, float] = field(default_factory=dict)
    preempt_scalable: bool = True
    share_slack: float = 0.1

    def weight_of(self, action_or_task: object) -> float:
        """Weight for an action (its own ``weight`` wins) or a task id."""
        if isinstance(action_or_task, Action):
            if action_or_task.weight is not None:
                return max(_EPS, float(action_or_task.weight))
            task_id = action_or_task.task_id
        else:
            task_id = str(action_or_task)
        return max(_EPS, float(self.weights.get(task_id, self.default_weight)))

    def quota_of(self, task_id: str) -> float:
        return float(self.quota.get(task_id, math.inf))


@dataclass
class TaskShard:
    """A detached per-task sub-queue in transit between partition
    replicas (see :meth:`PartitionQueue.detach_task`).  Tags are
    self-contained — merging needs only a monotone virtual-clock sync
    — and the payload is wire-serializable
    (:func:`repro.core.wire.encode_task_shard`), so a sub-queue can
    move between processes, not just between queues.

    >>> from repro.core.action import Action, fixed
    >>> src = PartitionQueue(fair=True)
    >>> dst = PartitionQueue(fair=True)
    >>> a = Action(name="x", cost={"r": fixed("r")}, task_id="mover",
    ...            trajectory_id="t0")
    >>> src.push(a)
    >>> shard = src.detach_task("mover")
    >>> (len(src), shard.task_id, len(shard.entries))
    (0, 'mover', 1)
    >>> dst.merge_shard(shard)
    >>> [x.name for x in dst.ordered()]
    ['x']
    >>> dst.vtime >= shard.vtime  # clock sync is monotone
    True
    """

    task_id: str
    entries: List[Tuple[Tuple[float, int], Action]]
    finish_tag: float  # the task's virtual finish chain at detach
    vtime: float  # the source partition's clock at detach


def default_cost(action: Action, rtype: Optional[str]) -> float:
    """SFQ service cost in resource-seconds the action will actually
    occupy at its minimum allocation: min units of the partition's
    resource × estimated duration AT that allocation (1.0 when
    unprofiled).  Using the elastic min-unit duration — not the 1-unit
    base — matters: charging a scalable action its un-sped-up base would
    over-bill elastic tenants in virtual time and hand their share to
    rigid ones."""
    units = 1
    if rtype is not None:
        req = action.cost.get(rtype)
        if req is not None:
            units = req.min_units
    if action.base_duration is None:
        dur = 1.0
    elif rtype is not None and rtype == action.key_resource:
        dur = action.get_dur(action.cost[rtype].min_units)
    else:
        dur = action.base_duration
    return max(_EPS, units * dur)


class PartitionQueue:
    """Per-task sub-queues drained by weighted start-time fair queueing.

    Mutations are O(log n) tag work plus one insertion into the cached
    merged order (a sorted list — arrivals of one task never force the
    other tasks' sub-queues to be re-tagged or re-merged, which is what
    keeps a task's arrival from dirtying anything but its own
    sub-queue).  Removals are lazy tombstones; the merged order compacts
    when more than half its entries are stale.  ``fair=False`` orders by
    the global arrival sequence alone — the pre-fairness FCFS deque.
    """

    def __init__(
        self,
        fair: bool = False,
        weight_of: Optional[Callable[[Action], float]] = None,
        cost_of: Optional[Callable[[Action], float]] = None,
    ) -> None:
        self.fair = fair
        self._weight_of = weight_of or (lambda a: 1.0)
        self._cost_of = cost_of or (lambda a: 1.0)
        # --- sub-queues + tags -------------------------------------------
        self._subs: Dict[str, "OrderedDict[int, Action]"] = {}
        self._uid_task: Dict[int, str] = {}
        self._key: Dict[int, Tuple[float, int]] = {}  # uid -> (vstart, seq)
        self._task_finish: Dict[str, float] = {}  # task -> last finish tag
        self._vtime = 0.0  # partition virtual clock
        self._seq = 0  # ascending for appends
        self._head_seq = 0  # descending for at-head requeues
        # --- merged-order cache (sorted by key; stale entries tombstoned)
        self._order: List[Tuple[Tuple[float, int], Action]] = []
        self._stale = 0
        self.compactions = 0  # telemetry: full rebuilds of the merge
        # bumped on every membership mutation (push / remove / detach /
        # merge).  Tags are fixed at admission, so an unchanged version
        # means ordered() yields the identical sequence — callers may
        # cache derived views (the wire encoder does) against it.
        self.version = 0

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._uid_task)

    def __bool__(self) -> bool:
        return bool(self._uid_task)

    def __contains__(self, uid: int) -> bool:
        return uid in self._uid_task

    @property
    def vtime(self) -> float:
        return self._vtime

    def tag_of(self, uid: int) -> Optional[Tuple[float, int]]:
        return self._key.get(uid)

    def tasks(self) -> List[str]:
        return [t for t, sub in self._subs.items() if sub]

    # ------------------------------------------------------------------
    def push(self, action: Action, at_head: bool = False) -> None:
        self.version += 1
        task = action.task_id
        sub = self._subs.setdefault(task, OrderedDict())
        if not self.fair:
            # FCFS ablation: tags collapse to the arrival sequence; the
            # descending head counter reproduces deque appendleft order.
            if at_head:
                self._head_seq -= 1
                key = (0.0, self._head_seq)
            else:
                self._seq += 1
                key = (0.0, self._seq)
        elif at_head:
            # retry-at-head: resume at the front of its OWN sub-queue
            # without re-charging the task's virtual finish chain (the
            # original admission already advanced it).
            self._head_seq -= 1
            if sub:
                head_start = self._key[next(iter(sub))][0]
            else:
                head_start = self._vtime
            key = (head_start, self._head_seq)
        else:
            w = self._weight_of(action)
            start = max(self._vtime, self._task_finish.get(task, 0.0))
            self._task_finish[task] = start + self._cost_of(action) / w
            self._seq += 1
            key = (start, self._seq)
        if at_head:
            sub[action.uid] = action
            sub.move_to_end(action.uid, last=False)
        else:
            sub[action.uid] = action
        self._uid_task[action.uid] = task
        self._key[action.uid] = key
        insort(self._order, (key, action), key=lambda e: e[0])

    def remove(self, uid: int, served: bool = False) -> Optional[Action]:
        """Drop ``uid`` (tombstoning its merged-order entry).  ``served``
        marks an action entering service: the virtual clock advances to
        its start tag so later arrivals cannot back-date behind it."""
        task = self._uid_task.pop(uid, None)
        if task is None:
            return None
        self.version += 1
        action = self._subs[task].pop(uid)
        key = self._key.pop(uid)
        if served and self.fair:
            self._vtime = max(self._vtime, key[0])
        if self.fair and not self._uid_task:
            self._end_busy_period()
        self._stale += 1
        if self._stale > max(16, len(self._order) // 2):
            self._compact()
        return action

    def _end_busy_period(self) -> None:
        """SFQ resume rule at a full drain (the last queued action left).

        The virtual clock jumps (monotonically — never backward) to the
        maximum finish tag any task was charged, and the per-task finish
        chains reset: every debt is settled at the end of a busy period.
        Without this, the drain freezes ``V`` at the last *start* tag
        while stale ``F_task`` entries persist — after the refill, tasks
        that never queued during the old busy period would be granted
        stale (unfairly small) start tags ``S = V_old`` and slot in ahead
        of a returning task still paying ``F_task > V_old`` for service
        it received before the queue went idle.  After the rule, every
        arrival in the new busy period starts level at the settled
        clock."""
        if self._task_finish:
            self._vtime = max(
                self._vtime, max(self._task_finish.values())
            )
            self._task_finish.clear()

    def _compact(self) -> None:
        self._order = [e for e in self._order if self._key.get(e[1].uid) == e[0]]
        self._stale = 0
        self.compactions += 1

    # ------------------------------------------------------------------
    # sub-queue detach / merge (the distribution seam: a shard owns whole
    # per-task sub-queues and syncs only the partition virtual clock)
    # ------------------------------------------------------------------
    def sync_vtime(self, v: float) -> None:
        """Advance the partition virtual clock to an external observation
        (a peer shard's clock at merge).  Monotone by construction — the
        clock can never leap backward."""
        if self.fair:
            self._vtime = max(self._vtime, float(v))

    def detach_task(self, task_id: str) -> Optional["TaskShard"]:
        """Detach ``task_id``'s whole sub-queue for remote ownership.

        The shard is self-contained: it carries the queued actions with
        their original ``(vstart, seq)`` tags, the task's virtual finish
        tag, and this partition's clock at detach time — everything a
        remote scheduler needs to keep draining the task fairly.  The
        entries are tombstoned here (not served: the clock does NOT
        advance, and a detach that empties the partition is not a
        busy-period end — the work still exists, elsewhere)."""
        sub = self._subs.pop(task_id, None)
        if not sub:
            return None
        self.version += 1
        entries: List[Tuple[Tuple[float, int], Action]] = []
        for uid, action in sub.items():
            self._uid_task.pop(uid, None)
            entries.append((self._key.pop(uid), action))
        self._stale += len(entries)
        if self._stale > max(16, len(self._order) // 2):
            self._compact()
        return TaskShard(
            task_id=task_id,
            entries=entries,
            finish_tag=self._task_finish.pop(task_id, 0.0),
            vtime=self._vtime,
        )

    def merge_shard(self, shard: "TaskShard") -> None:
        """Re-adopt a detached sub-queue (possibly into a *different*
        partition replica).  Tags are self-contained, so entries merge
        with their original keys; only the virtual clock needs syncing —
        monotone max, so neither side's clock moves backward — and the
        task's finish chain resumes from the later of the two tags."""
        self.sync_vtime(shard.vtime)
        self.version += 1
        sub = self._subs.setdefault(shard.task_id, OrderedDict())
        for key, action in shard.entries:
            if action.uid in self._uid_task:
                continue  # already re-queued locally; never double-admit
            sub[action.uid] = action
            self._uid_task[action.uid] = shard.task_id
            self._key[action.uid] = key
            # restoring the key re-validates a tombstone left by detach
            # in THIS queue — only insert when no entry already sits at
            # (key, action), or ordered() would yield the action twice
            if self._resurrect(key, action):
                self._stale = max(0, self._stale - 1)
            else:
                insort(self._order, (key, action), key=lambda e: e[0])
            self._seq = max(self._seq, key[1])
        self._task_finish[shard.task_id] = max(
            self._task_finish.get(shard.task_id, 0.0), shard.finish_tag
        )

    # ------------------------------------------------------------------
    def ordered(self) -> List[Action]:
        """Waiting actions in fair service order (FCFS within a task,
        min-start-tag across tasks; arrival order when ``fair=False``)."""
        key = self._key
        return [a for k, a in self._order if key.get(a.uid) == k]

    def head(self) -> Optional[Action]:
        key = self._key
        for k, a in self._order:
            if key.get(a.uid) == k:
                return a
        return None

    # ------------------------------------------------------------------
    # per-task introspection (telemetry / starvation tracking)
    # ------------------------------------------------------------------
    def backlog(self) -> Dict[str, int]:
        return {t: len(sub) for t, sub in self._subs.items() if sub}

    def backlog_cost(self) -> Dict[str, float]:
        """Queued *work*, per task, in this queue's cost units (the
        same ``cost_of`` WFQ tags are charged in — resource-seconds
        under :func:`default_cost`).  The rebalance policy weighs moves
        by queued work, not action count: ten 1-second actions and one
        10-second action are the same backlog."""
        return {
            t: sum(self._cost_of(a) for a in sub.values())
            for t, sub in self._subs.items()
            if sub
        }

    def oldest_submit_by_task(self) -> Dict[str, float]:
        """Earliest submit time among queued actions, per task — the
        numerator of the starvation-age telemetry."""
        out: Dict[str, float] = {}
        for t, sub in self._subs.items():
            times = [a.submit_time for a in sub.values() if not math.isnan(a.submit_time)]
            if times:
                out[t] = min(times)
        return out

    def _resurrect(self, key: Tuple[float, int], action: Action) -> bool:
        """True iff ``_order`` already holds the exact (key, action)
        entry — a tombstone this queue's own detach left behind, now
        valid again because the key was restored."""
        i = bisect_left(self._order, key, key=lambda e: e[0])
        while i < len(self._order) and self._order[i][0] == key:
            if self._order[i][1] is action:
                return True
            i += 1
        return False

    # bisect helper exposed for tests: rank of a hypothetical key
    def _rank(self, key: Tuple[float, int]) -> int:
        return bisect_left([k for k, _ in self._order], key)
