"""Elastic resource scheduling (paper §4.2, Algorithms 1 & 2).

Objective: minimize the sum of Action Completion Times

    ACTs = sum_i (T_i^q + T_i)                                   (Eq. 2)

Ordering is FCFS (starvation invalidates whole trajectories, so the
paper fixes ordering and optimizes *allocation*).  Each scheduling round:

1. take the largest FCFS prefix of the waiting queue whose *minimum*
   vectorized requirements every touched manager can accommodate
   (Alg. 1 line 2);
2. split candidates by their **key elasticity resource** (scaling along
   the key resource does not disturb other dimensions — §4.1 assumption);
3. groups with unknown/zero elasticity are selected directly at
   least-required units;
4. scalable groups run **greedy eviction**: starting from the full
   group, repeatedly evict the latest-arrived candidate and re-arrange
   the rest optimally (DPArrange); stop as soon as eviction no longer
   lowers the approximated ΣACT.  The approximation (Alg. 2) =
   exact ACTs of candidates under the DP allocation + estimated ACTs of
   the remaining queue inserted min-allocation into a completion-time
   heap, with ``depth`` letting the first remaining action probe several
   DoPs (depth 2–3 suffices per the paper).

Implementation notes kept faithful to the pseudo code, with two
reconciliations (flagged in-line): Alg. 2 line 13 pops from the scratch
heap (the paper's ``heap`` is a typo — popping the original would leak
state across depth probes), and eviction is capped at ``|C_j| - 1`` so
the FCFS head always schedules (Alg. 1 line 12's ``C_j[:-t+1]`` is empty
under Python slicing but plainly means "keep at least the head").
"""

from __future__ import annotations

import heapq
import itertools
import math
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

from repro.core.action import Action, DurationHistory
from repro.core.dparrange import (
    DPResult,
    DPTask,
    TransitionTable,
    dp_arrange,
    dp_arrange_prefixes,
)
from repro.core.fairqueue import FairSharePolicy
from repro.core.managers.base import ResourceManager

INF = math.inf


def candidate_window(
    waiting: Sequence[Action],
    managers: Dict[str, ResourceManager],
    limit: int = 128,
) -> List[Action]:
    """Largest FCFS prefix admissible at min units, in one O(window) pass.

    Equivalent to re-testing ``can_accommodate`` on every prefix (the
    seed's O(n²) scan): each manager's admission cursor sees exactly the
    subsequence of prefix actions that touch it.
    """
    out: List[Action] = []
    cursors: Dict[str, object] = {}
    for action in waiting[: min(len(waiting), limit)]:
        ok = True
        for rtype in action.cost:
            manager = managers.get(rtype)
            if manager is None:
                continue
            cur = cursors.get(rtype)
            if cur is None:
                cur = cursors[rtype] = manager.begin_admission()
            if not manager.admit_one(cur, action):
                ok = False
                break
        if not ok:
            break
        out.append(action)
    return out


@dataclass
class Decision:
    """One scheduled action with concrete per-resource unit counts."""

    action: Action
    units: Dict[str, int]


@dataclass
class ScheduleResult:
    decisions: List[Decision] = field(default_factory=list)
    objective: float = 0.0
    evicted: int = 0  # candidates deferred by greedy eviction this round


class ElasticScheduler:
    def __init__(
        self,
        depth: int = 2,
        candidate_limit: int = 128,
        history: Optional[DurationHistory] = None,
        estimate_units: str = "min",  # "min" (paper Alg. 2) | "dp_avg"
        cache_dp: Optional[bool] = None,
        fair_share: Optional[FairSharePolicy] = None,
    ) -> None:
        self.depth = depth
        self.candidate_limit = candidate_limit
        self.history = history or DurationHistory()
        # Multi-tenant fairness (None = single-tenant, pre-fairness
        # behaviour, bit-identical): per-task weights scale the DP
        # objective and the Alg. 2 estimate (weighted ΣACT), and
        # ``preempt_scalable`` lets an over-share task's scalable
        # allocations shrink to min units before an under-share task's
        # actions are deferred by eviction.  The orchestrator assigns
        # this when constructed with a FairSharePolicy.
        self.fair_share = fair_share
        # Prefix-DP memo for incremental rounds: keyed on the manager's
        # dp_cache_key (free state) + the exact task tuple, so a round
        # whose resource group did not change reuses the arrangement.
        # None = off (seed-faithful direct use); the Orchestrator enables
        # it when running incrementally.
        self.cache_dp = cache_dp
        self.dp_cache_max = 512
        self._dp_cache: "OrderedDict[Hashable, List[Optional[DPResult]]]" = OrderedDict()
        self.dp_cache_hits = 0
        self.dp_cache_misses = 0
        # Sharded rounds run arrange() concurrently from a thread pool;
        # the two LRU caches below are the only cross-partition mutable
        # state the policy touches during planning, so they are guarded
        # by one small lock (never held across a DP compute — concurrent
        # misses on the same key recompute deterministically and the
        # last write wins).
        self._cache_lock = threading.Lock()
        # Dense DPArrange (PR 2): run the DP as vectorized array sweeps
        # over precomputed operator transition tables instead of the
        # dict-of-dicts reference.  Tables are pure functions of the
        # manager's free state, so they are LRU-cached on dp_cache_key
        # and shared across rounds AND across different task profiles
        # (unlike the prefix-result memo above, which also keys the task
        # tuple).  ``dense_backend``: None -> numpy; "jax" -> jitted
        # segment-min scan for large state spaces.
        self.use_dense = True
        self.dense_backend: Optional[str] = None
        self.table_cache_max = 256
        self._table_cache: "OrderedDict[Hashable, Optional[TransitionTable]]" = (
            OrderedDict()
        )
        self.table_cache_hits = 0
        self.table_cache_misses = 0
        # BEYOND-PAPER (EXPERIMENTS.md §Perf, scheduler iterations): the
        # paper's Alg. 2 prices evicted/remaining actions at MIN-unit
        # durations, so under a burst eviction never engages (deferring a
        # 50 s-at-1-core action "costs" its full 50 s even though the next
        # round would grant it a large DoP) and the head of the burst
        # hogs the pool.  ``estimate_units="dp_avg"`` prices deferred
        # scalable actions at the average DoP the current DP granted —
        # value-consistent with the policy's own future behaviour.
        # Default "min" = paper-faithful reproduction baseline.
        self.estimate_units = estimate_units
        # BEYOND-PAPER: Alg. 1 stops at the FIRST eviction that fails to
        # improve the objective; under a burst the payoff of wave-forming
        # (keep few at max DoP) lies past that local bump.  "exhaustive"
        # scans every prefix — O(n) extra heap estimates on top of the
        # single prefix-DP pass, so the asymptotic cost is unchanged.
        self.eviction_search = "greedy"
        # BEYOND-PAPER (EXPERIMENTS.md §Perf): under steady saturated flow
        # a lone arriving scalable action grabs whatever 1-2 cores are
        # free *now* instead of waiting one completion for an efficient
        # DoP — the Alg. 2 completion heap abstracts away *how many* units
        # each completion frees, so "wait for 4 cores" is inexpressible
        # and the myopic grab always wins the comparison.  ``dop_floor``
        # removes sub-floor unit choices from the DP's feasible sets; an
        # infeasible prefix prices as +inf and (with exhaustive search)
        # eviction defers the tail until the floor is affordable.  When
        # even one action cannot get the floor the round keeps the paper
        # fallback (min units) so the FCFS head is never starved.
        # ``floor_pressure`` < inf auto-disengages the floor when queued
        # min-unit demand exceeds that multiple of the free units (deep
        # queue = throughput mode, where min units maximize aggregate
        # efficiency).  Measured (EXPERIMENTS.md §Perf): on the original
        # hand-written scenarios the gate could not distinguish mid-
        # from deep-congestion — the candidate window fills to capacity
        # at min units in both.  The generated deep_congestion scenario
        # (scenarios.py) now produces that separation: 1.21x mean-ACT
        # win at depth vs exactly 1.00x at mid, benched and CI-gated in
        # BENCH_generated.json (generated_gate_win_*).  The knobs stay
        # default-off; scenarios opt in via ScenarioSpec.policy.
        self.dop_floor: Optional[int] = None
        self.floor_pressure: float = INF

    # ------------------------------------------------------------------
    # Alg. 1
    # ------------------------------------------------------------------
    def schedule(
        self,
        waiting: Sequence[Action],
        executing: Sequence[Action],
        managers: Dict[str, ResourceManager],
        now: float,
    ) -> ScheduleResult:
        if not waiting:
            return ScheduleResult()
        candidates = self._candidate_window(waiting, managers)
        remaining = list(waiting[len(candidates) :])
        return self.arrange(candidates, remaining, executing, managers, now)

    # ------------------------------------------------------------------
    # Alg. 1 lines 3+ — SchedulingPolicy protocol entry point: the caller
    # (the Orchestrator) has already picked the FCFS candidate window.
    # ------------------------------------------------------------------
    def arrange(
        self,
        candidates: Sequence[Action],
        remaining: Sequence[Action],
        executing: Sequence[Action],
        managers: Dict[str, ResourceManager],
        now: float,
    ) -> ScheduleResult:
        result = ScheduleResult()
        if not candidates:
            return result

        # split by key elasticity resource (Alg. 1 line 4)
        groups: Dict[Optional[str], List[Action]] = {}
        for a in candidates:
            key = a.key_resource if a.scalable else None
            groups.setdefault(key, []).append(a)

        # units already committed this round per resource type — elastic
        # scale-up must never spill into co-scheduled actions' shares.
        committed: Dict[str, int] = {}

        def commit(units: Dict[str, int]) -> None:
            for r, u in units.items():
                committed[r] = committed.get(r, 0) + u

        # non-scalable / unknown-elasticity: select directly at min units
        for a in groups.pop(None, []):
            units = a.min_cost()
            commit(units)
            result.decisions.append(Decision(a, units))

        for rtype, group in groups.items():
            manager = managers[rtype]
            # per-node sub-domains (CPU manager schedules per node, §5.2)
            for _, part in manager.partition(group).items():
                kept, alloc, obj, evicted = self._greedy_eviction(
                    part,
                    rtype,
                    manager,
                    remaining,
                    executing,
                    now,
                    reserve=committed.get(rtype, 0),
                )
                result.evicted += evicted
                result.objective += obj
                for a in kept:
                    units = a.min_cost()
                    units[rtype] = alloc.get(str(a.uid), units[rtype])
                    commit(units)
                    result.decisions.append(Decision(a, units))

        return result

    # ------------------------------------------------------------------
    def _candidate_window(
        self, waiting: Sequence[Action], managers: Dict[str, ResourceManager]
    ) -> List[Action]:
        """Largest FCFS prefix accommodatable at min units (Alg. 1 line 2).

        Incremental: one admission cursor per touched manager accumulates
        the per-resource prefix state action by action — O(window) total,
        where the former per-prefix ``can_accommodate`` rescan was
        O(window²).  This is the same cursor protocol the orchestrator's
        round loop uses, so standalone ``schedule()`` and orchestrated
        ``arrange()`` compute identical windows.
        """
        return candidate_window(waiting, managers, self.candidate_limit)

    # ------------------------------------------------------------------
    def _greedy_eviction(
        self,
        group: List[Action],
        rtype: str,
        manager: ResourceManager,
        remaining: Sequence[Action],
        executing: Sequence[Action],
        now: float,
        reserve: int = 0,
    ) -> Tuple[List[Action], Dict[str, int], float, int]:
        """Alg. 1 lines 7-12.  Returns (kept, allocation, objective, #evicted).

        Multi-tenant fairness (``fair_share``): per-task weights scale
        both the exact DP part and the Alg. 2 estimate (weighted ΣACT);
        uniform weights reduce exactly to the unweighted objective.  When
        the greedy pass would defer an *under-share* task's actions while
        an *over-share* task holds scalable DoP>1 allocations,
        ``preempt_scalable`` re-runs the pass with the over-share tasks
        clamped to minimum units — shrinking the rich tenant before the
        poor one is evicted — and adopts the re-run iff it strictly keeps
        more actions.
        """
        # remaining actions contending for this resource (Alg. 2 line 2:
        # W.split(R_j) - C_j); evicted candidates are prepended as they
        # re-enter the queue ahead of ``remaining``.
        rest_same = [a for a in remaining if a.key_resource == rtype or rtype in a.cost]

        floor = self.dop_floor
        if floor:
            # adaptive: a deep queue means throughput mode — min units
            # maximize aggregate efficiency (E(m) <= 1), so disengage the
            # floor when demand at min units already swamps what's free.
            demand = sum(a.key_units()[0] for a in group) + sum(
                a.key_units()[0] if a.scalable else 1 for a in rest_same
            )
            free = max(1, manager.available - reserve)
            if demand > self.floor_pressure * free:
                floor = None

        fs = self.fair_share
        gw: Optional[Tuple[float, ...]] = None
        rw: Optional[Tuple[float, ...]] = None
        if fs is not None:
            gw = tuple(fs.weight_of(a) for a in group)
            rw = tuple(fs.weight_of(a) for a in rest_same)
            if len(set(gw) | set(rw)) <= 1:
                # uniform weights scale every term identically — the
                # argmin (and hence every decision) equals the unweighted
                # objective, so keep the bit-identical single-tenant path.
                gw = rw = None

        tasks = self._dp_tasks(group, floor)
        best_kept, best_alloc, obj = self._evict_pass(
            tasks, group, rest_same, rtype, manager, executing, now, reserve,
            gw, rw, floor,
        )

        if (
            fs is not None
            and fs.preempt_scalable
            and best_kept < len(group)
        ):
            over, under = self._share_bands(group, rest_same, manager)
            deferred_tasks = {a.task_id for a in group[best_kept:]}
            clampable = any(
                a.task_id in over and len(tasks[i].units) > 1
                for i, a in enumerate(group)
            )
            if (deferred_tasks & under) and clampable:
                clamped = self._dp_tasks(group, floor, clamp_tasks=over)
                kept2, alloc2, obj2 = self._evict_pass(
                    clamped, group, rest_same, rtype, manager, executing, now,
                    reserve, gw, rw, floor,
                )
                # the two passes optimize over different feasible sets, so
                # their objectives are not comparable — adopt the clamped
                # arrangement iff shrinking the over-share tenants lets
                # strictly more (under-share) work launch this round.
                if kept2 > best_kept:
                    best_kept, best_alloc, obj = kept2, alloc2, obj2

        kept = group[:best_kept]
        # translate positional task names back to action uids for callers
        uid_alloc = {str(group[int(k)].uid): v for k, v in best_alloc.items()}
        return kept, uid_alloc, obj, len(group) - best_kept

    # ------------------------------------------------------------------
    def _dp_tasks(
        self,
        group: List[Action],
        floor: Optional[int],
        clamp_tasks: frozenset = frozenset(),
    ) -> List[DPTask]:
        """DPTask rows for ``group``.  Tasks are named POSITIONALLY
        ("0".."m-1"), not by uid: the DP result depends only on the
        ordered (units, durations) profiles, so positional names let
        ``_prefixes_cached`` share arrangements across rounds whose task
        multiset recurs with fresh actions.  ``clamp_tasks``: tenants
        whose scalable unit choices collapse to min units (the
        preempt_scalable shrink)."""
        tasks = []
        for i, a in enumerate(group):
            units = a.key_units()
            if floor:
                floored = tuple(m for m in units if m >= floor)
                if floored:
                    units = floored
            # per-action duration-vector memo: the elasticity curve is
            # immutable, and the same action re-enters _greedy_eviction on
            # every round it stays queued.
            memo = a.metadata.get("_dp_durs")
            if memo is None or memo[0] != units:
                memo = (units, tuple(a.get_dur(m) for m in units))
                a.metadata["_dp_durs"] = memo
            units, durs = memo
            if a.task_id in clamp_tasks and len(units) > 1:
                units, durs = units[:1], durs[:1]
            tasks.append(DPTask(name=str(i), units=units, durations=durs))
        return tasks

    # ------------------------------------------------------------------
    def _share_bands(
        self,
        group: Sequence[Action],
        rest_same: Sequence[Action],
        manager: ResourceManager,
    ) -> Tuple[set, set]:
        """(over-share, under-share) tenants by live occupancy vs the
        weighted fair share over the tasks currently active (holding
        units or waiting) on this manager."""
        fs = self.fair_share
        usage = manager.task_usage()
        total = sum(usage.values())
        active = (
            {a.task_id for a in group}
            | {a.task_id for a in rest_same}
            | set(usage)
        )
        if fs is None or total <= 0 or len(active) < 2:
            return set(), set()
        wsum = sum(fs.weight_of(t) for t in active)
        over: set = set()
        under: set = set()
        for t in active:
            target = fs.weight_of(t) / wsum
            share = usage.get(t, 0) / total
            if share > target * (1.0 + fs.share_slack):
                over.add(t)
            elif share < target:
                under.add(t)
        return over, under

    # ------------------------------------------------------------------
    def _evict_pass(
        self,
        tasks: List[DPTask],
        group: List[Action],
        rest_same: List[Action],
        rtype: str,
        manager: ResourceManager,
        executing: Sequence[Action],
        now: float,
        reserve: int,
        gw: Optional[Tuple[float, ...]],
        rw: Optional[Tuple[float, ...]],
        floor: Optional[int] = None,
    ) -> Tuple[int, Dict[str, int], float]:
        """One greedy-eviction sweep over the prefix DP; returns
        (#kept, positional allocation, objective)."""
        # ONE DP pass yields the exact-part objective of every prefix
        # (greedy eviction only ever evaluates prefixes).
        prefixes = self._prefixes_cached(tasks, group, manager, reserve, gw)

        exec_tail = [
            max(0.0, e.finish_time - now)
            for e in executing
            if rtype in e.cost and not math.isnan(e.finish_time)
        ]

        # Estimate-part durations are prefix-invariant in the default
        # ("min") pricing mode and without a DoP floor (a floored row's
        # durations[0] is the floored, not the true, min-unit duration):
        # hoist them out of the eviction loop so each prefix probe is
        # pure heap arithmetic instead of re-deriving every remaining
        # action's duration.  A preempt-clamped row keeps hoisting —
        # clamping truncates to the TRUE min-unit choice.
        hoist = self.estimate_units != "dp_avg" and floor is None
        if hoist:
            group_min_durs = [t.durations[0] for t in tasks]
            rest_same_durs = [self._dur(a, None) for a in rest_same]

        def objective(n_keep: int) -> Tuple[float, Dict[str, int]]:
            dp = prefixes[n_keep] if n_keep < len(prefixes) else None
            if dp is None:
                return INF, {}
            # pre-sorted completion array: ESTIMATE's sorted-merge replay
            # consumes it via a cursor, shared across all depth probes
            # (no per-probe heap copy / heapify)
            base = sorted(
                [dp.durations[t.name] for t in tasks[:n_keep]] + exec_tail
            )
            rest = list(group[n_keep:]) + rest_same  # evicted rejoin the queue
            rest_w = None if gw is None else list(gw[n_keep:]) + list(rw or ())
            est_units = None
            if self.estimate_units == "dp_avg" and dp.allocation:
                est_units = int(
                    sum(dp.allocation.values()) / max(1, len(dp.allocation))
                )
            rest_durs = group_min_durs[n_keep:] + rest_same_durs if hoist else None
            return (
                dp.total_duration
                + self._estimate(base, rest, est_units, rest_durs, rest_w),
                dp.allocation,
            )

        obj, best_alloc = objective(len(group))
        best_kept = len(group)
        # evict the last (latest-arrived) candidate while it helps.  Full
        # eviction (defer even the head rather than run it at
        # starvation-level DoP) is allowed ONLY when in-flight completions
        # guarantee a future scheduling round — otherwise keep >= 1 so the
        # FCFS head can never be starved.
        max_evict = len(group) if exec_tail else len(group) - 1
        for t in range(1, max_evict + 1):
            new_obj, new_alloc = objective(len(group) - t)
            if new_obj >= obj:
                if self.eviction_search == "greedy":
                    break
                continue  # exhaustive: keep scanning past local bumps
            obj, best_kept, best_alloc = new_obj, len(group) - t, new_alloc
        return best_kept, best_alloc, obj

    # ------------------------------------------------------------------
    def _prefixes_cached(
        self,
        tasks: List[DPTask],
        group: List[Action],
        manager: ResourceManager,
        reserve: int,
        weights: Optional[Tuple[float, ...]] = None,
    ) -> List[Optional[DPResult]]:
        """dp_arrange_prefixes, memoized on (manager free-state key, task
        tuple).  DPTask captures the unit sets *and* durations, and the
        manager key captures everything its dp_operator reads, so equal
        keys are guaranteed to reproduce the same DP — results are shared
        across rounds whose group and free resources did not change.

        Two cache levels: the prefix-result memo above (``cache_dp``,
        incremental rounds only), and the dense transition-table LRU
        (always on with ``use_dense``) — tables depend only on the
        manager's free state + the distinct unit choices, so they hit
        even when durations or group composition change every round."""
        mkey = manager.dp_cache_key(group, reserve)

        def compute() -> List[Optional[DPResult]]:
            # operator construction stays on the miss path — a DP-memo
            # hit must not pay for manager state snapshots
            operator = manager.dp_operator(group, reserve)
            if not self.use_dense:
                return dp_arrange_prefixes(tasks, operator, table=None, weights=weights)
            table = self._table_for(operator, tasks, mkey)
            return dp_arrange_prefixes(
                tasks, operator, table=table, backend=self.dense_backend,
                weights=weights,
            )

        if not self.cache_dp or mkey is None:
            return compute()
        # weights scale the memoized objectives, so they are part of the key
        key = (mkey, tuple(tasks), weights)
        with self._cache_lock:
            hit = self._dp_cache.get(key)
            if hit is not None:
                self.dp_cache_hits += 1
                self._dp_cache.move_to_end(key)
                return hit
            self.dp_cache_misses += 1
        prefixes = compute()
        with self._cache_lock:
            self._dp_cache[key] = prefixes
            if len(self._dp_cache) > self.dp_cache_max:
                self._dp_cache.popitem(last=False)
        return prefixes

    # ------------------------------------------------------------------
    def _table_for(
        self,
        operator,
        tasks: Sequence[DPTask],
        mkey: Optional[Hashable],
    ) -> Optional[TransitionTable]:
        """Transition table for ``operator`` over the tasks' distinct unit
        choices, LRU-cached on (manager free-state key, choice tuple).

        ``dp_cache_key`` captures everything the operator's transitions
        and validity read (e.g. the GPU manager's free-chunk level
        counts), so a free-state change rotates the key and the stale
        table simply ages out — the invalidation regression test pins
        this.  ``mkey is None`` (state-dependent manager) builds fresh.
        A cached ``None`` records that the operator cannot export a table
        (unsupported topology or over the state limit) so the round falls
        straight back to the sparse reference without re-probing."""
        ks = tuple(sorted({k for t in tasks for k in t.units}))
        if mkey is None:
            return operator.transition_table(ks)
        key = (mkey, ks)
        with self._cache_lock:
            if key in self._table_cache:
                self.table_cache_hits += 1
                self._table_cache.move_to_end(key)
                return self._table_cache[key]
            self.table_cache_misses += 1
        table = operator.transition_table(ks)
        with self._cache_lock:
            self._table_cache[key] = table
            if len(self._table_cache) > self.table_cache_max:
                self._table_cache.popitem(last=False)
        return table

    # ------------------------------------------------------------------
    # Alg. 2
    # ------------------------------------------------------------------
    def _approx_objective(
        self,
        kept: List[Action],
        rest: Sequence[Action],
        rtype: str,
        manager: ResourceManager,
        executing: Sequence[Action],
        now: float,
        reserve: int = 0,
    ) -> Tuple[float, Dict[str, int]]:
        """getApproximatedObjective: exact DP part + heap estimate part.

        Queue time already incurred is identical across strategies within
        a round and is dropped from the comparison (constant shift).
        """
        if not kept:
            return INF, {}
        tasks = [
            DPTask(
                name=str(a.uid),
                units=a.key_units(),
                durations=tuple(a.get_dur(m) for m in a.key_units()),
            )
            for a in kept
        ]
        dp = dp_arrange(tasks, manager.dp_operator(kept, reserve))
        if dp is None:
            return INF, {}
        exact_obj = dp.total_duration

        # completions: candidates' completions + in-flight completions,
        # pre-sorted once for ESTIMATE's sorted-merge replay
        completions: List[float] = [dp.durations[t.name] for t in tasks]
        for e in executing:
            if rtype in e.cost and not math.isnan(e.finish_time):
                completions.append(max(0.0, e.finish_time - now))
        completions.sort()

        approx_obj = self._estimate(completions, list(rest))
        return exact_obj + approx_obj, dp.allocation

    def _estimate(
        self,
        completions: List[float],
        rest: List[Action],
        est_units: Optional[int] = None,
        rest_durs: Optional[List[float]] = None,
        rest_weights: Optional[List[float]] = None,
    ) -> float:
        """Alg. 2 ESTIMATE: insert the remaining queue min-allocation into
        the completion schedule; the *first* remaining action probes up
        to ``depth`` unit choices.  ``completions`` must be sorted
        ascending — it is shared READ-ONLY across all depth probes, so
        the former per-probe ``list(heap)`` copy + O(k) ``heapify``
        replay collapses into one sorted-merge (:meth:`_replay`) whose
        only mutable state is the small heap of newly generated
        completion times.  ``est_units`` (beyond-paper "dp_avg" mode)
        prices scalable actions at that DoP instead of min.
        ``rest_durs``, when given, are the precomputed min-allocation
        durations aligned with ``rest`` (callers hoist them out of the
        eviction loop — they do not depend on the kept prefix).
        ``rest_weights`` (multi-tenant fairness) weights each remaining
        action's completion-time contribution — the estimate part of the
        weighted ΣACT objective."""
        if not rest:
            return 0.0
        first = rest[0]
        probes = self._depth_probes(first)
        if rest_durs is None:
            tail_durs = [self._dur(a, est_units) for a in rest[1:]]
        else:
            tail_durs = rest_durs[1:]
        w0, tail_weights = 1.0, None
        if rest_weights is not None:
            w0, tail_weights = rest_weights[0], rest_weights[1:]
        best = INF
        for d in probes:
            t0 = self._dur(first, d if est_units is None else max(d or 1, est_units))
            best = min(best, self._replay(completions, t0, tail_durs, w0, tail_weights))
        return best

    @staticmethod
    def _replay(
        completions: List[float],
        t0: float,
        tail_durs: List[float],
        w0: float = 1.0,
        tail_weights: Optional[List[float]] = None,
    ) -> float:
        """One ESTIMATE replay as a sorted merge.

        Equivalent to the heap simulation (pop the earliest completion,
        start the next queued action on it, push its completion): because
        every generated completion is >= the value popped for it, the pop
        sequence is non-decreasing, so the pre-sorted base array can be
        consumed with a cursor and only *generated* completions need a
        heap.  Identical objective to the heap replay — ties between the
        cursor head and the generated heap pick the same value either
        way.  ``w0``/``tail_weights`` weight each contribution (weighted
        ΣACT); the defaults multiply by exactly 1.0, which is the
        identity in IEEE-754, so the unweighted objective is bit-identical
        to the pre-fairness code."""
        i = 0
        n = len(completions)
        gen: List[float] = []
        obj = 0.0
        ws = itertools.chain((w0,), tail_weights or itertools.repeat(1.0))
        for dur, w in zip(itertools.chain((t0,), tail_durs), ws):
            if i < n and (not gen or completions[i] <= gen[0]):
                ts = completions[i]
                i += 1
            elif gen:
                ts = heapq.heappop(gen)
            else:
                ts = 0.0
            c = ts + dur
            obj += w * c
            heapq.heappush(gen, c)
        return obj

    def _depth_probes(self, action: Action) -> List[Optional[int]]:
        if not action.scalable:
            return [None]
        feasible = action.key_units()
        probes = [m for m in feasible if m <= max(self.depth, feasible[0])]
        return probes[: self.depth] or [feasible[0]]

    def _dur(self, action: Action, m: Optional[int]) -> float:
        if action.base_duration is None:
            return self.history.estimate(action)
        feasible = action.key_units()
        if m is None:
            m = feasible[0]
        # snap to the largest feasible unit count <= m
        m = max((u for u in feasible if u <= m), default=feasible[0])
        return action.get_dur(m)
