"""Sharded scheduling rounds: parallel per-partition planning, serialized
validated commit (the ROADMAP's async-rounds item, step one).

The serial round loop re-arranges every dirty partition in one thread,
mutating manager state as it walks — decision latency grows linearly
with the number of dirty partitions, which is the control-plane scale
wall once the external fleet (and therefore the partition count) grows.
This module converts the round's core invariant from "a round mutates
managers as it walks partitions" into **plan-then-commit**:

* the dirty set is split into **shards** — each shard owns *whole*
  partitions (and with it, whole per-task WFQ sub-queues: a
  :class:`~repro.core.fairqueue.PartitionQueue` never straddles
  shards), assigned by deterministic striping over the sorted keys;
* each shard snapshots the managers' free state
  (:meth:`ResourceManager.snapshot`) and runs ``policy.arrange`` for
  its partitions concurrently on a thread pool, producing
  **launch intents** (:class:`PartitionPlan`) without touching live
  state;
* a single-threaded **commit phase** replays the intents in global
  sorted partition order against *live* managers.  A plan that no
  longer fits (another shard's commit took the capacity, a trajectory
  bound elsewhere) fails ``try_allocate``, rolls back through
  ``release_unlaunched``, and re-dirties its partition — exactly the
  retry rail ordinary ``try_allocate`` refusals already ride — so
  conflicts cost one extra round, never a lost or double-launched
  action.

Snapshot contract — what a shard may read while planning:

* the **manager snapshots** handed to it: ``available``/``capacity``,
  ``begin_admission``/``admit_one``, ``dp_operator``/``dp_cache_key``,
  ``partition`` (the CPU manager's trajectory binding mutates only the
  snapshot), ``task_usage``, ``min_units``.
* **off-snapshot (live) state that is frozen during a round's plan
  phase** and therefore safe to read: the partition queues it owns
  (``ordered()``/``head()``), the orchestrator's executing map, policy
  configuration, and the virtual clock — no event callback runs while
  plans are outstanding.
* **never off-snapshot**: ``try_allocate``/``release*``/``note_*`` and
  any manager internals behind the snapshot (free cores, chunk
  allocators, token buckets).  Placement is commit-phase only, against
  live managers, on the orchestrator thread.

Decision-latency accounting: the round is charged
``max(per-shard plan cost) + commit wall`` — the **critical path** a
fleet of per-shard workers (the multi-process managers this engine is
the prerequisite for) would pay.  Two plan modes measure it:

* ``plan_mode="inline"`` (default): shards are planned back-to-back on
  the orchestrator thread, each timed with ``perf_counter`` free of any
  interference — exact per-shard costs, no pool dispatch overhead.
  This is the DES benchmarking mode: plans are deterministic and
  identical in every mode, so only the latency *accounting* needs the
  critical-path model.
* ``plan_mode="threads"``: shards are dispatched to a process-wide
  thread pool — real in-process concurrency for deployments where plan
  cost lives in GIL-releasing code (the dense-DP NumPy sweeps, large
  state spaces).  Per-shard timings then include GIL waits, so the
  charged critical path is conservative (an upper bound).

The real plan-phase wall clock is always recorded in
``Telemetry.plan_wall_s`` alongside the modeled critical path
(``Telemetry.plan_critical_s``), so the two are never conflated.

``shards=None`` on the :class:`~repro.core.orchestrator.Orchestrator`
keeps the serial loop bit-identical; ``shards=N`` must produce identical
launch traces on conflict-free workloads (partitions whose actions touch
disjoint resource types — the equivalence suites), proven by
``tests/test_shards.py`` and gated in CI by the shard-smoke benchmark.
"""

from __future__ import annotations

import math
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import TYPE_CHECKING, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.action import Action, ActionState
from repro.core.fairqueue import FairSharePolicy
from repro.core.managers.base import Allocation
from repro.core.scheduler import Decision, ScheduleResult, candidate_window

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (typing only)
    from repro.core.orchestrator import Orchestrator


@dataclass
class PartitionPlan:
    """One partition's launch intents, planned off-snapshot.

    ``result is None`` with ``planned=True`` means the quota gate held
    the whole window (``held`` actions stay queued, partition stays
    watched); ``planned=False`` means the queue was already empty at
    plan time (nothing to commit beyond watch-list cleanup)."""

    part: str
    result: Optional[ScheduleResult] = None
    held: int = 0
    wall_s: float = 0.0  # this partition's arrange wall time
    shard: int = 0
    planned: bool = True


# ---------------------------------------------------------------------------
# The plan core — shared verbatim by the serial loop, the in-process
# sharded plan phase, AND the out-of-process RemoteShardWorker
# (repro.core.remote).  Keeping it a free function over explicit inputs
# is what guarantees remote plans are bit-identical to inline ones:
# there is exactly one implementation to diverge from.
# ---------------------------------------------------------------------------


def apply_quota(
    part: str,
    waiting: List[Action],
    managers: Mapping[str, object],
    fair_share: FairSharePolicy,
) -> Tuple[List[Action], int]:
    """Hard share caps: withhold from this round's window the actions of
    tasks at/above their quota fraction of the partition manager's
    capacity.  Held actions stay queued (the partition stays watched); a
    completion releasing units re-dirties it.  ``managers`` is the
    planning view — live for the serial loop, snapshots otherwise.

    The per-task budget walk admits min-unit requirements in service
    order (exact for rigid actions; scalable grants beyond min units are
    clamped against the same budget at launch).  Progress rail: a task
    holding NOTHING always gets its first window action even when its
    min units exceed the configured cap — a sub-min quota must degrade
    to "one action at a time", never to a silent permanent hold."""
    manager = managers.get(part)
    if manager is None or manager.capacity <= 0:
        return waiting, 0
    usage = manager.task_usage()
    budget: Dict[str, float] = {}
    eligible: List[Action] = []
    held = 0
    for a in waiting:
        t = a.task_id
        q = fair_share.quota_of(t)
        if math.isinf(q):
            eligible.append(a)
            continue
        first = t not in budget
        if first:
            budget[t] = q * manager.capacity - usage.get(t, 0)
        req = a.cost.get(part)
        need = req.min_units if req is not None else 1
        if need <= budget[t] or (first and usage.get(t, 0) == 0):
            budget[t] -= need
            eligible.append(a)
        else:
            held += 1
    return eligible, held


def plan_partition(
    part: str,
    waiting: List[Action],
    executing: Sequence[Action],
    managers: Mapping[str, object],
    policy: object,
    fair_share: Optional[FairSharePolicy],
    now: float,
    incremental: bool,
    shard: int = 0,
) -> PartitionPlan:
    """Arrange one partition against ``managers`` WITHOUT touching any
    shared orchestrator state — safe to run from a plan thread or a
    separate process.  The only writes it performs land on the given
    managers (the CPU manager's trajectory binding — snapshots absorb
    them off the live path), per-action metadata owned by this
    partition, and the policy's lock-guarded caches.

    ``waiting`` must already be in the partition queue's service order
    (WFQ: FCFS within a task, min-virtual-start-tag across tasks; plain
    arrival order with ``fair_share=None`` or a single task)."""
    held = 0
    if fair_share is not None and fair_share.quota:
        waiting, held = apply_quota(part, waiting, managers, fair_share)
        if not waiting:
            return PartitionPlan(part, result=None, held=held, shard=shard)
    t0 = time.perf_counter()
    if incremental:
        limit = getattr(policy, "candidate_limit", 128)
        candidates = candidate_window(waiting, managers, limit)
        result = policy.arrange(
            candidates, waiting[len(candidates):], executing, managers, now
        )
    else:
        result = policy.schedule(waiting, executing, managers, now)
    wall = time.perf_counter() - t0
    return PartitionPlan(part, result=result, held=held, wall_s=wall, shard=shard)


# ---------------------------------------------------------------------------
# The commit core — the manager-mutating middle of a launch, shared
# verbatim by the client-serial commit engine (Orchestrator._launch) and
# the worker-owned commit engine (a RemoteShardWorker committing a
# round's intents against the authoritative replicas it holds a lease
# on — repro.core.remote).  Like the plan core above, keeping these free
# functions over explicit inputs is what makes worker-side commits
# bit-identical to client-serial ones: one implementation, zero drift.
# ---------------------------------------------------------------------------


def quota_reservations(
    decisions: Sequence[Decision],
    managers: Mapping[str, object],
    fair_share: Optional[FairSharePolicy],
) -> Optional[Dict[Tuple[str, str], int]]:
    """Min-unit budget reservations per (quota'd task, rtype) over a
    commit batch.  Admission (:func:`apply_quota`) guaranteed every
    admitted action its *min* units within the task's budget; an elastic
    grant scaled beyond min must therefore be clamped against the budget
    MINUS the min-unit reservations of the batch's not-yet-launched
    sibling actions — otherwise the first scalable launch eats the whole
    budget and the siblings' min-unit progress rail pushes the task past
    its cap mid-flight."""
    if fair_share is None or not fair_share.quota:
        return None
    pending: Dict[Tuple[str, str], int] = {}
    for d in decisions:
        if math.isinf(fair_share.quota_of(d.action.task_id)):
            continue
        for rtype in d.units:
            req = d.action.cost.get(rtype)
            if req is None or rtype not in managers:
                continue
            key = (d.action.task_id, rtype)
            pending[key] = pending.get(key, 0) + req.min_units
    return pending or None


def quota_clamp(
    action: Action,
    rtype: str,
    units: int,
    managers: Mapping[str, object],
    fair_share: Optional[FairSharePolicy],
    pending: Optional[Dict[Tuple[str, str], int]] = None,
) -> int:
    """Cap an elastic grant against the task's remaining quota budget on
    ``rtype``: snap down to the largest feasible unit count within the
    budget — net of the min-unit reservations still ``pending`` for the
    task's other actions in this commit batch — but never below min
    units (the progress rail — admission already decided this action may
    run)."""
    if fair_share is None:
        return units
    q = fair_share.quota_of(action.task_id)
    if math.isinf(q):
        return units
    manager = managers.get(rtype)
    req = action.cost.get(rtype)
    if manager is None or req is None or units <= req.min_units:
        return units
    allowed = q * manager.capacity - manager.task_usage().get(action.task_id, 0)
    if pending:
        allowed -= pending.get((action.task_id, rtype), 0)
    if units <= allowed:
        return units
    return max((u for u in req.units if u <= allowed), default=req.min_units)


def commit_decision(
    decision: Decision,
    managers: Mapping[str, object],
    fair_share: Optional[FairSharePolicy],
    quota_pending: Optional[Dict[Tuple[str, str], int]] = None,
) -> Optional[Tuple[Dict[str, int], List[Allocation]]]:
    """Acquire one decision's allocation vector against ``managers``
    (live managers client-side, leased authoritative replicas
    worker-side): release the action's own min-unit reservations from
    the batch's pending map, clamp elastic grants against quota, then
    ``try_allocate`` each rtype in sorted order with full rollback
    through ``release_unlaunched`` on refusal (so consumable state —
    quota tokens — is refunded: the action never started).  Returns the
    granted ``(units, allocations)`` or None when the launch is refused
    (a commit-phase conflict or a withdrawn action) — the manager state
    is then exactly as it was, minus the reservation release."""
    action = decision.action
    if quota_pending is not None:
        # this action's own min-unit reservation no longer binds its
        # siblings' clamp once it reaches the front of the batch —
        # released BEFORE the withdrawn-action early-out below, or a
        # withdrawn sibling's reservation would over-clamp the rest of
        # the batch against budget nobody is going to use
        for rtype in decision.units:
            key = (action.task_id, rtype)
            req = action.cost.get(rtype)
            if req is not None and key in quota_pending:
                quota_pending[key] = max(0, quota_pending[key] - req.min_units)
    if action.state is not ActionState.QUEUED:
        return None  # withdrawn between arrange and launch
    # elastic grants are capped against the task's quota budget up front
    # so the charged duration matches the actual allocation
    units = {
        rtype: quota_clamp(action, rtype, u, managers, fair_share, quota_pending)
        for rtype, u in decision.units.items()
    }
    allocs: List[Allocation] = []
    for rtype in sorted(units):
        manager = managers.get(rtype)
        if manager is None:
            continue
        alloc = manager.try_allocate(action, units[rtype])
        if alloc is None:
            # rollback a partial acquisition (or a commit whose plan no
            # longer fits the committing state)
            for a in allocs:
                managers[a.rtype].release_unlaunched(action, a)
            return None
        allocs.append(alloc)
    for a in allocs:  # multi-tenant share accounting
        managers[a.rtype].note_allocated(action.task_id, a.units)
    return units, allocs


def classify_after_commit(
    queue, evicted: int, failed: int, held: int, managers: Mapping[str, object]
) -> Optional[str]:
    """Post-commit partition classification, shared by both commit
    engines.  A partition may only go clean in states that are no-ops
    until the next event: deliberate deferrals (eviction, quota holds)
    and refused allocations are time/state-dependent — they stay on the
    ``"watch"`` list and re-run every round.  Otherwise the policy
    launched its whole window; the partition is clean exactly when the
    remaining head is inadmissible at min units *now* against the
    committing managers, else it is ``"dirty"`` and re-enters this
    round's fixpoint loop.  ``queue`` is anything with truthiness + a
    ``head()`` peek (a PartitionQueue client-side, a remaining-waiting
    view worker-side)."""
    if not queue:
        return None
    if evicted or failed or held:
        return "watch"
    head = queue.head()
    if head is not None and candidate_window([head], managers, 1):
        return "dirty"
    return None


def duration_of(action: Action, key_units: Optional[int], history) -> float:
    """An action's charged execution duration at its granted key-resource
    units: the host-local sampler when present (never crosses the wire —
    worker-owned multi-pass commit is gated off when any queued action
    carries one), else the unit-scaled elasticity table, else the
    name-keyed history estimate."""
    if action.duration_sampler is not None:
        return action.duration_sampler(key_units or 1)
    d = action.get_dur(key_units) if key_units is not None else action.get_dur()
    if math.isnan(d):
        d = history.estimate(action)
    return d


class SnapshotMap:
    """Lazy manager-snapshot view handed to a shard's plan pass.

    Looks like the orchestrator's ``managers`` mapping, but the first
    access to an rtype snapshots that manager — a shard owning two of
    sixteen pools copies two free states, not sixteen.  Read-only from
    the caller's perspective (the snapshots themselves absorb the plan's
    mutations, e.g. CPU trajectory binding)."""

    __slots__ = ("_live", "_snaps")

    def __init__(self, managers: Dict[str, object]) -> None:
        self._live = managers
        self._snaps: Dict[str, object] = {}

    def __getitem__(self, rtype: str):
        snap = self._snaps.get(rtype)
        if snap is None:
            snap = self._snaps[rtype] = self._live[rtype].snapshot()
        return snap

    def get(self, rtype: str, default=None):
        if rtype not in self._live:
            return default
        return self[rtype]

    def __contains__(self, rtype: str) -> bool:
        return rtype in self._live

    def __len__(self) -> int:
        return len(self._live)

    def keys(self):
        return self._live.keys()


# Process-wide plan pools, shared across orchestrators (tests build
# dozens; per-instance pools would leak idle threads).  Keyed by size;
# workers are daemonic-by-default executor threads that die with the
# process.
_POOLS: Dict[int, ThreadPoolExecutor] = {}
_POOLS_LOCK = threading.Lock()


def _pool(workers: int) -> ThreadPoolExecutor:
    with _POOLS_LOCK:
        pool = _POOLS.get(workers)
        if pool is None:
            pool = _POOLS[workers] = ThreadPoolExecutor(
                max_workers=workers, thread_name_prefix=f"shard-plan-{workers}"
            )
        return pool


#: Estimated per-shard plan cost (seconds) above which ``plan_mode=
#: "auto"`` dispatches to the thread pool: below it the pool's dispatch
#: + wakeup overhead (~100-500 us/shard) outweighs any overlap, and plan
#: cost that small is dict work that holds the GIL anyway.  Above it the
#: plan phase is dominated by the dense-DP NumPy sweeps, which release
#: the GIL — the regime the ROADMAP's profiling item identified as the
#: only one where pooled planning pays.
AUTO_THREADS_CUTOVER_S = 2e-3

#: EWMA smoothing for the measured per-partition plan cost that drives
#: the auto plan-mode decision.
AUTO_EWMA_ALPHA = 0.2


class RoundExecutor:
    """Plans a round's dirty partitions across ``shards`` workers and
    hands the orchestrator an ordered commit list.

    ``plan_mode``:

    * ``"inline"`` — shards planned back-to-back on the orchestrator
      thread (exact contention-free critical-path accounting);
    * ``"threads"`` — shards dispatched to a process-wide thread pool;
    * ``"auto"`` — pick between the two per round from a measured
      per-partition plan-cost EWMA (see :data:`AUTO_THREADS_CUTOVER_S`);
      every decision is logged in ``Telemetry.plan_mode_rounds``;
    * ``"remote"`` — each shard's plan phase runs in a
      :class:`~repro.core.remote.RemoteShardWorker` behind a
      :class:`~repro.core.remote.ShardTransport` (snapshots and plans
      cross a serialization boundary; shard frames are dispatched
      pipelined — shard *i+1* encodes while shard *i* is in flight —
      against workers holding resident replicas refreshed in place;
      see :mod:`repro.core.remote`).

    Plans are deterministic — identical in every mode."""

    PLAN_MODES = ("inline", "threads", "auto", "remote")

    def __init__(
        self,
        orch: "Orchestrator",
        shards: int,
        plan_mode: str = "inline",
        transport="loopback",
        wire_codec: str = "json",
    ) -> None:
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        if plan_mode not in self.PLAN_MODES:
            raise ValueError(f"unknown plan_mode {plan_mode!r}")
        self.orch = orch
        self.shards = int(shards)
        self.plan_mode = plan_mode
        # measured per-partition plan cost (seconds), EWMA — drives the
        # "auto" inline-vs-threads pick and is exported to telemetry
        self.plan_cost_ewma: Optional[float] = None
        # same EWMA kept per partition: a rebalance policy's signal for
        # how expensive each partition's plan phases are where they run
        self.plan_cost_by_part: Dict[str, float] = {}
        self._remote = None
        if plan_mode == "remote":
            from repro.core.remote import RemoteRoundClient

            self._remote = RemoteRoundClient(orch, transport, codec=wire_codec)

    def close(self) -> None:
        """Shut down any out-of-process shard workers (idempotent)."""
        if self._remote is not None:
            self._remote.close()

    # ------------------------------------------------------------------
    def assign(self, keys: Sequence[str]) -> List[List[str]]:
        """Deterministic shard ownership: stripe the sorted partition
        keys round-robin.  Whole partitions only — and therefore whole
        WFQ sub-queues, since a PartitionQueue's per-task sub-queues
        never leave their partition."""
        ordered = sorted(keys)
        n = max(1, min(self.shards, len(ordered)))
        return [ordered[i::n] for i in range(n)]

    # ------------------------------------------------------------------
    def plan_round(self, keys: Sequence[str]) -> Tuple[List[PartitionPlan], float]:
        """Plan every partition in ``keys``; returns the plans in global
        sorted partition order (the commit order — identical to the
        serial loop's walk) plus the round's critical-path plan cost:
        the maximum per-shard plan time."""
        groups = self.assign(keys)
        telemetry = self.orch.telemetry
        if self._remote is not None:
            plans, critical = self._remote.plan_round(groups)
            plans.sort(key=lambda p: p.part)
            self._note_plan_costs(plans)
            return plans, critical

        mode = self.plan_mode
        if mode == "auto":
            mode = self._auto_mode(groups)
            telemetry.note_plan_mode(mode, self.plan_cost_ewma)
        t_wall = time.perf_counter()
        if len(groups) == 1 or mode == "inline":
            results = [self._plan_shard(i, g) for i, g in enumerate(groups)]
        else:
            pool = _pool(self.shards)
            futs = [
                pool.submit(self._plan_shard, i, group)
                for i, group in enumerate(groups)
            ]
            results = [f.result() for f in futs]
        telemetry.plan_wall_s += time.perf_counter() - t_wall

        plans: List[PartitionPlan] = []
        critical = 0.0
        for shard_idx, (shard_plans, plan_s) in enumerate(results):
            critical = max(critical, plan_s)
            telemetry.note_shard_round(shard_idx, len(shard_plans), plan_s)
            plans.extend(shard_plans)
        telemetry.plan_critical_s += critical
        plans.sort(key=lambda p: p.part)
        self._note_plan_costs(plans)
        return plans, critical

    # ------------------------------------------------------------------
    def _auto_mode(self, groups: List[List[str]]) -> str:
        """The per-round inline-vs-threads pick: dispatch to the pool
        only when the measured plan-cost EWMA predicts a per-shard plan
        phase expensive enough to amortize pool dispatch (and there is
        more than one shard to overlap).  Before any measurement exists
        the round plans inline — the measurement itself is free there."""
        if len(groups) <= 1 or self.plan_cost_ewma is None:
            return "inline"
        est_shard_cost = self.plan_cost_ewma * max(len(g) for g in groups)
        return "threads" if est_shard_cost >= AUTO_THREADS_CUTOVER_S else "inline"

    def _note_plan_costs(self, plans: Sequence[PartitionPlan]) -> None:
        """Fold this round's measured per-partition plan walls into the
        EWMA that drives (and is reported beside) the auto decision."""
        ewma = self.plan_cost_ewma
        by_part = self.plan_cost_by_part
        for p in plans:
            if not p.planned:
                continue
            ewma = (
                p.wall_s
                if ewma is None
                else AUTO_EWMA_ALPHA * p.wall_s + (1.0 - AUTO_EWMA_ALPHA) * ewma
            )
            prev = by_part.get(p.part)
            by_part[p.part] = (
                p.wall_s
                if prev is None
                else AUTO_EWMA_ALPHA * p.wall_s + (1.0 - AUTO_EWMA_ALPHA) * prev
            )
        self.plan_cost_ewma = ewma
        if ewma is not None:
            self.orch.telemetry.plan_cost_ewma_s = ewma

    # ------------------------------------------------------------------
    def _plan_shard(
        self, shard_idx: int, keys: Sequence[str]
    ) -> Tuple[List[PartitionPlan], float]:
        """One shard's work unit: snapshot the managers' free state once,
        then arrange each owned partition against the snapshots.  May
        run on a pool thread; must only touch snapshot state and this
        shard's own partitions (see the module docstring's contract).
        The returned cost is this shard's plan wall time — exact in
        ``inline`` mode (nothing else runs), an upper bound (includes
        GIL waits) in ``threads`` mode."""
        t0 = time.perf_counter()
        snapshots = SnapshotMap(self.orch.managers)
        plans = [
            self.orch._plan_partition(part, snapshots, shard=shard_idx)
            for part in keys
        ]
        return plans, time.perf_counter() - t0
