"""Socket shard transport: the multi-host leg of the control plane.

:mod:`repro.core.remote` defined the byte boundary
(:class:`~repro.core.remote.ShardTransport`: ``submit``/``recv``/
``close`` over opaque frames) and two local carriers — loopback and a
``multiprocessing`` pipe.  This module adds the carrier that leaves the
machine:

* :class:`SocketTransport` — one TCP connection per shard worker,
  frames length-prefixed (4-byte big-endian) around the existing
  :func:`repro.core.wire.encode_frame` bytes, with connect and read
  timeouts.  Every failure mode surfaces as a typed
  :class:`~repro.core.wire.TransportError` (``connect`` /
  ``read_timeout`` / ``truncated_frame`` / ``frame_too_large`` /
  ``reset`` / ``closed``) so the round client can treat worker loss
  uniformly.  The connection is lazy: constructing the transport never
  touches the network, and after any failure the connection is dropped
  so the *next* submit transparently reconnects — a fresh connection
  means a fresh worker (see below), which lands exactly on the
  existing restarted-worker recovery rail (full re-send +
  ``reset_interns``).
* :class:`WorkerServer` — the serving side: accepts connections and
  runs **one fresh** :class:`~repro.core.remote.RemoteShardWorker` per
  connection on its own thread.  Binding the worker's lifetime to the
  connection is what makes reconnect semantics trivial: client-side
  state reset after a drop is always consistent with the worker it
  will reach next.  In-process (for tests and the chaos suite: kill /
  restart without port churn) or standalone via
  ``python -m repro.core.transport`` / ``tools/shard_worker.py``.
  The served worker speaks the *whole* frame surface — plan rounds,
  batched rounds, drain, and the worker-owned two-phase commit frames
  (``plan_commit`` / ``commit_decide``): a socket fleet can run
  ``commit_mode="worker"`` with no transport-level opt-in, and a
  fresh-per-connection worker holds no leases, which is exactly the
  state the coordinator's fresh-grant / ``stale_epoch`` rail expects.
* :func:`socket_fleet` — a transport factory mapping shard index →
  address, the shape :class:`~repro.core.remote.RemoteRoundClient`
  accepts for multi-host fleets.
* :class:`ChaosTransport` — deterministic packet-level fault
  injection for the chaos suite: scheduled submit/recv failures
  (connection reset, mid-frame truncation) and silent worker amnesia
  (reconnect-to-fresh-worker, which the client must absorb through
  the typed stale-state errors).  Wraps any inner transport factory.

Wire format on the socket (both directions)::

    +--------------------+---------------------------+
    | length: u32 (BE)   | frame: length bytes       |
    +--------------------+---------------------------+

where *frame* is a :func:`repro.core.wire.encode_frame` payload (JSON
text or the 0xB1 binary codec — self-describing, so the prefix carries
no codec bit).  A length above
:data:`repro.core.wire.MAX_FRAME_BYTES` is rejected before any
allocation.  There is no shutdown frame: closing the connection is the
shutdown signal (unlike the mp-pipe transport, TCP has real EOF).
"""

from __future__ import annotations

import argparse
import socket
import struct
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import wire
from repro.core.wire import TransportError

_LEN = struct.Struct(">I")

#: Defaults for the client-side socket timeouts (seconds).  Connect is
#: short — a dead host should fail fast into the inline-fallback rail;
#: read is long — it bounds a *worker plan phase*, not a network RTT.
CONNECT_TIMEOUT_S = 5.0
READ_TIMEOUT_S = 60.0


def _read_exact(sock: socket.socket, n: int, what: str) -> bytes:
    """Read exactly ``n`` bytes or raise the matching typed error."""
    buf = bytearray()
    while len(buf) < n:
        try:
            chunk = sock.recv(min(1 << 16, n - len(buf)))
        except socket.timeout:
            raise TransportError(
                "read_timeout", f"socket read timed out awaiting {what}"
            ) from None
        except OSError as e:
            raise TransportError("reset", f"connection lost reading {what}: {e}") from None
        if not chunk:
            raise TransportError(
                "truncated_frame",
                f"peer closed mid-{what} ({len(buf)}/{n} bytes)",
            )
        buf += chunk
    return bytes(buf)


def read_frame(sock: socket.socket, what: str = "frame") -> bytes:
    """Read one length-prefixed frame; typed errors on every failure."""
    header = _read_exact(sock, _LEN.size, f"{what} header")
    (n,) = _LEN.unpack(header)
    if n > wire.MAX_FRAME_BYTES:
        raise TransportError(
            "frame_too_large",
            f"{what} length {n} exceeds MAX_FRAME_BYTES {wire.MAX_FRAME_BYTES}",
        )
    if n == 0:
        raise TransportError("truncated_frame", f"zero-length {what}")
    return _read_exact(sock, n, what)


def write_frame(sock: socket.socket, blob: bytes, what: str = "frame") -> None:
    """Write one length-prefixed frame; typed errors on every failure."""
    if len(blob) > wire.MAX_FRAME_BYTES:
        raise TransportError(
            "frame_too_large",
            f"{what} length {len(blob)} exceeds MAX_FRAME_BYTES {wire.MAX_FRAME_BYTES}",
        )
    try:
        sock.sendall(_LEN.pack(len(blob)) + blob)
    except socket.timeout:
        raise TransportError("read_timeout", f"socket send timed out on {what}") from None
    except OSError as e:
        raise TransportError("reset", f"connection lost sending {what}: {e}") from None


class SocketTransport:
    """One shard worker over one TCP connection (lazy, reconnecting).

    Implements the :class:`~repro.core.remote.ShardTransport` contract
    (single in-flight request: ``submit`` then ``recv``).  The
    connection is established on first use; any transport failure drops
    it, so the next ``submit`` reconnects — reaching a *fresh* worker
    on a :class:`WorkerServer` (worker-per-connection), which the round
    client's reset/full-resend rail absorbs.  ``close()`` is idempotent
    and thread-safe: closing from another thread while a ``recv`` is
    blocked shuts the socket down, waking the reader with a typed
    error (the concurrent-close contract the round client relies on
    during teardown)."""

    def __init__(
        self,
        addr: Tuple[str, int],
        connect_timeout: float = CONNECT_TIMEOUT_S,
        read_timeout: float = READ_TIMEOUT_S,
    ) -> None:
        self.addr = (addr[0], int(addr[1]))
        self.connect_timeout = connect_timeout
        self.read_timeout = read_timeout
        self._sock: Optional[socket.socket] = None
        self._closed = False
        self._lock = threading.Lock()

    # -- ShardTransport contract ---------------------------------------
    def submit(self, request: bytes) -> None:
        blob = request.encode("utf-8") if isinstance(request, str) else request
        sock = self._connect()
        try:
            write_frame(sock, blob, "request")
        except TransportError:
            self.reset()
            raise

    def recv(self) -> bytes:
        sock = self._sock
        if sock is None:
            raise TransportError("closed", "recv() without a live connection")
        try:
            return read_frame(sock, "response")
        except TransportError:
            self.reset()
            raise

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self.reset()

    def __enter__(self) -> "SocketTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- connection management -----------------------------------------
    def _connect(self) -> socket.socket:
        with self._lock:
            if self._closed:
                raise TransportError("closed", "transport already closed")
            if self._sock is not None:
                return self._sock
            try:
                sock = socket.create_connection(self.addr, timeout=self.connect_timeout)
            except OSError as e:
                raise TransportError(
                    "connect", f"cannot reach shard worker at {self.addr}: {e}"
                ) from None
            sock.settimeout(self.read_timeout)
            try:
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            except OSError:  # pragma: no cover - exotic stacks
                pass
            self._sock = sock
            return sock

    def reset(self) -> None:
        """Drop the current connection (the transport stays usable: the
        next ``submit`` reconnects unless closed).  Safe to call from
        another thread — a reader blocked in ``recv`` wakes with a
        typed error."""
        sock, self._sock = self._sock, None
        if sock is not None:
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                sock.close()
            except OSError:
                pass


def socket_fleet(
    addrs: Sequence[Tuple[str, int]],
    connect_timeout: float = CONNECT_TIMEOUT_S,
    read_timeout: float = READ_TIMEOUT_S,
) -> Callable[[int], SocketTransport]:
    """Transport factory for a worker fleet: shard index *i* connects to
    ``addrs[i % len(addrs)]``.  Pass the returned callable as the
    orchestrator's ``transport``."""
    if not addrs:
        raise ValueError("socket_fleet: need at least one worker address")
    fixed = [(h, int(p)) for h, p in addrs]

    def factory(shard_idx: int) -> SocketTransport:
        return SocketTransport(
            fixed[shard_idx % len(fixed)],
            connect_timeout=connect_timeout,
            read_timeout=read_timeout,
        )

    return factory


# ---------------------------------------------------------------------------
# the serving side
# ---------------------------------------------------------------------------


def serve_connection(conn: socket.socket, plan_delay_s: float = 0.0) -> None:
    """Serve one connection with one fresh worker until EOF.

    The worker's entire cache state (intern table, snapshot bases,
    resident replicas) lives and dies with the connection — a
    reconnecting client always faces a blank worker, which its
    reset/full-resend rail expects.  ``plan_delay_s`` marks the worker
    a plan-phase straggler (scenario fault injection — see
    :class:`repro.core.remote.RemoteShardWorker`)."""
    from repro.core.remote import RemoteShardWorker

    worker = RemoteShardWorker(plan_delay_s=plan_delay_s)
    try:
        while True:
            try:
                request = read_frame(conn, "request")
            except TransportError:
                return  # client went away (EOF, reset, oversized garbage)
            write_frame(conn, worker.handle_bytes(request), "response")
    except TransportError:  # pragma: no cover - client died mid-response
        return
    finally:
        try:
            conn.close()
        except OSError:
            pass


class WorkerServer:
    """A shard-worker endpoint: accept loop + one worker thread per
    connection.  ``port=0`` binds an ephemeral port (read ``.port``).

    ``kill_connections()`` hard-drops every live connection — the
    chaos suite's "worker died" lever: each connection IS a worker, so
    dropping it kills the worker while the endpoint stays up for the
    client's reconnect (no port churn, deterministic under the DES
    harness)."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 plan_delay_s: float = 0.0) -> None:
        # per-endpoint straggler injection: every worker served from
        # this endpoint inflates its per-partition plan wall (the
        # scenario fault schedule's remote-path straggler lever)
        self.plan_delay_s = plan_delay_s
        srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        srv.bind((host, port))
        srv.listen(64)
        self._srv = srv
        self.host, self.port = srv.getsockname()[:2]
        self._closed = False
        self._conns: List[socket.socket] = []
        self._lock = threading.Lock()
        self._threads: List[threading.Thread] = []
        self._accept = threading.Thread(
            target=self._accept_loop, name=f"shard-srv-{self.port}", daemon=True
        )
        self._accept.start()

    @property
    def addr(self) -> Tuple[str, int]:
        return (self.host, self.port)

    def _accept_loop(self) -> None:
        while True:
            try:
                conn, _peer = self._srv.accept()
            except OSError:
                return  # listening socket closed
            with self._lock:
                if self._closed:
                    try:
                        conn.close()
                    except OSError:
                        pass
                    return
                self._conns.append(conn)
                self._conns = [c for c in self._conns if c.fileno() != -1]
            t = threading.Thread(
                target=serve_connection, args=(conn, self.plan_delay_s),
                daemon=True,
            )
            t.start()
            self._threads.append(t)

    def kill_connections(self) -> int:
        """Drop every live worker connection (leaves the endpoint up);
        returns the number of connections killed."""
        with self._lock:
            conns, self._conns = self._conns, []
        killed = 0
        for c in conns:
            if c.fileno() == -1:
                continue
            killed += 1
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        return killed

    def close(self) -> None:
        """Stop accepting, drop live connections, join worker threads."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        try:
            self._srv.close()
        except OSError:
            pass
        self.kill_connections()
        for t in self._threads:
            t.join(timeout=2)
        self._accept.join(timeout=2)

    def __enter__(self) -> "WorkerServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# deterministic fault injection (the chaos suite's packet-level lever)
# ---------------------------------------------------------------------------


class ChaosPlan:
    """One worker's fault plan, shared across transport recreations.

    ``schedule`` maps a 0-based request index to a fault name; the
    request counter lives here — NOT on the transport object — because
    the round client tears down and rebuilds a failed transport, and
    the plan must keep counting (and keep its remaining faults) across
    that rebuild for the storm to be deterministic."""

    __slots__ = ("schedule", "requests", "faults_fired")

    def __init__(self, schedule: Optional[Dict[int, str]] = None) -> None:
        self.schedule = dict(schedule or {})
        self.requests = 0
        self.faults_fired = 0


class ChaosTransport:
    """Wraps a transport with a scheduled fault plan.

    The :class:`ChaosPlan` maps a 0-based request index (counted across
    the plan's whole life, including re-sends and client-side transport
    rebuilds) to a fault:

    * ``"drop_submit"`` — the request never leaves: the inner transport
      is torn down and ``submit`` raises ``TransportError("reset")``;
    * ``"drop_recv"`` — the request is swallowed after submit: the
      inner transport is torn down and ``recv`` raises
      ``TransportError("reset")`` (worker died holding the request);
    * ``"truncate"`` — like ``drop_recv`` but surfaces as
      ``TransportError("truncated_frame")`` (peer died mid-frame);
    * ``"amnesia"`` — *silent* worker replacement before submit: the
      inner transport is recreated (fresh worker), no error raised —
      the worker answers the stale-referencing request with a typed
      ``stale_ref``/``stale_intern`` error, which the client's
      full-resend recovery rail must absorb (the stale-ref storm).

    Faults are one-shot per index, so a storm is deterministic and
    replayable; the inner transport is rebuilt via ``factory`` after
    every injected teardown.  Build fleets with :func:`chaos_fleet`."""

    def __init__(
        self,
        factory: Callable[[], object],
        plan: Optional[ChaosPlan] = None,
        schedule: Optional[Dict[int, str]] = None,
    ) -> None:
        self._factory = factory
        self._inner = factory()
        self.plan = plan if plan is not None else ChaosPlan(schedule)
        self._pending_fault: Optional[str] = None
        self._pending_idx = 0

    def _teardown(self) -> None:
        try:
            self._inner.close()
        except Exception:  # noqa: BLE001 - already failing
            pass
        self._inner = self._factory()

    def submit(self, request: bytes) -> None:
        plan = self.plan
        idx = plan.requests
        plan.requests += 1
        fault = plan.schedule.pop(idx, None)
        if fault == "amnesia":
            plan.faults_fired += 1
            self._teardown()
            fault = None
        elif fault == "drop_submit":
            plan.faults_fired += 1
            self._teardown()
            raise TransportError("reset", f"chaos: request {idx} dropped at submit")
        self._pending_fault = fault
        self._pending_idx = idx
        self._inner.submit(request)

    def recv(self) -> bytes:
        fault, self._pending_fault = self._pending_fault, None
        if fault is not None:
            self.plan.faults_fired += 1
            self._teardown()
            if fault == "truncate":
                raise TransportError(
                    "truncated_frame",
                    f"chaos: response {self._pending_idx} truncated mid-frame",
                )
            raise TransportError(
                "reset", f"chaos: response {self._pending_idx} dropped"
            )
        return self._inner.recv()

    def close(self) -> None:
        self._inner.close()


def chaos_fleet(
    inner_factory: Callable[[int], object],
    schedules: Dict[int, Dict[int, str]],
) -> Callable[[int], ChaosTransport]:
    """A chaos-wrapped transport factory for the round client.

    ``schedules`` maps shard index → fault plan (see
    :class:`ChaosTransport`).  Each shard's :class:`ChaosPlan` is
    created once and survives client-side transport rebuilds, so the
    storm stays deterministic end to end.  The returned factory exposes
    the live plans as ``factory.plans`` (shard → :class:`ChaosPlan`)
    for assertions on faults fired."""
    plans: Dict[int, ChaosPlan] = {
        i: ChaosPlan(sched) for i, sched in schedules.items()
    }

    def factory(shard_idx: int) -> ChaosTransport:
        plan = plans.setdefault(shard_idx, ChaosPlan())
        return ChaosTransport(lambda: inner_factory(shard_idx), plan=plan)

    factory.plans = plans  # type: ignore[attr-defined]
    return factory


# ---------------------------------------------------------------------------
# standalone entrypoint (tools/shard_worker.py is a thin wrapper)
# ---------------------------------------------------------------------------


def main(argv: Optional[Sequence[str]] = None) -> int:
    """Serve shard workers on a TCP endpoint until interrupted.

    Prints ``PORT <n>`` (flushed) once listening — a launcher binding
    port 0 reads the actual port from the first stdout line."""
    parser = argparse.ArgumentParser(
        description="Serve ARL-Tangram shard plan workers over TCP"
    )
    parser.add_argument("--host", default="127.0.0.1")
    parser.add_argument("--port", type=int, default=0, help="0 = ephemeral")
    args = parser.parse_args(argv)
    server = WorkerServer(args.host, args.port)
    print(f"PORT {server.port}", flush=True)
    try:
        threading.Event().wait()  # serve until killed
    except KeyboardInterrupt:
        pass
    finally:
        server.close()
    return 0


if __name__ == "__main__":  # pragma: no cover - exercised via subprocess
    raise SystemExit(main())
