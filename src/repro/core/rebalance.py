"""Telemetry-driven sub-queue rebalancing across replica pools.

PR 5 built the mechanism — :meth:`Orchestrator.migrate_task` moves a
whole WFQ task sub-queue between replica partitions with fair ordering
preserved, and :meth:`Orchestrator.rebalance` evens depths on demand.
This module adds the *driver*: a :class:`RebalancePolicy` evaluated on
a virtual-time cadence (:meth:`Orchestrator.enable_rebalance`) that
reads live telemetry —

* per-replica **queue depth** and per-task backlog (count and queued
  work in cost units, :meth:`PartitionQueue.backlog_cost`),
* per-task **starvation ages** (now − oldest queued submit),
* per-pool **utilization** (busy fraction of the replica's manager),
* per-partition **plan-cost EWMAs** from the round engine (a proxy for
  how expensive a partition's rounds are where they're planned),

— and orders migrations through the existing ``migrate_task``
machinery.  The decision rule is deliberately the proven one from
``Orchestrator.rebalance`` (move the sub-queue whose size is closest
to half the depth gap — the best single move), extended with the
telemetry the cadence makes available: the most loaded replica is the
source (depth, then worst starvation, then plan cost), the least
loaded *unsaturated* replica is the sink, and among equally
gap-improving sub-queues the most starved task moves first (it reaches
service soonest on the idle pool).

Everything is deterministic: signals are snapshots of DES state, ties
break on sorted names, and the cadence fires at fixed virtual-time
periods — the same run always makes the same moves, which is what lets
the bench gate measured ACT wins.

Cost model honesty: migrations are not free (detach + retarget + merge
walls land in ``Telemetry.migration_wall_s``; each move also dirties
two partitions, forcing replans).  ``min_gap`` is the hysteresis that
keeps the policy from thrashing sub-queues between near-balanced
pools, and ``max_moves`` bounds the work any single tick may order.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple


@dataclass
class RebalanceSignals:
    """One cadence tick's snapshot of the orchestrator's telemetry
    (collected by ``Orchestrator._rebalance_signals``; all maps are
    keyed by replica partition name)."""

    now: float
    #: queued actions per replica
    depths: Dict[str, int] = field(default_factory=dict)
    #: per replica: task -> queued action count
    backlogs: Dict[str, Dict[str, int]] = field(default_factory=dict)
    #: per replica: task -> queued work in WFQ cost units
    backlog_cost: Dict[str, Dict[str, float]] = field(default_factory=dict)
    #: per replica: task -> starvation age of its oldest queued action
    starvation: Dict[str, Dict[str, float]] = field(default_factory=dict)
    #: per replica: busy fraction of the pool manager (1 - free/capacity)
    utilization: Dict[str, float] = field(default_factory=dict)
    #: per partition: plan-cost EWMA from the round engine (seconds)
    plan_cost_s: Dict[str, float] = field(default_factory=dict)


class RebalancePolicy:
    """Decides sub-queue migrations from one tick's signals.

    ``period_s`` is the cadence (virtual seconds between evaluations),
    ``min_gap`` the depth-gap hysteresis below which no move is worth
    its cost, ``max_moves`` the per-tick move budget, and
    ``util_ceiling`` the sink gate: a replica already busier than this
    fraction receives no new sub-queues (its queue would grow, not
    drain)."""

    def __init__(
        self,
        period_s: float = 0.25,
        min_gap: int = 2,
        max_moves: int = 2,
        util_ceiling: float = 0.95,
    ) -> None:
        if period_s <= 0:
            raise ValueError(f"period_s must be > 0, got {period_s}")
        self.period_s = float(period_s)
        self.min_gap = int(min_gap)
        self.max_moves = int(max_moves)
        self.util_ceiling = float(util_ceiling)

    # ------------------------------------------------------------------
    def decide(
        self, signals: RebalanceSignals, replicas: Sequence[str]
    ) -> List[Tuple[str, str, str]]:
        """The tick's migration orders as ``(task_id, src, dst)``
        triples, at most ``max_moves`` of them.  Later moves see the
        depths earlier ones will produce (the tick plans a consistent
        batch, not ``max_moves`` copies of the same move)."""
        ordered = sorted(replicas)
        depths = {p: signals.depths.get(p, 0) for p in ordered}
        backlogs = {p: dict(signals.backlogs.get(p, {})) for p in ordered}
        moves: List[Tuple[str, str, str]] = []
        for _ in range(self.max_moves):
            src = max(ordered, key=lambda p: self._load(signals, p, depths))
            dst = self._sink(signals, ordered, depths, src)
            if dst is None:
                break
            gap = depths[src] - depths[dst]
            if gap <= self.min_gap:
                break
            task, n = self._pick_subqueue(signals, backlogs[src], src, gap)
            if task is None:
                break
            moves.append((task, src, dst))
            depths[src] -= n
            depths[dst] += n
            backlogs[src].pop(task, None)
            backlogs[dst][task] = backlogs[dst].get(task, 0) + n
        return moves

    # ------------------------------------------------------------------
    def _load(self, signals: RebalanceSignals, p: str, depths: Dict[str, int]):
        """Source ranking: queue depth first (the quantity migration
        directly moves), then worst starvation age, then the partition's
        plan-cost EWMA.  The name tiebreak keeps max() deterministic."""
        starv = signals.starvation.get(p, {})
        return (
            depths[p],
            max(starv.values(), default=0.0),
            signals.plan_cost_s.get(p, 0.0),
            p,
        )

    def _sink(
        self,
        signals: RebalanceSignals,
        ordered: Sequence[str],
        depths: Dict[str, int],
        src: str,
    ):
        """Sink: the least-loaded replica still below the utilization
        ceiling (shallowest queue, then least busy, then name)."""
        candidates = [
            p
            for p in ordered
            if p != src
            and signals.utilization.get(p, 0.0) < self.util_ceiling
        ]
        if not candidates:
            return None
        return min(
            candidates,
            key=lambda p: (depths[p], signals.utilization.get(p, 0.0), p),
        )

    def _pick_subqueue(
        self,
        signals: RebalanceSignals,
        backlog: Dict[str, int],
        src: str,
        gap: int,
    ):
        """The sub-queue to move: size closest to half the gap (the
        move that most evens the pair — same math as
        ``Orchestrator.rebalance``), then the most starved task, then
        queued work, then name."""
        starv = signals.starvation.get(src, {})
        cost = signals.backlog_cost.get(src, {})
        best = None
        for t, n in sorted(backlog.items()):
            if n <= 0 or abs(gap - 2 * n) >= gap:
                continue  # the move must strictly shrink the gap
            key = (abs(gap - 2 * n), -starv.get(t, 0.0), -cost.get(t, 0.0), t)
            if best is None or key < best[0]:
                best = (key, t, n)
        if best is None:
            return None, 0
        return best[1], best[2]
