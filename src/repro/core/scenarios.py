"""Scenario factory: declarative, seeded workload generation.

Every bench scenario used to be a hand-written Python generator (an
``_churn_action(i)`` here, a wave loop there) — adding a workload meant
adding code, and the CI gates were only as strong as the handful of
shapes someone had written down.  This module replaces that with a
**declarative** :class:`ScenarioSpec`: fleet topology (pools), traffic
streams (tenant + action mix), an arrival process, and a fault
schedule, all plain frozen dataclasses that encode to a wire-codec-style
dict (:func:`encode_scenario` / :func:`decode_scenario`, versioned
envelope, unknown fields ignored, malformed fields rejected with typed
:class:`ScenarioError`\\ s).

**Determinism is the contract.**  :func:`compile_scenario` turns a spec
into a :class:`CompiledScenario` — a deterministic event stream of
:class:`ActionTemplate`\\ s — using only ``random.Random(seed)`` uniforms
fed through in-house inverse-CDF / Box-Muller transforms (never
``random.lognormvariate`` or numpy, whose numeric paths may drift across
versions).  Identical spec + seed ⇒ **byte-identical** stream
(:meth:`CompiledScenario.stream_bytes`), which is what makes the
differential replay rail possible: the same compiled stream drives the
DES benches (``bench_scheduler.py --suite generated``), the chaos
harness, *and* the live-mode runner (:mod:`repro.core.live`), with
sim-vs-live launch traces compared structurally.

Arrival processes: Poisson, diurnal (sinusoid-modulated Poisson via
thinning), burst-pause, synchronized waves, one-shot burst, and
closed-loop (completions refill the queue in bursts — the paper's
rollout-batch shape; closed-loop streams must use deterministic
duration kinds, since refill times are decided by the run, not the
compiler).  Duration distributions: fixed, cycle (the legacy benches'
``base + step * (idx % mod)`` shape), lognormal, and Pareto heavy-tail
(DeepSearch-style tool latencies).

A worked example (doctested; see docs/scenarios.md for the schema):

>>> spec = ScenarioSpec(
...     name="doc",
...     seed=7,
...     pools=(PoolSpec("pool0", kind="pool", cores=2),),
...     streams=(StreamSpec(
...         mix=MixSpec(
...             pattern=(0,),
...             kinds=(ActionKindSpec(
...                 name="tool", units=(1,),
...                 duration=DurationSpec(kind="fixed", base=0.5)),),
...         ),
...         pools=("pool0",), traj="t{seq}"),),
...     arrival=ArrivalSpec(kind="burst", n=4),
... )
>>> compiled = compile_scenario(spec)
>>> [ev.template.trajectory_id for ev in compiled.events]
['t0', 't1', 't2', 't3']
>>> compiled.stream_bytes() == compile_scenario(spec).stream_bytes()
True
>>> decode_scenario(encode_scenario(spec)) == spec
True
"""

from __future__ import annotations

import dataclasses
import json
import math
import random
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import wire
from repro.core.action import (
    Action,
    AmdahlElasticity,
    LinearElasticity,
    ResourceRequest,
)

#: Version of the scenario-spec encoding.  Additive fields ride within a
#: version (decoders ignore unknown keys, the wire idiom); a breaking
#: change bumps it and the decoder refuses the mismatch with a typed
#: error.
SCENARIO_VERSION = 1

#: Compiled-stream preview length for unbounded closed-loop streams
#: (the serialized event stream must be finite to be byte-comparable).
DEFAULT_MAX_ACTIONS = 2048


class ScenarioError(ValueError):
    """A malformed scenario spec.  ``code`` names the failure class so
    callers (and tests) can assert on *what* was wrong, not on message
    prose."""

    def __init__(self, code: str, message: str) -> None:
        super().__init__(message)
        self.code = code


def _require(cond: bool, code: str, message: str) -> None:
    if not cond:
        raise ScenarioError(code, message)


# ---------------------------------------------------------------------------
# Deterministic distributions (raw uniforms only — stable across Python
# versions and platforms, which is what the bit-identical rail rides on)
# ---------------------------------------------------------------------------

#: Duration kinds whose samples are pure functions of the action's
#: indices (no rng) — the only kinds closed-loop streams may use.
DETERMINISTIC_DURATIONS = frozenset({"fixed", "cycle"})

#: Index sources a cycle duration may key on.
INDEX_SOURCES = ("seq", "slot", "wave", "wave_plus_slot")


def _std_normal(rng: random.Random) -> float:
    """One standard-normal draw via Box–Muller from two raw uniforms."""
    u1 = max(rng.random(), 1e-12)
    u2 = rng.random()
    return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)


@dataclass(frozen=True)
class DurationSpec:
    """How an action kind's base duration (T_ori) is produced.

    * ``fixed``     — always ``base``.
    * ``cycle``     — ``base + step * ((idx + offset) % mod)`` where
      ``idx`` comes from ``index`` (the legacy benches' deterministic
      duration ladders are exactly this shape).
    * ``lognormal`` — ``exp(base + sigma * z)`` (``base`` is the
      log-mean mu), clamped to ``[lo, hi]`` when set.
    * ``pareto``    — ``base * (1 - u)^(-1/alpha)`` (``base`` is the
      scale x_m), clamped to ``hi`` when set — the heavy tail.
    """

    kind: str = "fixed"
    base: float = 1.0
    step: float = 0.0
    mod: int = 1
    offset: int = 0
    index: str = "seq"
    sigma: float = 0.5
    alpha: float = 1.5
    lo: Optional[float] = None
    hi: Optional[float] = None

    def __post_init__(self) -> None:
        _require(
            self.kind in ("fixed", "cycle", "lognormal", "pareto"),
            "bad_duration", f"unknown duration kind {self.kind!r}",
        )
        _require(self.index in INDEX_SOURCES, "bad_duration",
                 f"unknown duration index source {self.index!r}")
        if self.kind == "cycle":
            _require(self.mod >= 1, "bad_duration",
                     f"cycle mod must be >= 1, got {self.mod}")
        if self.kind == "pareto":
            _require(self.alpha > 0, "bad_duration",
                     f"pareto alpha must be > 0, got {self.alpha}")
            _require(self.base > 0, "bad_duration",
                     f"pareto scale must be > 0, got {self.base}")
        if self.kind == "lognormal":
            _require(self.sigma >= 0, "bad_duration",
                     f"lognormal sigma must be >= 0, got {self.sigma}")
        if self.kind in ("fixed", "cycle"):
            _require(self.base > 0 or self.step > 0, "bad_duration",
                     "duration base must be positive")

    @property
    def deterministic(self) -> bool:
        return self.kind in DETERMINISTIC_DURATIONS

    def sample(self, idx: Dict[str, int], rng: random.Random) -> float:
        if self.kind == "fixed":
            return self.base
        if self.kind == "cycle":
            return self.base + self.step * ((idx[self.index] + self.offset) % self.mod)
        if self.kind == "lognormal":
            v = math.exp(self.base + self.sigma * _std_normal(rng))
        else:  # pareto
            u = rng.random()
            v = self.base * (1.0 - u) ** (-1.0 / self.alpha)
        if self.lo is not None:
            v = max(v, self.lo)
        if self.hi is not None:
            v = min(v, self.hi)
        return v


# ---------------------------------------------------------------------------
# Action kinds, mixes, streams
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ActionKindSpec:
    """One action archetype in a stream's mix.

    ``rtype=None`` binds the action to the pool the stream fans it onto
    (replica-fleet shape); a set ``rtype`` pins it (multiplexed-fleet
    shape, e.g. ``cpu`` / ``gpu``); a non-empty ``rtype_cycle`` picks
    ``rtype_cycle[idx % len]`` per action (the churn bench's rotating
    API fleet).  ``elasticity`` is ``None`` (rigid), ``("amdahl",
    serial)``, or ``("linear", 0.0)``."""

    name: str
    units: Tuple[int, ...]
    duration: DurationSpec
    elasticity: Optional[Tuple[str, float]] = None
    rtype: Optional[str] = None
    rtype_cycle: Tuple[str, ...] = ()
    service: Optional[str] = None

    def __post_init__(self) -> None:
        _require(bool(self.units), "bad_kind", f"{self.name}: empty unit set")
        _require(all(u > 0 for u in self.units), "bad_kind",
                 f"{self.name}: units must be positive")
        if self.elasticity is not None:
            model = self.elasticity[0]
            _require(model in ("amdahl", "linear"), "bad_kind",
                     f"{self.name}: unknown elasticity model {model!r}")
            _require(len(self.units) > 1, "bad_kind",
                     f"{self.name}: elastic kind needs > 1 feasible unit")
        _require(not (self.rtype and self.rtype_cycle), "bad_kind",
                 f"{self.name}: rtype and rtype_cycle are exclusive")

    def resolve_rtype(self, pool: str, idx: int) -> str:
        if self.rtype_cycle:
            return self.rtype_cycle[idx % len(self.rtype_cycle)]
        return self.rtype if self.rtype is not None else pool

    def build_elasticity(self):
        if self.elasticity is None:
            return None
        model, param = self.elasticity
        return (AmdahlElasticity(param) if model == "amdahl"
                else LinearElasticity())


@dataclass(frozen=True)
class MixSpec:
    """Which :class:`ActionKindSpec` the stream's ``idx``-th slot draws:
    ``kinds[pattern[idx % len(pattern)]]`` — the deterministic cyclic
    mixes every legacy bench used.  (A weighted random mix is just a
    pattern sampled offline; keeping the mix deterministic keeps the
    compiled stream byte-stable.)"""

    pattern: Tuple[int, ...]
    kinds: Tuple[ActionKindSpec, ...]

    def __post_init__(self) -> None:
        _require(bool(self.kinds), "bad_mix", "mix has no action kinds")
        _require(bool(self.pattern), "bad_mix", "mix has an empty pattern")
        _require(
            all(0 <= p < len(self.kinds) for p in self.pattern),
            "bad_mix",
            f"pattern indexes outside kinds[0..{len(self.kinds) - 1}]",
        )

    def kind_at(self, idx: int) -> ActionKindSpec:
        return self.kinds[self.pattern[idx % len(self.pattern)]]


@dataclass(frozen=True)
class StreamSpec:
    """One traffic stream: a tenant (``task_id`` + fair-share weight /
    quota), an action mix, the pools it fans over, and a trajectory-id
    pattern (placeholders: ``{seq}`` ``{slot}`` ``{wave}`` ``{pool}``
    ``{pidx}`` ``{task}``).  ``phase`` offsets every index the mix and
    durations see — the fairness bench de-phases twin tenants this way."""

    mix: MixSpec
    pools: Tuple[str, ...] = ()
    task_id: str = "task0"
    weight: Optional[float] = None
    quota: Optional[float] = None
    phase: int = 0
    traj: str = "t{seq}"


# ---------------------------------------------------------------------------
# Pools (fleet topology)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PoolSpec:
    """One resource pool.  ``kind``:

    * ``pool`` — plain :class:`ResourceManager` with ``cores`` units
      (the replica-fleet pools);
    * ``cpu``  — :class:`CpuManager` over one ``cores``-core node;
    * ``gpu``  — :class:`GpuManager` over one node with one
      ``service`` at ``capacity`` (the reward-model fleet);
    * ``api``  — :class:`BasicResourceManager` with ``concurrency``
      concurrent slots (rate-limited external tools)."""

    name: str
    kind: str = "pool"
    cores: int = 8
    service: Optional[str] = None
    capacity: float = 40.0
    concurrency: int = 3

    def __post_init__(self) -> None:
        _require(self.kind in ("pool", "cpu", "gpu", "api"), "bad_pool",
                 f"{self.name}: unknown pool kind {self.kind!r}")
        if self.kind in ("pool", "cpu"):
            _require(self.cores > 0, "bad_pool",
                     f"{self.name}: cores must be > 0")
        if self.kind == "api":
            _require(self.concurrency > 0, "bad_pool",
                     f"{self.name}: concurrency must be > 0")


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArrivalSpec:
    """When actions arrive.

    * ``burst``       — ``n`` actions per stream×pool at ``at``.
    * ``waves``       — ``per_wave`` actions per stream×pool every
      ``period_s``, ``waves`` times (the synchronized fleet churn).
    * ``poisson``     — exponential gaps at ``rate_hz`` until
      ``horizon_s``, round-robin over the stream's pools.
    * ``diurnal``     — non-homogeneous Poisson, rate
      ``rate_hz * (1 + amplitude * sin(2*pi*t/period_s)) / (1+amplitude)``
      sampled by thinning (peak rate ``rate_hz``).
    * ``burst_pause`` — ``burst`` same-instant actions, then silence,
      every ``period_s``, ``waves`` times.
    * ``closed_loop`` — ``prime`` actions up front (spaced
      ``prime_spacing_s`` apart; streams staggered by
      ``stream_stagger_s``), then every ``wave`` completions of a stream
      trigger a ``wave``-sized same-instant refill, bounded by ``total``
      actions and/or the ``horizon_s`` clock.
    """

    kind: str
    n: int = 0
    at: float = 0.0
    period_s: float = 1.0
    waves: int = 1
    per_wave: int = 1
    burst: int = 1
    rate_hz: float = 1.0
    amplitude: float = 0.5
    horizon_s: Optional[float] = None
    prime: int = 0
    wave: int = 1
    total: Optional[int] = None
    prime_spacing_s: float = 0.0
    stream_stagger_s: float = 0.0

    def __post_init__(self) -> None:
        kinds = ("burst", "waves", "poisson", "diurnal", "burst_pause",
                 "closed_loop")
        _require(self.kind in kinds, "bad_arrival",
                 f"unknown arrival kind {self.kind!r}")
        if self.kind in ("poisson", "diurnal"):
            _require(self.rate_hz > 0, "bad_arrival", "rate_hz must be > 0")
            _require(self.horizon_s is not None and self.horizon_s > 0,
                     "bad_arrival", f"{self.kind} arrivals need horizon_s")
        if self.kind == "diurnal":
            _require(0 <= self.amplitude <= 1, "bad_arrival",
                     "diurnal amplitude must be in [0, 1]")
        if self.kind in ("waves", "burst_pause"):
            _require(self.period_s > 0, "bad_arrival", "period_s must be > 0")
            _require(self.waves >= 1, "bad_arrival", "waves must be >= 1")
        if self.kind == "closed_loop":
            _require(self.prime >= 1, "bad_arrival",
                     "closed_loop needs prime >= 1")
            _require(self.wave >= 1, "bad_arrival",
                     "closed_loop needs wave >= 1")
            _require(self.total is not None or self.horizon_s is not None,
                     "bad_arrival",
                     "closed_loop needs a total or horizon_s bound")


# ---------------------------------------------------------------------------
# Fault schedule
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    * ``kill_worker``  — hard-drop every worker connection at virtual
      ``at`` (the chaos suite's kill lever).
    * ``packet``       — inject ``fault`` (``drop_submit`` /
      ``drop_recv`` / ``truncate`` / ``amnesia``) on ``shard``'s
      ``index``-th request (:class:`~repro.core.transport.ChaosPlan`).
    * ``straggler``    — per-action latency inflation: actions bound to
      ``pool`` whose arrival falls in ``[at, until)`` have their
      durations multiplied by ``factor``; additionally ``plan_delay_s``
      > 0 marks worker ``worker`` a plan-phase straggler (its reported
      per-partition plan wall is inflated by that much — the rebalance
      cadence's plan-cost signal)."""

    kind: str
    at: float = 0.0
    until: Optional[float] = None
    shard: int = 0
    index: int = 0
    fault: str = "drop_recv"
    pool: Optional[str] = None
    factor: float = 1.0
    worker: Optional[int] = None
    plan_delay_s: float = 0.0

    def __post_init__(self) -> None:
        _require(self.kind in ("kill_worker", "packet", "straggler"),
                 "bad_fault", f"unknown fault kind {self.kind!r}")
        if self.kind == "packet":
            _require(
                self.fault in ("drop_submit", "drop_recv", "truncate",
                               "amnesia"),
                "bad_fault", f"unknown packet fault {self.fault!r}",
            )
        if self.kind == "straggler":
            _require(self.factor >= 1.0, "bad_fault",
                     "straggler factor must be >= 1")
            _require(self.pool is not None or self.worker is not None,
                     "bad_fault", "straggler needs a pool or a worker")


# ---------------------------------------------------------------------------
# The spec
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ScenarioSpec:
    """A complete generated scenario: fleet + streams + arrivals +
    faults (+ an optional scheduler-knob override the scenario is built
    to evaluate — the wave-forming gate specs carry theirs here)."""

    name: str
    pools: Tuple[PoolSpec, ...]
    streams: Tuple[StreamSpec, ...]
    arrival: ArrivalSpec
    seed: int = 0
    faults: Tuple[FaultSpec, ...] = ()
    policy: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        _require(bool(self.pools), "bad_spec", "spec has no pools")
        _require(bool(self.streams), "bad_spec", "spec has no streams")
        names = [p.name for p in self.pools]
        _require(len(set(names)) == len(names), "bad_pool",
                 f"duplicate pool names in {names}")
        known = set(names)
        for s in self.streams:
            for p in s.pools:
                _require(p in known, "unknown_pool",
                         f"stream {s.task_id!r} targets unknown pool {p!r}")
            for k in s.mix.kinds:
                if k.rtype is not None:
                    _require(k.rtype in known, "unknown_pool",
                             f"kind {k.name!r} targets unknown pool {k.rtype!r}")
                for rt in k.rtype_cycle:
                    _require(rt in known, "unknown_pool",
                             f"kind {k.name!r} cycles unknown pool {rt!r}")
                if self.arrival.kind == "closed_loop":
                    _require(k.duration.deterministic,
                             "closed_loop_stochastic",
                             f"kind {k.name!r}: closed-loop streams need "
                             f"deterministic durations (refill times are "
                             f"run-decided, so stochastic draws would not "
                             f"be replayable)")

    # -- fault-schedule views (what the harnesses consume) ------------
    def kill_times(self) -> Tuple[float, ...]:
        return tuple(f.at for f in self.faults if f.kind == "kill_worker")

    def packet_plan(self) -> Dict[int, Dict[int, str]]:
        plan: Dict[int, Dict[int, str]] = {}
        for f in self.faults:
            if f.kind == "packet":
                plan.setdefault(f.shard, {})[f.index] = f.fault
        return plan

    def stragglers(self) -> Tuple[FaultSpec, ...]:
        return tuple(f for f in self.faults if f.kind == "straggler")


# ---------------------------------------------------------------------------
# Wire-codec-style encoding (dict <-> spec, versioned, typed errors)
# ---------------------------------------------------------------------------

_SPEC_TYPES = {
    "duration": DurationSpec,
    "kindspec": ActionKindSpec,
    "mix": MixSpec,
    "stream": StreamSpec,
    "pool": PoolSpec,
    "arrival": ArrivalSpec,
    "fault": FaultSpec,
}


def _enc(obj: Any) -> Any:
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        out = {}
        for f in dataclasses.fields(obj):
            v = getattr(obj, f.name)
            if v == f.default and f.default is not dataclasses.MISSING:
                continue  # sparse encoding: defaults stay implicit
            out[f.name] = _enc(v)
        return out
    if isinstance(obj, tuple):
        return [_enc(v) for v in obj]
    if isinstance(obj, dict):
        return {k: _enc(v) for k, v in obj.items()}
    return obj


def _dec(cls, payload: Any, where: str):
    """Build dataclass ``cls`` from a dict, ignoring unknown keys (the
    wire idiom: additive fields never break an old decoder)."""
    if not isinstance(payload, dict):
        raise ScenarioError(
            "bad_field", f"{where}: expected an object, got "
            f"{type(payload).__name__}")
    fields = {f.name: f for f in dataclasses.fields(cls)}
    kwargs: Dict[str, Any] = {}
    for key, value in payload.items():
        f = fields.get(key)
        if f is None:
            continue
        kwargs[key] = _dec_field(f, value, f"{where}.{key}")
    try:
        return cls(**kwargs)
    except ScenarioError:
        raise
    except (TypeError, ValueError) as e:
        raise ScenarioError("bad_field", f"{where}: {e}") from None


def _dec_field(f, value: Any, where: str) -> Any:
    ann = str(f.type)
    if value is None:
        return None
    if "DurationSpec" in ann:
        return _dec(DurationSpec, value, where)
    if "MixSpec" in ann:
        return _dec(MixSpec, value, where)
    if "ActionKindSpec" in ann:
        return tuple(_dec(ActionKindSpec, v, f"{where}[{i}]")
                     for i, v in enumerate(value))
    if "StreamSpec" in ann:
        return tuple(_dec(StreamSpec, v, f"{where}[{i}]")
                     for i, v in enumerate(value))
    if "PoolSpec" in ann:
        return tuple(_dec(PoolSpec, v, f"{where}[{i}]")
                     for i, v in enumerate(value))
    if "ArrivalSpec" in ann:
        return _dec(ArrivalSpec, value, where)
    if "FaultSpec" in ann:
        return tuple(_dec(FaultSpec, v, f"{where}[{i}]")
                     for i, v in enumerate(value))
    if isinstance(value, list):
        # plain tuples of scalars, or the elasticity (model, param) pair
        return tuple(tuple(v) if isinstance(v, list) else v for v in value)
    return value


def encode_scenario(spec: ScenarioSpec) -> Dict[str, Any]:
    """Spec -> versioned wire dict (sparse: defaulted fields omitted)."""
    return wire.envelope("scenario_spec", {"spec": _enc(spec)})


def decode_scenario(payload: Any) -> ScenarioSpec:
    """Versioned wire dict -> validated spec (typed errors)."""
    if not isinstance(payload, dict):
        raise ScenarioError("bad_envelope", "scenario payload must be a dict")
    if payload.get("v") != wire.WIRE_VERSION:
        raise ScenarioError(
            "bad_version",
            f"scenario version {payload.get('v')!r} != {wire.WIRE_VERSION}")
    if payload.get("kind") != "scenario_spec":
        raise ScenarioError(
            "bad_envelope", f"expected kind 'scenario_spec', got "
            f"{payload.get('kind')!r}")
    body = payload.get("spec")
    return _dec(ScenarioSpec, body, "spec")


def load_scenario(path: str) -> ScenarioSpec:
    """Read + decode a spec file (JSON envelope on disk)."""
    with open(path) as f:
        return decode_scenario(json.load(f))


def save_scenario(spec: ScenarioSpec, path: str) -> None:
    with open(path, "w") as f:
        json.dump(encode_scenario(spec), f, indent=2, sort_keys=True)
        f.write("\n")


# ---------------------------------------------------------------------------
# Compilation: spec -> deterministic event stream
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ActionTemplate:
    """A frozen description of one action occurrence.  Templates are
    what the stream serializes (Actions are mutable and carry a global
    uid counter); :meth:`build` mints a fresh :class:`Action` — both the
    DES driver and the live runner build from the same templates, which
    is the replay rail's invariant."""

    name: str
    rtype: str
    units: Tuple[int, ...]
    base_duration: float
    elasticity: Optional[Tuple[str, float]] = None
    service: Optional[str] = None
    task_id: str = "task0"
    trajectory_id: str = "traj0"

    def build(self, fn: Optional[Callable[..., object]] = None) -> Action:
        kwargs: Dict[str, Any] = dict(
            name=self.name,
            cost={self.rtype: ResourceRequest(self.rtype, self.units)},
            base_duration=self.base_duration,
            task_id=self.task_id,
            trajectory_id=self.trajectory_id,
            service=self.service,
            fn=fn,
        )
        if self.elasticity is not None:
            model, param = self.elasticity
            kwargs["key_resource"] = self.rtype
            kwargs["elasticity"] = (
                AmdahlElasticity(param) if model == "amdahl"
                else LinearElasticity()
            )
        return Action(**kwargs)

    def to_dict(self) -> Dict[str, Any]:
        return _enc(self)


@dataclass(frozen=True)
class ArrivalEvent:
    """One stream occurrence: submit ``template`` at virtual ``t``
    (``None`` for closed-loop refills, whose time the run decides)."""

    t: Optional[float]
    stream: int
    template: ActionTemplate


@dataclass
class CompiledScenario:
    """The deterministic event stream a spec compiles to."""

    spec: ScenarioSpec
    events: Tuple[ArrivalEvent, ...]
    #: per stream: total actions this run may submit (None = unbounded,
    #: horizon-gated)
    totals: Tuple[Optional[int], ...]
    time_scale: float = 1.0

    def to_dict(self) -> Dict[str, Any]:
        return wire.envelope("scenario_stream", {
            "scenario": self.spec.name,
            "seed": self.spec.seed,
            "time_scale": self.time_scale,
            "events": [
                {"t": ev.t, "stream": ev.stream, **ev.template.to_dict()}
                for ev in self.events
            ],
        })

    def stream_bytes(self) -> bytes:
        """Canonical byte serialization — the bit-identical-replay rail:
        equal spec + seed must produce equal bytes, asserted in CI."""
        return json.dumps(
            self.to_dict(), sort_keys=True, separators=(",", ":")
        ).encode("utf-8")

    def fingerprint(self) -> str:
        return wire.fingerprint(self.to_dict())


def _traj(pattern: str, *, seq: int, slot: int, wave: int, pool: str,
          pidx: int, task: str) -> str:
    return pattern.format(seq=seq, slot=slot, wave=wave, pool=pool,
                          pidx=pidx, task=task)


def _straggle_factor(spec: ScenarioSpec, rtype: str,
                     t: Optional[float]) -> float:
    """Per-action latency inflation from the fault schedule (stragglers
    pinned to a pool, windowed on arrival time when it is known)."""
    factor = 1.0
    for f in spec.stragglers():
        if f.pool != rtype:
            continue
        if t is not None:
            if t < f.at or (f.until is not None and t >= f.until):
                continue
        factor *= f.factor
    return factor


def _template(spec: ScenarioSpec, stream: StreamSpec, rng: random.Random,
              *, seq: int, slot: int, wave: int, pool: str, pidx: int,
              t: Optional[float], time_scale: float) -> ActionTemplate:
    kind = stream.mix.kind_at(seq)
    rtype = kind.resolve_rtype(pool, seq)
    idx = {"seq": seq, "slot": slot, "wave": wave,
           "wave_plus_slot": wave + slot}
    dur = kind.duration.sample(idx, rng)
    dur *= _straggle_factor(spec, rtype, t)
    name = kind.name.format(rtype=rtype)
    return ActionTemplate(
        name=name,
        rtype=rtype,
        units=kind.units,
        base_duration=dur * time_scale,
        elasticity=kind.elasticity,
        service=kind.service,
        task_id=stream.task_id,
        trajectory_id=_traj(stream.traj, seq=seq, slot=slot, wave=wave,
                            pool=pool, pidx=pidx, task=stream.task_id),
    )


def _stream_rng(spec: ScenarioSpec, stream_idx: int) -> random.Random:
    # int-only seeding: str seeds hash identically everywhere, but int
    # arithmetic is simplest to reason about and version-proof
    return random.Random(spec.seed * 1_000_003 + stream_idx * 7919 + 17)


def _open_loop_times(spec: ScenarioSpec, rng: random.Random) -> List[float]:
    """Timed arrival instants for one stream (open-loop kinds only)."""
    arr = spec.arrival
    out: List[float] = []
    if arr.kind == "burst":
        out = [arr.at] * arr.n
    elif arr.kind == "waves":
        for w in range(arr.waves):
            out += [w * arr.period_s] * arr.per_wave
    elif arr.kind == "burst_pause":
        for w in range(arr.waves):
            out += [w * arr.period_s] * arr.burst
    elif arr.kind == "poisson":
        t = 0.0
        while True:
            t += -math.log(max(1e-12, 1.0 - rng.random())) / arr.rate_hz
            if t >= arr.horizon_s:
                break
            out.append(t)
    elif arr.kind == "diurnal":
        # thinning: candidates at the peak rate, accepted at rate(t)/peak
        t = 0.0
        while True:
            t += -math.log(max(1e-12, 1.0 - rng.random())) / arr.rate_hz
            if t >= arr.horizon_s:
                break
            rate = (1.0 + arr.amplitude * math.sin(
                2.0 * math.pi * t / arr.period_s)) / (1.0 + arr.amplitude)
            if rng.random() < rate:
                out.append(t)
    return out


def compile_scenario(
    spec: ScenarioSpec,
    max_actions: int = DEFAULT_MAX_ACTIONS,
    time_scale: float = 1.0,
) -> CompiledScenario:
    """Spec -> :class:`CompiledScenario`.

    Open-loop arrivals compile to fully-timed events.  Closed-loop
    arrivals compile to timed prime events plus untimed refill templates
    in deterministic draw order (bounded by ``total`` or previewed to
    ``max_actions`` for horizon-gated streams — the driver keeps drawing
    from the same pure index functions past the preview).  ``time_scale``
    multiplies every duration and arrival time — the live runner's knob
    for shrinking a virtual scenario onto real seconds."""
    events: List[ArrivalEvent] = []
    totals: List[Optional[int]] = []
    arr = spec.arrival
    for si, stream in enumerate(spec.streams):
        rng = _stream_rng(spec, si)
        pools = stream.pools or ("",)
        if arr.kind == "closed_loop":
            total = arr.total
            totals.append(total)
            n_preview = total if total is not None else max_actions
            seq = 0
            for n in range(n_preview):
                t: Optional[float]
                if n < arr.prime:
                    t = (arr.stream_stagger_s * si
                         + arr.prime_spacing_s * n) * time_scale
                else:
                    t = None
                pool = pools[0]
                idx = stream.phase + seq
                events.append(ArrivalEvent(
                    t=t, stream=si,
                    template=_template(
                        spec, stream, rng, seq=idx, slot=0, wave=0,
                        pool=pool, pidx=0, t=t, time_scale=time_scale),
                ))
                seq += 1
        elif arr.kind == "waves":
            totals.append(arr.waves * arr.per_wave * len(pools))
            for w in range(arr.waves):
                t = w * arr.period_s * time_scale
                for pidx, pool in enumerate(pools):
                    for slot in range(arr.per_wave):
                        idx = stream.phase + slot
                        events.append(ArrivalEvent(
                            t=t, stream=si,
                            template=_template(
                                spec, stream, rng, seq=idx, slot=slot,
                                wave=w, pool=pool, pidx=pidx, t=t,
                                time_scale=time_scale),
                        ))
        else:
            times = _open_loop_times(spec, rng)
            totals.append(len(times) * (1 if arr.kind != "burst" else 1))
            for n, t0 in enumerate(times):
                pidx = n % len(pools)
                pool = pools[pidx]
                t = t0 * time_scale
                idx = stream.phase + n
                events.append(ArrivalEvent(
                    t=t, stream=si,
                    template=_template(
                        spec, stream, rng, seq=idx, slot=n, wave=0,
                        pool=pool, pidx=pidx, t=t,
                        time_scale=time_scale),
                ))
    return CompiledScenario(
        spec=spec, events=tuple(events), totals=tuple(totals),
        time_scale=time_scale,
    )


# ---------------------------------------------------------------------------
# Fleet construction + the one spec-driven bench path
# ---------------------------------------------------------------------------


def build_managers(spec: ScenarioSpec, loop) -> Dict[str, Any]:
    """Instantiate the fleet a spec declares (pool order preserved —
    manager construction order is part of scenario determinism)."""
    from repro.core.cluster import ApiResourceSpec, CpuNodeSpec, GpuNodeSpec
    from repro.core.managers.base import ResourceManager
    from repro.core.managers.basic import BasicResourceManager
    from repro.core.managers.cpu import CpuManager
    from repro.core.managers.gpu import GpuManager, ServiceSpec

    managers: Dict[str, Any] = {}
    for p in spec.pools:
        if p.kind == "pool":
            managers[p.name] = ResourceManager(p.name, p.cores)
        elif p.kind == "cpu":
            managers[p.name] = CpuManager([CpuNodeSpec("n0", cores=p.cores)])
        elif p.kind == "gpu":
            services = [ServiceSpec(p.service, p.capacity)] if p.service else []
            managers[p.name] = GpuManager([GpuNodeSpec("g0")], services)
        else:  # api
            managers[p.name] = BasicResourceManager(
                ApiResourceSpec(p.name, mode="concurrency",
                                max_concurrency=p.concurrency),
                loop.clock,
            )
    return managers


def build_fair_share(spec: ScenarioSpec):
    """A :class:`FairSharePolicy` when any stream declares a weight or
    quota; ``None`` otherwise (single-tenant specs stay on the FCFS
    fast path)."""
    from repro.core.fairqueue import FairSharePolicy

    weights = {s.task_id: s.weight for s in spec.streams
               if s.weight is not None}
    quota = {s.task_id: s.quota for s in spec.streams
             if s.quota is not None}
    if not weights and not quota:
        return None
    return FairSharePolicy(weights=weights, quota=quota)


def build_policy(spec: ScenarioSpec, gated: bool = False):
    """The scheduler for a spec run.  ``gated=True`` applies the spec's
    ``policy`` knob overrides (the wave-forming gate configs); the
    default run is always the paper-faithful baseline scheduler."""
    from repro.core.scheduler import ElasticScheduler

    knobs = dict(spec.policy) if gated else {}
    kwargs = {}
    if "estimate_units" in knobs:
        kwargs["estimate_units"] = knobs.pop("estimate_units")
    policy = ElasticScheduler(**kwargs)
    for key, value in knobs.items():
        _require(hasattr(policy, key), "bad_policy",
                 f"unknown scheduler knob {key!r}")
        setattr(policy, key, value)
    return policy


class ScenarioDriver:
    """Feeds a compiled stream into an orchestrator.

    Open-loop events are scheduled at their compiled times.  Closed-loop
    streams mirror the legacy benches exactly: primes are submitted with
    their compiled delays, and every completed action ticks its stream's
    wave counter — each full wave triggers one same-instant refill burst
    drawn from the untimed tail of the stream (templates past the
    compiled preview are drawn on demand from the same pure index
    functions, so unbounded streams never diverge from the preview)."""

    def __init__(self, compiled: CompiledScenario, orch,
                 payload: Optional[Callable[[ActionTemplate],
                                            Callable[..., object]]] = None,
                 ) -> None:
        self.compiled = compiled
        self.orch = orch
        self.payload = payload
        self.spec = compiled.spec
        self.submitted = [0] * len(self.spec.streams)
        self._events_by_stream: List[List[ArrivalEvent]] = [
            [] for _ in self.spec.streams
        ]
        for ev in compiled.events:
            self._events_by_stream[ev.stream].append(ev)
        self._wave_pending = [0] * len(self.spec.streams)

    def _build(self, template: ActionTemplate) -> Action:
        fn = self.payload(template) if self.payload is not None else None
        return template.build(fn)

    # -- template access past the compiled preview ---------------------
    def _template_at(self, si: int, n: int) -> ActionTemplate:
        evs = self._events_by_stream[si]
        if n < len(evs):
            return evs[n].template
        stream = self.spec.streams[si]
        pools = stream.pools or ("",)
        return _template(
            self.spec, stream, _stream_rng(self.spec, si),
            seq=stream.phase + n, slot=0, wave=0, pool=pools[0], pidx=0,
            t=None, time_scale=self.compiled.time_scale)

    # -- installation --------------------------------------------------
    def install(self) -> None:
        """Wire the whole stream onto the orchestrator's event loop
        (call once, before ``orch.run()``)."""
        arr = self.spec.arrival
        if arr.kind == "closed_loop":
            self._install_closed_loop()
        elif arr.kind == "waves":
            self._install_waves()
        else:
            for ev in self.compiled.events:
                self._submit_at(ev.stream, ev.template, ev.t or 0.0)
                self.submitted[ev.stream] += 1

    def _submit_at(self, si: int, template: ActionTemplate,
                   t: float, track: bool = False):
        fut = self.orch.submit(self._build(template), delay=t - self.orch.now)
        if track:
            fut.add_done_callback(lambda _f, si=si: self._on_done(si))
        return fut

    def _install_waves(self) -> None:
        # mirror the legacy fleet loop: one synchronous wave now, then a
        # self-rescheduling chain every period (identical event order)
        arr = self.spec.arrival
        by_wave: Dict[int, List[ArrivalEvent]] = {}
        for ev in self.compiled.events:
            w = int(round((ev.t or 0.0)
                          / (arr.period_s * self.compiled.time_scale)))
            by_wave.setdefault(w, []).append(ev)

        def submit_wave(w: int) -> None:
            for ev in by_wave.get(w, []):
                self.orch.submit(self._build(ev.template))
                self.submitted[ev.stream] += 1
            if w + 1 < arr.waves:
                self.orch.loop.call_after(
                    arr.period_s * self.compiled.time_scale,
                    lambda: submit_wave(w + 1))

        submit_wave(0)

    def _install_closed_loop(self) -> None:
        arr = self.spec.arrival
        for si in range(len(self.spec.streams)):
            evs = self._events_by_stream[si]
            if arr.stream_stagger_s or not arr.prime_spacing_s:
                # legacy fairness shape: one staggered same-instant burst
                t0 = arr.stream_stagger_s * si * self.compiled.time_scale

                def prime(si=si):
                    for _ in range(min(arr.prime,
                                       len(self._events_by_stream[si]))):
                        self._submit_burst_one(si)

                self.orch.loop.call_after(t0, prime)
            else:
                # legacy churn shape: spaced submit() calls made up front
                for n in range(min(arr.prime, len(evs))):
                    ev = evs[n]
                    self._submit_at(si, ev.template, ev.t or 0.0, track=True)
                    self.submitted[si] += 1

    def _submit_burst_one(self, si: int) -> None:
        n = self.submitted[si]
        total = self.compiled.totals[si]
        if total is not None and n >= total:
            return
        self.submitted[si] = n + 1
        fut = self.orch.submit(self._build(self._template_at(si, n)))
        fut.add_done_callback(lambda _f, si=si: self._on_done(si))

    def _on_done(self, si: int) -> None:
        arr = self.spec.arrival
        horizon = arr.horizon_s
        if horizon is not None and self.orch.now >= (
                horizon * self.compiled.time_scale):
            return
        total = self.compiled.totals[si]
        if total is not None and self.submitted[si] >= total:
            return
        self._wave_pending[si] += 1
        if self._wave_pending[si] < arr.wave:
            return
        self._wave_pending[si] = 0
        for _ in range(arr.wave):
            if total is not None and self.submitted[si] >= total:
                break
            self._submit_burst_one(si)


def install_scenario(spec_or_compiled, orch, payload=None) -> ScenarioDriver:
    """Compile (if needed) and install a scenario onto ``orch``.
    ``payload`` maps templates to live-mode callables (see
    :mod:`repro.core.live`); sim runs leave it ``None``."""
    compiled = (
        spec_or_compiled
        if isinstance(spec_or_compiled, CompiledScenario)
        else compile_scenario(spec_or_compiled)
    )
    driver = ScenarioDriver(compiled, orch, payload=payload)
    driver.install()
    return driver


# ---------------------------------------------------------------------------
# Structural launch traces (the sim-vs-live differential rail)
# ---------------------------------------------------------------------------


def structural_trace(records) -> Dict[str, List[Tuple[str, str, str]]]:
    """Per-pool launch ORDER: ``rtype -> [(name, task, trajectory)]``
    sorted by start time.  This is the timing-free shape of a run — a
    live run must reproduce the sim's per-pool order exactly (real
    timing is reported separately, never compared)."""
    by_pool: Dict[str, List[Tuple[float, str, str, str]]] = {}
    for r in records:
        for rtype in r.units:
            by_pool.setdefault(rtype, []).append(
                (r.start, r.name, r.task_id, r.trajectory_id))
    return {
        pool: [(n, t, traj) for _, n, t, traj in sorted(rows)]
        for pool, rows in by_pool.items()
    }


# ---------------------------------------------------------------------------
# The legacy bench scenarios, re-expressed as specs
# ---------------------------------------------------------------------------

#: The churn bench's rate-limited tool fleet (DeepSearch shape).
CHURN_APIS = (
    "google_search", "web_fetch", "pdf_parse", "embed", "code_exec",
    "translate",
)


def fleet_churn_spec(queue: int = 128, waves: int = 16, cores: int = 8,
                     period_s: float = 4.0, pools: int = 8) -> ScenarioSpec:
    """The symmetric fleet churn (`shards`/`remote`/`chaos` suites):
    every wave lands the same action multiset on every pool at one
    instant, so nearly every round re-plans many dirty partitions."""
    per_pool = max(1, queue // pools)
    reward = ActionKindSpec(
        name="reward", units=(1, 2, 4, 8), elasticity=("amdahl", 0.05),
        duration=DurationSpec(kind="cycle", base=4.0, step=0.5, mod=4,
                              index="wave_plus_slot"),
    )
    tool = ActionKindSpec(
        name="tool", units=(1,),
        duration=DurationSpec(kind="cycle", base=0.5, step=0.1, mod=3,
                              index="wave"),
    )
    return ScenarioSpec(
        name="fleet_churn",
        pools=tuple(PoolSpec(f"pool{k}", kind="pool", cores=cores)
                    for k in range(pools)),
        streams=(StreamSpec(
            mix=MixSpec(pattern=(0, 0, 1), kinds=(reward, tool)),
            pools=tuple(f"pool{k}" for k in range(pools)),
            traj="p{pidx}-{wave}-{slot}",
        ),),
        arrival=ArrivalSpec(kind="waves", period_s=period_s, waves=waves,
                            per_wave=per_pool),
    )


def churn_spec(queue: int = 128, events: int = 256) -> ScenarioSpec:
    """The mixed agentic-RL churn (`latency` suite): scalable cpu/gpu
    reward backlogs plus a high-frequency stream of short rate-limited
    tool/api calls, closed-loop wave refills."""
    kinds = (
        ActionKindSpec(  # i % 8 == 0: scalable cpu reward
            name="reward", rtype="cpu", units=(1, 2, 4, 8),
            elasticity=("amdahl", 0.05),
            duration=DurationSpec(kind="cycle", base=5.0, step=1.0, mod=7),
        ),
        ActionKindSpec(  # i % 8 == 1: rigid cpu tool call
            name="tool", rtype="cpu", units=(1,),
            duration=DurationSpec(kind="cycle", base=0.5, step=0.1, mod=5),
        ),
        ActionKindSpec(  # i % 8 == 2: gpu reward-model scoring
            name="rm:score", rtype="gpu", units=(1, 2, 4),
            elasticity=("amdahl", 0.15), service="rm0",
            duration=DurationSpec(kind="cycle", base=1.0, step=0.25, mod=4),
        ),
        ActionKindSpec(  # i % 8 in 3..7: rotating rate-limited APIs
            name="api:{rtype}", rtype_cycle=CHURN_APIS, units=(1,),
            duration=DurationSpec(kind="cycle", base=0.3, step=0.2, mod=3),
        ),
    )
    return ScenarioSpec(
        name="churn",
        pools=(
            PoolSpec("cpu", kind="cpu", cores=32),
            PoolSpec("gpu", kind="gpu", service="rm0", capacity=40.0),
        ) + tuple(PoolSpec(api, kind="api", concurrency=3)
                  for api in CHURN_APIS),
        streams=(StreamSpec(
            mix=MixSpec(pattern=(0, 1, 2, 3, 3, 3, 3, 3), kinds=kinds),
            traj="c{seq}",
        ),),
        arrival=ArrivalSpec(
            kind="closed_loop", prime=queue, wave=max(8, queue // 4),
            total=queue + events, prime_spacing_s=0.001,
        ),
    )


#: The fairness bench's configured weights (targets are w_i / sum(w)).
FAIRNESS_WEIGHTS = {"heavy0": 2.0, "heavy1": 2.0, "light0": 1.0,
                    "light1": 1.0}


def _heavy_stream(task: str, phase: int) -> StreamSpec:
    score = ActionKindSpec(
        name="rm:score", rtype="gpu", units=(1, 2, 4),
        elasticity=("amdahl", 0.15), service="rm0",
        duration=DurationSpec(kind="cycle", base=1.0, step=0.2, mod=3),
    )
    reward = ActionKindSpec(
        name="reward", rtype="cpu", units=(2, 4, 8),
        elasticity=("amdahl", 0.08),
        duration=DurationSpec(kind="cycle", base=3.5, step=0.3, mod=4),
    )
    return StreamSpec(
        mix=MixSpec(pattern=(0, 0, 0, 0, 0, 1), kinds=(reward, score)),
        task_id=task, weight=FAIRNESS_WEIGHTS[task], phase=phase,
        traj="{task}-{seq}",
    )


def _light_stream(task: str, phase: int) -> StreamSpec:
    tool = ActionKindSpec(
        name="tool", rtype="cpu", units=(1,),
        duration=DurationSpec(kind="cycle", base=0.4, step=0.1, mod=3),
    )
    probe = ActionKindSpec(
        name="rm:probe", rtype="gpu", units=(1,), service="rm0",
        duration=DurationSpec(kind="fixed", base=0.3),
    )
    return StreamSpec(
        mix=MixSpec(pattern=(0, 0, 0, 0, 0, 0, 0, 1), kinds=(tool, probe)),
        task_id=task, weight=FAIRNESS_WEIGHTS[task], phase=phase,
        traj="{task}-{seq}",
    )


def fairness_spec(horizon_s: float = 90.0,
                  tasks: Optional[Sequence[str]] = None) -> ScenarioSpec:
    """The multi-tenant fairness scenario (`fairness` suite): 2 heavy +
    2 light tenants, closed-loop wave refills, horizon-gated."""
    tasks = list(tasks or FAIRNESS_WEIGHTS)
    streams = []
    for t in tasks:
        phase = 3 if t.endswith("1") else 0
        streams.append(_heavy_stream(t, phase) if t.startswith("heavy")
                       else _light_stream(t, phase))
    return ScenarioSpec(
        name="fairness",
        pools=(
            PoolSpec("cpu", kind="cpu", cores=16),
            PoolSpec("gpu", kind="gpu", service="rm0", capacity=40.0),
        ),
        streams=tuple(streams),
        arrival=ArrivalSpec(
            kind="closed_loop", prime=12, wave=6, horizon_s=horizon_s,
            stream_stagger_s=0.001,
        ),
    )


def chaos_storm_spec(queue: int = 128, waves: int = 16,
                     kill_times: Sequence[float] = (
                         5.0, 9.0, 13.0, 21.0, 29.0, 37.0)) -> ScenarioSpec:
    """The fleet churn plus the kill-storm fault schedule (`chaos`
    suite, scenario a): server-side connection drops at fixed virtual
    times, all after the warm-up window."""
    base = fleet_churn_spec(queue=queue, waves=waves)
    return dataclasses.replace(
        base, name="chaos_storm",
        faults=tuple(FaultSpec(kind="kill_worker", at=t)
                     for t in kill_times),
    )


def chaos_packet_spec(queue: int = 128, waves: int = 16) -> ScenarioSpec:
    """Fleet churn + the mixed packet-fault schedule (`chaos` b)."""
    base = fleet_churn_spec(queue=queue, waves=waves)
    plan = {
        0: {3: "drop_recv", 7: "amnesia", 10: "truncate"},
        1: {4: "drop_submit", 8: "amnesia"},
        2: {5: "amnesia", 9: "drop_recv"},
    }
    return dataclasses.replace(
        base, name="chaos_packet",
        faults=tuple(
            FaultSpec(kind="packet", shard=s, index=i, fault=f)
            for s, sched in sorted(plan.items())
            for i, f in sorted(sched.items())
        ),
    )


def chaos_amnesia_spec(queue: int = 128, waves: int = 16) -> ScenarioSpec:
    """Fleet churn + the pure-amnesia schedule (`chaos` c): silent
    worker swaps that must surface as typed stale-ref errors."""
    base = fleet_churn_spec(queue=queue, waves=waves)
    plan = {0: {3: "amnesia", 6: "amnesia"}, 1: {4: "amnesia"},
            2: {5: "amnesia"}, 3: {7: "amnesia"}}
    return dataclasses.replace(
        base, name="chaos_amnesia",
        faults=tuple(
            FaultSpec(kind="packet", shard=s, index=i, fault=f)
            for s, sched in sorted(plan.items())
            for i, f in sorted(sched.items())
        ),
    )


# ---------------------------------------------------------------------------
# Generated scenarios beyond the legacy set
# ---------------------------------------------------------------------------


def deep_congestion_spec(n: int = 24, cores: int = 48,
                         base: float = 55.0) -> ScenarioSpec:
    """The wave-forming gate's target regime: one same-instant burst of
    long, highly scalable actions (powers-of-two DoP up to 32, near-
    linear Amdahl) against a pool far smaller than aggregate demand.
    Here pricing deferred actions at min units (the paper's Alg. 2)
    spreads everything thin, while the gated config
    (``estimate_units="dp_avg"`` + ``eviction_search="exhaustive"`` +
    ``dop_floor``) forms waves at high DoP and wins on mean ACT."""
    burst = ActionKindSpec(
        name="reward", units=(1, 2, 4, 8, 16, 32),
        elasticity=("amdahl", 0.05),
        duration=DurationSpec(kind="cycle", base=base, step=1.0, mod=5),
    )
    return ScenarioSpec(
        name="deep_congestion",
        pools=(PoolSpec("cpu", kind="pool", cores=cores),),
        streams=(StreamSpec(
            mix=MixSpec(pattern=(0,), kinds=(burst,)),
            pools=("cpu",), traj="d{slot}",
        ),),
        arrival=ArrivalSpec(kind="burst", n=n),
        policy={"estimate_units": "dp_avg",
                "eviction_search": "exhaustive", "dop_floor": 8},
    )


def mid_congestion_spec(n: int = 3, cores: int = 48,
                        base: float = 55.0) -> ScenarioSpec:
    """The control for the gate: the same action shape at a depth the
    pool can absorb near max DoP — the gated config must be ~a no-op
    here (that separation is what EXPERIMENTS.md could not produce from
    the hand-written scenarios)."""
    spec = deep_congestion_spec(n=n, cores=cores, base=base)
    return dataclasses.replace(spec, name="mid_congestion")


def heavy_tail_spec(horizon_s: float = 120.0, rate_hz: float = 2.0,
                    seed: int = 11) -> ScenarioSpec:
    """Production-shaped tool latencies: Poisson arrivals of rigid tool
    calls whose durations are Pareto (alpha=1.6, heavy tail) — the
    DeepSearch latency shape the paper measures against."""
    tool = ActionKindSpec(
        name="tool", units=(1,),
        duration=DurationSpec(kind="pareto", base=0.4, alpha=1.6, hi=120.0),
    )
    return ScenarioSpec(
        name="heavy_tail", seed=seed,
        pools=(PoolSpec("cpu", kind="pool", cores=16),),
        streams=(StreamSpec(
            mix=MixSpec(pattern=(0,), kinds=(tool,)),
            pools=("cpu",), traj="h{seq}",
        ),),
        arrival=ArrivalSpec(kind="poisson", rate_hz=rate_hz,
                            horizon_s=horizon_s),
    )


def diurnal_spec(horizon_s: float = 240.0, rate_hz: float = 4.0,
                 period_s: float = 60.0, seed: int = 13) -> ScenarioSpec:
    """Diurnal waves: sinusoid-modulated Poisson arrivals of a mixed
    rigid/scalable stream over a 4-pool fleet — the
    millions-of-users-scale arrival shape, shrunk to bench time."""
    reward = ActionKindSpec(
        name="reward", units=(1, 2, 4), elasticity=("amdahl", 0.1),
        duration=DurationSpec(kind="lognormal", base=0.5, sigma=0.6,
                              hi=60.0),
    )
    tool = ActionKindSpec(
        name="tool", units=(1,),
        duration=DurationSpec(kind="lognormal", base=-0.7, sigma=0.4,
                              hi=10.0),
    )
    return ScenarioSpec(
        name="diurnal", seed=seed,
        pools=tuple(PoolSpec(f"pool{k}", kind="pool", cores=8)
                    for k in range(4)),
        streams=(StreamSpec(
            mix=MixSpec(pattern=(0, 1, 1), kinds=(reward, tool)),
            pools=("pool0", "pool1", "pool2", "pool3"), traj="u{seq}",
        ),),
        arrival=ArrivalSpec(kind="diurnal", rate_hz=rate_hz,
                            amplitude=0.8, period_s=period_s,
                            horizon_s=horizon_s),
    )


def live_smoke_spec(n_pools: int = 4, per_pool: int = 6) -> ScenarioSpec:
    """The CI live-mode scenario: ``n_pools`` single-unit device pools
    (one emulated XLA host device each) fed rigid kernel actions with
    strictly distinct durations — per-pool launch order is then fully
    determined by FCFS, so the sim-vs-live structural-equivalence gate
    is deterministic by construction, not by timing luck."""
    work = ActionKindSpec(
        name="kernel", units=(1,),
        duration=DurationSpec(kind="cycle", base=0.6, step=0.17, mod=7),
    )
    return ScenarioSpec(
        name="live_smoke",
        pools=tuple(PoolSpec(f"dev{k}", kind="pool", cores=1)
                    for k in range(n_pools)),
        streams=(StreamSpec(
            mix=MixSpec(pattern=(0,), kinds=(work,)),
            pools=tuple(f"dev{k}" for k in range(n_pools)),
            traj="k{pidx}-{slot}",
        ),),
        arrival=ArrivalSpec(kind="waves", period_s=2.0, waves=3,
                            per_wave=per_pool // 3 or 1),
    )


def straggler_fleet_spec(pools: int = 3, cores: int = 2, n: int = 36,
                         duration: float = 1.5, straggler_worker: int = 0,
                         plan_delay_s: float = 0.004) -> ScenarioSpec:
    """The remote-path straggler scenario (tests/test_rebalance.py):
    two equally-deep replica pools plus an idle sink, planned over a
    two-worker socket fleet where one worker's plan phase is inflated.
    Depth and starvation tie across the loaded pools, so the rebalance
    source pick falls through to the plan-cost EWMA — the straggled
    worker's pool must be the one load migrates away from.  Each loaded
    pool carries two task sub-queues: a movable sub-queue must be
    strictly smaller than the depth gap, so a single whole-pool
    sub-queue could never migrate and the rail would be vacuous."""
    work = ActionKindSpec(
        name="w", units=(1,),
        duration=DurationSpec(kind="fixed", base=duration),
    )
    loaded = [f"pool{k}" for k in range(pools - 1)]
    return ScenarioSpec(
        name="straggler_fleet",
        pools=tuple(PoolSpec(f"pool{k}", kind="pool", cores=cores)
                    for k in range(pools)),
        streams=tuple(
            StreamSpec(
                mix=MixSpec(pattern=(0,), kinds=(work,)),
                pools=(p,), task_id=f"t{p}{sub}", traj=p + sub + "-{slot}",
            )
            for p in loaded for sub in ("a", "b")
        ),
        arrival=ArrivalSpec(kind="burst", n=n // (2 * (pools - 1))),
        faults=(FaultSpec(kind="straggler", worker=straggler_worker,
                          plan_delay_s=plan_delay_s),),
    )


#: Registry of the committed generated scenarios (name -> builder), the
#: source of truth the spec files under benchmarks/scenarios/ are
#: exported from (tests assert the files match the builders).
SCENARIO_BUILDERS: Dict[str, Callable[[], ScenarioSpec]] = {
    "fleet_churn": fleet_churn_spec,
    "churn": churn_spec,
    "fairness": fairness_spec,
    "chaos_storm": chaos_storm_spec,
    "chaos_packet": chaos_packet_spec,
    "chaos_amnesia": chaos_amnesia_spec,
    "deep_congestion": deep_congestion_spec,
    "mid_congestion": mid_congestion_spec,
    "heavy_tail": heavy_tail_spec,
    "diurnal": diurnal_spec,
    "live_smoke": live_smoke_spec,
    "straggler_fleet": straggler_fleet_spec,
}
