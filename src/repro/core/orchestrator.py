"""Event-driven orchestrator core (paper §3, engineered for scale).

The seed ``Tangram`` facade rebuilt the whole scheduling problem from
scratch on every submission/completion and scanned / ``remove()``d a
single global waiting list — O(n²) control-plane work per round.  This
module restructures orchestration as an *incremental* event-driven
subsystem:

* **Partitioned waiting queues** — one queue per scheduling partition
  (an action's key elasticity resource, or its sole resource type).
  Admission, removal, and retry-at-head are all O(1) tag work; FCFS
  order is preserved *within* a task, and partitions of unrelated
  resources no longer block each other.  Each partition queue is a
  :class:`~repro.core.fairqueue.PartitionQueue`: with a
  :class:`~repro.core.fairqueue.FairSharePolicy` it holds per-task
  sub-queues drained by weighted start-time fair queueing (multi-tenant
  fair share, optional quota caps); with ``fair_share=None`` it is the
  plain cross-task FCFS deque (bit-identical to the pre-fairness path,
  and the fairness ablation).
* **Event coalescing** — all submissions/completions arriving at the
  same virtual timestamp are folded into ONE scheduling round (the
  round fires as a zero-delay event behind them).
* **Dirty tracking** — a round only re-runs the policy for partitions
  whose queue or manager state actually changed.  A partition goes
  *clean* only in states that are provably time-independent no-ops
  (empty queue, or FCFS head inadmissible at min units); partitions
  that deferred work stay on a watch list and re-run every round, so
  incremental rounds launch exactly what full rescheduling would.
* **Incremental candidate window** — managers expose an admission
  cursor (:meth:`ResourceManager.begin_admission` /
  :meth:`~ResourceManager.admit_one`) so the FCFS window is computed
  in O(window) instead of O(window²) full rescans.  The cursor loop
  lives in :func:`repro.core.scheduler.candidate_window` (re-exported
  here) and is shared by the policy's standalone ``schedule()`` path.
* **Pluggable policy** — anything satisfying :class:`SchedulingPolicy`
  (the ported :class:`~repro.core.scheduler.ElasticScheduler`, or the
  FCFS/static baselines in :mod:`repro.core.baselines`) drives the same
  orchestrator; systems are composed, not duck-typed.
* **Action lifecycle** — per-attempt deadlines (``Action.timeout_s``)
  raised as loop events, bounded retry with re-queue at the FCFS head
  (``Action.max_retries``), cancellation, release-on-failure through
  the managers, failure/retry telemetry, and
  :meth:`Future.set_exception` propagation.

Set ``incremental=False`` to force full rescheduling every round (every
partition dirty, no DP memo, the policy's own window scan) — the
equivalence tests run both modes over identical workloads and assert
identical launch traces.

``shards=N`` turns the round loop into the **plan/commit engine**
(:mod:`repro.core.shards`): dirty partitions are planned concurrently
over manager free-state snapshots and committed serially against live
state, with conflicts re-dirtied onto the ordinary retry rail.
``shards=None`` (default) keeps the serial loop bit-identical to the
pre-shard code; on conflict-free workloads the two produce identical
launch traces (tests/test_shards.py).
"""

from __future__ import annotations

import math
import time
from functools import partial
from typing import (
    TYPE_CHECKING,
    Dict,
    Iterable,
    List,
    Mapping,
    Optional,
    Protocol,
    Sequence,
    Set,
    Tuple,
)

from repro.core.action import (
    TERMINAL_STATES,
    Action,
    ActionState,
    DurationHistory,
    ResourceRequest,
)
from repro.core.fairqueue import FairSharePolicy, PartitionQueue, default_cost
from repro.core.managers.base import Allocation, ResourceManager
from repro.core.scheduler import (
    Decision,
    ElasticScheduler,
    ScheduleResult,
    candidate_window,
)
from repro.core.shards import (
    PartitionPlan,
    RoundExecutor,
    classify_after_commit,
    commit_decision,
    duration_of,
    plan_partition,
    quota_clamp,
    quota_reservations,
)
from repro.core.simulator import EventLoop, Future
from repro.core.telemetry import ActionRecord, Telemetry

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.rebalance import RebalancePolicy, RebalanceSignals

# Decision latency charged per scheduling round when not measuring the
# real wall clock (Table 1 shows sub-3% system overhead on CPU workloads).
SCHED_TICK_S = 0.0005
# Max consecutive no-progress retry ticks between real events (stalled-
# launch guard); bounds DES work when a queue is truly unschedulable.
STALL_RETRY_LIMIT = 4


class ActionError(Exception):
    """Terminal action failure, delivered via ``Future.set_exception``."""

    def __init__(self, action: Action, reason: str) -> None:
        super().__init__(f"{action.name}#{action.uid}: {reason}")
        self.action = action
        self.reason = reason


class ActionTimeout(ActionError):
    pass


class ActionCancelled(ActionError):
    pass


class SchedulingPolicy(Protocol):
    """What the orchestrator needs from a scheduling algorithm.

    ``arrange`` receives an already-computed FCFS candidate window plus
    the rest of the queue and returns unit decisions; ``schedule`` is
    the self-windowing entry point used for full (non-incremental)
    rescheduling and by standalone callers.
    """

    candidate_limit: int

    def arrange(
        self,
        candidates: Sequence[Action],
        remaining: Sequence[Action],
        executing: Sequence[Action],
        managers: Dict[str, ResourceManager],
        now: float,
    ) -> ScheduleResult: ...

    def schedule(
        self,
        waiting: Sequence[Action],
        executing: Sequence[Action],
        managers: Dict[str, ResourceManager],
        now: float,
    ) -> ScheduleResult: ...


class CommitEngine:
    """Commit-phase seam for sharded rounds: the client-serial default.

    The plan phase is already pluggable (inline / threads / remote
    workers — :class:`~repro.core.shards.RoundExecutor`); this class is
    the same seam for the COMMIT phase.  The base implementation is the
    original client-serial walk, kept bit-identical: every plan's
    intents are validated-and-launched against the live local managers
    in global sorted partition order on the orchestrator thread.

    ``commit_mode="worker"`` swaps in
    :class:`~repro.core.remote.WorkerCommitEngine`: remote workers hold
    the *authoritative* manager replicas for the rtypes they own (under
    epoch-stamped ownership leases) and commit becomes a two-phase
    prepare → intent/ack → commit|abort exchange over the wire, with
    conflicts resolved worker-side on the same shared commit core
    (:func:`repro.core.shards.commit_decision`).  Any round the worker
    engine cannot own outright (cross-owner resource footprints, lost
    workers) falls back to this serial walk — the always-correct rail.
    """

    mode = "client"

    def __init__(self, orch: "Orchestrator") -> None:
        self.orch = orch

    def fused_round(self, keys: Sequence[str]) -> Optional[bool]:
        """Offer the engine a whole fixpoint pass (plan AND commit) for
        the dirty ``keys``.  Returns None to decline — the orchestrator
        then runs the ordinary plan_round + :meth:`commit_round` split —
        or the pass's any-launch-failed flag when the engine handled it
        end-to-end (the worker-owned fused ``plan_commit`` path)."""
        return None

    def commit_round(self, plans: Sequence[PartitionPlan]) -> int:
        """Commit one pass's plans (already in global sorted partition
        order); returns the number of refused launches (conflicts)."""
        orch = self.orch
        conflicts = 0
        for plan in plans:
            conflicts += orch._commit_partition(plan)
        return conflicts

    def fence(self, rtypes: Optional[Sequence[str]] = None) -> int:
        """Fence ownership state covering ``rtypes`` (None = all) before
        a handoff (``migrate_task``/``rebalance``): any in-flight or
        unconfirmed prepared intents touching them are deterministically
        aborted and their leases revoked (epoch bump), so a later ack
        from the old owner can never land.  Returns the number of
        aborted intents; the serial engine holds no leases — a no-op."""
        return 0

    def close(self) -> None:
        """Release engine-held protocol state (idempotent)."""


class Orchestrator:
    """Event-driven control plane: queues, rounds, lifecycle, migration,
    telemetry.

    Public surface (contract-level docs on each method): ``submit`` /
    ``cancel`` drive the action lifecycle; ``trajectory_start`` /
    ``trajectory_end`` bracket per-trajectory manager state; ``run``
    drains the loop; ``migrate_task`` / ``rebalance`` move WFQ
    sub-queues between partition replicas; ``queue_depth`` /
    ``in_flight`` / ``starvation_ages`` / ``telemetry`` observe; and
    ``close`` releases out-of-process workers.  See
    ``docs/architecture.md`` for how the pieces compose and
    ``examples/remote_round.py`` for a runnable end-to-end round."""

    def __init__(
        self,
        managers: Dict[str, ResourceManager],
        loop: Optional[EventLoop] = None,
        policy: Optional[SchedulingPolicy] = None,
        charge_real_sched_latency: bool = False,
        incremental: bool = True,
        fair_share: Optional[FairSharePolicy] = None,
        shards: Optional[int] = None,
        plan_mode: str = "inline",
        transport="loopback",
        wire_codec: str = "json",
        commit_mode: str = "client",
        commit_max_passes: int = 8,
    ) -> None:
        self.loop = loop or EventLoop()
        self.history = DurationHistory()
        self.managers = managers
        self.telemetry = Telemetry()
        self.charge_real_sched_latency = charge_real_sched_latency
        self.incremental = incremental
        # Multi-tenant fair share: None = single-tenant FCFS queues (the
        # pre-fairness path and the fairness ablation); a FairSharePolicy
        # turns every partition into weighted per-task sub-queues (WFQ)
        # and makes a fairness-capable policy weight its objective.
        self.fair_share = fair_share
        self.policy = policy or ElasticScheduler(history=self.history)
        if getattr(self.policy, "cache_dp", False) is None:
            # DP memoization is only sound/useful on the incremental path
            self.policy.cache_dp = incremental
        if (
            fair_share is not None
            and hasattr(self.policy, "fair_share")
            and self.policy.fair_share is None
        ):
            self.policy.fair_share = fair_share
        # --- partitioned queues + reverse index -------------------------
        self._queues: Dict[str, PartitionQueue] = {}
        self._rtype_index: Dict[str, Dict[str, int]] = {}  # rtype -> {part: n}
        # --- execution state ---------------------------------------------
        self._executing: Dict[int, Action] = {}
        self._futures: Dict[int, Future] = {}
        self._allocs: Dict[int, List[Allocation]] = {}
        self._pending_ev: Dict[int, object] = {}  # delayed _enqueue events
        self._completion_ev: Dict[int, object] = {}
        self._deadline_ev: Dict[int, object] = {}
        # --- incremental round state ---------------------------------------
        self._dirty: Set[str] = set()
        self._watch: Set[str] = set()  # partitions with deferred work
        self._round_scheduled = False
        self._refill_wake_at = math.inf
        self._stall_retries = 0  # consecutive no-event retry ticks
        # --- telemetry-driven rebalance cadence (enable_rebalance) ---------
        self._rebalance_policy = None
        self._rebalance_replicas: List[str] = []
        self._rebalance_armed = False
        # Sharded plan/commit rounds (None = the serial loop, bit-
        # identical to the pre-shard engine).  shards=1 still exercises
        # the snapshot plan/commit machinery — the equivalence tests'
        # control arm.  plan_mode: "inline" (exact critical-path
        # accounting), "threads" (in-process pool), "auto" (per-round
        # pick from the measured plan-cost EWMA), or "remote" (each
        # shard's plan phase in a separate worker process behind the
        # ``transport`` — "loopback" plans in-process through the full
        # wire codecs, "process" spawns real workers, or a callable
        # ``shard_idx -> ShardTransport`` such as
        # repro.core.transport.socket_fleet for workers on other
        # machines; ``wire_codec`` — "binary" compact frames or "json"
        # v1 text).  Plans are identical in every mode and codec, and a
        # lost worker's partitions fall back to inline planning (see
        # repro.core.remote).
        self.shards = shards
        self._executor = (
            RoundExecutor(
                self, shards, plan_mode, transport=transport, wire_codec=wire_codec
            )
            if shards is not None
            else None
        )
        # Commit-phase seam: "client" (default) keeps the serial
        # validated commit against live local managers, bit-identical to
        # the pre-engine code.  "worker" (requires plan_mode="remote")
        # moves authoritative manager replicas out to the shard workers
        # under epoch-stamped ownership leases — commit becomes a
        # two-phase prepare/ack exchange over the wire, and dependent
        # fixpoint passes batch into one fused plan_commit frame
        # (bounded by commit_max_passes; 1 = one pass per wire round,
        # the sequential control arm).  Launch traces are identical in
        # both modes; ineligible or degraded rounds fall back to the
        # client-serial walk.
        if commit_mode not in ("client", "worker"):
            raise ValueError(f"unknown commit_mode {commit_mode!r}")
        self.commit_mode = commit_mode
        self.commit_max_passes = int(commit_max_passes)
        if commit_mode == "worker":
            if self._executor is None or self._executor._remote is None:
                raise ValueError(
                    "commit_mode='worker' requires shards=N with plan_mode='remote'"
                )
            from repro.core.remote import WorkerCommitEngine

            self._commit_engine: CommitEngine = WorkerCommitEngine(
                self, self._executor._remote
            )
        else:
            self._commit_engine = CommitEngine(self)
        self.stats: Dict[str, int] = {
            "rounds": 0,
            "partition_runs": 0,
            "partitions_skipped": 0,
            "events_coalesced": 0,
            "launch_failures": 0,
            "quota_deferrals": 0,
            "sharded_rounds": 0,
        }

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def submit(self, action: Action, delay: float = 0.0) -> Future:
        """Submit an action for scheduling after ``delay`` virtual
        seconds (0 = this instant, coalesced with same-timestamp
        events into one round).  Returns a :class:`Future` resolved
        with the action's execution duration on completion, or with an
        :class:`ActionError` subclass on timeout/cancellation.  The
        action must be freshly constructed (PENDING); resubmitting a
        live or terminal action is undefined."""
        fut = Future()
        self._futures[action.uid] = fut
        self._pending_ev[action.uid] = self.loop.call_after(
            delay, lambda: self._enqueue(action)
        )
        return fut

    def cancel(self, action: Action) -> bool:
        """Withdraw a queued or running action; resolves its future with
        :class:`ActionCancelled`.  Returns False if already terminal."""
        if action.state in TERMINAL_STATES or action.uid not in self._futures:
            return False
        released = self._withdraw(action)
        self.telemetry.cancellations += 1
        self._finalize_failure(
            action, ActionState.CANCELLED, ActionCancelled(action, "cancelled")
        )
        self._dirty.add(self._partition_of(action))
        self._dirty_rtypes(released)
        self._request_round()
        return True

    def trajectory_start(self, trajectory_id: str, metadata: Optional[dict] = None) -> None:
        """Announce a trajectory to every manager (lifetime hooks, e.g.
        the CPU manager's memory pinning) before its actions arrive."""
        for m in self.managers.values():
            m.trajectory_start(trajectory_id, metadata or {})
        self._mark_all_dirty()

    def trajectory_end(self, trajectory_id: str) -> None:
        """Release per-trajectory manager state (idempotent)."""
        for m in self.managers.values():
            m.trajectory_end(trajectory_id)
        # freed trajectory memory may unblock admission
        self._mark_all_dirty()
        self._request_round()

    def run(self, until: Optional[float] = None) -> float:
        """Drain the event loop (optionally up to virtual time
        ``until``); returns the clock after the last event."""
        return self.loop.run(until=until)

    def close(self) -> None:
        """Release out-of-process resources (remote shard workers).
        Idempotent; a no-op for in-process plan modes."""
        self._commit_engine.close()
        if self._executor is not None:
            self._executor.close()

    @property
    def now(self) -> float:
        """Current virtual time (the event loop's clock)."""
        return self.loop.clock.now()

    def queue_depth(self) -> int:
        """Actions currently queued across all partitions."""
        return sum(len(q) for q in self._queues.values())

    def in_flight(self) -> int:
        """Actions currently executing (holding allocations)."""
        return len(self._executing)

    def starvation_ages(self) -> Dict[str, float]:
        """Live starvation telemetry: per task, the age (now - submit) of
        its oldest queued action across all partitions."""
        now = self.now
        ages: Dict[str, float] = {}
        for queue in self._queues.values():
            for task, oldest in queue.oldest_submit_by_task().items():
                age = now - oldest
                if age > ages.get(task, -math.inf):
                    ages[task] = age
        return ages

    # ------------------------------------------------------------------
    # sub-queue migration between partition replicas (the "sub-queue is
    # the shard unit" seam: PartitionQueue.detach_task / merge_shard /
    # sync_vtime, wired into live orchestration)
    # ------------------------------------------------------------------
    def migrate_task(self, task_id: str, src: str, dst: str) -> int:
        """Move ``task_id``'s queued sub-queue from partition ``src`` to
        the replica partition ``dst``; returns the number of migrated
        actions (0 when the task has nothing queued on ``src``).

        ``src`` and ``dst`` must be *replicas*: equivalent resource
        pools (same unit semantics — e.g. the symmetric per-pool
        managers of a fleet), both with live managers.  Each migrated
        action's cost vector is retargeted from ``src`` to ``dst``
        (unit sets preserved); actions whose cost touches other
        resource types keep those dimensions untouched, but the move
        must land the action in ``dst``'s partition — a cost vector
        that would re-partition elsewhere raises ``ValueError`` before
        anything is mutated.

        WFQ semantics ride along for free (the detach/merge seam's
        whole point): the detached :class:`~repro.core.fairqueue.TaskShard`
        carries its actions' original virtual-time tags plus the source
        clock, merging syncs ``dst``'s clock monotonically, and the
        task's finish chain resumes from the later of the two tags — so
        fair ordering is preserved and no queue's clock ever moves
        backward.  Actions already RUNNING on ``src`` are not touched
        (they hold ``src`` allocations until they complete).
        """
        if src == dst:
            return 0
        if src not in self.managers or dst not in self.managers:
            raise ValueError(f"migrate_task: unknown partition {src!r} or {dst!r}")
        src_q = self._queues.get(src)
        if src_q is None:
            return 0
        # validate the replica contract BEFORE detaching: every queued
        # action of the task must re-partition onto dst after retarget
        for a in src_q.ordered():
            if a.task_id != task_id:
                continue
            kr = dst if a.key_resource == src else a.key_resource
            cost_keys = {dst if r == src else r for r in a.cost}
            part = kr if kr is not None else (min(cost_keys) if cost_keys else "*")
            if part != dst:
                raise ValueError(
                    f"migrate_task: {a.name}#{a.uid} would re-partition onto "
                    f"{part!r}, not {dst!r} — {src!r}/{dst!r} are not replicas "
                    f"for its cost vector {sorted(a.cost)}"
                )
        # ownership handoff fence: abort any in-flight/unconfirmed
        # worker-side commit intents touching either partition's rtype
        # before queue state moves (no-op for the client-serial engine)
        self._commit_engine.fence((src, dst))
        t0 = time.perf_counter()
        shard = src_q.detach_task(task_id)
        if shard is None:
            return 0
        for _key, action in shard.entries:
            self._index_remove(src, action)
            self._retarget(action, src, dst)
            self._index_add(dst, action)
        dst_q = self._queues.get(dst)
        if dst_q is None:
            dst_q = self._queues[dst] = self._make_queue(dst)
        dst_q.merge_shard(shard)
        n = len(shard.entries)
        self.telemetry.note_migration(n, time.perf_counter() - t0)
        self._dirty.add(src)
        self._dirty.add(dst)
        self._request_round()
        return n

    @staticmethod
    def _retarget(action: Action, src: str, dst: str) -> None:
        """Rewrite one action's cost vector from the ``src`` resource to
        its ``dst`` replica (unit sets preserved)."""
        req = action.cost.pop(src, None)
        if req is not None:
            action.cost[dst] = ResourceRequest(dst, req.units)
        if action.key_resource == src:
            action.key_resource = dst
        # derived per-resource caches keyed on the old rtype are stale
        action.metadata.pop("_dp_durs", None)

    def rebalance(
        self, replicas: Sequence[str], max_gap: int = 1
    ) -> int:
        """Even out queued backlog across a replica group by migrating
        whole task sub-queues from the most- to the least-loaded
        partition until the depth gap is at most ``max_gap`` (or no
        single sub-queue move improves it).  Returns the number of
        migrated actions.  Deterministic: ties break on sorted
        partition/task names — a rebalance at the same state always
        makes the same moves.  This is the hook a deployment's
        rebalancer (or a test) drives; migration cost and counts land
        in ``Telemetry.migrations``/``migrated_actions``/
        ``migration_wall_s``."""
        moved = 0
        while True:
            depths = {p: len(self._queues.get(p) or ()) for p in replicas}
            hi = max(sorted(depths), key=lambda p: depths[p])
            lo = min(sorted(depths), key=lambda p: depths[p])
            gap = depths[hi] - depths[lo]
            if gap <= max_gap:
                return moved
            src_q = self._queues.get(hi)
            backlog = src_q.backlog() if src_q is not None else {}
            # moving n actions turns the pair's gap into |gap - 2n|, so
            # the best single move is the sub-queue whose size is
            # closest to gap/2 — anything larger inverts the imbalance
            # and anything is only worth moving if the gap strictly
            # shrinks (ties break on fewer migrated actions, then task
            # name, for determinism)
            candidates = [
                (abs(gap - 2 * n), n, t)
                for t, n in sorted(backlog.items())
                if 0 < n and abs(gap - 2 * n) < gap
            ]
            if not candidates:
                return moved
            _, _, task = min(candidates)
            moved += self.migrate_task(task, hi, lo)

    # ------------------------------------------------------------------
    # telemetry-driven rebalance cadence (repro.core.rebalance)
    # ------------------------------------------------------------------
    def enable_rebalance(
        self,
        replicas: Sequence[str],
        policy: Optional["RebalancePolicy"] = None,
        period_s: Optional[float] = None,
    ) -> None:
        """Drive sub-queue rebalancing across the ``replicas`` group on
        a virtual-time cadence: every ``policy.period_s`` seconds (while
        any replica has queued work) a :class:`~repro.core.rebalance.
        RebalancePolicy` reads live signals — queue depths, per-task
        backlog and queued work, starvation ages, pool utilization, the
        round engine's per-partition plan-cost EWMAs — and orders
        migrations through :meth:`migrate_task`.  The cadence disarms
        itself when the replicas drain (so ``run()`` terminates) and
        re-arms on the next enqueue.  ``replicas`` must be genuine
        replicas (same unit semantics — the :meth:`migrate_task`
        contract).  Deterministic under the DES clock: the same run
        always makes the same moves."""
        from repro.core.rebalance import RebalancePolicy

        if policy is None:
            policy = (
                RebalancePolicy()
                if period_s is None
                else RebalancePolicy(period_s=period_s)
            )
        elif period_s is not None:
            policy.period_s = float(period_s)
        replicas = sorted(replicas)
        for p in replicas:
            if p not in self.managers:
                raise ValueError(f"enable_rebalance: unknown replica partition {p!r}")
        self._rebalance_replicas = replicas
        self._rebalance_policy = policy
        self._arm_rebalance()

    def _arm_rebalance(self) -> None:
        if self._rebalance_policy is None or self._rebalance_armed:
            return
        self._rebalance_armed = True
        self.loop.call_after(self._rebalance_policy.period_s, self._rebalance_tick)

    def _rebalance_tick(self) -> None:
        self._rebalance_armed = False
        policy = self._rebalance_policy
        if policy is None:
            return
        if not any(self._queues.get(p) for p in self._rebalance_replicas):
            return  # drained: stay disarmed until the next enqueue
        self.telemetry.rebalance_ticks += 1
        moves = policy.decide(self._rebalance_signals(), self._rebalance_replicas)
        for task, src, dst in moves:
            if self.migrate_task(task, src, dst):
                self.telemetry.rebalance_moves += 1
        self._arm_rebalance()

    def _rebalance_signals(self) -> "RebalanceSignals":
        """Snapshot the policy's inputs from live orchestrator state."""
        from repro.core.rebalance import RebalanceSignals

        now = self.now
        sig = RebalanceSignals(now=now)
        for p in self._rebalance_replicas:
            q = self._queues.get(p)
            sig.depths[p] = len(q) if q is not None else 0
            sig.backlogs[p] = q.backlog() if q else {}
            sig.backlog_cost[p] = q.backlog_cost() if q else {}
            sig.starvation[p] = (
                {t: now - s for t, s in q.oldest_submit_by_task().items()}
                if q
                else {}
            )
            m = self.managers.get(p)
            sig.utilization[p] = m.utilization() if m is not None else 0.0
        if self._executor is not None:
            sig.plan_cost_s = dict(self._executor.plan_cost_by_part)
        return sig

    # ------------------------------------------------------------------
    # queue + index plumbing (all O(1))
    # ------------------------------------------------------------------
    @staticmethod
    def _partition_of(action: Action) -> str:
        if action.key_resource is not None:
            return action.key_resource
        return min(action.cost) if action.cost else "*"

    def _make_queue(self, part: str) -> PartitionQueue:
        fs = self.fair_share
        if fs is None:
            return PartitionQueue(fair=False)
        rtype = part if part in self.managers else None
        return PartitionQueue(
            fair=True,
            weight_of=fs.weight_of,
            cost_of=partial(default_cost, rtype=rtype),
        )

    def _index_add(self, part: str, action: Action) -> None:
        for rtype in action.cost:
            self._rtype_index.setdefault(rtype, {})
            self._rtype_index[rtype][part] = self._rtype_index[rtype].get(part, 0) + 1

    def _index_remove(self, part: str, action: Action) -> None:
        for rtype in action.cost:
            counts = self._rtype_index.get(rtype)
            if counts is None:
                continue
            left = counts.get(part, 0) - 1
            if left <= 0:
                counts.pop(part, None)
            else:
                counts[part] = left

    def _enqueue(self, action: Action, at_head: bool = False) -> None:
        self._pending_ev.pop(action.uid, None)
        if action.state in TERMINAL_STATES:
            return  # cancelled while the delayed submission was in flight
        part = self._partition_of(action)
        queue = self._queues.get(part)
        if queue is None:
            queue = self._queues[part] = self._make_queue(part)
        action.state = ActionState.QUEUED
        if not at_head:
            action.submit_time = self.now
        # an arrival only touches its task's sub-queue (tag + one merge
        # insert) and dirties this partition — no other task re-tags
        queue.push(action, at_head=at_head)
        self._index_add(part, action)
        self._arm_deadline(action)
        self._stall_retries = 0
        self._dirty.add(part)
        self._request_round()
        # new queued work re-arms the rebalance cadence (it disarms
        # itself when the replica group drains, so run() terminates)
        self._arm_rebalance()

    def _dequeue(self, action: Action, served: bool = False) -> None:
        part = self._partition_of(action)
        queue = self._queues.get(part)
        if queue is not None and action.uid in queue:
            queue.remove(action.uid, served=served)
            self._index_remove(part, action)

    def _dirty_rtypes(self, rtypes: Iterable[str]) -> None:
        for rtype in rtypes:
            self._dirty.update(self._rtype_index.get(rtype, ()))

    def _mark_all_dirty(self) -> None:
        self._dirty.update(k for k, q in self._queues.items() if q)

    # ------------------------------------------------------------------
    # scheduling rounds
    # ------------------------------------------------------------------
    def _request_round(self) -> None:
        if self._round_scheduled:
            self.stats["events_coalesced"] += 1
            return
        self._round_scheduled = True
        self.loop.call_after(0.0, self._round)

    def _round(self) -> None:
        self._round_scheduled = False
        for m in self.managers.values():
            if hasattr(m, "set_time"):
                m.set_time(self.now)

        if self.incremental:
            self._dirty |= self._watch
        else:
            self._mark_all_dirty()
        self.stats["partitions_skipped"] += sum(
            1 for k, q in self._queues.items() if q and k not in self._dirty
        )
        if not any(self._queues.get(k) for k in self._dirty):
            self._dirty.clear()
            return
        self.stats["rounds"] += 1
        self.telemetry.sched_invocations += 1

        if self._executor is not None:
            any_failed = self._sharded_fixpoint()
        else:
            t0 = time.perf_counter()
            any_failed = False
            # fixpoint: launching may re-expose an admissible head (the
            # classification in _commit_partition re-dirties such
            # partitions); every extra pass strictly consumes resources,
            # so this terminates within the round's virtual instant.
            while True:
                keys = sorted(k for k in self._dirty if self._queues.get(k))
                self._dirty.clear()
                if not keys:
                    break
                for key in keys:
                    any_failed |= self._run_partition(key)
            self.telemetry.sched_wall_s += time.perf_counter() - t0

        self._post_round(any_failed)

    def _sharded_fixpoint(self) -> bool:
        """The plan/commit round loop (shards=N): plan all dirty
        partitions in parallel over free-state snapshots, then commit
        serially in the same sorted order the serial loop walks.  A
        commit whose allocation no longer fits live state rolls back
        (``release_unlaunched``) and leaves its partition watched — the
        same rail ordinary ``try_allocate`` refusals ride — so the next
        round replans it against fresh state.

        Decision latency charged per plan/commit pass is the critical
        path ``max(per-shard plan CPU) + commit wall`` — what a fleet of
        per-shard workers pays; the real in-process plan wall clock is
        recorded separately (``Telemetry.plan_wall_s``).

        The commit walk itself sits behind the :class:`CommitEngine`
        seam: the default engine is the client-serial loop this
        docstring describes; the worker-owned engine may take a whole
        pass (plan AND commit fused into one wire exchange per owner
        worker) via ``fused_round`` — its charged commit critical path
        is then ``max(per-worker commit wall)``, with the client's
        mirror-apply wall recorded separately
        (``Telemetry.commit_apply_s``) — never conflated."""
        any_failed = False
        while True:
            keys = sorted(k for k in self._dirty if self._queues.get(k))
            self._dirty.clear()
            if not keys:
                return any_failed
            if len(keys) == 1:
                # one dirty partition has no parallelism to exploit: the
                # serial runner (live-state planning, no snapshot cost)
                # is cheaper and trivially plan/commit-equivalent
                t0 = time.perf_counter()
                any_failed |= self._run_partition(keys[0])
                self.telemetry.sched_wall_s += time.perf_counter() - t0
                continue
            self.stats["sharded_rounds"] += 1
            handled = self._commit_engine.fused_round(keys)
            if handled is not None:
                any_failed |= handled
                continue
            plans, critical = self._executor.plan_round(keys)
            t0 = time.perf_counter()
            conflicts = self._commit_engine.commit_round(plans)
            if conflicts:
                any_failed = True
                self.telemetry.commit_conflicts += conflicts
            commit_wall = time.perf_counter() - t0
            self.telemetry.commit_wall_s += commit_wall
            self.telemetry.sched_wall_s += critical + commit_wall

    def _run_partition(self, part: str) -> bool:
        """One serial policy pass over a partition (plan against LIVE
        managers, commit immediately); returns True if any launch failed
        (decision made but allocation refused)."""
        return self._commit_partition(self._plan_partition(part, self.managers)) > 0

    def _plan_partition(
        self, part: str, managers: Mapping[str, ResourceManager], shard: int = 0
    ) -> PartitionPlan:
        """Arrange one partition against ``managers`` (live for the
        serial loop, free-state snapshots for a shard) WITHOUT touching
        shared orchestrator state — safe to run from a plan thread.  The
        plan core itself (:func:`repro.core.shards.plan_partition`) is a
        free function shared verbatim with the out-of-process
        :class:`~repro.core.remote.RemoteShardWorker`, which is what
        keeps remote plans bit-identical to inline ones."""
        queue = self._queues.get(part)
        if not queue:
            return PartitionPlan(part, planned=False, shard=shard)
        # WFQ service order: FCFS within a task, min-virtual-start-tag
        # across tasks — so the candidate window below is drawn
        # round-robin-by-virtual-time across tasks.  With fair_share=None
        # (or a single task) this IS plain arrival order.
        return plan_partition(
            part,
            queue.ordered(),
            list(self._executing.values()),
            managers,
            self.policy,
            self.fair_share,
            self.now,
            self.incremental,
            shard=shard,
        )

    def _commit_partition(self, plan: PartitionPlan) -> int:
        """Validate-and-launch one partition's intents against LIVE
        manager state (single-threaded), then classify the partition;
        returns the number of refused launches (decisions made but
        allocation refused)."""
        part = plan.part
        queue = self._queues.get(part)
        if not plan.planned or not queue:
            self._watch.discard(part)
            return 0
        self.stats["partition_runs"] += 1
        self.stats["quota_deferrals"] += plan.held
        if plan.result is None:
            # the quota gate held the entire window
            self._watch.discard(part)
            if plan.held:
                self._watch.add(part)
            return 0
        overhead = plan.wall_s if self.charge_real_sched_latency else SCHED_TICK_S
        quota_pending = self._quota_reservations(plan.result.decisions)
        failed = 0
        for decision in plan.result.decisions:
            if not self._launch(decision, overhead, quota_pending):
                failed += 1
        # cleanliness classification is the shared core's
        # classify_after_commit (see its contract); the worker-owned
        # commit engine runs the same function over its replicas, which
        # is what keeps worker-computed fixpoint passes identical to the
        # serial loop's.  Quota-clock changes are covered by the refill
        # wake; "dirty" re-enters this round's fixpoint loop.
        self._watch.discard(part)
        cls = classify_after_commit(
            queue, plan.result.evicted, failed, plan.held, self.managers
        )
        if cls == "watch":
            self._watch.add(part)
        elif cls == "dirty":
            self._dirty.add(part)
        return failed

    def _quota_reservations(
        self, decisions: Sequence[Decision]
    ) -> Optional[Dict[Tuple[str, str], int]]:
        """Thin wrapper over the shared commit core's
        :func:`repro.core.shards.quota_reservations` (see its contract —
        the ROADMAP's "exact quota for scalable scale-up" item), bound
        to the live managers + this orchestrator's fair-share policy."""
        return quota_reservations(decisions, self.managers, self.fair_share)

    def _quota_clamp(
        self,
        action: Action,
        rtype: str,
        units: int,
        pending: Optional[Dict[Tuple[str, str], int]] = None,
    ) -> int:
        """Thin wrapper over the shared commit core's
        :func:`repro.core.shards.quota_clamp`, bound to live state."""
        return quota_clamp(
            action, rtype, units, self.managers, self.fair_share, pending
        )

    def _post_round(self, any_failed: bool) -> None:
        if any_failed:
            self.stats["launch_failures"] += 1
        if not any(self._queues.values()):
            return
        # quota refills may unblock queued actions even without completions
        wake = min(
            (
                m.time_to_next_refill()
                for m in self.managers.values()
                if hasattr(m, "time_to_next_refill")
            ),
            default=math.inf,
        )
        if math.isfinite(wake) and wake > 0 and self.now + wake < self._refill_wake_at:
            self._refill_wake_at = self.now + wake
            self.loop.call_after(wake + 1e-6, self._on_refill_wake)
            return
        # stalled-launch guard: work was decided-but-refused or deferred,
        # nothing is in flight to guarantee a future round, and no refill
        # is coming — schedule a retry tick unconditionally.  Retries
        # back off geometrically and are bounded between real events, so
        # an unschedulable queue quiesces instead of spinning the loop.
        stalled = any_failed or bool(self._watch)
        if stalled and not self._executing and self._stall_retries < STALL_RETRY_LIMIT:
            delay = SCHED_TICK_S * (1 << self._stall_retries)
            self._stall_retries += 1

            def _retry() -> None:
                self._mark_all_dirty()
                self._request_round()

            self.loop.call_after(delay, _retry)

    def _on_refill_wake(self) -> None:
        self._refill_wake_at = math.inf
        self._stall_retries = 0
        self._mark_all_dirty()
        self._request_round()

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------
    def _launch(
        self,
        decision: Decision,
        sched_overhead: float,
        quota_pending: Optional[Dict[Tuple[str, str], int]] = None,
    ) -> bool:
        action = decision.action
        # the manager-mutating middle (reservation release, quota clamp,
        # sorted try_allocate with rollback, share accounting) is the
        # shared commit core — one implementation with the worker-owned
        # commit engine's replica-side commit
        granted = commit_decision(
            decision, self.managers, self.fair_share, quota_pending
        )
        if granted is None:
            return False
        units, allocs = granted
        self._dequeue(action, served=True)
        self._executing[action.uid] = action
        self._allocs[action.uid] = allocs
        action.state = ActionState.RUNNING
        action.start_time = self.now
        overhead = sched_overhead + sum(a.overhead for a in allocs)
        action.sys_overhead = overhead

        key_units = units.get(action.key_resource or "", None)
        duration = self._duration_of(action, key_units)
        self._schedule_completion(action, duration, overhead)
        return True

    def _schedule_completion(
        self, action: Action, duration: float, overhead: float
    ) -> None:
        """Arm the completion of a launched action.

        The DES completes by clock: a single timer at the modeled finish
        instant.  This is the live-mode seam — a live orchestrator
        (:class:`repro.core.live.LiveOrchestrator`) overrides this one
        method to run the action's real payload on a worker thread and
        complete when the work actually returns, leaving every other
        lifecycle path (withdraw, deadline, retry, telemetry) shared."""
        action.finish_time = self.now + overhead + duration
        self._completion_ev[action.uid] = self.loop.call_at(
            action.finish_time, lambda: self._complete(action, duration)
        )

    def _duration_of(self, action: Action, key_units: Optional[int]) -> float:
        return duration_of(action, key_units, self.history)

    def _complete(self, action: Action, duration: float) -> None:
        self._completion_ev.pop(action.uid, None)
        self._cancel_deadline(action)
        self._executing.pop(action.uid, None)
        allocs = self._allocs.pop(action.uid, [])
        released: Set[str] = set()
        for alloc in allocs:
            self.managers[alloc.rtype].release(action, alloc)
            self.managers[alloc.rtype].note_released(action.task_id, alloc.units)
            released.add(alloc.rtype)
        action.state = ActionState.DONE
        self.history.observe(action.name, duration)
        self.telemetry.record(
            ActionRecord(
                name=action.name,
                task_id=action.task_id,
                trajectory_id=action.trajectory_id,
                submit=action.submit_time,
                start=action.start_time,
                finish=action.finish_time,
                sys_overhead=action.sys_overhead,
                units={a.rtype: a.units for a in allocs},
                retries=action.attempts,
            )
        )
        fut = self._futures.pop(action.uid, None)
        if fut is not None:
            fut.set_result(duration)
        self._stall_retries = 0
        self._dirty_rtypes(released)
        self._request_round()

    # ------------------------------------------------------------------
    # lifecycle: deadlines, retries, cancellation
    # ------------------------------------------------------------------
    def _arm_deadline(self, action: Action) -> None:
        self._cancel_deadline(action)
        if action.timeout_s is None:
            return
        self._deadline_ev[action.uid] = self.loop.call_after(
            action.timeout_s, lambda: self._on_deadline(action)
        )

    def _cancel_deadline(self, action: Action) -> None:
        ev = self._deadline_ev.pop(action.uid, None)
        if ev is not None:
            self.loop.cancel(ev)

    def _withdraw(self, action: Action) -> Set[str]:
        """Pull an action out of the system (queued or running); returns
        the resource types whose state changed."""
        self._cancel_deadline(action)
        pending = self._pending_ev.pop(action.uid, None)
        if pending is not None:
            self.loop.cancel(pending)
        released: Set[str] = set()
        if action.state is ActionState.RUNNING:
            ev = self._completion_ev.pop(action.uid, None)
            if ev is not None:
                self.loop.cancel(ev)
            self._executing.pop(action.uid, None)
            for alloc in self._allocs.pop(action.uid, []):
                self.managers[alloc.rtype].release_on_failure(action, alloc)
                self.managers[alloc.rtype].note_released(action.task_id, alloc.units)
                released.add(alloc.rtype)
        elif action.state is ActionState.QUEUED:
            self._dequeue(action)
        return released

    def _on_deadline(self, action: Action) -> None:
        if action.state in TERMINAL_STATES:
            return  # stale timer
        self.telemetry.timeouts += 1
        released = self._withdraw(action)
        action.attempts += 1
        if action.attempts <= action.max_retries:
            # bounded retry: back to the FCFS head of its partition
            self.telemetry.retries += 1
            self._enqueue(action, at_head=True)
        else:
            action.failure = f"timeout after {action.attempts} attempt(s)"
            self._finalize_failure(
                action, ActionState.TIMEOUT, ActionTimeout(action, action.failure)
            )
            # removal may unblock queued work behind the departed head
            self._dirty.add(self._partition_of(action))
        # either way the withdrawn attempt's resources are free again —
        # wake every partition waiting on them (the retry may not be the
        # one that can use them, e.g. when it re-queues quota-blocked)
        self._dirty_rtypes(released)
        self._request_round()

    def _finalize_failure(
        self, action: Action, state: ActionState, exc: ActionError
    ) -> None:
        action.state = state
        action.finish_time = self.now
        if action.failure is None:
            action.failure = exc.reason
        self.telemetry.record(
            ActionRecord(
                name=action.name,
                task_id=action.task_id,
                trajectory_id=action.trajectory_id,
                submit=action.submit_time,
                start=action.start_time,
                finish=action.finish_time,
                sys_overhead=action.sys_overhead,
                units={},
                failed=True,
                retries=max(0, action.attempts - 1),
            )
        )
        fut = self._futures.pop(action.uid, None)
        if fut is not None:
            fut.set_exception(exc)
